"""Instruction-queue engines: dynamic pipeline schedules (DESIGN.md §11).

Acceptance, per ISSUE:

1. Closed form: the executed instruction log and the schedule clock land
   exactly on ``commodel.pp_schedule_stats`` — per-stage StageForward
   counts, boundary hops, SampleTokens, ticks, busy fractions.
2. Bitwise identity: every microbatch's greedy tokens at depth d equal
   depth 1 and solo serving — contiguous AND paged, including under
   scripted preemption / fault schedules (the PR 6 recovery ladder
   survives the dynamic schedule).
3. Traffic identity: each decode round's measured TransferRecords equal
   the PP closed form at the group batch; ``pp_schedule_ops`` composes
   the same totals.
4. The degenerate ``FusedQueue`` preserves the fused backends' behavior
   (StepRecord wall/stage fields, occupancy bookkeeping, proxy safety).
5. The 3-axis (t, c, p) = (2, 2, 2) layout — token identity plus
   predicted == compiled HLO == measured — on the 8-device host mesh
   (``multidevice``: the 2-device CI leg skips it).
"""
import hashlib
import json

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core.hlo_comm import parse_hlo_collectives, summarize
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.engine import InferenceEngine
from repro.runtime.faults import Fault, FaultInjector
from repro.runtime.request import Request
from repro.runtime.schedule import (BoundaryRecv, BoundarySend, FusedQueue,
                                    PrefillChunk, SampleToken, StageForward,
                                    Sync, make_queue)
from repro.runtime.scheduler import Scheduler, VirtualClock, serve

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")

MAX_LEN = 64
GROUP = 2          # slots per microbatch group (bench OCC_GROUP)
NEW_TOKENS = 5     # per request → NEW_TOKENS - 1 decode rounds


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
            for _ in range(n)]


def _requests(cfg, n):
    return [Request(rid=i, prompt=p, max_new_tokens=NEW_TOKENS)
            for i, p in enumerate(_prompts(cfg, n))]


def _pp(cfg, params, p, d, **kw):
    return make_backend("pp", cfg, params, num_slots=GROUP * d,
                        max_len=MAX_LEN, t=1, p=p, inflight=d, **kw)


def _count(ops):
    counts = {}
    for o in ops:
        counts[o.collective] = counts.get(o.collective, 0) + o.count
    return counts


def _hlo_counts(hlo):
    return {k: v["count"] for k, v in summarize(
        parse_hlo_collectives(hlo)).items()}


# ---------------------------------------------------------------------------
# closed form: pp_schedule_stats pins the executed program
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p,depth,rounds", [(2, 1, 4), (2, 2, 4), (4, 3, 5),
                                            (4, 4, 5), (8, 2, 3)])
def test_pp_schedule_stats_closed_form(p, depth, rounds):
    st = cm.pp_schedule_stats(p, depth, rounds)
    assert st.ticks == rounds * max(p, depth) + min(p, depth) - 1
    assert st.stage_forwards == (depth * rounds,) * p
    assert st.boundary_sends == (p - 1) * 2 * depth * rounds
    assert st.samples == depth * rounds
    assert st.busy_fraction == depth * rounds / st.ticks
    # depth capped by p never beats a fully busy pipeline
    assert st.busy_fraction <= 1.0


def test_pp_schedule_stats_validates():
    with pytest.raises(ValueError):
        cm.pp_schedule_stats(0, 1, 1)
    with pytest.raises(ValueError):
        cm.pp_schedule_stats(2, -1, 1)
    assert cm.pp_schedule_stats(2, 0, 4).ticks == 0
    assert cm.pp_schedule_ops(get_config("llama32-3b"), 0, 4, 2) == []
    assert cm.pp_schedule_ops(get_config("llama32-3b"), 2, 4, 1) == []


@pytest.mark.parametrize("d", [1, 2])
def test_executed_instructions_match_closed_form(setup, d):
    """One admission wave at depth d: the queue's instruction log and
    schedule clock are exactly pp_schedule_stats(p, d, rounds)."""
    cfg, params = setup
    p, rounds = 2, NEW_TOKENS - 1
    backend = _pp(cfg, params, p, d)
    sched = Scheduler(backend, clock=VirtualClock())
    sched.run(_requests(cfg, GROUP * d))
    q = sched._queue
    st = cm.pp_schedule_stats(p, d, rounds)
    assert q.ticks == st.ticks
    assert tuple(q.busy) == st.stage_forwards
    assert q.idle == [st.ticks - b for b in st.stage_forwards]
    for s in range(p):
        assert sum(1 for i in q.log
                   if isinstance(i, StageForward) and i.stage == s) \
            == st.stage_forwards[s]
    n_send = sum(1 for i in q.log if isinstance(i, BoundarySend))
    n_recv = sum(1 for i in q.log if isinstance(i, BoundaryRecv))
    assert n_send == n_recv == st.boundary_sends // 2
    assert sum(1 for i in q.log if isinstance(i, SampleToken)) == st.samples
    # one PrefillChunk per admitted request, logged before its decode
    assert sum(1 for i in q.log if isinstance(i, PrefillChunk)) == GROUP * d


def test_occupancy_report_matches_closed_form(setup):
    """ServingReport.occupancy() reproduces the closed form through the
    StepRecord deltas — the quantity the pp-occupancy bench series gates."""
    cfg, params = setup
    p, rounds, waves = 2, NEW_TOKENS - 1, 2
    reports = {}
    for d in (1, 2):
        backend = _pp(cfg, params, p, d)
        # R = GROUP·p requests: depth 1 runs two admission waves, depth 2 one
        reports[d] = serve(backend, _requests(cfg, GROUP * p),
                           clock=VirtualClock())
    occ1 = reports[1].occupancy()
    occ2 = reports[2].occupancy()
    st1 = cm.pp_schedule_stats(p, 1, rounds)
    st2 = cm.pp_schedule_stats(p, 2, rounds)
    assert occ1["ticks"] == waves * st1.ticks
    assert occ2["ticks"] == st2.ticks
    assert occ1["decode_tokens"] == occ2["decode_tokens"] \
        == GROUP * p * rounds
    assert occ1["stage_busy_fraction"] == [st1.busy_fraction] * p
    assert occ2["stage_busy_fraction"] == [st2.busy_fraction] * p
    # the tentpole ratio: depth p fills the bubble
    ratio = occ2["tokens_per_tick"] / occ1["tokens_per_tick"]
    assert ratio == pytest.approx(waves * st1.ticks / st2.ticks)
    assert ratio >= 1.5
    assert occ2["busy_fraction_mean"] >= 0.8


# ---------------------------------------------------------------------------
# bitwise identity: depth d == depth 1 == solo, contiguous and paged
# ---------------------------------------------------------------------------


def _solo_reference(cfg, params, req):
    eng = InferenceEngine(cfg, params, max_len=MAX_LEN, decode_chunk=1)
    out = eng.generate(np.asarray(req.prompt)[None, :],
                       max_new_tokens=req.max_new_tokens)
    return np.asarray(out)[0].tolist()


@pytest.mark.parametrize("paged", [False, True])
def test_depth_identity_contiguous_and_paged(setup, paged):
    cfg, params = setup
    p = 2
    reqs = _requests(cfg, GROUP * p)
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs[:2]}
    got = {}
    for d in (1, 2):
        kw = dict(paged=True, page_size=8, num_pages=64) if paged else {}
        backend = _pp(cfg, params, p, d, **kw)
        got[d] = serve(backend, _requests(cfg, GROUP * p),
                       clock=VirtualClock()).tokens_by_rid()
    assert got[1] == got[2]
    for rid, ref in refs.items():
        assert got[2][rid] == ref, f"rid {rid} diverged from solo serving"


@needs_mesh
def test_depth_identity_pp4(setup):
    """pp4 at depth 4: one wave of 4 groups, tokens == depth 1 (which runs
    4 waves), ticks == closed form at both depths."""
    cfg, params = setup
    p, rounds = 4, NEW_TOKENS - 1
    got, ticks = {}, {}
    for d in (1, 4):
        sched = Scheduler(_pp(cfg, params, p, d), clock=VirtualClock())
        rep = sched.run(_requests(cfg, GROUP * p))
        got[d] = rep.tokens_by_rid()
        ticks[d] = rep.occupancy()["ticks"]
    assert got[1] == got[4]
    assert ticks[1] == p * cm.pp_schedule_stats(p, 1, rounds).ticks
    assert ticks[4] == cm.pp_schedule_stats(p, 4, rounds).ticks
    # the ISSUE's headline: ≥ 2× tokens/tick at depth p
    assert ticks[1] / ticks[4] >= 2.0


# ---------------------------------------------------------------------------
# recovery ladder under the dynamic schedule
# ---------------------------------------------------------------------------


def test_transient_faults_identical_at_depth2(setup):
    cfg, params = setup
    ref = serve(_pp(cfg, params, 2, 1), _requests(cfg, 4),
                clock=VirtualClock()).tokens_by_rid()
    inj = FaultInjector.scripted({
        ("decode", 2): Fault("decode", "transient"),
        ("pp_transfer", 4): Fault("pp_transfer", "transient")})
    rep = Scheduler(_pp(cfg, params, 2, 2), clock=VirtualClock(),
                    faults=inj, retry_backoff=0.1).run(_requests(cfg, 4))
    assert rep.tokens_by_rid() == ref
    assert rep.retries >= 2


def test_pool_pressure_preemption_identical_at_depth2(setup):
    """A page pool that cannot hold both groups forces real mid-schedule
    preemption: victims come only from groups with no issued work, the
    preempted requests recompute, and the streams stay bitwise identical."""
    cfg, params = setup
    ref = serve(_pp(cfg, params, 2, 1), _requests(cfg, 4),
                clock=VirtualClock()).tokens_by_rid()
    backend = _pp(cfg, params, 2, 2, paged=True, page_size=8, num_pages=6)
    rep = Scheduler(backend, clock=VirtualClock(),
                    admission="optimistic").run(_requests(cfg, 4))
    assert rep.preemptions > 0
    assert rep.tokens_by_rid() == ref
    assert backend.pool.stats().used_tokens == 0


def test_scripted_pool_fault_identical_at_depth2(setup):
    cfg, params = setup
    ref = serve(_pp(cfg, params, 2, 1), _requests(cfg, 4),
                clock=VirtualClock()).tokens_by_rid()
    inj = FaultInjector.scripted({("pool", 3): Fault("pool", "oom")})
    rep = Scheduler(_pp(cfg, params, 2, 2, paged=True, page_size=8,
                        num_pages=64),
                    clock=VirtualClock(), faults=inj,
                    admission="optimistic").run(_requests(cfg, 4))
    assert rep.preemptions == 1
    assert rep.tokens_by_rid() == ref


def test_cancel_mid_schedule_drains_only_that_round(setup):
    """Cancelling a request mid-schedule syncs the queue (its in-flight
    instructions drain) and the survivors' streams are untouched."""
    cfg, params = setup
    ref = serve(_pp(cfg, params, 2, 1), _requests(cfg, 4),
                clock=VirtualClock()).tokens_by_rid()
    sched = Scheduler(_pp(cfg, params, 2, 2), clock=VirtualClock())
    for r in _requests(cfg, 4):
        sched.submit(r)
    for _ in range(4):
        sched.step()
    assert sched.cancel(2)
    got = sched.run().tokens_by_rid()
    for rid in (0, 1, 3):
        assert got[rid] == ref[rid]
    assert len(got[2]) < len(ref[2])
    # the cancel logged a Sync barrier before touching slot state
    assert any(isinstance(i, Sync) for i in sched._queue.log)


# ---------------------------------------------------------------------------
# traffic: measured per-round transfers == closed form == pp_schedule_ops
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [1, 2])
def test_round_transfers_match_closed_form(setup, d):
    cfg, params = setup
    p, rounds = 2, NEW_TOKENS - 1
    backend = _pp(cfg, params, p, d)
    rep = serve(backend, _requests(cfg, GROUP * d), clock=VirtualClock())
    send = [o for o in backend.decode_comm_ops(batch=GROUP)
            if o.collective == "send"]
    want_count = sum(o.count for o in send)
    want_bytes = sum(o.total_msg_bytes for o in send)
    dec = [r for r in rep.steps if r.phase == "decode"]
    assert len(dec) == d * rounds
    for r in dec:
        assert r.measured_transfers["count"] == want_count
        assert r.measured_transfers["bytes"] == want_bytes
    # pp_schedule_ops composes the identical totals (host f32: b=4)
    ops = cm.pp_schedule_ops(cfg, d, rounds, p, t=1, b=4, group=GROUP)
    s_ops = [o for o in ops if o.collective == "send"]
    assert sum(o.count for o in s_ops) == len(dec) * want_count
    assert sum(o.total_msg_bytes for o in s_ops) == len(dec) * want_bytes


def test_token_checksum_depth_invariant(setup):
    """The bench's token_checksum construction is depth-invariant — the
    same hash the pp-occupancy gate compares across depths."""
    cfg, params = setup
    sums = set()
    for d in (1, 2):
        got = serve(_pp(cfg, params, 2, d), _requests(cfg, 4),
                    clock=VirtualClock()).tokens_by_rid()
        sums.add(hashlib.sha256(
            json.dumps(got, sort_keys=True).encode()).hexdigest())
    assert len(sums) == 1


# ---------------------------------------------------------------------------
# degenerate FusedQueue + StepRecord surface
# ---------------------------------------------------------------------------


def test_fused_queue_on_gspmd_backend(setup):
    cfg, params = setup
    backend = make_backend("gspmd", cfg, params, num_slots=2,
                           max_len=MAX_LEN)
    q = make_queue(backend)
    assert isinstance(q, FusedQueue)
    assert (q.p, q.depth, q.group_size) == (1, 1, 2)
    assert q.busy_groups() == set() and q.pending_groups() == set()
    q.begin_round(0, np.zeros(2, np.int32), np.zeros(2, np.int32))
    assert q.pending_groups() == {0} and q.busy_groups() == set()
    with pytest.raises(RuntimeError):
        q.begin_round(0, np.zeros(2, np.int32), np.zeros(2, np.int32))


def test_step_records_carry_wall_and_stage_fields(setup):
    cfg, params = setup
    backend = _pp(cfg, params, 2, 2, paged=True, page_size=8, num_pages=64)
    rep = Scheduler(backend, clock=VirtualClock(),
                    chunk_size=4).run(_requests(cfg, 4))
    dec = [r for r in rep.steps if r.phase == "decode"]
    pre = [r for r in rep.steps if r.phase == "prefill"]
    assert dec and pre
    for r in dec:
        assert r.wall_s > 0.0
        assert len(r.stage_busy) == len(r.stage_idle) == 2
        assert sum(r.stage_busy) > 0
    for r in pre:
        assert r.wall_s > 0.0
        assert r.stage_busy is None and r.stage_idle is None
    # fused backends keep the degenerate [1]/[0] stage shape
    rep = serve(make_backend("gspmd", cfg, params, num_slots=2,
                             max_len=MAX_LEN),
                _requests(cfg, 2), clock=VirtualClock())
    for r in rep.steps:
        if r.phase == "decode":
            assert r.stage_busy == [1] and r.stage_idle == [0]


def test_make_backend_rejects_bad_inflight(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="inflight"):
        make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN,
                     inflight=2)
    with pytest.raises(ValueError, match="divide"):
        make_backend("pp", cfg, params, num_slots=3, max_len=MAX_LEN,
                     t=1, p=2, inflight=2)


# ---------------------------------------------------------------------------
# occupancy in the analytical stack (slo + planner)
# ---------------------------------------------------------------------------


def test_predict_slo_occupancy_term(setup):
    cfg, _ = setup
    from repro.core.slo import predict_goodput, predict_slo
    base = predict_slo(cfg, 8, 4, t=1, p=4)
    same = predict_slo(cfg, 8, 4, t=1, p=4, inflight=1)
    assert base.e2e == same.e2e and base.tpot == same.tpot
    assert base.occupancy == 0.25
    assert base.breakdown["tpot_effective"] == base.tpot
    deep = predict_slo(cfg, 8, 4, t=1, p=4, inflight=4)
    assert deep.occupancy == 1.0
    assert deep.breakdown["tpot_effective"] == deep.tpot / 4
    assert deep.e2e < base.e2e
    # depth beyond p saturates; p=1 has no bubble to fill
    assert predict_slo(cfg, 8, 4, t=1, p=4, inflight=8).occupancy == 1.0
    assert predict_slo(cfg, 8, 4, t=2, p=1, inflight=4).occupancy == 1.0
    gp1 = predict_goodput(cfg, 8, 8, num_slots=4, capacity_tokens=512)
    gp4 = predict_goodput(cfg, 8, 8, num_slots=4, capacity_tokens=512,
                          t=1, p=4, inflight=4)
    assert gp1.breakdown["pp_occupancy"] == 1.0
    assert gp4.breakdown["pp_occupancy"] == 1.0
    assert gp4.goodput_tok_s > predict_goodput(
        cfg, 8, 8, num_slots=4, capacity_tokens=512,
        t=1, p=4).goodput_tok_s


def test_planner_ranks_with_occupancy(setup):
    cfg, _ = setup
    from repro.core.planner import plan
    base = plan(cfg, 4, 64, 16, objective="tpot")
    deep = plan(cfg, 4, 64, 16, objective="tpot", inflight=4)
    for c in base:
        assert c.occupancy == (1.0 if c.pipeline_parallel == 1
                               else 1 / c.pipeline_parallel)
    # filling the bubble can only help PP layouts: the best pp>1
    # candidate's score improves, pure-TP scores are unchanged
    b_by = {(c.tensor_parallel, c.context_parallel, c.pipeline_parallel): c
            for c in base}
    d_by = {(c.tensor_parallel, c.context_parallel, c.pipeline_parallel): c
            for c in deep}
    for key, c in d_by.items():
        if key[2] == 1:
            assert c.score == b_by[key].score
        else:
            assert c.score < b_by[key].score
            assert c.occupancy == min(4, key[2]) / key[2]


# ---------------------------------------------------------------------------
# the 3-axis point: (t, c, p) = (2, 2, 2) with the dynamic schedule
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_three_axis_dynamic_schedule(setup):
    """(2,2,2) on the 8-device mesh at depth 2: tokens bitwise equal the
    (1,1,2) depth-1 stream, ticks/busy on the closed form, per-stage
    decode HLO collective-free over cp with the hybrid TP rows, and the
    measured per-round boundary bytes at the [group, h/t] shard."""
    cfg, params = setup
    t, c, p = 2, 2, 2
    ref = serve(_pp(cfg, params, p, 1), _requests(cfg, GROUP * p),
                clock=VirtualClock()).tokens_by_rid()
    backend = make_backend("pp", cfg, params, num_slots=GROUP * 2,
                           max_len=MAX_LEN, t=t, c=c, p=p, inflight=2)
    # predicted == compiled: per-stage decode modules show the hybrid
    # TP schedule (cp replicates decode, adding no collectives)
    for s in range(p):
        assert _hlo_counts(backend.stage_decode_hlo(s)) == \
            cm.hybrid_stage_collectives(cfg, t, p, s, c=c, phase="decode"), s
    backend.drain_transfers()
    rep = serve(backend, _requests(cfg, GROUP * p), clock=VirtualClock())
    assert rep.tokens_by_rid() == ref
    occ = rep.occupancy()
    st = cm.pp_schedule_stats(p, 2, NEW_TOKENS - 1)
    assert occ["ticks"] == st.ticks
    assert occ["stage_busy_fraction"] == [st.busy_fraction] * p
    # predicted == measured: every round's boundary hops at batch=GROUP
    send = [o for o in backend.decode_comm_ops(batch=GROUP)
            if o.collective == "send"]
    dec = [r for r in rep.steps if r.phase == "decode"]
    for r in dec:
        assert r.measured_transfers["count"] == sum(o.count for o in send)
        assert r.measured_transfers["bytes"] == \
            sum(o.total_msg_bytes for o in send)
