"""Cross-request prefix caching end to end (ISSUE 9 acceptance criteria).

1. Index semantics: radix/chain lookup over page-granular blocks, longest
   cached prefix capped at ``prompt_len - 1``, LRU eviction, ref-counted
   pages that outlive the slot that wrote them.
2. Token identity: cache-hit streams (pages adopted, only the novel suffix
   prefilled) are bitwise identical to the undisturbed solo runs at
   (t, p) ∈ {(1,1), (2,1), (1,2), (2,2)} — including the COW-divergence
   case (prompt fully covered by the cache) and preemption under
   optimistic admission.
3. Counts: the hit request's executed prefill collectives match
   ``commodel.prefix_cache_ops`` (suffix rows only), the compiled HLO of
   the paged pass, and — on PP — the measured boundary transfers.
4. Analytics: ``slo.predict_slo(hit_rate=...)`` mixes cold and hit TTFT
   (bitwise-unchanged at hit_rate=0) and the planner re-ranks layouts
   under template-heavy traffic.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core.hlo_comm import parse_hlo_collectives, summarize
from repro.core.planner import plan
from repro.core.slo import predict_slo
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.engine import InferenceEngine
from repro.runtime.kvpool import KVPool
from repro.runtime.prefix_index import PrefixIndex
from repro.runtime.request import Request, make_template_trace
from repro.runtime.scheduler import Scheduler, VirtualClock

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")

MAX_LEN = 64
PAGE = 8
CHUNK = 4
TEMPLATE_LEN = 16       # two full pages at PAGE=8
SUF = 5                 # novel suffix of the primary hit request

LAYOUTS = [
    pytest.param("gspmd", dict(), id="t1p1"),
    pytest.param("tp", dict(t=2), marks=needs_mesh, id="t2p1"),
    pytest.param("pp", dict(t=1, p=2), marks=needs_mesh, id="t1p2"),
    pytest.param("pp", dict(t=2, p=2), marks=needs_mesh, id="t2p2"),
]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _template(cfg, n=TEMPLATE_LEN, seed=7):
    rng = np.random.default_rng(seed)
    return rng.integers(2, cfg.vocab_size, n).astype(np.int32)


def _warm_requests(cfg):
    """One request that writes the template's pages and indexes them."""
    t = _template(cfg)
    suf = np.random.default_rng(8).integers(
        2, cfg.vocab_size, SUF).astype(np.int32)
    return [Request(rid=0, prompt=np.concatenate([t, suf]),
                    max_new_tokens=4)]


def _hit_requests(cfg):
    """Same template, distinct suffixes — plus one prompt that IS the
    template exactly (fully covered: hit capped at 15, the shared tail
    page must COW before the final-position prefill writes it)."""
    t = _template(cfg)
    rng = np.random.default_rng(9)
    reqs = []
    for i, (s, n) in enumerate([(SUF, 6), (3, 4)]):
        suf = rng.integers(2, cfg.vocab_size, s).astype(np.int32)
        suf[0] = 2 + i          # rid-unique first suffix token
        reqs.append(Request(rid=i + 1, prompt=np.concatenate([t, suf]),
                            max_new_tokens=n))
    reqs.append(Request(rid=9, prompt=t.copy(), max_new_tokens=4))
    return reqs


def _solo_reference(cfg, params, req):
    eng = InferenceEngine(cfg, params, max_len=MAX_LEN, decode_chunk=1)
    out = eng.generate(np.asarray(req.prompt)[None, :],
                       max_new_tokens=req.max_new_tokens)
    return np.asarray(out)[0].tolist()


def _hlo_counts(hlo: str):
    return {k: v["count"]
            for k, v in summarize(parse_hlo_collectives(hlo)).items()}


def _count(ops, phase=None):
    counts = {}
    for o in ops:
        if phase in (None, o.phase):
            counts[o.collective] = counts.get(o.collective, 0) + o.count
    return counts


# ---------------------------------------------------------------------------
# index semantics (pool-only, no model)
# ---------------------------------------------------------------------------


def test_index_longest_chain_lookup_and_cap():
    """Lookup walks the block chain as far as it matches; a fully covered
    prompt is capped at prompt_len - 1 so the final position is always
    prefilled (that's what makes the tail page a COW candidate)."""
    pool = KVPool(num_pages=16, page_size=4)
    idx = PrefixIndex(pool)
    toks = np.arange(100, 112, dtype=np.int32)          # 12 = 3 full blocks
    pages = pool.allocate(0, len(toks))
    assert idx.insert(toks, pages) == 3
    assert idx.insert(toks, pages) == 0                 # idempotent

    # longer prompt sharing the prefix: all 3 blocks match, length = 12
    longer = np.concatenate([toks, np.asarray([7, 8], np.int32)])
    hit = idx.lookup(longer)
    assert (hit.length, hit.pages) == (12, list(pages))

    # the exact prompt: capped one short, same pages (tail shared partially)
    hit = idx.lookup(toks)
    assert hit.hit and hit.length == 11 and hit.pages == list(pages)

    # divergence at block 2 stops the chain after 2 blocks
    fork = toks.copy()
    fork[9] = 999
    hit = idx.lookup(fork)
    assert hit.length == 8 and hit.pages == list(pages[:2])

    # divergence at block 0 is a clean miss
    assert not idx.lookup(np.arange(50, 62, dtype=np.int32)).hit
    assert idx.stats()["hits"] == 3 and idx.stats()["misses"] == 1


def test_index_pins_pages_past_owner_free():
    """Cached pages stay live (and reclaimable) after the writing slot
    frees; clear() returns every page to the free list."""
    pool = KVPool(num_pages=16, page_size=4)
    idx = PrefixIndex(pool)
    toks = np.arange(0, 12, dtype=np.int32)
    pages = pool.allocate(0, len(toks))
    idx.insert(toks, pages)
    pool.free(0)
    assert all(pool.page_refcount(pg) == 1 for pg in pages)
    assert idx.reclaimable_pages() == 3
    assert idx.lookup(np.concatenate([toks, toks[:1]])).length == 12
    assert idx.clear() == 3
    assert pool.stats().used_tokens == 0
    assert pool.free_pages == pool.num_pages - 1


def test_index_lru_eviction_order():
    """evict_one drops the least-recently-used entry; a lookup refreshes
    every matched block, so the untouched chain goes first — and losing
    block 0 breaks that chain entirely."""
    pool = KVPool(num_pages=16, page_size=4)
    idx = PrefixIndex(pool)
    a = np.arange(0, 8, dtype=np.int32)
    b = np.arange(40, 48, dtype=np.int32)
    idx.insert(a, pool.allocate(0, 8))
    idx.insert(b, pool.allocate(1, 8))
    idx.lookup(np.concatenate([a, a[:1]]))      # refresh a's entries
    assert idx.evict_one()
    assert not idx.lookup(np.concatenate([b, b[:1]])).hit   # b block 0 gone
    assert idx.lookup(np.concatenate([a, a[:1]])).length == 8
    idx.clear()
    assert not idx.evict_one()                  # empty index: False


def test_index_capacity_and_validation():
    pool = KVPool(num_pages=16, page_size=4)
    with pytest.raises(ValueError):
        PrefixIndex(pool, max_entries=0)
    idx = PrefixIndex(pool, max_entries=2)
    toks = np.arange(0, 12, dtype=np.int32)
    idx.insert(toks, pool.allocate(0, 12))
    assert len(idx) == 2 and idx.evictions == 1


def test_index_evict_for_frees_pool_pressure():
    """evict_for pops LRU entries until the pool can satisfy the claim —
    the primitive behind the backend's claim guard."""
    pool = KVPool(num_pages=7, page_size=4)     # 6 usable
    idx = PrefixIndex(pool)
    for owner in range(3):
        toks = np.arange(owner * 100, owner * 100 + 8, dtype=np.int32)
        idx.insert(toks, pool.allocate(owner, 8))
        pool.free(owner)
    assert pool.free_pages == 0
    assert idx.evict_for(4) == 4                # one page per entry
    assert pool.free_pages >= 4 and len(idx) == 2


# ---------------------------------------------------------------------------
# backend wiring: validation + admission gate under cache pressure
# ---------------------------------------------------------------------------


def test_prefix_cache_requires_paged_c1(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="paged"):
        make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN,
                     prefix_cache=True)
    with pytest.raises(ValueError, match="c=1|context"):
        make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN,
                     paged=True, page_size=PAGE, c=2, prefix_cache=True)


def test_admission_counts_reclaimable_and_evicts_under_pressure(setup):
    """A pool full of cold cache is not a full pool: can_admit counts the
    index's reclaimable pages, and the claim guard evicts LRU entries when
    an allocation would otherwise MemoryError."""
    cfg, params = setup
    be = make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN,
                      paged=True, page_size=PAGE, num_pages=6,  # 5 usable
                      prefix_cache=True)
    sched = Scheduler(be, clock=VirtualClock())
    sched.run(_warm_requests(cfg))              # 21 tokens -> 3 pages
    assert len(be.prefix_index) == 2            # 2 full template blocks
    assert be.pool.free_pages == 3
    # a 37-token cold prompt needs 5 pages; 3 free + 2 reclaimable fit it
    assert be.can_admit(37, 1)
    be.begin_prefill(0, 37, 1)
    assert len(be.prefix_index) == 0            # both entries evicted
    assert be.prefix_index.evictions == 2
    be.free_slots([0])
    assert be.pool.stats().used_tokens == 0


# ---------------------------------------------------------------------------
# acceptance: cache-hit streams bitwise identical, 4 layouts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,kw", LAYOUTS)
@pytest.mark.parametrize("chunk", [None, CHUNK],
                         ids=["whole", f"chunk{CHUNK}"])
def test_cache_hit_streams_bitwise_identical(setup, kind, kw, chunk):
    """Warm batch writes + indexes the template; hit batch adopts it and
    prefills only suffixes.  Every hit stream equals the undisturbed solo
    run — including the fully covered prompt whose shared tail page COWs —
    and the pool drains to zero once the index is cleared."""
    cfg, params = setup
    backend = make_backend(kind, cfg, params, num_slots=4, max_len=MAX_LEN,
                           paged=True, page_size=PAGE, prefix_cache=True,
                           **kw)
    sched = Scheduler(backend, clock=VirtualClock(), chunk_size=chunk)
    warm = sched.run(_warm_requests(cfg))
    assert all(m.cached_prefix_len == 0 for m in warm.metrics)
    assert len(backend.prefix_index) == TEMPLATE_LEN // PAGE

    report = sched.run(_hit_requests(cfg))
    got = report.tokens_by_rid()
    for r in _hit_requests(cfg):
        assert got[r.rid] == _solo_reference(cfg, params, r), \
            f"{kind}{kw}: cache-hit stream {r.rid} diverged"

    hits = {m.rid: m.cached_prefix_len for m in report.metrics}
    assert hits[1] == TEMPLATE_LEN and hits[2] == TEMPLATE_LEN
    assert hits[9] == TEMPLATE_LEN - 1          # full cover, capped
    assert backend.pool.stats().cow_copies >= 1, \
        "fully covered prompt must have COWed its shared tail page"
    if chunk is not None:
        recs = [s for s in report.steps if s.phase == "prefill"]
        assert {s.cached_prefix_len for s in recs if s.rid == 1} \
            == {TEMPLATE_LEN}
        # suffix-only chunking: ceil(5/4) passes instead of ceil(21/4)
        assert len([s for s in recs if s.rid == 1]) == -(-SUF // CHUNK)

    backend.prefix_index.clear()
    assert backend.pool.stats().used_tokens == 0
    assert backend.pool.free_pages == backend.pool.num_pages - 1
    assert not backend.pool.owners()


# ---------------------------------------------------------------------------
# acceptance: executed counts == prefix_cache_ops == HLO == PP transfers
# ---------------------------------------------------------------------------


@needs_mesh
def test_tp_hit_counts_match_commodel_and_hlo(setup):
    """(2,1): the hit request's phase="prefill" StepRecords sum exactly to
    prefix_cache_ops' executed column (suffix rows only), each chunk keeps
    the invariant per-chunk schedule, and the compiled HLO of the paged
    pass at the actual suffix chunk lengths reports the same counts."""
    cfg, params = setup
    backend = make_backend("tp", cfg, params, num_slots=4, max_len=MAX_LEN,
                           t=2, paged=True, page_size=PAGE,
                           prefix_cache=True)
    sched = Scheduler(backend, clock=VirtualClock(), chunk_size=CHUNK)
    warm = sched.run(_warm_requests(cfg))
    report = sched.run(_hit_requests(cfg))

    ops = cm.prefix_cache_ops(cfg, TEMPLATE_LEN, SUF, chunk=CHUNK, t=2,
                              gather_mode="allgather")
    recs = [s for s in report.steps if s.phase == "prefill" and s.rid == 1]
    assert len(recs) == -(-SUF // CHUNK)
    total = {}
    for r in recs:
        for k, v in r.collective_counts.items():
            total[k] = total.get(k, 0) + v
    assert total == ops.executed_counts

    # per-chunk counts are chunk-length-invariant and match the HLO of the
    # paged pass at the scheduler's actual suffix splits (4 then 1)
    per = {"allreduce": 2 * cfg.num_layers + 1, "allgather": 1}
    for r in recs:
        assert r.collective_counts == per
    for q_len in (CHUNK, SUF - CHUNK):
        assert _hlo_counts(backend.paged_step_hlo(q_len=q_len, batch=1)) \
            == per

    # savings are real: cold would have chunked the whole 21-token prompt
    cold_recs = [s for s in warm.steps if s.phase == "prefill"]
    assert len(cold_recs) == -(-(TEMPLATE_LEN + SUF) // CHUNK)
    assert ops.skipped_counts["allreduce"] > 0
    assert ops.skipped_bytes > 0


@needs_mesh
def test_pp_hit_measured_transfers_match_commodel(setup):
    """(1,2): each suffix chunk of the hit request ships exactly the
    predicted boundary bytes — the house invariant holds on the cache-hit
    path's measured transfers too."""
    cfg, params = setup
    backend = make_backend("pp", cfg, params, num_slots=4, max_len=MAX_LEN,
                           t=1, p=2, paged=True, page_size=PAGE,
                           prefix_cache=True)
    sched = Scheduler(backend, clock=VirtualClock(), chunk_size=CHUNK)
    sched.run(_warm_requests(cfg))
    report = sched.run(_hit_requests(cfg))

    recs = [s for s in report.steps if s.phase == "prefill" and s.rid == 1]
    sizes = [min(CHUNK, SUF - s) for s in range(0, SUF, CHUNK)]
    assert len(recs) == len(sizes)
    for rec, c in zip(recs, sizes):
        send = [o for o in backend.chunk_comm_ops(c)
                if o.collective == "send"][0]
        assert rec.measured_transfers["count"] == send.count == 2
        assert rec.measured_transfers["bytes"] == send.total_msg_bytes


# ---------------------------------------------------------------------------
# acceptance: preemption of cache-hit requests stays bitwise identical
# ---------------------------------------------------------------------------


def test_preempted_cache_hits_stay_bitwise_identical(setup):
    """Optimistic admission on an oversubscribed pool with the prefix
    cache live: hits happen, preemptions happen, and every stream still
    equals the solo run (a preempted hit recomputes COLD by design — its
    resumed prefix ends in generated tokens the index never saw)."""
    cfg, params = setup
    page, tmpl_len = 4, 8
    tmpl = _template(cfg, n=tmpl_len, seed=11)
    rng = np.random.default_rng(12)

    def _pressure_requests():
        reqs = []
        for i, (s, n) in enumerate([(3, 8), (5, 6), (2, 10), (4, 7)]):
            suf = rng.integers(2, cfg.vocab_size, s).astype(np.int32)
            suf[0] = 2 + i
            reqs.append(Request(rid=i, prompt=np.concatenate([tmpl, suf]),
                                max_new_tokens=n))
        return reqs

    backend = make_backend("gspmd", cfg, params, num_slots=3,
                           max_len=MAX_LEN, paged=True, page_size=page,
                           num_pages=10, prefix_cache=True)
    sched = Scheduler(backend, clock=VirtualClock(), admission="optimistic")
    warm = sched.run([Request(rid=99,
                              prompt=np.concatenate([tmpl, tmpl[:1]]),
                              max_new_tokens=2)])
    assert len(backend.prefix_index) == tmpl_len // page

    reqs = _pressure_requests()
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    report = sched.run(reqs)
    got = report.tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid], \
            f"preempted cache-hit request {r.rid} diverged"
    assert report.preemptions > 0, "pool pressure must have preempted"
    hits = {m.rid: m.cached_prefix_len for m in report.metrics}
    assert any(v > 0 for v in hits.values()), "no request hit the cache"
    # recompute passes went cold: their records price the full prefix
    for rec in report.steps:
        if rec.phase == "recompute":
            assert rec.cached_prefix_len is None

    backend.prefix_index.clear()
    assert backend.pool.stats().used_tokens == 0
    assert backend.pool.free_pages == backend.pool.num_pages - 1


# ---------------------------------------------------------------------------
# analytics: prefix_cache_ops closed form, SLO mixing, planner re-ranking
# ---------------------------------------------------------------------------


def test_prefix_cache_ops_closed_form(setup):
    """executed == chunked_prefill_ops over the suffix alone; hit_len=0
    degenerates to executed == cold; counts are batch- and chunk-length-
    invariant (only bytes scale)."""
    cfg, _ = setup
    ops = cm.prefix_cache_ops(cfg, 16, 5, chunk=4, t=2,
                              gather_mode="allgather")
    want = _count(cm.chunked_prefill_ops(cfg, 5, 4, 2, 1,
                                         gather_mode="allgather"))
    assert ops.executed_counts == want
    assert ops.cold_counts == _count(cm.chunked_prefill_ops(
        cfg, 21, 4, 2, 1, gather_mode="allgather"))
    assert all(v >= 0 for v in ops.skipped_counts.values())
    assert ops.skipped_bytes == ops.cold_bytes - ops.executed_bytes > 0

    miss = cm.prefix_cache_ops(cfg, 0, 21, chunk=4, t=2,
                               gather_mode="allgather")
    assert miss.executed_counts == miss.cold_counts
    assert miss.skipped_bytes == 0
    assert all(v == 0 for v in miss.skipped_counts.values())

    for batch in (1, 3):
        same = cm.prefix_cache_ops(cfg, 16, 5, chunk=4, t=2, batch=batch,
                                   gather_mode="allgather")
        assert same.executed_counts == ops.executed_counts

    with pytest.raises(ValueError):
        cm.prefix_cache_ops(cfg, -1, 5)
    with pytest.raises(ValueError):
        cm.prefix_cache_ops(cfg, 16, 0)


def test_predict_slo_hit_rate_mixing():
    """hit_rate mixes cold and hit reports linearly: TTFT/E2E/volume fall
    monotonically with hit_rate, TPOT never moves (decode is untouched),
    and hit_rate=0 is bitwise the uncached report."""
    cfg = get_config("llama32-3b")
    base = predict_slo(cfg, 512, 64, 4)
    zero = predict_slo(cfg, 512, 64, 4, hit_rate=0.0)
    assert (zero.ttft, zero.tpot, zero.e2e, zero.comm_volume) \
        == (base.ttft, base.tpot, base.e2e, base.comm_volume)

    reports = [predict_slo(cfg, 512, 64, 4, hit_rate=h, hit_len=256)
               for h in (0.25, 0.5, 0.9)]
    ttfts = [r.ttft for r in reports]
    assert ttfts == sorted(ttfts, reverse=True) and ttfts[0] < base.ttft
    assert all(r.tpot == base.tpot for r in reports)
    assert all(r.e2e < base.e2e for r in reports)
    assert all(r.comm_volume < base.comm_volume for r in reports)
    r = reports[1]
    assert r.breakdown["ttft_hit"] < r.breakdown["ttft_cold"]
    assert r.ttft == pytest.approx(
        0.5 * r.breakdown["ttft_cold"] + 0.5 * r.breakdown["ttft_hit"])
    # default hit_len is s_p - 1 (the fully covered prompt's cap)
    assert predict_slo(cfg, 512, 64, 4,
                       hit_rate=0.5).breakdown["hit_len"] == 511

    for bad in (-0.1, 1.1):
        with pytest.raises(ValueError):
            predict_slo(cfg, 512, 64, 4, hit_rate=bad)
    for bad_len in (0, 512):
        with pytest.raises(ValueError):
            predict_slo(cfg, 512, 64, 4, hit_rate=0.5, hit_len=bad_len)


def test_planner_reranks_under_template_traffic():
    """Template-heavy traffic shrinks prefill-bound advantages: on 8 chips
    at s_p=8192 pure TP=8 ranks below TP=2 CP=4 cold (CP shards the long
    prefill) but overtakes it at hit_rate=0.95 — most requests no longer
    prefill 8192 tokens, so decode-side strength wins."""
    cfg = get_config("llama32-3b")
    names = lambda cands: [c.name for c in cands]
    cold = names(plan(cfg, 8, 8192, 128, objective="ttft"))
    hot = names(plan(cfg, 8, 8192, 128, objective="ttft", hit_rate=0.95))
    tp8, cp4 = "TP=8 CP=1 PP=1", "TP=2 CP=4 PP=1"
    assert cold.index(tp8) > cold.index(cp4)
    assert hot.index(tp8) < hot.index(cp4)
    # hit_rate=0 leaves the ranking bitwise unchanged
    assert names(plan(cfg, 8, 8192, 128, objective="ttft",
                      hit_rate=0.0)) == cold


def test_template_trace_shapes():
    """make_template_trace: shared templates, rid-unique suffixes, zipf
    skew toward template 0."""
    reqs = make_template_trace(32, 0.0, 1000, n_templates=3,
                               template_len=12, suffix_lens=(2, 4))
    assert len(reqs) == 32
    prompts = [r.prompt for r in reqs]
    assert all(12 + 2 <= len(p) <= 12 + 4 for p in prompts)
    heads = {p[:12].tobytes() for p in prompts}
    assert 1 <= len(heads) <= 3                 # few shared templates
    assert len({p.tobytes() for p in prompts}) == 32   # no identical prompt
    with pytest.raises(ValueError):
        make_template_trace(4, 0.0, 1000, n_templates=0)
    with pytest.raises(ValueError):
        make_template_trace(4, 0.0, 1000, zipf_a=1.0)
