"""Robustness layer: token identity under adversity (ISSUE 6 acceptance).

1. Preemption-by-recompute: an optimistic-admission run on an oversubscribed
   pool preempts mid-decode and every request's final token stream is bitwise
   identical to an uninterrupted solo run — at (t,p) ∈ {(1,1),(2,1),(1,2)}
   paged.  Each preemption's recompute collectives are logged as
   phase="recompute" StepRecords whose counts match
   ``commodel.preemption_recompute_ops`` and (p>1) whose measured boundary
   transfers ship exactly the predicted bytes.
2. Retry-after-transient-fault runs are token-identical, with the backoff
   visible on the virtual clock; permanent faults finish with
   ``finish_reason="error"`` and leak nothing.
3. Deadlines shed hopeless requests mid-flight; ``cancel(rid)`` works at
   every lifecycle stage.
4. Chaos suite (hypothesis): under random seeded fault schedules the
   scheduler always terminates, surviving requests are token-identical to
   the fault-free run, and the pool leaks zero pages.
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import commodel as cm
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.engine import InferenceEngine
from repro.runtime.faults import Fault, FaultInjector, SITES
from repro.runtime.request import Request
from repro.runtime.scheduler import Scheduler, VirtualClock

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")

MAX_LEN = 64
PAGE = 4

# the three ISSUE layouts; the pool (10 pages = 9 usable × 4 positions) is
# oversubscribed against the trace's 13-page worst case, so optimistic
# admission must preempt to finish
LAYOUTS = [
    pytest.param("gspmd", dict(), id="t1p1"),
    pytest.param("tp", dict(t=2), marks=needs_mesh, id="t2p1"),
    pytest.param("pp", dict(t=1, p=2), marks=needs_mesh, id="t1p2"),
]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg):
    rng = np.random.default_rng(0)
    lens = [(7, 10), (11, 8), (5, 12), (9, 6)]
    return [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=n)
            for i, (s, n) in enumerate(lens)]


def _solo_reference(cfg, params, req):
    eng = InferenceEngine(cfg, params, max_len=MAX_LEN, decode_chunk=1)
    out = eng.generate(jnp.asarray(req.prompt)[None, :],
                       max_new_tokens=req.max_new_tokens)
    return np.asarray(out)[0].tolist()


def _refs(cfg, params):
    return {r.rid: _solo_reference(cfg, params, r) for r in _requests(cfg)}


# ---------------------------------------------------------------------------
# fault injector: determinism, independence, scripting
# ---------------------------------------------------------------------------


def test_injector_schedule_is_seed_deterministic():
    def schedule(seed):
        inj = FaultInjector(seed=seed, rates={"decode": 0.3, "pool": 0.3})
        for _ in range(50):
            inj.draw("decode")
            inj.draw("pool")
        return inj.injected

    a, b = schedule(7), schedule(7)
    assert a == b and len(a) > 0
    assert schedule(8) != a, "different seeds must differ"


def test_injector_sites_are_independent_streams():
    """Extra draws at one site must not shift another site's schedule."""
    inj1 = FaultInjector(seed=3, rates={"decode": 0.4, "prefill": 0.4},
                         max_faults=None)
    inj2 = FaultInjector(seed=3, rates={"decode": 0.4, "prefill": 0.4},
                         max_faults=None)
    for _ in range(30):
        inj1.draw("decode")
    for _ in range(200):                    # perturb an unrelated site
        inj2.draw("prefill")
    for _ in range(30):
        inj2.draw("decode")
    dec = lambda inj: [(s, i, f) for s, i, f in inj.injected if s == "decode"]
    assert dec(inj1) == dec(inj2)


def test_injector_max_faults_bounds_schedule():
    inj = FaultInjector(seed=0, rates={"decode": 1.0}, max_faults=5)
    got = [inj.draw("decode") for _ in range(20)]
    assert sum(f is not None for f in got) == 5
    assert all(f is None for f in got[5:])


def test_injector_scripted_exact_coordinates():
    plan = {("decode", 3): Fault("decode", "transient"),
            ("pool", 0): Fault("pool", "oom")}
    inj = FaultInjector.scripted(plan)
    hits = [(s, i) for s in ("decode", "pool") for i in range(6)
            if inj.draw(s) is not None]
    assert hits == [("decode", 3), ("pool", 0)]
    assert [(s, i) for s, i, _ in inj.injected] == [("decode", 3),
                                                    ("pool", 0)]


def test_fault_validation():
    with pytest.raises(ValueError):
        Fault("nowhere", "transient")
    with pytest.raises(ValueError):
        Fault("pool", "transient")           # pool only injects oom
    with pytest.raises(ValueError):
        Fault("decode", "delay")             # delays live on the transfer
    with pytest.raises(ValueError):
        FaultInjector(rates={"bogus": 0.1})
    with pytest.raises(ValueError):
        FaultInjector.scripted({("decode", 0): Fault("prefill", "transient")})
    assert set(SITES) == {"decode", "prefill", "pool", "pp_transfer",
                          "handoff"}


# ---------------------------------------------------------------------------
# acceptance 1: preemption-by-recompute token identity, 3 layouts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,kw", LAYOUTS)
def test_preempted_streams_bitwise_identical(setup, kind, kw):
    """Optimistic admission on an oversubscribed pool: preemptions happen,
    every final token stream equals the undisturbed solo run, recompute
    StepRecords carry commodel's predicted counts, and the pool drains to
    zero leaked pages."""
    cfg, params = setup
    refs = _refs(cfg, params)
    backend = make_backend(kind, cfg, params, num_slots=3, max_len=MAX_LEN,
                           paged=True, page_size=PAGE, num_pages=10, **kw)
    sched = Scheduler(backend, clock=VirtualClock(), admission="optimistic")
    report = sched.run(_requests(cfg))

    got = report.tokens_by_rid()
    for r in _requests(cfg):
        assert got[r.rid] == refs[r.rid], \
            f"{kind}{kw}: preempted request {r.rid} diverged"
    assert report.preemptions > 0, "pool pressure must have preempted"
    assert all(m.finish_reason == "length" for m in report.metrics)

    # one recompute record per preemption, counts == commodel at the
    # recorded prefix length
    recs = [s for s in report.steps if s.phase == "recompute"]
    assert len(recs) == report.preemptions
    t, p = kw.get("t", 1), kw.get("p", 1)
    for rec in recs:
        ops = cm.preemption_recompute_ops(cfg, rec.prefix_len, t, p,
                                          gather_mode="allgather")
        want = {}
        for o in ops:
            want[o.collective] = want.get(o.collective, 0) + o.count
        assert rec.collective_counts == want, \
            f"recompute counts diverge from commodel at {rec.prefix_len}"

    # zero page leak
    assert backend.pool.stats().used_tokens == 0
    assert backend.pool.free_pages == backend.pool.num_pages - 1
    assert not backend.pool.owners()


@needs_mesh
def test_recompute_measured_transfers_match_commodel(setup):
    """(1,2) paged: each recompute pass ships exactly the boundary bytes
    the comm model predicts for a prefill of the recomputed prefix — the
    house invariant extended to the failure path."""
    cfg, params = setup
    backend = make_backend("pp", cfg, params, num_slots=3, max_len=MAX_LEN,
                           t=1, p=2, paged=True, page_size=PAGE, num_pages=10)
    report = Scheduler(backend, clock=VirtualClock(),
                       admission="optimistic").run(_requests(cfg))
    recs = [s for s in report.steps if s.phase == "recompute"]
    assert recs, "expected preemptions under this pool"
    for rec in recs:
        # measured TransferRecords are host-side f32 (b=4), batch-1 pass
        send = [o for o in cm.preemption_recompute_ops(
                    cfg, rec.prefix_len, 1, 2, b=4,
                    gather_mode="allgather")
                if o.collective == "send"][0]
        assert rec.measured_transfers["count"] == send.count
        assert rec.measured_transfers["bytes"] == send.total_msg_bytes


def test_scripted_pool_fault_forces_one_preemption(setup):
    """An injected pool OOM takes the identical recovery path as real
    exhaustion: exactly one preemption, streams still bitwise identical."""
    cfg, params = setup
    refs = _refs(cfg, params)
    backend = make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN,
                           paged=True, page_size=PAGE)
    inj = FaultInjector.scripted({("pool", 3): Fault("pool", "oom")})
    report = Scheduler(backend, clock=VirtualClock(),
                       faults=inj).run(_requests(cfg)[:2])
    assert report.preemptions == 1
    assert len(inj.injected) == 1
    got = report.tokens_by_rid()
    for r in _requests(cfg)[:2]:
        assert got[r.rid] == refs[r.rid]
    assert backend.pool.stats().used_tokens == 0


def test_preemption_with_chunked_prefill(setup):
    """Recompute prefixes re-prefill through the chunked path too: chunk
    records are tagged phase="recompute" and identity still holds."""
    cfg, params = setup
    refs = _refs(cfg, params)
    backend = make_backend("gspmd", cfg, params, num_slots=3, max_len=MAX_LEN,
                           paged=True, page_size=PAGE, num_pages=10)
    report = Scheduler(backend, clock=VirtualClock(), chunk_size=4,
                       admission="optimistic").run(_requests(cfg))
    got = report.tokens_by_rid()
    for r in _requests(cfg):
        assert got[r.rid] == refs[r.rid]
    assert report.preemptions > 0
    recs = [s for s in report.steps if s.phase == "recompute"]
    assert len(recs) >= report.preemptions
    # each preemption's prefix re-chunks at chunk_size=4: every
    # (rid, prefix_len) group is a whole number of ceil(prefix/4) passes
    groups = {}
    for s in recs:
        groups[(s.rid, s.prefix_len)] = groups.get(
            (s.rid, s.prefix_len), 0) + 1
    for (rid, plen), n in groups.items():
        assert n % -(-plen // 4) == 0, \
            f"rid {rid}: {n} chunk records for a {plen}-token prefix"
    assert backend.pool.stats().used_tokens == 0


# ---------------------------------------------------------------------------
# acceptance 2: transient retry identity + permanent errors
# ---------------------------------------------------------------------------


def test_transient_decode_fault_retried_identically(setup):
    """A transient decode fault is absorbed by retry-with-backoff: streams
    identical, per-request retry counters bumped, and the backoff wait is
    visible on the virtual clock."""
    cfg, params = setup
    refs = _refs(cfg, params)
    backend = make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN)
    inj = FaultInjector.scripted({
        ("decode", 2): Fault("decode", "transient"),
        ("decode", 5): Fault("decode", "transient")})
    clock = VirtualClock()
    report = Scheduler(backend, clock=clock, faults=inj,
                       retry_backoff=0.1).run(_requests(cfg)[:2])
    got = report.tokens_by_rid()
    for r in _requests(cfg)[:2]:
        assert got[r.rid] == refs[r.rid]
    assert report.retries >= 2
    assert clock.now() >= 0.2, "two 0.1 s backoffs must show on the clock"


def test_transient_prefill_fault_retried_identically(setup):
    cfg, params = setup
    refs = _refs(cfg, params)
    backend = make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN)
    inj = FaultInjector.scripted({("prefill", 1):
                                  Fault("prefill", "transient")})
    clock = VirtualClock()
    report = Scheduler(backend, clock=clock, faults=inj,
                       retry_backoff=0.05).run(_requests(cfg)[:2])
    got = report.tokens_by_rid()
    for r in _requests(cfg)[:2]:
        assert got[r.rid] == refs[r.rid]
    assert report.retries == 1 and clock.now() >= 0.05


def test_permanent_prefill_fault_errors_one_request(setup):
    """A permanent fault during one request's prefill kills only that
    request ("error"); its slot and pages free, everyone else unaffected."""
    cfg, params = setup
    refs = _refs(cfg, params)
    backend = make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN,
                           paged=True, page_size=PAGE)
    inj = FaultInjector.scripted({("prefill", 1):
                                  Fault("prefill", "permanent")})
    report = Scheduler(backend, clock=VirtualClock(),
                       faults=inj).run(_requests(cfg))
    by = {m.rid: m for m in report.metrics}
    dead = [m.rid for m in report.metrics if m.finish_reason == "error"]
    assert len(dead) == 1
    for r in _requests(cfg):
        if r.rid not in dead:
            assert by[r.rid].tokens == refs[r.rid]
            assert by[r.rid].finish_reason == "length"
    assert backend.pool.stats().used_tokens == 0


def test_exhausted_retries_finish_with_error(setup):
    """retry_limit bounds the backoff loop: a fault that keeps firing past
    it finishes the active set with "error" instead of spinning forever."""
    cfg, params = setup
    backend = make_backend("gspmd", cfg, params, num_slots=1, max_len=MAX_LEN)
    plan = {("decode", i): Fault("decode", "transient") for i in range(10)}
    inj = FaultInjector.scripted(plan)
    report = Scheduler(backend, clock=VirtualClock(), faults=inj,
                       retry_limit=2).run(_requests(cfg)[:1])
    m = report.metrics[0]
    assert m.finish_reason == "error"
    assert m.retries == 2
    assert m.num_generated >= 1, "the prefill token predates the fault"


@needs_mesh
def test_pp_transfer_delay_stretches_clock_not_tokens(setup):
    """A pipeline-boundary latency spike is absorbed as pure wall time."""
    cfg, params = setup
    refs = _refs(cfg, params)
    backend = make_backend("pp", cfg, params, num_slots=2, max_len=MAX_LEN,
                           t=1, p=2)
    inj = FaultInjector.scripted({("pp_transfer", 1):
                                  Fault("pp_transfer", "delay",
                                        delay_s=0.25)})
    clock = VirtualClock()
    report = Scheduler(backend, clock=clock,
                       faults=inj).run(_requests(cfg)[:2])
    got = report.tokens_by_rid()
    for r in _requests(cfg)[:2]:
        assert got[r.rid] == refs[r.rid]
    assert clock.now() >= 0.25


# ---------------------------------------------------------------------------
# acceptance 3: deadlines and cancellation
# ---------------------------------------------------------------------------


def test_deadline_sheds_queued_request(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    backend = make_backend("gspmd", cfg, params, num_slots=1, max_len=MAX_LEN)
    hog = Request(rid=0, prompt=rng.integers(2, cfg.vocab_size, 8),
                  max_new_tokens=6)
    doomed = Request(rid=1, prompt=rng.integers(2, cfg.vocab_size, 8),
                     max_new_tokens=4, ttft_deadline=0.5)
    clock = VirtualClock()
    sched = Scheduler(backend, clock=clock)
    sched.submit([hog, doomed])
    sched.step()                             # hog admitted, doomed queued
    clock.advance(1.0)                       # doomed's TTFT budget expires
    report = sched.run()
    by = {m.rid: m for m in report.metrics}
    assert by[1].finish_reason == "deadline"
    assert by[1].num_generated == 0
    assert by[0].finish_reason == "length"


def test_deadline_sheds_active_request_keeping_tokens(setup):
    cfg, params = setup
    refs = _refs(cfg, params)
    req = _requests(cfg)[0]                  # budget 10
    req.deadline = 0.5
    backend = make_backend("gspmd", cfg, params, num_slots=1, max_len=MAX_LEN)
    clock = VirtualClock()
    sched = Scheduler(backend, clock=clock)
    sched.submit(req)
    for _ in range(3):
        sched.step()                         # 3 tokens in, still alive
    clock.advance(1.0)
    report = sched.run()
    m = report.metrics[0]
    assert m.finish_reason == "deadline"
    assert 0 < m.num_generated < req.max_new_tokens
    assert m.tokens == refs[0][:m.num_generated], \
        "shed request's partial stream must still be exact"


def test_cancel_at_every_lifecycle_stage(setup):
    cfg, params = setup
    refs = _refs(cfg, params)
    reqs = _requests(cfg)[:3]
    backend = make_backend("gspmd", cfg, params, num_slots=1, max_len=MAX_LEN)
    sched = Scheduler(backend, clock=VirtualClock())
    sched.submit(reqs)
    sched.step()                             # rid 0 active, 1 & 2 queued
    assert sched.cancel(2) is True           # queued
    sched.step()
    assert sched.cancel(0) is True           # active, keeps its tokens
    assert sched.cancel(42) is False         # unknown
    report = sched.run()
    by = {m.rid: m for m in report.metrics}
    assert by[0].finish_reason == "cancelled"
    assert 0 < by[0].num_generated < reqs[0].max_new_tokens
    assert by[0].tokens == refs[0][:by[0].num_generated]
    assert by[2].finish_reason == "cancelled" and by[2].num_generated == 0
    assert by[1].finish_reason == "length" and by[1].tokens == refs[1]
    assert sched.cancel(0) is False, "already finished"


def test_admission_mode_validation(setup):
    cfg, params = setup
    contiguous = make_backend("gspmd", cfg, params, num_slots=1,
                              max_len=MAX_LEN)
    with pytest.raises(ValueError, match="paged"):
        Scheduler(contiguous, clock=VirtualClock(), admission="optimistic")
    with pytest.raises(ValueError, match="admission"):
        Scheduler(contiguous, clock=VirtualClock(), admission="yolo")
    with pytest.raises(ValueError):
        Scheduler(contiguous, clock=VirtualClock(), retry_limit=-1)


# ---------------------------------------------------------------------------
# acceptance 4: chaos suite (hypothesis)
# ---------------------------------------------------------------------------


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYP = True
except ImportError:                           # pragma: no cover
    HAVE_HYP = False


@functools.lru_cache(maxsize=1)
def _chaos_env():
    """One backend + reference set shared across chaos examples (compiles
    once; every example must leave the pool clean for the next)."""
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    backend = make_backend("gspmd", cfg, params, num_slots=3, max_len=MAX_LEN,
                           paged=True, page_size=PAGE, num_pages=10)
    return cfg, params, backend


if HAVE_HYP:

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=6, deadline=None)
    def test_chaos_terminates_survivors_identical_no_leak(seed):
        """Random seeded fault schedule over every site: the run always
        terminates, requests that finished normally are token-identical to
        the fault-free run, and the pool leaks zero pages — even when
        requests died mid-prefill or mid-decode."""
        cfg, params, backend = _chaos_env()
        refs = _refs(cfg, params)
        inj = FaultInjector(seed=seed,
                            rates={"decode": 0.05, "prefill": 0.05,
                                   "pool": 0.10},
                            transient_frac=0.7, max_faults=16)
        sched = Scheduler(backend, clock=VirtualClock(),
                          admission="optimistic", faults=inj,
                          retry_backoff=1e-4)
        report = sched.run(_requests(cfg))     # termination == returning
        for m in report.metrics:
            if m.finish_reason in ("length", "eos"):
                assert m.tokens == refs[m.rid], \
                    f"seed {seed}: survivor {m.rid} diverged"
            else:
                assert m.finish_reason == "error"
        # zero page leak, whatever the fault schedule did
        assert backend.pool.stats().used_tokens == 0
        assert backend.pool.free_pages == backend.pool.num_pages - 1
        assert not backend.pool.owners()
