"""Training substrate: loss descent, fused-CE equivalence, optimizer,
checkpoint roundtrip, data pipeline determinism."""
import dataclasses
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import get_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.train import (_ce, ce_from_hidden_chunked, make_loss_fn,
                                 make_train_step)


def test_loss_decreases_on_fixed_batch():
    cfg = get_config("internlm2-1.8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=cosine_schedule(1e-3, 2, 100))
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 2,
                                          cfg.vocab_size)}
    losses = []
    for _ in range(15):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_fused_ce_matches_dense():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 9, 32)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((32, 77)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, 77, (2, 9)), jnp.int32)
    dense = _ce(x @ head, tgt, 77)
    fused = ce_from_hidden_chunked(x, head, tgt, chunk=13)  # uneven chunks
    assert float(jnp.abs(dense - fused)) < 1e-5


def test_fused_loss_fn_matches_dense_loss_fn():
    cfg = get_config("gemma-7b").reduced()      # tied embeddings path
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 2,
                                          cfg.vocab_size)}
    dense, _ = make_loss_fn(model, "dense")(params, batch)
    fused, _ = make_loss_fn(model, "fused")(params, batch)
    assert float(jnp.abs(dense - fused)) < 1e-4


def test_grad_clip_bounds_update():
    opt = AdamW(grad_clip=1.0)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = opt.update(huge, state, params)
    assert float(metrics["grad_norm"]) > 1e5       # raw norm reported
    # post-clip first moment bounded by (1-b1)·clip
    new_p, new_s, _ = opt.update(huge, state, params)
    assert float(jnp.abs(new_s["m"]["w"]).max()) <= 0.1 + 1e-6


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, 10, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-9)
    assert float(lr(55)) < float(lr(20))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "c": jnp.asarray([1, 2], jnp.int32)}
    save_checkpoint(str(tmp_path / "ck"), tree, step=7)
    loaded, step = load_checkpoint(str(tmp_path / "ck"))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(tree["a"]["b"]), loaded["a"]["b"])
    np.testing.assert_array_equal(np.asarray(tree["c"]), loaded["c"])


def test_data_pipeline_determinism_and_sharding():
    ds = SyntheticTokens(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    full = ds.batch_at(5)
    # any host slice matches the corresponding rows of the global batch
    np.testing.assert_array_equal(full[2:5], ds.batch_at(5, 2, 5))
    # deterministic across calls, different across steps/seeds
    np.testing.assert_array_equal(full, ds.batch_at(5))
    assert not np.array_equal(full, ds.batch_at(6))
    assert not np.array_equal(
        full, SyntheticTokens(1000, 32, 8, seed=4).batch_at(5))
    # BOS resets + vocab range
    assert (full[:, 0] == ds.bos_id).all()
    assert full.min() >= 1 and full.max() < 1000


def test_train_step_with_remat_matches_no_remat():
    cfg = dataclasses.replace(get_config("granite-8b").reduced())
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 2,
                              cfg.vocab_size)
    loss_fn = make_loss_fn(model)
    g1 = jax.grad(lambda p: loss_fn(p, {"tokens": toks})[0])(params)
    cfg2 = dataclasses.replace(cfg, remat="full")
    model2 = get_model(cfg2)
    g2 = jax.grad(lambda p: make_loss_fn(model2)(p, {"tokens": toks})[0])(params)
    leaves1, leaves2 = jax.tree.leaves(g1), jax.tree.leaves(g2)
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)
