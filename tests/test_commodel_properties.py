"""Property-based invariants of the analytical comm model (hypothesis)."""
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.core import commodel as cm

CFG = get_config("llama31-8b")

t_strat = st.sampled_from([2, 4, 8])
p_strat = st.sampled_from([2, 4, 8])
sp_strat = st.integers(min_value=1, max_value=2048)
sd_strat = st.integers(min_value=2, max_value=2048)


@given(sp=sp_strat, sd=sd_strat, t=t_strat, p=p_strat)
@settings(max_examples=80, deadline=None)
def test_hybrid_degenerates_to_tp_and_pp(sp, sd, t, p):
    """hybrid(t, p=1) == TP(t); hybrid(t=1, p) == PP(p)."""
    assert cm.total_volume(cm.hybrid_comm_ops(CFG, sp, sd, t, 1)) == \
        pytest.approx(cm.total_volume(cm.tp_comm_ops(CFG, sp, sd, t)))
    assert cm.total_volume(cm.hybrid_comm_ops(CFG, sp, sd, 1, p)) == \
        pytest.approx(cm.total_volume(cm.pp_comm_ops(CFG, sp, sd, p)))


@given(sp=sp_strat, sd=sd_strat, t=t_strat)
@settings(max_examples=60, deadline=None)
def test_volume_monotone_in_decode_length(sp, sd, t):
    assert cm.v_tp(CFG, sp, sd + 1, t) > cm.v_tp(CFG, sp, sd, t)


@given(sp=sp_strat, sd=sd_strat, t=t_strat)
@settings(max_examples=60, deadline=None)
def test_volume_sublinear_in_decode_length(sp, sd, t):
    """(S_p + S_d - 1) scaling ⇒ doubling S_d at-most-doubles volume, and
    strictly less whenever there is a prefill to amortize (S_p > 1)."""
    v1 = cm.v_tp(CFG, sp, sd, t)
    v2 = cm.v_tp(CFG, sp, 2 * sd, t)
    assert v2 <= 2 * v1 + 1e-9
    if sp > 1:
        assert v2 < 2 * v1


@given(sp=sp_strat, sd=sd_strat, t=t_strat, p=p_strat)
@settings(max_examples=60, deadline=None)
def test_ops_nonnegative_and_consistent(sp, sd, t, p):
    for o in cm.hybrid_comm_ops(CFG, sp, sd, t, p):
        assert o.count >= 0 and o.msg_bytes >= 0
        assert o.wire_bytes <= o.total_msg_bytes * 2   # AR factor ≤ 2
        assert o.phase in ("prefill", "decode")


@given(sd=sd_strat, t=t_strat)
@settings(max_examples=40, deadline=None)
def test_gather_mode_allgather_upper_bounds_gather(sd, t):
    """XLA all-gather of full logits moves ≥ the NCCL gather's v/t slices."""
    g = cm.total_volume([o for o in cm.tp_comm_ops(CFG, 128, sd, t)
                         if o.collective == "gather"])
    ag = cm.total_volume(
        [o for o in cm.tp_comm_ops(CFG, 128, sd, t, gather_mode="allgather")
         if o.collective == "allgather"])
    assert ag >= g


@given(sp=sp_strat, sd=sd_strat, e=st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_moe_alltoall_scales_with_topk(sp, sd, e):
    moe_cfg = get_config("mixtral-8x22b")
    ops = cm.moe_comm_ops(moe_cfg, sp, sd, e)
    assert len(ops) == 2
    total_tokens_moved = sum(o.count * o.shape[0] for o in ops)
    # dispatch+combine, top-2 copies of every processed token, 2L layers
    expected = 2 * moe_cfg.num_layers * 2 * (sp + (sd - 1))
    assert total_tokens_moved == expected


@given(sp=sp_strat, t=t_strat, p=p_strat)
@settings(max_examples=40, deadline=None)
def test_encoder_has_no_decode_phase(sp, t, p):
    enc = get_config("hubert-xlarge")
    ops = cm.comm_ops_for(enc, sp, 4096, t, p)
    assert all(o.phase == "prefill" for o in ops)


@given(b=st.integers(min_value=1, max_value=256), t=t_strat)
@settings(max_examples=30, deadline=None)
def test_batch_scales_token_rows(b, t):
    """Beyond-paper batched serving: rows scale linearly with batch."""
    one = cm.tp_comm_ops(CFG, 128, 128, t, batch=1)
    many = cm.tp_comm_ops(CFG, 128, 128, t, batch=b)
    for o1, ob in zip(one, many):
        assert ob.elements == o1.elements * b
        assert ob.count == o1.count
