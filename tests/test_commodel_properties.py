"""Property-based invariants of the analytical comm model (hypothesis)."""
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.core import commodel as cm

CFG = get_config("llama31-8b")

t_strat = st.sampled_from([2, 4, 8])
p_strat = st.sampled_from([2, 4, 8])
sp_strat = st.integers(min_value=1, max_value=2048)
sd_strat = st.integers(min_value=2, max_value=2048)


@given(sp=sp_strat, sd=sd_strat, t=t_strat, p=p_strat)
@settings(max_examples=80, deadline=None)
def test_hybrid_degenerates_to_tp_and_pp(sp, sd, t, p):
    """hybrid(t, p=1) == TP(t); hybrid(t=1, p) == PP(p)."""
    assert cm.total_volume(cm.hybrid_comm_ops(CFG, sp, sd, t, 1)) == \
        pytest.approx(cm.total_volume(cm.tp_comm_ops(CFG, sp, sd, t)))
    assert cm.total_volume(cm.hybrid_comm_ops(CFG, sp, sd, 1, p)) == \
        pytest.approx(cm.total_volume(cm.pp_comm_ops(CFG, sp, sd, p)))


@given(sp=sp_strat, sd=sd_strat, t=t_strat)
@settings(max_examples=60, deadline=None)
def test_volume_monotone_in_decode_length(sp, sd, t):
    assert cm.v_tp(CFG, sp, sd + 1, t) > cm.v_tp(CFG, sp, sd, t)


@given(sp=sp_strat, sd=sd_strat, t=t_strat)
@settings(max_examples=60, deadline=None)
def test_volume_sublinear_in_decode_length(sp, sd, t):
    """(S_p + S_d - 1) scaling ⇒ doubling S_d at-most-doubles volume, and
    strictly less whenever there is a prefill to amortize (S_p > 1)."""
    v1 = cm.v_tp(CFG, sp, sd, t)
    v2 = cm.v_tp(CFG, sp, 2 * sd, t)
    assert v2 <= 2 * v1 + 1e-9
    if sp > 1:
        assert v2 < 2 * v1


@given(sp=sp_strat, sd=sd_strat, t=t_strat, p=p_strat)
@settings(max_examples=60, deadline=None)
def test_ops_nonnegative_and_consistent(sp, sd, t, p):
    for o in cm.hybrid_comm_ops(CFG, sp, sd, t, p):
        assert o.count >= 0 and o.msg_bytes >= 0
        assert o.wire_bytes <= o.total_msg_bytes * 2   # AR factor ≤ 2
        assert o.phase in ("prefill", "decode")


@given(sd=sd_strat, t=t_strat)
@settings(max_examples=40, deadline=None)
def test_gather_mode_allgather_upper_bounds_gather(sd, t):
    """XLA all-gather of full logits moves ≥ the NCCL gather's v/t slices."""
    g = cm.total_volume([o for o in cm.tp_comm_ops(CFG, 128, sd, t)
                         if o.collective == "gather"])
    ag = cm.total_volume(
        [o for o in cm.tp_comm_ops(CFG, 128, sd, t, gather_mode="allgather")
         if o.collective == "allgather"])
    assert ag >= g


@given(sp=sp_strat, sd=sd_strat, e=st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_moe_alltoall_scales_with_topk(sp, sd, e):
    moe_cfg = get_config("mixtral-8x22b")
    ops = cm.moe_comm_ops(moe_cfg, sp, sd, e)
    assert len(ops) == 2
    total_tokens_moved = sum(o.count * o.shape[0] for o in ops)
    # dispatch+combine, top-2 copies of every processed token, 2L layers
    expected = 2 * moe_cfg.num_layers * 2 * (sp + (sd - 1))
    assert total_tokens_moved == expected


@given(sp=sp_strat, t=t_strat, p=p_strat)
@settings(max_examples=40, deadline=None)
def test_encoder_has_no_decode_phase(sp, t, p):
    enc = get_config("hubert-xlarge")
    ops = cm.comm_ops_for(enc, sp, 4096, t, p)
    assert all(o.phase == "prefill" for o in ops)


@given(b=st.integers(min_value=1, max_value=256), t=t_strat)
@settings(max_examples=30, deadline=None)
def test_batch_scales_token_rows(b, t):
    """Beyond-paper batched serving: rows scale linearly with batch."""
    one = cm.tp_comm_ops(CFG, 128, 128, t, batch=1)
    many = cm.tp_comm_ops(CFG, 128, 128, t, batch=b)
    for o1, ob in zip(one, many):
        assert ob.elements == o1.elements * b
        assert ob.count == o1.count


# ---------------------------------------------------------------------------
# batch invariance of per-step collective COUNTS (the property the
# continuous-batching scheduler's fixed-capacity decode step relies on:
# runtime/scheduler.assert_counts_batch_invariant)
# ---------------------------------------------------------------------------

batch_strat = st.integers(min_value=2, max_value=128)


def _counts(ops):
    out = {}
    for o in ops:
        key = (o.collective, o.phase)
        out[key] = out.get(key, 0) + o.count
    return out


@given(sp=sp_strat, sd=sd_strat, t=st.sampled_from([1, 2, 4, 8]),
       p=st.sampled_from([1, 2, 4, 8]), b=batch_strat)
@settings(max_examples=80, deadline=None)
def test_comm_ops_counts_batch_invariant(sp, sd, t, p, b):
    """Tables III–VI carry no batch term in any count column: growing the
    batch must change message bytes only, never the number of calls."""
    one = cm.comm_ops_for(CFG, sp, sd, t, p, batch=1,
                          gather_mode="allgather")
    many = cm.comm_ops_for(CFG, sp, sd, t, p, batch=b,
                           gather_mode="allgather")
    assert _counts(one) == _counts(many)


@given(sp=sp_strat, sd=sd_strat, t=st.sampled_from([1, 2, 4, 8]),
       p=st.sampled_from([1, 2, 4, 8]), b=batch_strat)
@settings(max_examples=80, deadline=None)
def test_comm_ops_wire_bytes_linear_in_batch(sp, sd, t, p, b):
    """Wire bytes scale EXACTLY linearly with batch, per op and in total."""
    one = cm.comm_ops_for(CFG, sp, sd, t, p, batch=1,
                          gather_mode="allgather")
    many = cm.comm_ops_for(CFG, sp, sd, t, p, batch=b,
                           gather_mode="allgather")
    assert len(one) == len(many)
    for o1, ob in zip(one, many):
        assert (ob.collective, ob.phase, ob.count) == \
            (o1.collective, o1.phase, o1.count)
        assert ob.wire_bytes == pytest.approx(b * o1.wire_bytes)
    assert cm.total_volume(many) == pytest.approx(b * cm.total_volume(one))


# ---------------------------------------------------------------------------
# wire-factor table: every collective kind × every dtype width — the closed
# forms every byte prediction in the repo reduces to.  The 1-byte widths are
# the DESIGN.md §12 quantized payloads (int8 / fp8 both travel at 1 byte);
# 2/4/8 are bf16 / f32+scales / f64.
# ---------------------------------------------------------------------------

CLOSED_FORM_FACTORS = {
    "allreduce": lambda d: 2.0 * (d - 1) / d,
    "allgather": lambda d: (d - 1) / d,
    "reducescatter": lambda d: (d - 1) / d,
    "gather": lambda d: 1.0,
    "alltoall": lambda d: (d - 1) / d,
    "send": lambda d: 1.0,
    "recv": lambda d: 0.0,
    "collectivepermute": lambda d: 1.0,
}

WIDTHS = [cm.QUANT_WIRE_BYTES["int8"], cm.QUANT_WIRE_BYTES["fp8"], 2, 4, 8]


@given(kind=st.sampled_from(sorted(CLOSED_FORM_FACTORS)),
       w=st.sampled_from(WIDTHS), d=st.integers(min_value=2, max_value=16),
       count=st.integers(min_value=1, max_value=64),
       rows=st.integers(min_value=1, max_value=512),
       cols=st.integers(min_value=1, max_value=4096))
@settings(max_examples=200, deadline=None)
def test_wire_factor_closed_form_every_kind_every_width(kind, w, d, count,
                                                        rows, cols):
    """CommOp.wire_bytes == count · rows · cols · width · factor(kind, d)
    for EVERY collective kind the model emits at EVERY payload width."""
    op = cm.CommOp(kind, "decode", count, (rows, cols), d, w)
    assert op.msg_bytes == rows * cols * w
    assert op.wire_bytes == pytest.approx(
        count * rows * cols * w * CLOSED_FORM_FACTORS[kind](d))


@given(kind=st.sampled_from(sorted(CLOSED_FORM_FACTORS)),
       w=st.sampled_from(WIDTHS), d=st.integers(min_value=2, max_value=16),
       k=st.integers(min_value=1, max_value=8))
@settings(max_examples=120, deadline=None)
def test_wire_bytes_linear_in_width(kind, w, d, k):
    """Scaling the payload width k× scales message AND wire bytes exactly
    k× — the linearity the int8/fp8 wire savings rest on."""
    one = cm.CommOp(kind, "decode", 3, (7, 129), d, w)
    wide = cm.CommOp(kind, "decode", 3, (7, 129), d, w * k)
    assert wide.msg_bytes == k * one.msg_bytes
    assert wide.wire_bytes == pytest.approx(k * one.wire_bytes)


# ---------------------------------------------------------------------------
# quantized two-step decomposition (DESIGN.md §12): the 3-row expansion of
# one decode allreduce must sit on the quant_ar_wire_ratio closed form for
# every (h, t, chunk), and inherit batch invariance of counts
# ---------------------------------------------------------------------------


@given(h=st.integers(min_value=8, max_value=8192),
       t=t_strat, quant=st.sampled_from(["int8", "fp8"]),
       chunk=st.sampled_from([32, 64, 128, 256]),
       rows=st.integers(min_value=1, max_value=64),
       count=st.integers(min_value=1, max_value=128))
@settings(max_examples=150, deadline=None)
def test_quant_decomposition_matches_closed_form_ratio(h, t, quant, chunk,
                                                       rows, count):
    """amax-AR + int8 RS + int8 AG wire bytes over the bf16 AR they replace
    == quant_ar_wire_ratio (t-invariant, odd chunk remainders included)."""
    qops = cm.quant_decode_ar_ops("decode", count, rows, h, t, quant, chunk)
    assert [o.collective for o in qops] == \
        ["allreduce", "reducescatter", "allgather"]
    base = cm.CommOp("allreduce", "decode", count, (rows, h), t, 2)
    got = sum(o.wire_bytes for o in qops) / base.wire_bytes
    assert got == pytest.approx(
        cm.quant_ar_wire_ratio(h, t, quant=quant, chunk=chunk, b=2))
    assert got < 0.6   # the acceptance bound, for every shape drawn


@given(sp=sp_strat, sd=sd_strat, t=st.sampled_from([1, 2, 4, 8]),
       p=st.sampled_from([1, 2, 4]), b=batch_strat,
       quant=st.sampled_from(["int8", "fp8"]))
@settings(max_examples=80, deadline=None)
def test_quant_counts_batch_invariant(sp, sd, t, p, b, quant):
    """The quantized decomposition adds rows, never batch-dependent counts —
    the scheduler's fixed-capacity decode step stays valid under quant."""
    one = cm.comm_ops_for(CFG, sp, sd, t, p, batch=1,
                          gather_mode="allgather", quant=quant)
    many = cm.comm_ops_for(CFG, sp, sd, t, p, batch=b,
                           gather_mode="allgather", quant=quant)
    assert _counts(one) == _counts(many)


@given(sp=sp_strat, sd=sd_strat, t=st.sampled_from([2, 4, 8]),
       quant=st.sampled_from(["int8", "fp8"]))
@settings(max_examples=60, deadline=None)
def test_quant_strictly_cheaper_on_decode_wire(sp, sd, t, quant):
    """At t ≥ 2 the quantized decode wire volume is strictly below the
    full-width model's; at t == 1 the knob is a no-op."""
    base = cm.total_volume(cm.comm_ops_for(CFG, sp, sd, t, 1,
                                           gather_mode="allgather"),
                           phase="decode")
    q = cm.total_volume(cm.comm_ops_for(CFG, sp, sd, t, 1,
                                        gather_mode="allgather", quant=quant),
                        phase="decode")
    assert q < base
    assert cm.comm_ops_for(CFG, sp, sd, 1, 1, quant=quant) == \
        cm.comm_ops_for(CFG, sp, sd, 1, 1)


# ---------------------------------------------------------------------------
# slo.split_p2p_count: the intra/cross split must conserve the call count
# ---------------------------------------------------------------------------


@given(count=st.integers(min_value=0, max_value=10_000),
       p=st.sampled_from([2, 3, 4, 8]),
       cross=st.integers(min_value=0, max_value=8))
@settings(max_examples=200, deadline=None)
def test_p2p_split_conserves_count(count, p, cross):
    """Pinned for p ∈ {2, 3, 4, 8}: intra + cross == count with both parts
    in range, for every cross-link configuration (incl. cross > p-1)."""
    from repro.core.slo import split_p2p_count
    n_intra, n_cross = split_p2p_count(count, p, cross)
    assert n_intra + n_cross == count
    assert 0 <= n_intra <= count
    assert 0 <= n_cross <= count
    if cross == 0:
        assert n_cross == 0
    if cross >= p - 1:
        assert n_intra == 0 or count == 0
