"""§Perf variant equivalence: every optimization must be numerically
indistinguishable from the paper-faithful baseline it replaces."""
import dataclasses

import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.kernels.rwkv6_scan.chunked import wkv6_chunked
from repro.kernels.rwkv6_scan.ref import wkv6_ref
from repro.models.layers import (MaskSpec, chunked_gqa_attention,
                                 gqa_attention)
from repro.models.transformer import get_model


class TestChunkedAttention:
    @pytest.mark.parametrize("spec", [
        MaskSpec(), MaskSpec(window=40),
        MaskSpec(mode="prefix", prefix_len=16),
        MaskSpec(mode="bidirectional")])
    @pytest.mark.parametrize("kv_chunk", [16, 64])
    def test_matches_reference(self, spec, kv_chunk):
        rng = np.random.default_rng(0)
        B, S, Hq, Hkv, D = 2, 128, 4, 2, 32
        q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
        want = gqa_attention(q, k, v, spec.materialize(S, S))
        got = chunked_gqa_attention(q, k, v, spec, kv_chunk=kv_chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    @pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x22b",
                                      "paligemma-3b", "hubert-xlarge"])
    def test_model_level_equivalence(self, arch):
        cfg = get_config(arch).reduced()
        cfg2 = dataclasses.replace(cfg, attention_impl="chunked",
                                   attention_chunk=16)
        m1, m2 = get_model(cfg), get_model(cfg2)
        params = m1.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        kw = {}
        if cfg.family == "encoder":
            kw["features"] = jnp.asarray(
                rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
            l1, _ = m1.forward(params, **kw)
            l2, _ = m2.forward(params, **kw)
        else:
            toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 32)),
                               jnp.int32)
            if cfg.family == "vlm":
                kw["prefix_emb"] = jnp.asarray(rng.standard_normal(
                    (2, cfg.num_prefix_tokens, cfg.d_model)),
                    jnp.float32) * 0.02
            l1, _ = m1.forward(params, toks, **kw)
            l2, _ = m2.forward(params, toks, **kw)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   atol=5e-4)


class TestChunkedWKV6:
    @given(chunk=st.sampled_from([8, 16, 32]),
           seed=st.integers(0, 10))
    @settings(max_examples=10, deadline=None)
    def test_matches_oracle_with_extreme_decays(self, chunk, seed):
        rng = np.random.default_rng(seed)
        B, S, H, hs = 2, 64, 2, 16
        r, k, v = (jnp.asarray(rng.standard_normal((B, S, H, hs)) * 0.5,
                               jnp.float32) for _ in range(3))
        dw = rng.uniform(-3, 2.5, (B, S, H, hs))   # decay w ∈ (~1e-5, 0.95)
        w = jnp.asarray(np.exp(-np.exp(dw)), jnp.float32)
        u = jnp.asarray(rng.standard_normal((H, hs)) * 0.3, jnp.float32)
        s0 = jnp.asarray(rng.standard_normal((B, H, hs, hs)) * 0.1,
                         jnp.float32)
        wy, ws = wkv6_ref(r, k, v, w, u, s0)
        gy, gs = wkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
        np.testing.assert_allclose(np.asarray(gy), np.asarray(wy), atol=1e-4)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), atol=1e-4)

    def test_model_level_equivalence(self):
        cfg = get_config("rwkv6-7b").reduced()
        cfg2 = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, scan_impl="chunked",
                                         scan_chunk=16))
        m1, m2 = get_model(cfg), get_model(cfg2)
        params = m1.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 2,
                                  cfg.vocab_size)
        l1, _ = m1.forward(params, toks)
        l2, _ = m2.forward(params, toks)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-4)


class TestChunkedSelectiveScan:
    @given(chunk=st.sampled_from([8, 16, 32]), seed=st.integers(0, 5))
    @settings(max_examples=8, deadline=None)
    def test_matches_oracle(self, chunk, seed):
        from repro.models.hybrid import selective_scan, selective_scan_chunked
        rng = np.random.default_rng(seed)
        B, S, di, N = 2, 64, 12, 8
        xm = jnp.asarray(rng.standard_normal((B, S, di)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.001, 0.5, (B, S, di)), jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
        A = -jnp.exp(jnp.asarray(rng.uniform(-2, 2, (di, N)), jnp.float32))
        D = jnp.ones((di,), jnp.float32)
        s0 = jnp.asarray(rng.standard_normal((B, di, N)) * 0.1, jnp.float32)
        wy, ws = selective_scan(xm, dt, Bm, Cm, A, D, s0)
        gy, gs = selective_scan_chunked(xm, dt, Bm, Cm, A, D, s0, chunk=chunk)
        np.testing.assert_allclose(np.asarray(gy), np.asarray(wy), atol=1e-4)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), atol=1e-4)

    def test_model_level_equivalence(self):
        cfg = get_config("hymba-1.5b").reduced()
        cfg2 = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, scan_impl="chunked",
                                         scan_chunk=16))
        m1, m2 = get_model(cfg), get_model(cfg2)
        params = m1.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 2,
                                  cfg.vocab_size)
        l1, _ = m1.forward(params, toks)
        l2, _ = m2.forward(params, toks)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-4)


class TestMaskSpec:
    @given(q_len=st.integers(1, 40), kv_len=st.integers(1, 40),
           window=st.one_of(st.none(), st.integers(1, 32)),
           prefix=st.integers(0, 16),
           mode=st.sampled_from(["causal", "bidirectional", "prefix"]))
    @settings(max_examples=60, deadline=None)
    def test_block_matches_materialized(self, q_len, kv_len, window, prefix,
                                        mode):
        spec = MaskSpec(mode=mode, window=window, prefix_len=prefix)
        full = spec.materialize(q_len, kv_len)
        block = spec.block(jnp.arange(q_len), jnp.arange(kv_len))
        np.testing.assert_array_equal(np.asarray(full), np.asarray(block))
