"""Inference-engine behaviour: greedy determinism, encoder path, ring-buffer
sliding-window correctness beyond the window boundary."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import get_model
from repro.runtime.engine import InferenceEngine


def test_greedy_generation_deterministic():
    cfg = get_config("internlm2-1.8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, max_len=64)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 2,
                                 cfg.vocab_size)
    out1 = np.asarray(engine.generate(prompts, max_new_tokens=8))
    out2 = np.asarray(engine.generate(prompts, max_new_tokens=8))
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 8)
    assert (out1 < cfg.vocab_size).all()       # pad logits never win argmax


def test_encoder_engine_path():
    cfg = get_config("hubert-xlarge").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params)
    feats = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    logits = engine.encode(feats)
    assert logits.shape == (2, 24, cfg.padded_vocab)
    with pytest.raises(ValueError):
        engine.generate(jnp.zeros((1, 4), jnp.int32))


def test_sliding_window_ring_buffer_beyond_window():
    """Decode past the window: ring cache must equal windowed full forward."""
    cfg = dataclasses.replace(get_config("mixtral-8x22b").reduced(),
                              sliding_window=16)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, P = 1, 40, 8                         # decode 32 tokens past W=16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 2,
                              cfg.vocab_size)
    full, _ = model.forward(params, toks)      # forward applies the window
    _, cache, _ = model.prefill(params, toks[:, :P], max_len=S)
    assert cache["k"].shape[2] == 16           # ring width == window
    errs = []
    for t in range(P, S):
        logits, cache = model.decode_step(params, cache, toks[:, t], t)
        errs.append(np.abs(np.asarray(logits) -
                           np.asarray(full[:, t])).max())
    assert max(errs) < 5e-3, f"ring-buffer decode diverges: {max(errs):.2e}"


def test_rwkv_state_cache_is_constant_size():
    cfg = get_config("rwkv6-7b").reduced()
    model = get_model(cfg)
    small = jax.eval_shape(lambda: model.init_cache(2, 128))
    large = jax.eval_shape(lambda: model.init_cache(2, 1 << 19))
    assert jax.tree.map(lambda a: a.shape, small) == \
        jax.tree.map(lambda a: a.shape, large)   # O(1) in seq_len
