"""Kernel ops-dispatch: the REPRO_PALLAS_INTERPRET=1 path must route through
the Pallas kernels (interpret mode) and agree with the default jnp path."""
import os

import numpy as np
import pytest

import jax.numpy as jnp


@pytest.fixture()
def interpret_env(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")


def test_flash_attention_dispatch(interpret_env):
    from repro.kernels.flash_attention.ops import flash_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
    got = flash_attention(q, k, v)
    os.environ.pop("REPRO_PALLAS_INTERPRET", None)
    want = flash_attention(q, k, v)            # jnp ref path
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_attention_dispatch(interpret_env):
    from repro.kernels.decode_attention.ops import decode_attention
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 2, 64)), jnp.float32)
    valid = jnp.arange(128) < 77
    got = decode_attention(q, k, v, valid)
    os.environ.pop("REPRO_PALLAS_INTERPRET", None)
    want = decode_attention(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_rmsnorm_dispatch(interpret_env):
    from repro.kernels.rmsnorm.ops import rms_norm
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((33, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256,)) * 0.1, jnp.float32)
    got = rms_norm(x, w)
    os.environ.pop("REPRO_PALLAS_INTERPRET", None)
    want = rms_norm(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_wkv6_dispatch(interpret_env):
    from repro.kernels.rwkv6_scan.ops import wkv6
    rng = np.random.default_rng(3)
    r, k, v = (jnp.asarray(rng.standard_normal((1, 32, 2, 16)) * 0.5,
                           jnp.float32) for _ in range(3))
    w = jnp.asarray(rng.uniform(0.9, 0.999, (1, 32, 2, 16)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((2, 16)) * 0.3, jnp.float32)
    s0 = jnp.zeros((1, 2, 16, 16), jnp.float32)
    gy, gs = wkv6(r, k, v, w, u, s0)
    os.environ.pop("REPRO_PALLAS_INTERPRET", None)
    wy, ws = wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(wy), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), atol=1e-5)
