"""Disaggregated prefill/decode pools with modeled KV handoff (ISSUE 10).

1. Handoff units: ``commodel.kv_handoff_pages`` / ``kv_handoff_ops`` closed
   forms, and the device-level ``export_page``/``import_page`` roundtrip
   whose measured bytes ARE the closed form.
2. Token identity: a mixed trace served disaggregated (prefill pool +
   decode pool sharing one KVPool) produces token streams bitwise identical
   to the colocated run and to undisturbed solo runs — including under
   decode-pool preemption (warm recompute over handed-off pages) and
   injected faults on either side of the pool boundary.
3. Accounting: every handoff logs a phase="handoff" StepRecord whose
   predicted wire bytes (pages × kv_page_bytes) equal the measured device
   bytes exactly, and the shared pool drains to zero leaked pages.
4. Analytics: ``slo.predict_slo(handoff_pages=...)`` prices the
   interconnect term (bitwise unchanged at 0) and
   ``planner.plan_disagg`` prefers disagg on prefill-heavy mixes and
   colocated on short-chat traffic.
5. Warm recompute (DESIGN.md §13 x §10): a preempted request's re-admission
   takes a prefix-cache hit on its own prompt blocks instead of
   recomputing cold.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core.planner import TrafficClass, plan_disagg, recommend_disagg
from repro.core.slo import predict_slo
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.engine import InferenceEngine
from repro.runtime.faults import Fault, FaultInjector
from repro.runtime.request import Request
from repro.runtime.scheduler import DisaggScheduler, Scheduler, VirtualClock

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")

MAX_LEN = 64
PAGE = 4
ROUTE = 2 * PAGE          # DisaggScheduler's default routing threshold

# (prompt_len, max_new): rids 1 and 3 route to the prefill pool (>= ROUTE)
LENS = [(7, 8), (13, 6), (5, 8), (11, 6), (6, 7), (17, 5)]

POOL_LAYOUTS = [
    pytest.param("gspmd", dict(), id="gspmd-gspmd"),
    pytest.param("tp", dict(t=2), marks=needs_mesh, id="tp2-tp2"),
]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, n=len(LENS)):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=m)
            for i, (s, m) in enumerate(LENS[:n])]


def _refs(cfg, params, reqs):
    eng = InferenceEngine(cfg, params, max_len=MAX_LEN, decode_chunk=1)
    return {r.rid: np.asarray(eng.generate(
        jnp.asarray(r.prompt)[None, :],
        max_new_tokens=r.max_new_tokens))[0].tolist() for r in reqs}


def _pools(cfg, params, kind="gspmd", num_pages=None, dec_slots=3, **kw):
    """A decode pool + a prefill pool sharing its KVPool."""
    pages_per = -(-MAX_LEN // PAGE)
    if num_pages is None:
        num_pages = 1 + (dec_slots + 1) * pages_per
    dec = make_backend(kind, cfg, params, num_slots=dec_slots,
                       max_len=MAX_LEN, paged=True, page_size=PAGE,
                       num_pages=num_pages, prefix_cache=True, **kw)
    pre = make_backend(kind, cfg, params, num_slots=1, max_len=MAX_LEN,
                       paged=True, page_size=PAGE, pool=dec.pool,
                       owner_base=dec_slots, **kw)
    return pre, dec


# ---------------------------------------------------------------------------
# 1. closed forms and the device roundtrip
# ---------------------------------------------------------------------------


def test_kv_handoff_closed_forms():
    cfg = get_config("llama32-3b")
    assert cm.kv_handoff_pages(0, 16) == 0
    assert cm.kv_handoff_pages(15, 16) == 0
    assert cm.kv_handoff_pages(16, 16) == 1
    assert cm.kv_handoff_pages(33, 16) == 2      # partial tail never ships
    with pytest.raises(ValueError):
        cm.kv_handoff_pages(-1, 16)
    with pytest.raises(ValueError):
        cm.kv_handoff_pages(16, 0)
    ops = cm.kv_handoff_ops(cfg, 5, 16, b=2)
    assert [o.collective for o in ops] == ["send", "recv"]
    assert all(o.phase == "handoff" and o.workers == 2 for o in ops)
    # wire bytes: the send carries pages × page bytes, the recv is the
    # same transfer's other end (factor 0 — never double-charged)
    assert sum(o.wire_bytes for o in ops) == \
        5 * cm.kv_page_bytes(cfg, 16, b=2)


def test_export_import_roundtrip(setup):
    """A page prefilled on the prefill pool lands bitwise on the decode
    pool's device arrays, and the measured bytes are the closed form."""
    cfg, params = setup
    pre, dec = _pools(cfg, params)
    req = _requests(cfg)[5]                       # 17 tokens = 4 full pages
    pre.begin_prefill(0, req.prompt_len, 1)
    pre.prefill_whole(0, req.prompt)
    pages = [int(p) for p in pre.pool.block_table(pre._owner(0))]
    n_full = cm.kv_handoff_pages(req.prompt_len, PAGE)
    assert n_full == len(pages) - 1               # 17 = 4 full + 1 partial
    b = jnp.dtype(cfg.dtype).itemsize
    for pg in pages[:n_full]:
        data = pre.export_page(pg)
        got = dec.import_page(pg, data)
        assert got == cm.kv_page_bytes(cfg, PAGE, b=b)
        back = dec.export_page(pg)
        for key in ("k", "v"):
            np.testing.assert_array_equal(back[key], data[key])
    pre.free_slots([0])


# ---------------------------------------------------------------------------
# 2. + 3. disaggregated serving: identity and accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,kw", POOL_LAYOUTS)
def test_disagg_streams_bitwise_identical(setup, kind, kw):
    """Solo == colocated == disaggregated, and every handoff's predicted
    bytes equal the measured device bytes."""
    cfg, params = setup
    reqs = _requests(cfg)
    refs = _refs(cfg, params, reqs)

    colo = make_backend(kind, cfg, params, num_slots=3, max_len=MAX_LEN,
                        paged=True, page_size=PAGE, prefix_cache=True, **kw)
    rep_colo = Scheduler(colo, clock=VirtualClock(),
                         chunk_size=8).run(_requests(cfg))

    pre, dec = _pools(cfg, params, kind, **kw)
    ds = DisaggScheduler(pre, dec, clock=VirtualClock(), chunk_size=8)
    rep = ds.run(_requests(cfg))

    got = rep.tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid], f"request {r.rid} diverged"
    assert rep.tokens_by_rid() == rep_colo.tokens_by_rid()
    assert all(m.finish_reason == "length" for m in rep.metrics)

    # exactly the >= ROUTE prompts handed off, with closed-form page counts
    long_rids = {r.rid for r in reqs if r.prompt_len >= ROUTE}
    assert {h.rid for h in rep.handoffs} == long_rids
    b = jnp.dtype(cfg.dtype).itemsize
    for h in rep.handoffs:
        want = cm.kv_handoff_pages(reqs[h.rid].prompt_len, PAGE)
        assert h.pages == want
        assert h.bytes == h.predicted_bytes == \
            want * cm.kv_page_bytes(cfg, PAGE, b=b)

    # one phase="handoff" StepRecord per handoff, predicted == measured
    recs = [s for s in rep.decode.steps if s.phase == "handoff"]
    assert {r.rid for r in recs} == long_rids
    for rec in recs:
        assert rec.collective_counts == {"send": 1, "recv": 1}
        assert rec.predicted_wire_bytes == rec.measured_transfers["bytes"]
        assert rec.measured_transfers["count"] == \
            cm.kv_handoff_pages(reqs[rec.rid].prompt_len, PAGE)

    # handed-off requests hit the index at decode-pool admission: their
    # suffix prefill covers at most one page of positions
    for m in rep.metrics:
        if m.rid in long_rids:
            assert m.cached_prefix_len is not None
            assert m.prompt_len - m.cached_prefix_len <= PAGE

    # zero-leak drain: only index pins (negative owners) survive the run
    assert all(o < 0 for o in dec.pool.owners())
    dec.prefix_index.clear()
    assert dec.pool.free_pages == dec.pool.num_pages - 1


def test_disagg_preemption_warm_recompute_across_boundary(setup):
    """An injected pool OOM preempts a HANDED-OFF request mid-decode in
    the decode pool; its re-admission takes a prefix-cache hit on its own
    prompt blocks — pages the PREFILL pool wrote and shipped — and every
    stream still equals the solo run.  (A scripted fault, not real
    exhaustion: genuine pressure drains the index via ``_claim_guard``
    before the preemption fires, so the warm path needs room to hit.)"""
    cfg, params = setup
    reqs = _requests(cfg)
    refs = _refs(cfg, params, reqs)
    pre, dec = _pools(cfg, params)
    inj = FaultInjector.scripted({("pool", 6): Fault("pool", "oom")})
    ds = DisaggScheduler(pre, dec, clock=VirtualClock(), faults=inj)
    rep = ds.run(_requests(cfg))
    got = rep.tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid], f"request {r.rid} diverged"
    assert rep.decode.preemptions == 1
    # warm recompute (DESIGN.md §13 x §10): the recompute pass adopted the
    # preempted request's indexed prompt blocks — and the victim is a
    # handed-off long request, so the adopted pages crossed the pool
    # boundary before the preemption ever happened
    recs = [s for s in rep.decode.steps if s.phase == "recompute"]
    assert len(recs) == 1 and recs[0].cached_prefix_len
    victim = reqs[recs[0].rid]
    assert victim.prompt_len >= ROUTE, "victim should be a handed-off long"
    assert recs[0].cached_prefix_len < recs[0].prefix_len
    assert all(o < 0 for o in dec.pool.owners())


def test_warm_recompute_single_pool(setup):
    """Satellite: the same §13 x §10 interplay without disaggregation — a
    preempted request on a prefix-cached colocated backend re-admits warm
    (its prompt blocks are still indexed) and streams stay bitwise."""
    cfg, params = setup
    reqs = _requests(cfg)
    refs = _refs(cfg, params, reqs)
    backend = make_backend("gspmd", cfg, params, num_slots=3,
                           max_len=MAX_LEN, paged=True, page_size=PAGE,
                           prefix_cache=True)
    inj = FaultInjector.scripted({("pool", 3): Fault("pool", "oom")})
    rep = Scheduler(backend, clock=VirtualClock(),
                    faults=inj).run(_requests(cfg))
    got = rep.tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid], f"request {r.rid} diverged"
    assert rep.preemptions == 1
    recs = [s for s in rep.steps if s.phase == "recompute"]
    warm = [r for r in recs if r.cached_prefix_len]
    assert warm, "re-admission should have hit the index"
    for rec in warm:
        # the hit never covers the recomputed tail the §10 assertion
        # checks: generated tokens are not indexed
        assert rec.cached_prefix_len < rec.prefix_len


def test_disagg_deadline_sheds_prefill_queue(setup):
    """A hopeless TTFT deadline sheds the request out of the prefill-pool
    queue; everyone else finishes normally."""
    cfg, params = setup
    reqs = _requests(cfg, 4)
    # rid 1 routes long; its TTFT budget expires before the run starts
    reqs[1].ttft_deadline = 0.5
    pre, dec = _pools(cfg, params)
    clock = VirtualClock()
    sched = DisaggScheduler(pre, dec, clock=clock)
    sched.submit(reqs)
    clock.advance(1.0)
    rep = sched.run()
    by = {m.rid: m for m in rep.metrics}
    assert by[1].finish_reason == "deadline" and not by[1].tokens
    for rid in (0, 2, 3):
        assert by[rid].finish_reason == "length"
    assert not [h for h in rep.handoffs if h.rid == 1]


def test_disagg_faults_across_pool_boundary(setup):
    """Scripted faults on both sides of the boundary: transient prefill
    faults retry (retries folded into the request's metrics), a permanent
    handoff fault error-finishes ONLY its request, and surviving streams
    stay bitwise identical."""
    cfg, params = setup
    reqs = _requests(cfg)
    refs = _refs(cfg, params, reqs)

    # transient at the prefill pool's first pass: retried, stream intact
    pre, dec = _pools(cfg, params)
    faults = FaultInjector.scripted(
        {("prefill", 0): Fault("prefill", "transient")})
    rep = DisaggScheduler(pre, dec, clock=VirtualClock(),
                          faults=faults).run(_requests(cfg))
    got = rep.tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid]
    assert sum(m.retries for m in rep.metrics) >= 1

    # permanent at the second handoff page ship: that long request errors,
    # every other stream is untouched
    pre, dec = _pools(cfg, params)
    faults = FaultInjector.scripted(
        {("handoff", 1): Fault("handoff", "permanent")})
    rep = DisaggScheduler(pre, dec, clock=VirtualClock(),
                          faults=faults).run(_requests(cfg))
    by = {m.rid: m for m in rep.metrics}
    first_long = min(r.rid for r in reqs if r.prompt_len >= ROUTE)
    assert by[first_long].finish_reason == "error"
    for r in reqs:
        if r.rid != first_long:
            assert rep.tokens_by_rid()[r.rid] == refs[r.rid]
    assert all(o < 0 for o in dec.pool.owners())


def test_disagg_constructor_validation(setup):
    cfg, params = setup
    dec = make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN,
                       paged=True, page_size=PAGE, prefix_cache=True)
    lone = make_backend("gspmd", cfg, params, num_slots=1, max_len=MAX_LEN,
                        paged=True, page_size=PAGE)
    with pytest.raises(ValueError, match="share ONE KVPool"):
        DisaggScheduler(lone, dec)
    pre_overlap = make_backend("gspmd", cfg, params, num_slots=1,
                               max_len=MAX_LEN, paged=True, page_size=PAGE,
                               pool=dec.pool, owner_base=0)
    with pytest.raises(ValueError, match="disjoint owner ranges"):
        DisaggScheduler(pre_overlap, dec)
    pre = make_backend("gspmd", cfg, params, num_slots=1, max_len=MAX_LEN,
                       paged=True, page_size=PAGE, pool=dec.pool,
                       owner_base=2)
    nocache = make_backend("gspmd", cfg, params, num_slots=2,
                           max_len=MAX_LEN, paged=True, page_size=PAGE)
    with pytest.raises(ValueError, match="prefix index"):
        DisaggScheduler(pre, nocache)
    with pytest.raises(ValueError, match="route_prompt_len"):
        DisaggScheduler(pre, dec, route_prompt_len=PAGE - 1)
    with pytest.raises(ValueError, match="needs paged=True"):
        make_backend("gspmd", cfg, params, num_slots=1, max_len=MAX_LEN,
                     pool=dec.pool)
    with pytest.raises(ValueError, match="owner_base"):
        make_backend("gspmd", cfg, params, num_slots=1, max_len=MAX_LEN,
                     paged=True, page_size=PAGE, owner_base=-1)


# ---------------------------------------------------------------------------
# 4. analytics: the SLO interconnect term and the planner's decision rule
# ---------------------------------------------------------------------------


def test_predict_slo_handoff_term():
    cfg = get_config("llama32-3b")
    base = predict_slo(cfg, 16, 64, 2, 1)
    same = predict_slo(cfg, 16, 64, 2, 1, handoff_pages=0)
    assert same.ttft == base.ttft and same.e2e == base.e2e
    assert same.comm_volume == base.comm_volume
    assert "handoff_s" not in same.breakdown

    off = predict_slo(cfg, 16, 64, 2, 1, handoff_pages=16, page_size=16)
    want_bytes = sum(o.wire_bytes
                     for o in cm.kv_handoff_ops(cfg, 16, 16, b=2))
    assert off.breakdown["handoff_bytes"] == want_bytes
    assert off.comm_volume == base.comm_volume + want_bytes
    assert off.ttft == pytest.approx(base.ttft + off.breakdown["handoff_s"])
    # decode terms never move: the handoff happens before decode starts
    assert off.tpot == base.tpot
    with pytest.raises(ValueError):
        predict_slo(cfg, 16, 64, 2, 1, handoff_pages=-1)
    # rides through the hit_rate mix exactly once (linearity)
    mixed = predict_slo(cfg, 16, 64, 2, 1, hit_rate=0.5, hit_len=8,
                        handoff_pages=4)
    plain = predict_slo(cfg, 16, 64, 2, 1, hit_rate=0.5, hit_len=8)
    four = predict_slo(cfg, 16, 64, 2, 1, handoff_pages=4)
    assert mixed.ttft == pytest.approx(
        plain.ttft + (four.ttft - predict_slo(cfg, 16, 64, 2, 1).ttft))


def test_planner_disagg_decision_rule():
    """Prefill-heavy mixes rank a disagg split first; short-chat-only
    traffic keeps colocated (splitting only removes decode chips)."""
    cfg = get_config("llama32-3b")
    mixed = [TrafficClass("chat", 24, 128, 4.0),
             TrafficClass("summarize", 2048, 32, 0.6)]
    chat = [TrafficClass("chat", 24, 128, 4.0)]
    best_mixed = recommend_disagg(cfg, 8, mixed, objective="tpot")
    best_chat = recommend_disagg(cfg, 8, chat, objective="tpot")
    assert best_mixed.mode == "disagg"
    assert best_chat.mode == "colocated"
    # the disagg decode pool only ranks c == 1 layouts (§13 admission)
    cands = plan_disagg(cfg, 8, mixed, objective="tpot")
    assert all(c.decode_layout[1] == 1
               for c in cands if c.mode == "disagg")
    # every candidate's utilization is a feasible load
    assert all(c.utilization < 1.0 or c.score == float("inf")
               for c in cands)
    with pytest.raises(ValueError):
        plan_disagg(cfg, 8, [], objective="tpot")
    with pytest.raises(ValueError):
        TrafficClass("bad", 16, 16, 0.0)
    with pytest.raises(ValueError):
        plan_disagg(cfg, 8, chat, objective="bogus")
