"""KVPool block-allocator invariants (ISSUE 4 satellite; COW — ISSUE 9).

Deterministic unit tests always run; hypothesis drives randomized
alloc/extend/free/fork/adopt/handoff schedules against the same invariants
when the optional dep is present:

  * a page is never double-assigned (live tables are disjoint unless
    explicitly shared via ``fork`` / ``adopt``);
  * freed pages rejoin the free list and are reused;
  * ``stats()`` accounts for every page: free + allocated == num_pages,
    and shared pages count ONCE (physically) in the token columns;
  * copy-on-write (DESIGN.md §13): growing into a shared partial tail
    privatizes the page without ever mutating a sibling's committed rows —
    fingerprinted against a shadow device model replaying ``CowEvent``s —
    and a pool-oom mid-COW mutates nothing (no half-copied page leaks).
"""
import pytest

from repro.runtime.kvpool import CowEvent, KVPool, SCRATCH_PAGE


def _assert_invariants(pool: KVPool):
    stats = pool.stats()
    assert stats.free_pages + stats.allocated_pages == stats.num_pages
    # every live (owner, logical-page) mapping points at a non-scratch page,
    # and unshared pages appear in exactly one table
    seen = {}
    for owner in pool.owners():
        for pg in pool.block_table(owner):
            assert pg != SCRATCH_PAGE, f"owner {owner} maps scratch"
            assert 0 < pg < pool.num_pages
            seen.setdefault(pg, []).append(owner)
    for pg, owners in seen.items():
        assert pg not in pool._free, f"page {pg} live AND free"
        assert pool._refcount[pg] == len(owners)
    # per-owner capacity covers its length with < one page of slack
    for owner in pool.owners():
        cap = len(pool.block_table(owner)) * pool.page_size
        assert pool.length(owner) <= cap < pool.length(owner) + pool.page_size


# ---------------------------------------------------------------------------
# deterministic
# ---------------------------------------------------------------------------


def test_allocate_extend_free_roundtrip():
    pool = KVPool(num_pages=9, page_size=4)
    t0 = pool.allocate(0, 6)                 # 2 pages
    assert len(t0) == 2 and SCRATCH_PAGE not in t0
    t1 = pool.allocate(1, 4)                 # 1 page
    assert not set(t0) & set(t1), "double-assigned page"
    _assert_invariants(pool)
    # extend within the last page: no new page
    assert pool.extend(0, 8) == t0
    # crossing the boundary claims one more
    t0b = pool.extend(0, 9)
    assert len(t0b) == 3 and t0b[:2] == t0
    _assert_invariants(pool)
    pool.free(0)
    pool.free(1)
    assert pool.free_pages == 8              # all but scratch
    _assert_invariants(pool)


def test_freed_pages_are_reused():
    pool = KVPool(num_pages=4, page_size=2)   # 3 usable pages
    a = pool.allocate(0, 6)                  # takes all 3
    with pytest.raises(MemoryError):
        pool.allocate(1, 2)
    pool.free(0)
    b = pool.allocate(1, 6)
    assert sorted(a) == sorted(b), "freed pages not reused"
    # LIFO: the most recently freed page comes back first
    pool.free(1)
    last_freed = b[0]
    assert pool.allocate(2, 2) == [last_freed]


def test_double_allocate_and_shrink_rejected():
    pool = KVPool(num_pages=4, page_size=2)
    pool.allocate(0, 2)
    with pytest.raises(KeyError):
        pool.allocate(0, 2)
    with pytest.raises(ValueError):
        pool.extend(0, 1)
    with pytest.raises(ValueError):
        pool.allocate(1, 0)
    pool.free(7)                             # unknown owner: no-op
    _assert_invariants(pool)


def test_fork_shares_pages_refcounted():
    pool = KVPool(num_pages=6, page_size=4)
    t = pool.allocate(0, 8)
    assert pool.fork(0, 1) == t
    _assert_invariants(pool)
    pool.free(0)                             # pages stay live for owner 1
    assert pool.free_pages == 3
    assert pool.block_table(1) == t
    pool.free(1)
    assert pool.free_pages == 5
    _assert_invariants(pool)


def test_extend_into_shared_tail_page_cows():
    """Growing a forked sequence whose tail page is shared AND partial
    copy-on-writes it (DESIGN.md §13): a private page swaps into the
    grower's table, the sibling keeps the original, and a CowEvent names
    the committed rows the backend must replay; a page-aligned shared
    prefix grows onto fresh exclusive pages with no copy."""
    pool = KVPool(num_pages=8, page_size=4)
    pool.allocate(0, 6)                      # tail page half-full
    t0 = pool.fork(0, 1)
    t1 = pool.extend(1, 7)
    assert t1[0] == t0[0] and t1[-1] != t0[-1], "tail not privatized"
    assert pool.block_table(0) == t0, "sibling's table moved"
    assert pool.page_refcount(t0[-1]) == 1
    assert pool.take_cow_events() == [CowEvent(t0[-1], t1[-1], 2)]
    assert pool.take_cow_events() == [], "drain is not idempotent"
    assert pool.stats().cow_copies == 1
    _assert_invariants(pool)
    # exclusive partial tail (sibling gone): plain growth, no copy
    pool.free(0)
    assert len(pool.extend(1, 9)) == 3
    assert pool.take_cow_events() == []
    _assert_invariants(pool)
    # page-aligned fork: growth claims fresh pages, never touches shared
    pool2 = KVPool(num_pages=8, page_size=4)
    pool2.allocate(0, 8)
    pool2.fork(0, 1)
    t1 = pool2.extend(1, 9)
    assert t1[:2] == pool2.block_table(0) and len(t1) == 3
    assert pool2.take_cow_events() == []
    assert pool2.stats().cow_copies == 0
    _assert_invariants(pool2)


def test_cow_crossing_page_boundary_claims_both_atomically():
    """An extend that both COWs the tail AND grows past it claims every
    page in one step — the event's committed rows cover only the shared
    tail's occupancy, and the growth lands after the private copy."""
    pool = KVPool(num_pages=8, page_size=4)
    pool.allocate(0, 6)
    t0 = pool.block_table(0)
    pool.fork(0, 1)
    t1 = pool.extend(1, 12)                  # COW page + 1 growth page
    assert len(t1) == 3 and t1[0] == t0[0]
    assert t1[1] != t0[1] and t1[2] not in t0
    (ev,) = pool.take_cow_events()
    assert ev == CowEvent(t0[1], t1[1], 2)   # 6 - 4 committed tail rows
    _assert_invariants(pool)


def test_pool_oom_during_cow_mutates_nothing():
    """The scripted-fault case (ISSUE 9): COW needs a page the pool cannot
    supply — the MemoryError must leave tables, lengths, refcounts, the
    free list and the event log exactly as they were (the preemption
    ladder retries from a clean state, no half-copied page leaks)."""
    pool = KVPool(num_pages=3, page_size=4)  # 2 usable pages
    pool.allocate(0, 6)                      # takes both
    pool.fork(0, 1)
    before = (pool.stats(), pool.block_table(0), pool.block_table(1),
              pool.length(1), pool.free_pages)
    with pytest.raises(MemoryError):
        pool.extend(1, 7)                    # COW page unavailable
    after = (pool.stats(), pool.block_table(0), pool.block_table(1),
             pool.length(1), pool.free_pages)
    assert after == before, "failed COW mutated the pool"
    assert pool.take_cow_events() == [], "failed COW leaked an event"
    # freeing the sibling makes the SAME extend succeed copy-free
    pool.free(0)
    assert len(pool.extend(1, 7)) == 2
    assert pool.take_cow_events() == []
    _assert_invariants(pool)


def test_adopt_builds_owner_from_live_pages():
    """``adopt`` (the prefix index's cache-hit handoff) bumps refcounts on
    an explicit page list; dead pages, empty lists and ill-fitting token
    counts are rejected without mutation."""
    pool = KVPool(num_pages=8, page_size=4)
    t = pool.allocate(0, 8)
    assert pool.adopt(5, t, 7) == t          # partial-tail adoption ok
    assert pool.page_refcount(t[0]) == 2
    assert pool.length(5) == 7
    _assert_invariants(pool)
    pool.free(0)                             # adopter keeps the pages live
    assert pool.free_pages == 5
    with pytest.raises(KeyError):
        pool.adopt(5, t, 8)                  # live owner
    with pytest.raises(ValueError):
        pool.adopt(6, [], 1)
    with pytest.raises(ValueError):
        pool.adopt(6, t, 4)                  # 2 pages cannot hold 4 exactly
    with pytest.raises(ValueError):
        pool.adopt(6, [7], 2)                # page 7 is free, not live
    assert 6 not in pool.owners(), "rejected adopt left a partial owner"
    pool.free(5)
    assert pool.free_pages == 7
    _assert_invariants(pool)


def test_adopt_handoff_chain_refcounts():
    """The §14 handoff lifecycle on a shared pool: a prefill-pool owner
    allocates, the prefix index adopts (negative owner), the prefill slot
    frees — pages stay live through the index — then a decode-pool slot
    adopts the cached pages, the index evicts, and the decode free returns
    everything.  Zero pages leak at every stage."""
    pool = KVPool(num_pages=8, page_size=4)
    t = pool.allocate(4, 16)                 # prefill-pool owner range
    assert len(t) == 4 and pool.free_pages == 3
    assert pool.adopt(-1, t, 16) == t        # index holds the blocks
    pool.free(4)                             # prefill slot recycled
    assert pool.free_pages == 3, "index hold must keep pages live"
    assert all(pool.page_refcount(pg) == 1 for pg in t)
    assert pool.adopt(0, t, 16) == t         # decode-pool cache hit
    assert all(pool.page_refcount(pg) == 2 for pg in t)
    pool.free(-1)                            # index eviction under a hit
    assert pool.free_pages == 3, "held pages freed by eviction"
    assert pool.block_table(0) == t
    _assert_invariants(pool)
    pool.free(0)
    assert pool.free_pages == 7 and not pool.owners()
    _assert_invariants(pool)


def test_double_adopt_cow_isolates_siblings():
    """Two decode slots adopting the SAME index pages (a popular prefix)
    share them three ways; the first to decode past the partial tail
    copy-on-writes a private page while the sibling and the index keep the
    original table, and frees in any order keep live pages live."""
    pool = KVPool(num_pages=8, page_size=4)
    t = pool.allocate(4, 7)                  # 2 pages, partial tail
    pool.adopt(-1, t, 7)
    pool.free(4)
    pool.adopt(0, t, 7)
    pool.adopt(1, t, 7)                      # double adopt: refcount 3
    assert pool.page_refcount(t[-1]) == 3
    grown = pool.extend(0, 9)                # decode crosses the tail
    events = pool.take_cow_events()
    assert len(events) == 1 and events[0].src == t[-1]
    assert grown[1] != t[1] and grown[0] == t[0]
    assert pool.block_table(1) == t, "sibling table mutated by COW"
    assert pool.page_refcount(t[-1]) == 2    # index + sibling
    _assert_invariants(pool)
    pool.free(1)
    assert pool.page_refcount(t[-1]) == 1    # index alone
    pool.free(-1)
    pool.free(0)
    assert pool.free_pages == 7 and not pool.owners()
    _assert_invariants(pool)


def test_adopt_then_evict_keeps_holder_alive():
    """Index eviction (freeing the negative owner) while a decode slot
    still reads the pages must not recycle them: the holder's table stays
    intact and the pages only rejoin the free list on its own free."""
    pool = KVPool(num_pages=6, page_size=2)
    t = pool.allocate(3, 4)
    pool.adopt(-5, t, 4)
    pool.free(3)
    pool.adopt(0, t, 4)
    pool.free(-5)                            # evict under a live hit
    assert pool.block_table(0) == t
    assert all(pool.page_refcount(pg) == 1 for pg in t)
    # the evicted pages are NOT free — a fresh allocate cannot steal them
    fresh = pool.allocate(1, 6)
    assert not set(fresh) & set(t)
    _assert_invariants(pool)
    pool.free(0)
    pool.free(1)
    assert pool.free_pages == 5
    _assert_invariants(pool)


def test_stats_fragmentation_accounting():
    pool = KVPool(num_pages=8, page_size=4)
    pool.allocate(0, 5)                      # 2 pages, 3 slack
    pool.allocate(1, 4)                      # 1 page, 0 slack
    s = pool.stats()
    assert s.allocated_pages == 4            # 3 owned + scratch
    assert s.free_pages == 4
    assert s.used_tokens == 9
    assert s.internal_frag_tokens == 3
    assert s.capacity_tokens == 32
    assert 0 < s.utilization <= 1
    assert s.shared_pages == 0 and s.cow_copies == 0


def test_stats_count_shared_pages_once():
    """The ISSUE 9 bugfix: a page shared by k owners contributes its rows
    ONCE to ``used_tokens`` — the old per-owner sum double-counted every
    ref-shared page, pushing utilization past 1.0 under prefix sharing."""
    pool = KVPool(num_pages=4, page_size=4)  # 3 usable pages
    pool.allocate(0, 6)                      # 2 pages, 6 physical rows
    pool.fork(0, 1)
    pool.fork(0, 2, length=4)
    s = pool.stats()
    assert s.used_tokens == 6, "shared pages double-counted"
    assert s.shared_pages == 2
    assert s.internal_frag_tokens == 2
    assert s.utilization <= 1.0
    # owners reaching different depths into a shared page: deepest wins
    pool2 = KVPool(num_pages=4, page_size=4)
    pool2.allocate(0, 4)
    pool2.adopt(7, pool2.block_table(0), 2)  # shallower view, same page
    assert pool2.stats().used_tokens == 4
    assert pool2.stats().shared_pages == 1


def test_pool_too_small_rejected():
    with pytest.raises(ValueError):
        KVPool(num_pages=1, page_size=4)
    with pytest.raises(ValueError):
        KVPool(num_pages=4, page_size=0)


# ---------------------------------------------------------------------------
# randomized schedules (hypothesis, optional dep)
# ---------------------------------------------------------------------------


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYP = True
except ImportError:                           # pragma: no cover
    HAVE_HYP = False


if HAVE_HYP:
    op = st.tuples(st.sampled_from(["alloc", "extend", "free", "fork",
                                    "adopt", "handoff"]),
                   st.integers(0, 5), st.integers(1, 24))

    @given(ops=st.lists(op, min_size=1, max_size=60),
           num_pages=st.integers(2, 20), page_size=st.integers(1, 8))
    @settings(max_examples=60, deadline=None)
    def test_random_schedule_invariants(ops, num_pages, page_size):
        pool = KVPool(num_pages, page_size)
        for kind, owner, amount in ops:
            try:
                if kind == "alloc":
                    pool.allocate(owner, amount)
                elif kind == "extend":
                    pool.extend(owner, amount)
                elif kind == "fork":
                    pool.fork(owner, owner + 10)
                elif kind == "adopt":
                    pool.adopt(-(owner + 1), pool.block_table(owner),
                               pool.length(owner))
                elif kind == "handoff":
                    # §14 ship: a new owner adopts, the source slot frees
                    pool.adopt(owner + 20, pool.block_table(owner),
                               pool.length(owner))
                    pool.free(owner)
                else:
                    pool.free(owner)
            except (KeyError, ValueError, MemoryError):
                pass                          # rejected ops must not corrupt
            _assert_invariants(pool)
        for owner in list(pool.owners()):
            pool.free(owner)
        assert pool.free_pages == num_pages - 1
        assert pool.stats().used_tokens == 0

    @given(ops=st.lists(op, min_size=1, max_size=60),
           num_pages=st.integers(3, 24), page_size=st.integers(1, 8))
    @settings(max_examples=60, deadline=None)
    def test_cow_never_corrupts_sibling_rows(ops, num_pages, page_size):
        """The COW property suite (ISSUE 9): a shadow *device* model
        replays every ``CowEvent`` as a whole-page copy — exactly what the
        backends' ``_apply_cow`` does — and fingerprints every owner's
        committed rows against the values that owner (or its fork source)
        wrote.  Any schedule in which a grower's write lands in a page a
        sibling still reads, or a COW copies the wrong rows, shows up as a
        fingerprint mismatch; refcounts hitting zero at the wrong time
        show up through ``_assert_invariants``."""
        pool = KVPool(num_pages, page_size)
        device = {}    # physical page -> page_size rows of written values
        expect = {}    # owner -> committed values, logical order
        stamp = [0]    # globally unique write values

        def replay():
            for ev in pool.take_cow_events():
                device[ev.dst] = list(device[ev.src])

        def write(owner, start):
            table = pool.block_table(owner)
            for q in range(start, pool.length(owner)):
                stamp[0] += 1
                page = device.setdefault(table[q // page_size],
                                         [0] * page_size)
                page[q % page_size] = stamp[0]
                expect[owner].append(stamp[0])

        for kind, owner, amount in ops:
            try:
                if kind == "alloc":
                    pool.allocate(owner, amount)
                    expect[owner] = []
                    write(owner, 0)
                elif kind == "extend":
                    cur = pool.length(owner)
                    pool.extend(owner, cur + amount)
                    replay()                  # backend contract: copy THEN
                    write(owner, cur)         # write the new positions
                elif kind == "fork":
                    n = min(amount, pool.length(owner))
                    pool.fork(owner, owner + 10, length=n)
                    expect[owner + 10] = list(expect[owner][:n])
                elif kind == "adopt":
                    n = pool.length(owner)
                    pool.adopt(-(owner + 1), pool.block_table(owner), n)
                    expect[-(owner + 1)] = list(expect[owner])
                elif kind == "handoff":
                    n = pool.length(owner)
                    pool.adopt(owner + 20, pool.block_table(owner), n)
                    expect[owner + 20] = list(expect[owner])
                    pool.free(owner)
                    expect.pop(owner, None)
                else:
                    pool.free(owner)
                    expect.pop(owner, None)
            except (KeyError, ValueError, MemoryError):
                pass                          # rejected ops must not corrupt
            _assert_invariants(pool)
            for o in pool.owners():
                t = pool.block_table(o)
                got = [device[t[q // page_size]][q % page_size]
                       for q in range(pool.length(o))]
                assert got == expect[o], f"owner {o} rows corrupted"


# ---------------------------------------------------------------------------
# error paths (ISSUE 6 satellite): rejected ops must not corrupt the pool
# ---------------------------------------------------------------------------


def test_unknown_owner_errors():
    pool = KVPool(num_pages=4, page_size=2)
    pool.allocate(0, 2)
    with pytest.raises(KeyError):
        pool.extend(7, 4)                    # unknown owner cannot grow
    with pytest.raises(KeyError):
        pool.fork(7, 8)                      # unknown owner cannot be forked
    with pytest.raises(KeyError):
        pool.fork(0, 0)                      # fork onto a live owner
    with pytest.raises(KeyError):
        pool.block_table(7)
    _assert_invariants(pool)


def test_double_free_is_noop():
    """``free`` is idempotent by contract (the scheduler frees slots it may
    never have admitted into) — a double free must not re-free pages that
    another owner has since claimed."""
    pool = KVPool(num_pages=4, page_size=2)
    t0 = pool.allocate(0, 4)
    pool.free(0)
    t1 = pool.allocate(1, 4)                 # LIFO: reuses owner 0's pages
    assert sorted(t0) == sorted(t1)
    pool.free(0)                             # stale double free: no-op
    assert pool.block_table(1) == t1, "double free corrupted a live owner"
    assert pool.free_pages == 1
    _assert_invariants(pool)


def test_failed_claim_leaks_nothing():
    """``_claim`` checks capacity before popping a single page, so a failed
    allocate/extend rolls back to exactly the pre-call state."""
    pool = KVPool(num_pages=6, page_size=2)
    t0 = pool.allocate(0, 6)                 # 3 of 5 usable pages
    before = pool.stats()
    with pytest.raises(MemoryError):
        pool.allocate(1, 8)                  # needs 4, only 2 free
    assert pool.stats() == before, "failed allocate mutated the pool"
    assert 1 not in pool.owners(), "failed allocate left a partial owner"
    with pytest.raises(MemoryError):
        pool.extend(0, 12)                   # needs 3 more, only 2 free
    assert pool.stats() == before, "failed extend mutated the pool"
    assert pool.block_table(0) == t0
    assert pool.length(0) == 6, "failed extend changed the logical length"
    _assert_invariants(pool)
    # the pool is still fully usable after the failures
    pool.allocate(1, 4)
    pool.free(0)
    pool.free(1)
    assert pool.free_pages == 5
    _assert_invariants(pool)
