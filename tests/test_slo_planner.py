"""SLO-model reproduction of the paper's Figs 8–10 orderings + planner
recommendations (§V-C deployment guidance)."""
import pytest

from repro.configs import get_config
from repro.core.planner import feasible_layouts, plan, recommend
from repro.core.slo import predict_slo

L3 = get_config("llama32-3b")
L13 = get_config("llama2-13b")


class TestFig8TPScaling:
    def test_ttft_improves_with_tp(self):
        """Prefill is compute-bound: TTFT decreases TP2 → TP4 → TP8."""
        t2 = predict_slo(L3, 128, 128, t=2).ttft
        t4 = predict_slo(L3, 128, 128, t=4).ttft
        t8 = predict_slo(L3, 128, 128, t=8).ttft
        assert t2 > t4 > t8

    def test_tpot_degrades_cross_node(self):
        """TP=8 spans two nodes: decode becomes communication-bound."""
        t4 = predict_slo(L3, 128, 128, t=4)
        t8 = predict_slo(L3, 128, 128, t=8)
        assert t8.tpot > 3 * t4.tpot
        assert t8.e2e > t4.e2e

    def test_intra_node_scaling_helps(self):
        t2 = predict_slo(L3, 128, 128, t=2)
        t4 = predict_slo(L3, 128, 128, t=4)
        assert t4.tpot < t2.tpot and t4.e2e < t2.e2e


class TestFig9PPScaling:
    def test_ttft_grows_with_depth(self):
        vals = [predict_slo(L3, 128, 128, t=1, p=p).ttft for p in (2, 4, 8)]
        assert vals[0] < vals[1] < vals[2]

    def test_tpot_jumps_cross_node(self):
        p4 = predict_slo(L3, 128, 128, t=1, p=4)
        p8 = predict_slo(L3, 128, 128, t=1, p=8)
        assert p8.tpot > 2 * p4.tpot

    def test_pp_volume_beats_tp(self):
        tp = predict_slo(L3, 128, 128, t=4).comm_volume
        pp = predict_slo(L3, 128, 128, t=1, p=4).comm_volume
        assert pp < tp / 10


class TestFig10Hybrid:
    def test_tp8_optimal_for_13b(self):
        rows = {(t, p): predict_slo(L13, 128, 128, t=t, p=p)
                for t, p in ((8, 1), (1, 8), (2, 4), (4, 2))}
        best = min(rows, key=lambda k: rows[k].e2e)
        assert best == (8, 1)
        assert rows[(8, 1)].ttft < min(r.ttft for k, r in rows.items()
                                       if k != (8, 1)) / 3

    def test_pp8_moderate(self):
        pp8 = predict_slo(L13, 128, 128, t=1, p=8)
        tp8 = predict_slo(L13, 128, 128, t=8, p=1)
        assert pp8.comm_volume < tp8.comm_volume / 5
        assert pp8.ttft > tp8.ttft


class TestPlanner:
    def test_feasible_layouts_respect_divisibility(self):
        for t, p in feasible_layouts(L3, 8):
            assert L3.num_kv_heads % t == 0
            assert L3.num_layers % p == 0

    def test_short_sequence_prefers_tp(self):
        """Paper §V-C: interactive short-seq workloads ⇒ pure TP."""
        best = recommend(L13, 8, 128, 128, objective="ttft")
        assert best.pipeline_parallel == 1
        assert best.tensor_parallel == 8

    def test_volume_objective_prefers_pp(self):
        """Paper §V-C: bandwidth-constrained fabric ⇒ PP."""
        best = recommend(L13, 8, 128, 2048, objective="volume")
        assert best.tensor_parallel == 1
        assert best.pipeline_parallel == 8

    def test_volume_budget_excludes_tp(self):
        cands = plan(L13, 8, 128, 512, objective="e2e",
                     volume_budget=50 * 2**20)
        feasible = [c for c in cands if c.score != float("inf")]
        assert all(c.slo.comm_volume <= 50 * 2**20 for c in feasible)


class TestSLOSanity:
    @pytest.mark.parametrize("arch", ["llama32-3b", "llama2-13b",
                                      "granite-8b", "mixtral-8x22b"])
    def test_positive_and_ordered(self, arch):
        cfg = get_config(arch)
        r = predict_slo(cfg, 128, 128, t=4)
        assert 0 < r.ttft < 100 and 0 < r.tpot < 10
        assert r.e2e >= r.ttft

    def test_e2e_composition(self):
        r = predict_slo(L3, 128, 128, t=2)
        assert r.e2e == pytest.approx(r.ttft + 127 * r.tpot)
