"""SLO-model reproduction of the paper's Figs 8–10 orderings + planner
recommendations (§V-C deployment guidance)."""
import pytest

from repro.configs import get_config
from repro.core.planner import feasible_layouts, plan, recommend
from repro.core.slo import predict_slo

L3 = get_config("llama32-3b")
L13 = get_config("llama2-13b")


class TestFig8TPScaling:
    def test_ttft_improves_with_tp(self):
        """Prefill is compute-bound: TTFT decreases TP2 → TP4 → TP8."""
        t2 = predict_slo(L3, 128, 128, t=2).ttft
        t4 = predict_slo(L3, 128, 128, t=4).ttft
        t8 = predict_slo(L3, 128, 128, t=8).ttft
        assert t2 > t4 > t8

    def test_tpot_degrades_cross_node(self):
        """TP=8 spans two nodes: decode becomes communication-bound."""
        t4 = predict_slo(L3, 128, 128, t=4)
        t8 = predict_slo(L3, 128, 128, t=8)
        assert t8.tpot > 3 * t4.tpot
        assert t8.e2e > t4.e2e

    def test_intra_node_scaling_helps(self):
        t2 = predict_slo(L3, 128, 128, t=2)
        t4 = predict_slo(L3, 128, 128, t=4)
        assert t4.tpot < t2.tpot and t4.e2e < t2.e2e


class TestFig9PPScaling:
    def test_ttft_grows_with_depth(self):
        vals = [predict_slo(L3, 128, 128, t=1, p=p).ttft for p in (2, 4, 8)]
        assert vals[0] < vals[1] < vals[2]

    def test_tpot_jumps_cross_node(self):
        p4 = predict_slo(L3, 128, 128, t=1, p=4)
        p8 = predict_slo(L3, 128, 128, t=1, p=8)
        assert p8.tpot > 2 * p4.tpot

    def test_pp_volume_beats_tp(self):
        tp = predict_slo(L3, 128, 128, t=4).comm_volume
        pp = predict_slo(L3, 128, 128, t=1, p=4).comm_volume
        assert pp < tp / 10


class TestFig10Hybrid:
    def test_tp8_optimal_for_13b(self):
        rows = {(t, p): predict_slo(L13, 128, 128, t=t, p=p)
                for t, p in ((8, 1), (1, 8), (2, 4), (4, 2))}
        best = min(rows, key=lambda k: rows[k].e2e)
        assert best == (8, 1)
        assert rows[(8, 1)].ttft < min(r.ttft for k, r in rows.items()
                                       if k != (8, 1)) / 3

    def test_pp8_moderate(self):
        pp8 = predict_slo(L13, 128, 128, t=1, p=8)
        tp8 = predict_slo(L13, 128, 128, t=8, p=1)
        assert pp8.comm_volume < tp8.comm_volume / 5
        assert pp8.ttft > tp8.ttft


class TestPlanner:
    def test_feasible_layouts_respect_divisibility(self):
        for t, c, p in feasible_layouts(L3, 8):
            assert t * c * p == 8
            assert L3.num_kv_heads % t == 0
            assert p <= L3.num_layers

    def test_indivisible_layer_counts_are_feasible(self):
        """Satellite fix: PR 2's ``stage_layer_partition`` made p ∤ L legal
        in the engines (remainder spread over early stages), so the planner
        must enumerate those layouts — Llama-3.2-3B has 28 layers and p=8
        used to be silently excluded."""
        from repro.core.commodel import stage_layer_partition
        assert L3.num_layers == 28
        layouts = feasible_layouts(L3, 8)
        assert (1, 1, 8) in layouts                   # 28 % 8 != 0
        assert (2, 1, 4) in layouts
        for t, c, p in layouts:
            sizes = stage_layer_partition(L3.num_layers, p)
            assert sum(sizes) == L3.num_layers        # every layer assigned
            assert min(sizes) >= 1                    # no empty stage
        # a p > num_layers layout would leave empty stages: still rejected
        import dataclasses
        tiny = dataclasses.replace(L3, num_layers=4)
        assert all(p <= 4 for _, _, p in feasible_layouts(tiny, 8))
        # and the scored plan ranks the indivisible layout, not just lists it
        cands = plan(L3, 8, 128, 128, objective="e2e")
        assert any(c.pipeline_parallel == 8 for c in cands)

    def test_short_sequence_prefers_tp(self):
        """Paper §V-C: interactive short-seq workloads ⇒ pure TP — CP in
        the enumeration must NOT displace it (pure overhead at S_p=128)."""
        best = recommend(L13, 8, 128, 128, objective="ttft")
        assert best.pipeline_parallel == 1
        assert best.context_parallel == 1
        assert best.tensor_parallel == 8

    def test_long_prompt_prefers_cp(self):
        """arXiv:2408.10197 / DESIGN.md §9: a prefill-dominated long-prompt
        workload shards the sequence — the TTFT-best 8-chip layout carries
        c > 1 once the prompt is long enough."""
        best = recommend(L13, 8, 16384, 128, objective="ttft")
        assert best.context_parallel > 1
        assert best.pipeline_parallel == 1            # PP only hurts TTFT

    def test_volume_objective_prefers_pp_among_non_cp(self):
        """Paper §V-C: bandwidth-constrained fabric ⇒ PP.  With CP in the
        enumeration the global volume optimum may replicate decode over the
        cp axis (zero decode comm — a real 'prefill-sharded' config), but
        among the paper's own (t, p) plane PP=8 must stay volume-optimal
        and the overall winner can only improve on it."""
        cands = plan(L13, 8, 128, 2048, objective="volume")
        non_cp = [x for x in cands if x.context_parallel == 1]
        assert non_cp[0].tensor_parallel == 1
        assert non_cp[0].pipeline_parallel == 8
        assert cands[0].slo.comm_volume <= non_cp[0].slo.comm_volume

    def test_volume_budget_excludes_over_budget_with_cp(self):
        """Satellite: volume_budget still ranks over-budget layouts last
        with CP in the enumeration — every in-budget candidate (any c)
        respects the cap and precedes every over-budget one."""
        budget = 120 * 2**20
        cands = plan(L13, 8, 128, 512, objective="e2e",
                     volume_budget=budget)
        feasible = [x for x in cands if x.score != float("inf")]
        assert feasible, "some layout must fit the budget"
        assert all(x.slo.comm_volume <= budget for x in feasible)
        over = [x for x in cands if x.score == float("inf")]
        assert all(cands.index(f) < cands.index(o)
                   for f in feasible for o in over)


class TestCPScaling:
    """DESIGN.md §9 SLO guidance: CP wins TTFT on long prompts, is pure
    overhead on short ones, and never touches decode."""

    def test_ttft_improves_with_cp_on_long_prompts(self):
        vals = [predict_slo(L13, 8192, 128, t=2, c=c).ttft
                for c in (1, 2, 4, 8)]
        assert vals == sorted(vals, reverse=True)     # strictly improving
        assert vals[-1] < vals[0] / 3                 # and substantially

    def test_cp_is_overhead_on_short_prompts_at_fixed_chips(self):
        """At a fixed 8-chip budget and short prompts, trading TP degree
        for CP degree must not beat pure TP (the ring + extra allreduce
        buy nothing a bigger allreduce group didn't already)."""
        base = predict_slo(L13, 64, 128, t=8, c=1).ttft
        for t, c in ((4, 2), (2, 4), (1, 8)):
            assert predict_slo(L13, 64, 128, t=t, c=c).ttft >= base

    def test_decode_terms_independent_of_cp(self):
        for c in (2, 4):
            r1 = predict_slo(L13, 2048, 256, t=2, c=1)
            rc = predict_slo(L13, 2048, 256, t=2, c=c)
            assert rc.tpot == pytest.approx(r1.tpot)
            assert rc.breakdown["decode_comm_per_tok"] == pytest.approx(
                r1.breakdown["decode_comm_per_tok"])

    def test_ttft_monotone_in_cp_property(self):
        """Hypothesis sweep of the satellite claim: for long prompts, TTFT
        is non-increasing in the CP degree at a fixed TP degree."""
        pytest.importorskip("hypothesis")
        import hypothesis.strategies as st
        from hypothesis import given, settings

        @given(sp=st.integers(min_value=4096, max_value=65536),
               t=st.sampled_from([1, 2, 4]),
               ci=st.integers(min_value=0, max_value=2))
        @settings(max_examples=60, deadline=None)
        def check(sp, t, ci):
            c_lo, c_hi = (1, 2, 4)[ci], (2, 4, 8)[ci]
            lo = predict_slo(L13, sp, 128, t=t, c=c_lo).ttft
            hi = predict_slo(L13, sp, 128, t=t, c=c_hi).ttft
            assert hi <= lo + 1e-12

        check()


class TestSLOSanity:
    @pytest.mark.parametrize("arch", ["llama32-3b", "llama2-13b",
                                      "granite-8b", "mixtral-8x22b"])
    def test_positive_and_ordered(self, arch):
        cfg = get_config(arch)
        r = predict_slo(cfg, 128, 128, t=4)
        assert 0 < r.ttft < 100 and 0 < r.tpot < 10
        assert r.e2e >= r.ttft

    def test_e2e_composition(self):
        r = predict_slo(L3, 128, 128, t=2)
        assert r.e2e == pytest.approx(r.ttft + 127 * r.tpot)


class TestGoodput:
    """DESIGN.md §10: the recompute-tax goodput model behind the
    overload series of benchmarks/serving_bench.py."""

    def test_eos_heavy_mix_favors_optimistic(self):
        # requests commit 32 decode tokens but mostly stop after ~4:
        # conservative strands capacity on the unused reservation
        from repro.core.slo import predict_goodput
        kw = dict(num_slots=8, capacity_tokens=256, eos_mean=4.0)
        cons = predict_goodput(L3, 32, 32, admission="conservative", **kw)
        opt = predict_goodput(L3, 32, 32, admission="optimistic", **kw)
        assert cons.preempt_rate == 0.0
        assert opt.concurrency > cons.concurrency
        assert opt.goodput_tok_s >= cons.goodput_tok_s

    def test_full_budget_mix_favors_conservative(self):
        # every request decodes its whole budget: overcommit buys nothing
        # and the preemption tax makes optimistic strictly worse
        from repro.core.slo import predict_goodput
        kw = dict(num_slots=8, capacity_tokens=256)
        cons = predict_goodput(L3, 32, 32, admission="conservative", **kw)
        opt = predict_goodput(L3, 32, 32, admission="optimistic", **kw)
        assert opt.preempt_rate > 0.0
        assert cons.goodput_tok_s >= opt.goodput_tok_s

    def test_validation_and_zero_capacity(self):
        from repro.core.slo import predict_goodput
        with pytest.raises(ValueError, match="admission"):
            predict_goodput(L3, 32, 32, num_slots=4, capacity_tokens=256,
                            admission="yolo")
        with pytest.raises(ValueError, match="eos_mean"):
            predict_goodput(L3, 32, 32, num_slots=4, capacity_tokens=256,
                            eos_mean=0.0)
        r = predict_goodput(L3, 32, 32, num_slots=4, capacity_tokens=16)
        assert r.concurrency == 0 and r.goodput_tok_s == 0.0

    def test_recompute_time_is_a_frontendless_prefill(self):
        from repro.core.slo import (DEFAULT_OVERHEADS, predict_slo,
                                    recompute_time)
        rec = recompute_time(L3, 48, t=2)
        ttft = predict_slo(L3, 48, 1, t=2).ttft
        assert rec == pytest.approx(
            ttft - DEFAULT_OVERHEADS.request_overhead)
        assert recompute_time(L3, 96, t=2) > rec  # longer prefix, more work

    def test_recompute_ops_are_prefill_rows(self):
        from repro.core.commodel import comm_ops_for, preemption_recompute_ops
        ops = preemption_recompute_ops(L3, 40, 2, 2)
        full = comm_ops_for(L3, 40, 1, 2, 2)
        assert ops == [o for o in full if o.phase == "prefill"]
        assert ops and all(o.phase == "prefill" for o in ops)
