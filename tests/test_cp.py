"""Context parallelism: sequence-sharded prefill (ISSUE 5 acceptance).

1. Token identity: CP-sharded prefill through the scheduler is
   token-identical to the cp=1 path and to isolated serving on ragged
   traces at (t, c, p) ∈ {(1,2,1), (2,2,1), (1,2,2)} — contiguous slot
   caches AND paged pools (gather-into-slots / gather-into-pages handoff).
2. Counts: per-layer CP ring counts and bytes match
   ``commodel.cp_comm_ops``, the compiled HLO of the CP prefill (both
   unroll modes, scans trip-expanded), the per-stage prefill modules
   (``hybrid_stage_collectives(..., c, phase="prefill")``), and — for the
   PP hops — the measured TransferRecords at the [S/c, h/t] per-worker
   shard.
3. Decode is untouched: same per-step collective schedule and predictions
   at any c (CP is prefill-only, DESIGN.md §9).
4. Guards: gspmd rejects c>1, chunked prefill rejects c>1 backends,
   CP-padded prompts respect max_len.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core import parallel_exec as px
from repro.core.hlo_comm import parse_hlo_collectives, summarize
from repro.models import layers
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.engine import InferenceEngine
from repro.runtime.request import Request
from repro.runtime.scheduler import Scheduler, VirtualClock

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")
needs_pair = pytest.mark.skipif(len(jax.devices()) < 2,
                                reason="needs 2 host-platform devices")

MAX_LEN = 64
PAGE = 8

# (t, c, p) acceptance layouts; (1,2,1) runs on 2 devices, the rest on 4
LAYOUTS = [("tp", dict(t=1, c=2), 2),
           ("tp", dict(t=2, c=2), 4),
           ("pp", dict(t=1, c=2, p=2), 4)]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _ragged_requests(cfg):
    rng = np.random.default_rng(0)
    lens = [(7, 6), (11, 4), (5, 8), (9, 3)]   # odd lengths force padding
    return [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=n)
            for i, (s, n) in enumerate(lens)]


def _solo_reference(cfg, params, req):
    eng = InferenceEngine(cfg, params, max_len=MAX_LEN, decode_chunk=1)
    out = eng.generate(jnp.asarray(req.prompt)[None, :],
                       max_new_tokens=req.max_new_tokens)
    return np.asarray(out)[0].tolist()


def _count(ops, phase=None):
    counts = {}
    for o in ops:
        if phase in (None, o.phase):
            counts[o.collective] = counts.get(o.collective, 0) + o.count
    return counts


def _hlo_counts(hlo: str):
    return {k: v["count"]
            for k, v in summarize(parse_hlo_collectives(hlo)).items()}


# ---------------------------------------------------------------------------
# the ring primitive: assembly is bitwise, in absolute order
# ---------------------------------------------------------------------------


@needs_pair
def test_ring_kv_assemble_is_bitwise_and_ordered():
    """Every cp worker assembles the full [B, S, H, D] tensor, bitwise
    equal to the unsharded input, with blocks at their absolute offsets."""
    c = 2
    mesh = px.make_tp_cp_mesh(1, c)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((2, 8, 3, 4)), jnp.float32)

    # replicated out spec: each worker's assembled copy must equal the
    # unsharded input bitwise — blocks landed at their absolute offsets
    fn_full = shard_map(lambda b: layers.ring_kv_assemble(b, "cp", c),
                        mesh=mesh, in_specs=P(None, "cp"),
                        out_specs=P(None, None), check_rep=False)
    np.testing.assert_array_equal(np.asarray(jax.jit(fn_full)(k)),
                                  np.asarray(k))

    # per-worker view: worker w's own block of its assembled copy is the
    # input's rows [w*S/c, (w+1)*S/c) — absolute order, not arrival order
    def own_block(b):
        full = layers.ring_kv_assemble(b, "cp", c)
        idx = jax.lax.axis_index("cp")
        s_loc = b.shape[1]
        return jax.lax.dynamic_slice_in_dim(full, idx * s_loc, s_loc, axis=1)

    fn_own = shard_map(own_block, mesh=mesh, in_specs=P(None, "cp"),
                       out_specs=P(None, "cp"), check_rep=False)
    np.testing.assert_array_equal(np.asarray(jax.jit(fn_own)(k)),
                                  np.asarray(k))


@needs_pair
def test_block_level_cp_branch_matches_plain_attention(setup):
    """``blocks.dense_block_apply(cp_axis=...)`` — the block-level CP API
    — produces the same outputs and seeded cache as the unsharded block:
    the ring assembles K/V bitwise, so only the shard split differs."""
    from repro.models import blocks
    cfg, params = setup
    c = 2
    pl = {k: np.asarray(v[0]) for k, v in params["blocks"].items()}
    pl = {k: jnp.asarray(v) for k, v in pl.items()}
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(8), (2, 8))
    ref, ref_cache, _ = blocks.dense_block_apply(
        cfg, pl, x, positions, layers.make_mask(8, 8), build_cache_w=16)

    mesh = px.make_tp_cp_mesh(1, c)

    def fn(pl, x, positions):
        s_loc = x.shape[1]
        off = jax.lax.axis_index("cp") * s_loc
        mask = layers.make_mask(s_loc, c * s_loc, q_offset=off)
        y, cache, _ = blocks.dense_block_apply(
            cfg, pl, x, off + positions[:, :s_loc], mask,
            build_cache_w=16, cp_axis="cp", cp_size=c)
        return y, cache

    specs = jax.tree.map(lambda _: P(), pl)
    mapped = jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(specs, P(None, "cp"), P(None, None)),
        out_specs=(P(None, "cp"), {"k": P(), "v": P()}),
        check_rep=False))
    got, got_cache = mapped(pl, x, positions)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
    # the ring assembly itself is bitwise; the projection matmul on the
    # [S/c] shard tiles differently, leaving ~1e-7 noise in the cache
    for key in ("k", "v"):
        np.testing.assert_allclose(np.asarray(got_cache[key]),
                                   np.asarray(ref_cache[key]), atol=1e-5)


# ---------------------------------------------------------------------------
# analytical model: cp_comm_ops shapes and composition
# ---------------------------------------------------------------------------


def test_cp_comm_ops_counts_and_bytes(setup):
    cfg, _ = setup
    L, h = cfg.num_layers, cfg.d_model
    for s_p, c, t in [(8, 2, 1), (8, 2, 2), (128, 4, 2), (7, 2, 1)]:
        ops = cm.cp_comm_ops(cfg, s_p, c, t=t)
        ring = [o for o in ops if o.collective == "collectivepermute"][0]
        ar = [o for o in ops if o.collective == "allreduce"][0]
        shard = -(-s_p // c)
        assert ring.count == 2 * L * (c - 1)
        assert ring.shape == (shard, (cfg.num_kv_heads // t) * cfg.head_dim)
        assert ring.workers == c
        # ring hops are charged 1x wire (every rank ships its block)
        assert ring.wire_bytes == ring.total_msg_bytes
        assert ar.count == 1 and ar.shape == (1, h) and ar.workers == c
    assert cm.cp_comm_ops(cfg, 128, 1) == []


def test_comm_ops_for_composes_cp(setup):
    """c>1 shrinks the TP/PP prefill rows to the ceil(s_p/c) shard, adds
    the ring rows, and leaves every decode row untouched."""
    cfg, _ = setup
    base = cm.comm_ops_for(cfg, 4, 5, 2, 2, gather_mode="allgather")
    with_cp = cm.comm_ops_for(cfg, 8, 5, 2, 2, c=2,
                              gather_mode="allgather")
    dec = [o for o in base if o.phase == "decode"]
    dec_cp = [o for o in with_cp if o.phase == "decode"]
    assert dec == dec_cp
    # prefill TP rows at s_p=8, c=2 == the c=1 rows at s_p=4
    pre = [o for o in base if o.phase == "prefill"]
    pre_cp = [o for o in with_cp if o.phase == "prefill"
              if o.collective not in ("collectivepermute",)
              and not (o.collective == "allreduce" and o.workers == 2
                       and o.shape == (1, cfg.d_model))]
    assert pre == pre_cp


# ---------------------------------------------------------------------------
# acceptance 1: CP token-identical to cp=1 and solo on ragged traces
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,kw,ndev", LAYOUTS)
@pytest.mark.parametrize("paged", [False, True])
def test_cp_token_identity_on_ragged_traces(setup, kind, kw, ndev, paged):
    if len(jax.devices()) < ndev:
        pytest.skip(f"needs {ndev} host-platform devices")
    cfg, params = setup
    reqs = _ragged_requests(cfg)
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    backend = make_backend(kind, cfg, params, num_slots=2, max_len=MAX_LEN,
                           paged=paged, page_size=PAGE, **kw)
    got = Scheduler(backend, clock=VirtualClock()).run(
        _ragged_requests(cfg)).tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid], \
            f"cp {kind}{kw} paged={paged}: request {r.rid} diverged"
    if paged:
        # every page returned: the padded prefill stayed inside its slot's
        # own pages and eviction freed them all
        assert backend.pool.stats().used_tokens == 0
        assert backend.pool.free_pages == backend.pool.num_pages - 1


@needs_mesh
def test_cp_engine_generate_matches_tp_engine(setup):
    """Engine level, no scheduler: (2,2,1) cp prefill + fused decode equals
    the plain t=2 engine token for token, both unroll modes."""
    cfg, params = setup
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 2,
                              cfg.vocab_size)
    mesh_ref = px.make_tp_mesh(2)
    logits, cache = px.tp_prefill(cfg, mesh_ref, cache_w=32)(params, toks)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    ref, _ = px.tp_generate(cfg, mesh_ref, 5)(params, cache, tok0,
                                              jnp.int32(8))
    for unroll in (True, False):
        mesh = px.make_tp_cp_mesh(2, 2)
        lg, cc = px.cp_prefill(cfg, mesh, cache_w=32,
                               unroll=unroll)(params, toks, jnp.int32(7))
        np.testing.assert_array_equal(np.asarray(jnp.argmax(lg, -1)),
                                      np.asarray(tok0))
        # decode on the SAME (tp, cp) mesh consumes the cp-seeded cache;
        # feed the token as an uncommitted host array (tok0 lives on the
        # 2-device reference mesh)
        out, _ = px.tp_generate(cfg, mesh, 5)(params, cc,
                                              np.asarray(tok0),
                                              jnp.int32(8))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# acceptance 2: ring counts/bytes == commodel == compiled HLO == measured
# ---------------------------------------------------------------------------


@needs_pair
@pytest.mark.parametrize("t,ndev", [(1, 2), (2, 4)])
def test_cp_prefill_hlo_matches_commodel(setup, t, ndev):
    """(1,2,1)/(2,2,1): the CP prefill module shows exactly the predicted
    schedule — ring permutes + cp allreduce (+ TP rows at the shard) —
    with matching message bytes, in both unroll modes."""
    if len(jax.devices()) < ndev:
        pytest.skip(f"needs {ndev} host-platform devices")
    cfg, params = setup
    c, s_p = 2, 8
    backend = make_backend("tp", cfg, params, num_slots=2, max_len=MAX_LEN,
                           t=t, c=c)
    want_ops = backend.prefill_comm_ops(s_p)
    want = _count(want_ops)
    for unroll in (True, False):
        fn = px.cp_prefill(cfg, backend.mesh, cache_w=backend.cache_w,
                           unroll=unroll)
        hlo = fn.lower(params, jax.ShapeDtypeStruct((1, s_p), jnp.int32),
                       jax.ShapeDtypeStruct((), jnp.int32)) \
                .compile().as_text()
        colls = parse_hlo_collectives(hlo)
        assert _hlo_counts(hlo) == want, (t, unroll)
        # ring bytes: HLO permutes move exactly the predicted KV blocks
        # (f32 host platform — predict at b=4)
        pred_ring = [o for o in cm.cp_comm_ops(cfg, s_p, c, t=t, b=4)
                     if o.collective == "collectivepermute"][0]
        got_ring = [x for x in colls if x.kind == "collectivepermute"]
        assert sum(x.total_bytes for x in got_ring) == \
            pred_ring.total_msg_bytes
        assert sum(x.wire_bytes for x in got_ring) == pred_ring.wire_bytes
    # the backend's own prefill_hlo agrees
    assert _hlo_counts(backend.prefill_hlo(s_p)) == want


@needs_mesh
def test_cp_pp_stage_hlo_and_measured_transfers(setup):
    """(1,2,2): per-stage prefill HLO == hybrid_stage_collectives(c=2,
    phase="prefill"); decode stages stay collective-free; the boundary
    hop measured by TransferRecords carries the [S/c, h/t] per-worker
    shard commodel predicts."""
    cfg, params = setup
    t, c, p = 1, 2, 2
    backend = make_backend("pp", cfg, params, num_slots=2, max_len=MAX_LEN,
                           t=t, c=c, p=p)
    toks = jnp.zeros((1, 8), jnp.int32)
    for s in range(p):
        hlo = backend.engine.stage_hlo(backend.staged, toks, s)
        assert _hlo_counts(hlo) == cm.hybrid_stage_collectives(
            cfg, t, p, s, c=c, phase="prefill"), s
        # decode modules: replicated over cp, still zero collectives
        dec = backend.stage_decode_hlo(s)
        assert parse_hlo_collectives(dec) == []

    reqs = _ragged_requests(cfg)
    backend.drain_transfers()
    Scheduler(backend, clock=VirtualClock()).run(reqs)
    # replay: per request one prefill with (p-1)·2 hops of the padded
    # [1, ceil(s_p/c), h/t] pair — phase-filtered engine log
    want_count = sum((p - 1) * 2 for _ in reqs)
    want_bytes = sum(
        [o for o in backend.prefill_comm_ops(r.prompt_len)
         if o.collective == "send"][0].total_msg_bytes
        for r in reqs)
    got = backend.engine.transfer_summary(phase="prefill")
    assert got["count"] == want_count
    assert got["bytes"] == want_bytes


@needs_pair
@pytest.mark.parametrize("kind,kw,ndev", LAYOUTS)
def test_cp_decode_schedule_unchanged(setup, kind, kw, ndev):
    """CP is prefill-only: the decode step's predicted ops equal the c=1
    backend's, and (for the TP kinds) the compiled decode module shows the
    c=1 schedule."""
    if len(jax.devices()) < ndev:
        pytest.skip(f"needs {ndev} host-platform devices")
    cfg, params = setup
    backend = make_backend(kind, cfg, params, num_slots=2, max_len=MAX_LEN,
                           paged=False, **kw)
    base_kw = dict(kw)
    base_kw["c"] = 1
    if kind == "tp" and base_kw.get("t", 1) < 2:
        base_kw["t"] = 2            # tp kind needs a non-degenerate layout
        if len(jax.devices()) < 2:
            pytest.skip("needs 2 devices")
    base = make_backend(kind, cfg, params, num_slots=2, max_len=MAX_LEN,
                        **base_kw)
    if kw.get("t", 1) == base_kw.get("t", 1):
        assert _count(backend.decode_comm_ops()) == \
            _count(base.decode_comm_ops())
    if kind == "tp":
        want = ({"allreduce": 2 * cfg.num_layers + 1, "allgather": 1}
                if kw.get("t", 1) > 1 else {})
        assert _hlo_counts(backend.decode_step_hlo()) == want


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------


def test_cp_guards(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="explicit engines"):
        make_backend("gspmd", cfg, params, num_slots=2, c=2)
    with pytest.raises(ValueError, match="t >= 2 or c >= 2"):
        make_backend("tp", cfg, params, num_slots=2, t=1, c=1)


@needs_pair
def test_cp_rejects_chunked_prefill(setup):
    cfg, params = setup
    backend = make_backend("tp", cfg, params, num_slots=2, max_len=MAX_LEN,
                           t=1, c=2, paged=True, page_size=PAGE)
    with pytest.raises(ValueError, match="alternative"):
        Scheduler(backend, clock=VirtualClock(), chunk_size=4)


@needs_pair
def test_cp_sliding_window_serves_past_max_len(setup):
    """A sliding-window model serves prompts beyond max_len (the ring
    cache keeps the last W positions) — the CP padding guard must honor
    the same waiver the scheduler's admission check grants, and stay
    token-identical to the c=1 path."""
    import dataclasses
    cfg, _ = setup
    swa = dataclasses.replace(cfg, sliding_window=16)
    params = get_model(swa).init(jax.random.PRNGKey(0))
    req = Request(rid=0,
                  prompt=np.random.default_rng(3).integers(
                      2, swa.vocab_size, 41).astype(np.int32),
                  max_new_tokens=3)
    ref = make_backend("tp", swa, params, num_slots=1, max_len=32, t=2)
    want = Scheduler(ref, clock=VirtualClock()).run(
        [dataclasses.replace(req)]).tokens_by_rid()[0]
    cp = make_backend("tp", swa, params, num_slots=1, max_len=32, t=1, c=2)
    got = Scheduler(cp, clock=VirtualClock()).run(
        [dataclasses.replace(req)]).tokens_by_rid()[0]
    assert got == want


@needs_pair
def test_cp_padded_prompt_respects_max_len(setup):
    cfg, params = setup
    backend = make_backend("tp", cfg, params, num_slots=1, max_len=8,
                           t=1, c=2)
    sched = Scheduler(backend, clock=VirtualClock())
    # 7-token prompt pads to 8; with max_new_tokens=2 the cache needs
    # max(7+1, 8) = 8 positions — exactly fits
    sched.run([Request(rid=0, prompt=np.arange(2, 9, dtype=np.int32),
                       max_new_tokens=2)])
    with pytest.raises(ValueError, match="cache positions"):
        sched.submit(Request(rid=1, prompt=np.arange(2, 10, dtype=np.int32),
                             max_new_tokens=2))
