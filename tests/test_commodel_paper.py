"""Paper parity: the analytical comm model must reproduce the published
numbers in Tables III–VI and the scaling behavior of Figs 6–7 EXACTLY."""
import pytest

from repro.configs import get_config
from repro.core import commodel as cm


def _by(ops, coll, phase):
    return [o for o in ops if o.collective == coll and o.phase == phase]


class TestTable3TensorParallel:
    """Llama-3.1-8B, S_p = S_d = 128, TP ∈ {2, 4}."""

    @pytest.mark.parametrize("t", [2, 4])
    def test_counts_and_shapes(self, t):
        cfg = get_config("llama31-8b")
        ops = cm.tp_comm_ops(cfg, 128, 128, t)
        ar_p = _by(ops, "allreduce", "prefill")[0]
        assert ar_p.count == 65                      # 2·32 + 1
        assert ar_p.shape == (128, 4096)
        ar_d = _by(ops, "allreduce", "decode")[0]
        assert ar_d.count == 8255                    # 65 · 127
        assert ar_d.shape == (1, 4096)
        g_p = _by(ops, "gather", "prefill")[0]
        assert g_p.count == 1
        assert g_p.shape == (128256 // t,)           # [64128] at TP=2
        g_d = _by(ops, "gather", "decode")[0]
        assert g_d.count == 127

    def test_tp_invariance(self):
        """Varying TP degree must not change allreduce counts/sizes."""
        cfg = get_config("llama31-8b")
        for t in (2, 4, 8):
            ops = cm.tp_comm_ops(cfg, 128, 128, t)
            ar = _by(ops, "allreduce", "decode")[0]
            assert (ar.count, ar.msg_bytes) == (8255, 4096 * 2)


class TestTable4AllreduceAcrossModels:
    """Allreduce message size & count for 3.2-3B / 3.1-8B / 2-13B."""

    # (arch, prefill msg bytes, decode msg bytes, prefill count, decode count)
    ROWS = [
        ("llama32-3b", 786432, 6144, 57, 7239),
        ("llama31-8b", 1048576, 8192, 65, 8255),
        ("llama2-13b", 1310720, 10240, 81, 10287),
    ]

    @pytest.mark.parametrize("arch,pb,db,pc,dc", ROWS)
    def test_row(self, arch, pb, db, pc, dc):
        ops = cm.tp_comm_ops(get_config(arch), 128, 128, 4)
        ar_p = _by(ops, "allreduce", "prefill")[0]
        ar_d = _by(ops, "allreduce", "decode")[0]
        assert (ar_p.msg_bytes, ar_p.count) == (pb, pc)
        assert (ar_d.msg_bytes, ar_d.count) == (db, dc)


class TestTable5PipelineParallel:
    """Llama-3.1-8B send/recv counts across PP degrees."""

    @pytest.mark.parametrize("p,pre,dec", [(2, 2, 254), (4, 6, 762)])
    def test_counts(self, p, pre, dec):
        ops = cm.pp_comm_ops(get_config("llama31-8b"), 128, 128, p)
        for direction in ("send", "recv"):
            dp = _by(ops, direction, "prefill")[0]
            dd = _by(ops, direction, "decode")[0]
            assert dp.count == pre                   # (p-1)·2
            assert dd.count == dec                   # (p-1)·2·127
            assert dp.shape == (128, 4096)
            assert dd.shape == (1, 4096)

    def test_recv_not_double_charged(self):
        """Eq. 2 charges each link's bytes once (sends)."""
        cfg = get_config("llama31-8b")
        ops = cm.pp_comm_ops(cfg, 128, 128, 2)
        assert cm.total_volume(ops) == pytest.approx(cm.v_pp(cfg, 128, 128, 2))


class TestTable6Hybrid:
    """Llama-3.1-8B, TP=2 × PP=2."""

    def test_counts_and_shapes(self):
        ops = cm.hybrid_comm_ops(get_config("llama31-8b"), 128, 128, 2, 2)
        ar_p = _by(ops, "allreduce", "prefill")[0]
        assert ar_p.count == 33                      # 2·32/2 + 1
        assert ar_p.shape == (128, 4096)
        assert _by(ops, "allreduce", "decode")[0].count == 4191   # 33·127
        assert _by(ops, "allgather", "prefill")[0].count == 2     # 2(p-1)
        assert _by(ops, "allgather", "decode")[0].count == 254
        assert _by(ops, "gather", "prefill")[0].count == 1
        assert _by(ops, "gather", "decode")[0].count == 127
        s_p = _by(ops, "send", "prefill")[0]
        assert s_p.count == 2
        assert s_p.shape == (128, 2048)              # [S_p, h/t]
        assert _by(ops, "send", "decode")[0].count == 254


class TestClosedForms:
    """Op-level sums must equal the paper's closed-form equations."""

    @pytest.mark.parametrize("arch", ["llama32-3b", "llama31-8b", "llama2-13b"])
    @pytest.mark.parametrize("sp,sd", [(128, 128), (128, 512), (512, 128)])
    def test_eq1_tp(self, arch, sp, sd):
        cfg = get_config(arch)
        for t in (2, 4, 8):
            ops = cm.tp_comm_ops(cfg, sp, sd, t)
            assert cm.total_volume(ops) == pytest.approx(
                cm.v_tp(cfg, sp, sd, t), rel=1e-12)

    @pytest.mark.parametrize("p", [2, 4, 8])
    def test_eq2_pp(self, p):
        cfg = get_config("llama31-8b")
        ops = cm.pp_comm_ops(cfg, 128, 256, p)
        assert cm.total_volume(ops) == pytest.approx(cm.v_pp(cfg, 128, 256, p))

    @pytest.mark.parametrize("t,p", [(2, 2), (2, 4), (4, 2)])
    def test_eq3to7_hybrid(self, t, p):
        cfg = get_config("llama31-8b")
        ops = cm.hybrid_comm_ops(cfg, 128, 128, t, p)
        comp = cm.v_hybrid_components(cfg, 128, 128, t, p)
        got = {
            "allreduce": sum(o.wire_bytes for o in ops
                             if o.collective == "allreduce"),
            "allgather": sum(o.wire_bytes for o in ops
                             if o.collective == "allgather"),
            "gather": sum(o.wire_bytes for o in ops
                          if o.collective == "gather"),
            "p2p": sum(o.wire_bytes for o in ops
                       if o.collective in ("send", "recv")),
        }
        for k in comp:
            assert got[k] == pytest.approx(comp[k], rel=1e-12), k


class TestFig7Scaling:
    """Decode-length scaling: ~1.50× for 128→256 and ~1.67× for 256→512."""

    def test_growth_factors(self):
        cfg = get_config("llama31-8b")
        v = {sd: cm.v_tp(cfg, 128, sd, 4) for sd in (128, 256, 512)}
        # paper quotes 1.50× / 1.67× (the (S_p+S_d-1) term alone); the gather
        # term nudges the exact totals to 1.52 / 1.69
        assert v[256] / v[128] == pytest.approx(1.50, abs=0.03)
        assert v[512] / v[256] == pytest.approx(1.67, abs=0.03)

    def test_fig6_ordering(self):
        """PP=4 lowest volume, TP=4 highest, hybrid in between (Fig 6)."""
        for arch in ("llama32-3b", "llama31-8b", "llama2-13b"):
            cfg = get_config(arch)
            v_tp = cm.v_tp(cfg, 128, 128, 4)
            v_pp = cm.v_pp(cfg, 128, 128, 4)
            v_hy = cm.v_hybrid(cfg, 128, 128, 2, 2)
            assert v_pp < v_hy < v_tp

    def test_decode_dominates(self):
        """The decode stage generates 127× more ops than prefill (paper §V-A)."""
        ops = cm.tp_comm_ops(get_config("llama31-8b"), 128, 128, 4)
        n_pre = sum(o.count for o in ops if o.phase == "prefill")
        n_dec = sum(o.count for o in ops if o.phase == "decode")
        assert n_dec == 127 * n_pre
