"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
variant (2 layers, d_model ≤ 256, ≤ 4 experts) and runs one forward and one
train step on CPU, asserting output shapes and finiteness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import get_model
from repro.optim.adamw import AdamW
from repro.runtime.train import make_train_step

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("llama")]


def _batch_for(cfg, rng, B=2, S=32):
    if cfg.family == "encoder":
        return {
            "features": jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model)), jnp.float32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
        }
    batch = {"tokens": jnp.asarray(
        rng.integers(2, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["prefix_emb"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_prefix_tokens, cfg.d_model)),
            jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch_for(cfg, rng)
    if cfg.family == "encoder":
        logits, aux = model.forward(params, features=batch["features"])
        assert logits.shape == (2, 32, cfg.vocab_size)
    elif cfg.family == "vlm":
        logits, aux = model.forward(params, batch["tokens"],
                                    prefix_emb=batch["prefix_emb"])
        assert logits.shape == (2, 32 + cfg.num_prefix_tokens,
                                cfg.vocab_size)
    else:
        logits, aux = model.forward(params, batch["tokens"])
        assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW()
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch_for(cfg, np.random.default_rng(1))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: NaN loss"
    assert bool(jnp.isfinite(metrics["grad_norm"])), f"{arch}: NaN grads"
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if get_config(a).is_decoder])
def test_decode_matches_forward(arch):
    """Prefill + stepwise decode must reproduce teacher-forced logits."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    B, S, P = 2, 12, 8
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (B, S)), jnp.int32)
    kw = {}
    npre = 0
    if cfg.family == "vlm":
        kw["prefix_emb"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_prefix_tokens, cfg.d_model)),
            jnp.float32) * 0.02
        npre = cfg.num_prefix_tokens
    full, _ = model.forward(params, toks, **kw)
    last, cache, _ = model.prefill(params, toks[:, :P],
                                   max_len=S + npre + 4, **kw)
    errs = [np.abs(np.asarray(last) - np.asarray(full[:, npre + P - 1])).max()]
    pos = P + npre
    for t in range(P, S):
        logits, cache = model.decode_step(params, cache, toks[:, t], pos)
        errs.append(
            np.abs(np.asarray(logits) - np.asarray(full[:, npre + t])).max())
        pos += 1
    assert max(errs) < 5e-3, f"{arch}: decode diverges ({max(errs):.2e})"
