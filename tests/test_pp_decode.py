"""PP/hybrid decode subsystem: per-stage KV caches, pipelined generation,
layer-partition and logit-mask regressions, and measured-vs-predicted decode
communication parity (Eq. 2 / Table V decode rows, per-stage HLO counts)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core import parallel_exec as px
from repro.core.hlo_comm import parse_hlo_collectives, summarize
from repro.models.transformer import get_model
from repro.runtime.engine import InferenceEngine

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")

B, S_P, N_GEN = 2, 8, 5


def _setup(num_layers=4):
    cfg = get_config("llama32-3b").reduced(num_layers=num_layers)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_P), 2,
                              cfg.vocab_size)
    return cfg, params, toks


# ---------------------------------------------------------------------------
# satellite: uneven layer partition
# ---------------------------------------------------------------------------


def test_stage_layer_partition_covers_all_layers():
    """Indivisible layer counts must not silently drop layers (28 @ p=8
    used to run only 24)."""
    for L, p in [(28, 8), (5, 2), (7, 3), (9, 4), (32, 8)]:
        sizes = cm.stage_layer_partition(L, p)
        assert sum(sizes) == L
        assert max(sizes) - min(sizes) <= 1
        assert sizes == sorted(sizes, reverse=True)   # remainder goes early
    cfg = get_config("llama32-3b").reduced(num_layers=28)
    ranges = [px.stage_layer_range(cfg, 8, s) for s in range(8)]
    assert ranges[0] == (0, 4)
    assert ranges[-1] == (25, 28)
    for (lo, hi), (lo2, _) in zip(ranges, ranges[1:]):
        assert hi == lo2                              # contiguous cover
    assert ranges[-1][1] == 28


@needs_mesh
def test_uneven_layer_split_forward_matches_single_stage():
    """Regression: p=2/p=3 over 5 layers must equal the single-stage run
    (the old L//p split executed only 4 of the 5 layers)."""
    cfg, params, toks = _setup(num_layers=5)
    ref_eng = px.PipelineEngine(cfg, t=1, p=1)
    ref = np.asarray(ref_eng.forward(ref_eng.prepare(params), toks))
    for p in (2, 3):
        eng = px.PipelineEngine(cfg, t=1, p=p)
        out = np.asarray(eng.forward(eng.prepare(params), toks))
        np.testing.assert_allclose(ref, out, atol=2e-4)


# ---------------------------------------------------------------------------
# satellite: pad-vocab logit mask dtype
# ---------------------------------------------------------------------------


def test_pad_logit_mask_keeps_bf16_dtype():
    """Masking pad-vocab columns must not promote bf16 logits to f32 (nor
    overflow to -inf): the mask value is finfo(logits.dtype).min."""
    cfg = dataclasses.replace(get_config("llama32-3b").reduced(),
                              vocab_size=500, dtype="bfloat16")
    assert cfg.padded_vocab == 512                    # masking active
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_P), 2,
                              cfg.vocab_size)
    logits, _, _ = model.prefill(params, toks, max_len=32)
    assert logits.dtype == jnp.bfloat16
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert (np.asarray(jnp.argmax(logits, -1)) < cfg.vocab_size).all()


@needs_mesh
def test_pad_logit_mask_keeps_bf16_dtype_explicit_engines():
    cfg = dataclasses.replace(get_config("llama32-3b").reduced(),
                              vocab_size=500, dtype="bfloat16")
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_P), 2,
                              cfg.vocab_size)
    logits, _ = px.tp_prefill(cfg, px.make_tp_mesh(4))(params, toks)
    assert logits.dtype == jnp.bfloat16
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    eng = px.PipelineEngine(cfg, t=1, p=2)            # dense last-stage head
    out = eng.forward(eng.prepare(params), toks)
    assert out.dtype == jnp.bfloat16
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())


# ---------------------------------------------------------------------------
# tentpole: decode parity across engines
# ---------------------------------------------------------------------------


@needs_mesh
@pytest.mark.parametrize("unroll", [True, False])
@pytest.mark.parametrize("t,p", [(1, 2), (2, 2)])
def test_pipeline_generate_matches_tp_and_inference_engine(t, p, unroll):
    """Greedy tokens from PP/hybrid generate == TP engine == InferenceEngine
    on the same params (ISSUE decode-parity criterion)."""
    cfg, params, toks = _setup()
    mesh = px.make_tp_mesh(4)
    logits, cache = px.tp_prefill(cfg, mesh, cache_w=32,
                                  unroll=True)(params, toks)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    ref, _ = px.tp_generate(cfg, mesh, N_GEN)(params, cache, tok0,
                                              jnp.int32(S_P))
    ref = np.asarray(ref)

    ie = InferenceEngine(cfg, params, max_len=64, decode_chunk=1)
    ie_out = np.asarray(ie.generate(toks, max_new_tokens=N_GEN + 1))
    np.testing.assert_array_equal(ie_out[:, 0], np.asarray(tok0))
    np.testing.assert_array_equal(ie_out[:, 1:], ref)

    eng = px.PipelineEngine(cfg, t=t, p=p, unroll=unroll)
    staged = eng.prepare(params)
    lg, caches = eng.prefill_with_cache(staged, toks, cache_w=32)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lg, -1)),
                                  np.asarray(tok0))
    out, _ = eng.generate(staged, caches, tok0, S_P, N_GEN)
    np.testing.assert_array_equal(np.asarray(out), ref)


@needs_mesh
def test_pipeline_generate_uneven_layers():
    """Decode over an indivisible layer split stays token-identical to the
    fused TP path (all 5 layers' caches exercised)."""
    cfg, params, toks = _setup(num_layers=5)
    mesh = px.make_tp_mesh(4)
    logits, cache = px.tp_prefill(cfg, mesh, cache_w=32,
                                  unroll=True)(params, toks)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    ref, _ = px.tp_generate(cfg, mesh, N_GEN)(params, cache, tok0,
                                              jnp.int32(S_P))
    eng = px.PipelineEngine(cfg, t=2, p=2, unroll=False)
    staged = eng.prepare(params)
    _, caches = eng.prefill_with_cache(staged, toks, cache_w=32)
    out, _ = eng.generate(staged, caches, tok0, S_P, N_GEN)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@needs_mesh
def test_pipeline_decode_cache_donated_on_fast_path():
    cfg, params, toks = _setup()
    eng = px.PipelineEngine(cfg, t=1, p=2, unroll=False)
    staged = eng.prepare(params)
    logits, caches = eng.prefill_with_cache(staged, toks, cache_w=32)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    _, new_caches = eng.decode_once(staged, caches, tok0, S_P)
    assert all(c["k"].is_deleted() and c["v"].is_deleted() for c in caches)
    shapes = [c["k"].shape for c in new_caches]
    assert shapes == [(2, B, 32, cfg.num_kv_heads, cfg.head_dim)] * 2


# ---------------------------------------------------------------------------
# tentpole: measured decode communication == analytical predictions
# ---------------------------------------------------------------------------

LAYOUTS = [(1, 2), (1, 4), (2, 2)]


@needs_mesh
@pytest.mark.parametrize("t,p", LAYOUTS)
def test_decode_transfers_match_comm_model(t, p):
    """TransferRecords logged by generate == pp/hybrid_comm_ops decode send
    rows: count (p-1)·2·(s_d-1) and exact bytes (f32 host platform, b=4)."""
    cfg, params, toks = _setup()
    eng = px.PipelineEngine(cfg, t=t, p=p, unroll=False)
    staged = eng.prepare(params)
    logits, caches = eng.prefill_with_cache(staged, toks, cache_w=32)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    eng.generate(staged, caches, tok0, S_P, N_GEN)

    s_d = N_GEN + 1                   # prefill emits decoded token #1
    if t == 1:
        ops = cm.pp_comm_ops(cfg, S_P, s_d, p, b=4, batch=B)
    else:
        ops = cm.hybrid_comm_ops(cfg, S_P, s_d, t, p, b=4, batch=B,
                                 gather_mode="allgather")
    for phase in ("prefill", "decode"):
        want = [o for o in ops
                if o.collective == "send" and o.phase == phase][0]
        got = eng.transfer_summary(phase=phase)
        assert got["count"] == want.count
        assert got["bytes"] == want.total_msg_bytes


@needs_mesh
@pytest.mark.parametrize("unroll", [True, False])
def test_hybrid_stage_decode_hlo_matches_prediction(unroll):
    """Per-stage decode HLO collective counts == hybrid_stage_collectives,
    including an uneven 5-layer split (stage 0: 2·3+1 AR; stage 1: 2·2 AR +
    2 redistribute all-gathers + 1 logits all-gather)."""
    cfg, params, toks = _setup(num_layers=5)
    eng = px.PipelineEngine(cfg, t=2, p=2, unroll=unroll)
    staged = eng.prepare(params)
    logits, caches = eng.prefill_with_cache(staged, toks, cache_w=16)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    for s in range(2):
        hlo = eng.stage_decode_hlo(staged, caches, tok0, S_P, s)
        got = {k: v["count"]
               for k, v in summarize(parse_hlo_collectives(hlo)).items()}
        assert got == cm.hybrid_stage_collectives(cfg, 2, 2, s)
    assert cm.hybrid_stage_collectives(cfg, 2, 2, 0) == {"allreduce": 7}
    assert cm.hybrid_stage_collectives(cfg, 2, 2, 1) == {"allreduce": 4,
                                                         "allgather": 3}


@needs_mesh
def test_pure_pp_decode_stage_hlo_has_no_collectives():
    """t=1 stages are single-device: decode must move data only over the
    logged boundary transfers, never via in-module collectives."""
    cfg, params, toks = _setup()
    eng = px.PipelineEngine(cfg, t=1, p=2, unroll=False)
    staged = eng.prepare(params)
    logits, caches = eng.prefill_with_cache(staged, toks, cache_w=16)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    for s in range(2):
        hlo = eng.stage_decode_hlo(staged, caches, tok0, S_P, s)
        assert parse_hlo_collectives(hlo) == []


def test_hybrid_comm_ops_uneven_split_counts():
    """hybrid_comm_ops' per-stage allreduce count follows the uneven split
    (stage-0 rank view) and reduces to 2L/p + 1 when p divides L."""
    cfg = get_config("llama31-8b")                    # L=32
    even = cm.hybrid_comm_ops(cfg, 128, 128, 2, 2)
    ar = [o for o in even if o.collective == "allreduce"
          and o.phase == "prefill"][0]
    assert ar.count == 33                             # unchanged, 2·32/2 + 1
    cfg5 = dataclasses.replace(cfg, num_layers=5)
    odd = cm.hybrid_comm_ops(cfg5, 128, 128, 2, 2)
    ar = [o for o in odd if o.collective == "allreduce"
          and o.phase == "prefill"][0]
    assert ar.count == 2 * 3 + 1                      # stage 0 owns 3 layers
    # op-level sum must still equal the closed form on indivisible L
    comp = cm.v_hybrid_components(cfg5, 128, 128, 2, 2)
    got_ar = sum(o.wire_bytes for o in odd if o.collective == "allreduce")
    assert got_ar == pytest.approx(comp["allreduce"], rel=1e-12)
    assert cm.total_volume(odd) == pytest.approx(
        cm.v_hybrid(cfg5, 128, 128, 2, 2), rel=1e-12)
