"""Paged KV cache + chunked prefill (ISSUE 4 acceptance criteria).

1. Token identity: paged backends (chunked prefill + paged decode through
   the scheduler) match the contiguous backends AND isolated serving on
   ragged traces at (t, p) ∈ {(1,1), (2,1), (1,2), (2,2)}.
2. Counts: per-chunk prefill and per-step decode collective counts match
   ``commodel`` (``chunked_prefill_ops`` / ``comm_ops_for``) and the
   compiled HLO of the paged passes; PP chunk boundary hops measured ==
   predicted bytes.
3. The paged Pallas kernel (direct page indexing via scalar-prefetched
   block tables) matches the gather-based oracle.
4. Scheduler fix: iterations with no decoding slot never invoke the jitted
   decode step.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core.hlo_comm import parse_hlo_collectives, summarize
from repro.kernels.decode_attention.paged_kernel import \
    paged_decode_attention_pallas
from repro.kernels.decode_attention.ref import paged_decode_attention_ref
from repro.models import layers
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.engine import InferenceEngine
from repro.runtime.kvpool import KVPool
from repro.runtime.request import Request
from repro.runtime.scheduler import Scheduler, VirtualClock

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")

MAX_LEN = 64
PAGE = 8
CHUNK = 4

LAYOUTS = [("gspmd", dict()), ("tp", dict(t=2)),
           ("pp", dict(t=1, p=2)), ("pp", dict(t=2, p=2))]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _ragged_requests(cfg, eos_id=None):
    rng = np.random.default_rng(0)
    lens = [(7, 6), (11, 4), (5, 8), (9, 3)]
    return [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=n, eos_id=eos_id)
            for i, (s, n) in enumerate(lens)]


def _solo_reference(cfg, params, req):
    eng = InferenceEngine(cfg, params, max_len=MAX_LEN, decode_chunk=1)
    out = eng.generate(jnp.asarray(req.prompt)[None, :],
                       max_new_tokens=req.max_new_tokens)
    return np.asarray(out)[0].tolist()


# ---------------------------------------------------------------------------
# paged primitives: update/gather round-trips the contiguous layout
# ---------------------------------------------------------------------------


def test_paged_update_gather_matches_contiguous():
    """Writing a chunk through the block table then gathering the logical
    view reproduces the contiguous [B, S, H, D] layout exactly."""
    rng = np.random.default_rng(0)
    B, S, H, D, ps = 2, 11, 2, 4, 4
    n = -(-S // ps) + 1
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    pool = KVPool(num_pages=2 * n + 1, page_size=ps)
    bt = np.zeros((B, n), np.int32)
    for b in range(B):
        row = pool.allocate(b, S)
        bt[b, :len(row)] = row
    pages = jnp.zeros((2 * n + 1, ps, H, D), jnp.float32)
    ck, cv = layers.paged_cache_update(pages, pages, k, v,
                                       jnp.zeros((B,), jnp.int32),
                                       jnp.asarray(bt))
    got_k = layers.paged_gather(ck, jnp.asarray(bt))[:, :S]
    got_v = layers.paged_gather(cv, jnp.asarray(bt))[:, :S]
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(v))


def test_paged_attn_mask_is_causal_per_sequence():
    m = layers.paged_attn_mask(8, jnp.asarray([3, 0]), 2)   # [B,1,1,S,T]
    m = np.asarray(m)[:, 0, 0]
    # sequence 0: queries at positions 3,4
    assert m[0, 0].tolist() == [True] * 4 + [False] * 4
    assert m[0, 1].tolist() == [True] * 5 + [False] * 3
    # sequence 1: queries at positions 0,1
    assert m[1, 0].tolist() == [True] + [False] * 7
    assert m[1, 1].tolist() == [True] * 2 + [False] * 6


# ---------------------------------------------------------------------------
# acceptance 1: paged == contiguous == solo on ragged traces, 4 layouts
# ---------------------------------------------------------------------------


def test_paged_gspmd_matches_contiguous_and_solo(setup):
    cfg, params = setup
    reqs = _ragged_requests(cfg)
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    contiguous = make_backend("gspmd", cfg, params, num_slots=2,
                              max_len=MAX_LEN)
    got_c = Scheduler(contiguous, clock=VirtualClock()).run(
        _ragged_requests(cfg)).tokens_by_rid()
    paged = make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN,
                         paged=True, page_size=PAGE)
    report = Scheduler(paged, clock=VirtualClock(),
                       chunk_size=CHUNK).run(_ragged_requests(cfg))
    got_p = report.tokens_by_rid()
    for r in reqs:
        assert got_p[r.rid] == refs[r.rid], f"paged diverged on {r.rid}"
        assert got_c[r.rid] == refs[r.rid]
    # chunked prefill really ran: prompt 11 at chunk 4 takes 3 chunk steps
    chunks = [s for s in report.steps if s.phase == "prefill"]
    assert len(chunks) == sum(-(-r.prompt_len // CHUNK) for r in reqs)
    # all pages returned to the pool after the run
    assert paged.pool.stats().used_tokens == 0
    assert paged.pool.free_pages == paged.pool.num_pages - 1


@needs_mesh
@pytest.mark.parametrize("kind,kw", LAYOUTS[1:])
def test_paged_explicit_engines_match_solo(setup, kind, kw):
    cfg, params = setup
    reqs = _ragged_requests(cfg)
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    backend = make_backend(kind, cfg, params, num_slots=2, max_len=MAX_LEN,
                           paged=True, page_size=PAGE, **kw)
    got = Scheduler(backend, clock=VirtualClock(),
                    chunk_size=CHUNK).run(_ragged_requests(cfg)).tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid], \
            f"paged {kind}{kw}: request {r.rid} diverged"


def test_paged_protocol_entrypoint_matches_solo(setup):
    """prefill_into_slots (the non-chunked protocol entry) prefills straight
    into the pages as one maximal chunk — same tokens, no scheduler."""
    cfg, params = setup
    req = _ragged_requests(cfg)[0]
    ref = _solo_reference(cfg, params, req)
    backend = make_backend("gspmd", cfg, params, num_slots=2,
                           max_len=MAX_LEN, paged=True, page_size=PAGE)
    first = backend.prefill_into_slots([req.prompt], [1])
    toks = [int(first[0])]
    pos = np.array([0, req.prompt_len])
    cur = np.array([0, toks[-1]], np.int32)
    for _ in range(req.max_new_tokens - 1):
        nxt = backend.decode_step(cur, pos)
        toks.append(int(nxt[1]))
        cur[1] = nxt[1]
        pos[1] += 1
    assert toks == ref


def test_paged_rejects_unsupported_configs(setup):
    import dataclasses
    cfg, params = setup
    swa_cfg = dataclasses.replace(cfg, sliding_window=32)
    with pytest.raises(ValueError, match="sliding"):
        make_backend("gspmd", swa_cfg, params, num_slots=2, paged=True)
    moe_cfg = get_config("mixtral-8x22b").reduced(num_layers=2)
    with pytest.raises(ValueError, match="dense"):
        make_backend("gspmd", moe_cfg, params, num_slots=2, paged=True)


def test_chunked_prefill_requires_paged_backend(setup):
    cfg, params = setup
    backend = make_backend("gspmd", cfg, params, num_slots=2,
                           max_len=MAX_LEN)
    with pytest.raises(ValueError, match="paged"):
        Scheduler(backend, clock=VirtualClock(), chunk_size=4)


# ---------------------------------------------------------------------------
# acceptance 2: per-chunk + per-step counts == commodel == compiled HLO
# ---------------------------------------------------------------------------


def _hlo_counts(hlo: str):
    return {k: v["count"]
            for k, v in summarize(parse_hlo_collectives(hlo)).items()}


def _count(ops, phase=None):
    counts = {}
    for o in ops:
        if phase in (None, o.phase):
            counts[o.collective] = counts.get(o.collective, 0) + o.count
    return counts


def test_chunked_prefill_ops_totals(setup):
    """Chunked prefill sums to the monolithic prefill: allreduce counts
    scale with n_chunks, total allreduce BYTES are exactly the monolithic
    pass's, and the per-chunk schedule is batch-invariant."""
    cfg, _ = setup
    s_p, chunk = 11, 4
    mono = [o for o in cm.comm_ops_for(cfg, s_p, 1, 2, 1,
                                       gather_mode="allgather")
            if o.phase == "prefill"]
    chunked = cm.chunked_prefill_ops(cfg, s_p, chunk, 2, 1,
                                     gather_mode="allgather")
    n_chunks = -(-s_p // chunk)
    ar_mono = [o for o in mono if o.collective == "allreduce"]
    ar_chunk = [o for o in chunked if o.collective == "allreduce"]
    assert sum(o.count for o in ar_chunk) == \
        n_chunks * sum(o.count for o in ar_mono)
    assert sum(o.total_msg_bytes for o in ar_chunk) == \
        sum(o.total_msg_bytes for o in ar_mono)
    # the head runs per chunk: n_chunks all-gathers instead of 1
    assert sum(o.count for o in chunked if o.collective == "allgather") == \
        n_chunks
    # per-chunk counts don't depend on the chunk length or batch
    for c, batch in [(1, 1), (4, 1), (17, 3)]:
        per = cm.chunked_prefill_ops(cfg, c, c, 2, 1, batch=batch,
                                     gather_mode="allgather")
        assert _count(per) == {"allreduce": 2 * cfg.num_layers + 1,
                               "allgather": 1}


@needs_mesh
def test_paged_tp_chunk_and_decode_hlo_match_commodel(setup):
    """(2,1): compiled HLO of the paged pass at chunk lengths {1, CHUNK}
    and at the decode batch all report the contiguous step's schedule —
    (2L+1) allreduce + 1 logits all-gather — matching chunked_prefill_ops
    and the decode rows of comm_ops_for."""
    cfg, params = setup
    backend = make_backend("tp", cfg, params, num_slots=2, max_len=MAX_LEN,
                           t=2, paged=True, page_size=PAGE)
    want = {"allreduce": 2 * cfg.num_layers + 1, "allgather": 1}
    assert _count(backend.chunk_comm_ops(CHUNK)) == want
    assert _count(backend.decode_comm_ops(), "decode") == want
    for q_len, batch in [(CHUNK, 1), (1, 1), (1, backend.num_slots)]:
        got = _hlo_counts(backend.paged_step_hlo(q_len=q_len, batch=batch))
        assert got == want, (q_len, batch, got)


@needs_mesh
@pytest.mark.parametrize("t,p", [(1, 2), (2, 2)])
def test_paged_pp_stage_hlo_and_measured_chunks(setup, t, p):
    """(1,2)/(2,2): per-stage paged-pass HLO == hybrid_stage_collectives
    (chunk-length-invariant; zero collectives for t=1 stages), and every
    prefill chunk ships exactly the predicted boundary bytes."""
    cfg, params = setup
    backend = make_backend("pp", cfg, params, num_slots=2, max_len=MAX_LEN,
                           t=t, p=p, paged=True, page_size=PAGE)
    for stage in range(p):
        want = cm.hybrid_stage_collectives(cfg, t, p, stage)
        for q_len in (1, CHUNK):
            got = _hlo_counts(backend.stage_paged_hlo(stage, q_len=q_len))
            assert got == want, (stage, q_len, got)

    reqs = _ragged_requests(cfg)
    report = Scheduler(backend, clock=VirtualClock(),
                       chunk_size=CHUNK).run(reqs)
    sizes = [min(CHUNK, r.prompt_len - s)
             for r in sorted(reqs, key=lambda r: r.rid)
             for s in range(0, r.prompt_len, CHUNK)]
    chunks = [s for s in report.steps if s.phase == "prefill"]
    assert len(chunks) == len(sizes)
    for rec, c in zip(chunks, sizes):
        ops = backend.chunk_comm_ops(c)
        send = [o for o in ops if o.collective == "send"][0]
        assert rec.measured_transfers["count"] == send.count == (p - 1) * 2
        assert rec.measured_transfers["bytes"] == send.total_msg_bytes
        assert rec.collective_counts == _count(backend.chunk_comm_ops(CHUNK))
    # decode steps keep the contiguous schedule
    want_dec = _count(backend.decode_comm_ops(), "decode")
    for rec in report.steps:
        if rec.phase == "decode":
            assert rec.collective_counts == want_dec


# ---------------------------------------------------------------------------
# oversubscription: admission is page-aware, never MemoryError
# ---------------------------------------------------------------------------


def test_oversubscribed_pool_queues_instead_of_crashing(setup):
    """A pool with fewer pages than num_slots × worst-case must keep
    requests queued when pages run short (head-of-line, arrival order) and
    still finish everything — the admission gate covers each live request's
    committed decode growth, so mid-decode page extension can never fail."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=n)
            for i, (s, n) in enumerate([(30, 6), (25, 5), (28, 4), (20, 6)])]
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    # 2 slots would want 2×40 positions; give the pool 9 usable pages (72)
    backend = make_backend("gspmd", cfg, params, num_slots=2, max_len=MAX_LEN,
                           paged=True, page_size=PAGE, num_pages=10)
    report = Scheduler(backend, clock=VirtualClock(),
                       chunk_size=CHUNK).run(reqs)
    got = report.tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid]
    assert backend.pool.stats().used_tokens == 0


def test_request_larger_than_pool_rejected_at_submit(setup):
    cfg, params = setup
    backend = make_backend("gspmd", cfg, params, num_slots=1, max_len=MAX_LEN,
                           paged=True, page_size=PAGE, num_pages=3)
    sched = Scheduler(backend, clock=VirtualClock(), chunk_size=CHUNK)
    with pytest.raises(ValueError, match="pool capacity"):
        sched.submit(Request(rid=0, prompt=np.arange(2, 30, dtype=np.int32),
                             max_new_tokens=4))


# ---------------------------------------------------------------------------
# acceptance 3: paged Pallas kernel == gather oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ps,hq,hkv,d,n", [
    (16, 8, 2, 64, 4),
    (8, 4, 4, 32, 3),       # MHA
    (32, 4, 1, 64, 2),      # MQA
])
def test_paged_kernel_matches_ref(dtype, ps, hq, hkv, d, n):
    rng = np.random.default_rng(ps + hq + n)
    B, P = 3, n * 3 + 1
    q = jnp.asarray(rng.standard_normal((B, hq, d)), dtype)
    kp = jnp.asarray(rng.standard_normal((P, ps, hkv, d)), dtype)
    vp = jnp.asarray(rng.standard_normal((P, ps, hkv, d)), dtype)
    # each sequence owns a disjoint page run; lengths are ragged
    bt = jnp.asarray([[1 + b * n + j for j in range(n)] for b in range(B)],
                     jnp.int32)
    lengths = jnp.asarray([n * ps, ps + 1, 1], jnp.int32)
    got = paged_decode_attention_pallas(q, kp, vp, bt, lengths,
                                        interpret=True)
    want = paged_decode_attention_ref(q, kp, vp, bt, lengths)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# acceptance 4 (satellite fix): no jitted decode step without active slots
# ---------------------------------------------------------------------------


class _CountingBackend:
    """Transparent proxy that counts decode_step invocations."""

    def __init__(self, inner):
        self._inner = inner
        self.decode_calls = 0

    def decode_step(self, tokens, pos):
        self.decode_calls += 1
        return self._inner.decode_step(tokens, pos)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_no_decode_step_while_only_prefilling(setup):
    """With chunked prefill, iterations that only advance a prompt must not
    burn a fused decode step — the step count equals generated tokens."""
    cfg, params = setup
    req = Request(rid=0, prompt=np.arange(2, 2 + 17, dtype=np.int32),
                  max_new_tokens=3)
    backend = _CountingBackend(make_backend(
        "gspmd", cfg, params, num_slots=2, max_len=MAX_LEN, paged=True,
        page_size=PAGE))
    report = Scheduler(backend, clock=VirtualClock(), chunk_size=4).run([req])
    # 17-token prompt at chunk 4 = 5 chunk-only iterations; 2 decode steps
    # produce tokens 2 and 3 (the first comes from the final chunk)
    assert backend.decode_calls == req.max_new_tokens - 1
    assert len([s for s in report.steps if s.phase == "prefill"]) == 5
    assert report.metrics[0].num_generated == 3


def test_no_decode_step_while_queue_waits(setup):
    """Contiguous mode: a not-yet-arrived queue never triggers the jitted
    step either — the clock just advances to the next arrival."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    r = Request(rid=0, prompt=rng.integers(2, cfg.vocab_size, 6),
                max_new_tokens=2, arrival=50.0)
    backend = _CountingBackend(make_backend(
        "gspmd", cfg, params, num_slots=1, max_len=MAX_LEN))
    clock = VirtualClock()
    Scheduler(backend, clock=clock).run([r])
    assert backend.decode_calls == 1
    assert clock.now() >= 50.0
