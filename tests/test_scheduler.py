"""Continuous-batching serving layer (ISSUE 3 acceptance criteria).

1. Backend parity: every DecodeBackend produces token-identical output to
   its pre-refactor generate path for a same-length batch.
2. Ragged runs: a mixed-length scheduler run (queueing, mid-decode
   admission, eviction) yields per-request tokens identical to serving each
   request alone.
3. Per-step collective counts match ``commodel.comm_ops_for`` for the
   active backend at (t, p) ∈ {(1,1), (2,1), (1,2), (2,2)} — predicted
   (StepRecord), compiled (HLO) and measured (TransferRecords).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core import parallel_exec as px
from repro.core.hlo_comm import parse_hlo_collectives, summarize
from repro.models.transformer import get_model
from repro.runtime.backends import (ModelBackend, PPBackend, TPBackend,
                                    make_backend)
from repro.runtime.engine import InferenceEngine
from repro.runtime.request import Request, make_poisson_trace
from repro.runtime.scheduler import (Scheduler, VirtualClock,
                                     assert_counts_batch_invariant,
                                     step_collective_counts, serve)

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")

MAX_LEN = 64

# (t, p) ∈ {(1,1), (2,1), (1,2), (2,2)} — the ISSUE's four layouts
LAYOUTS = [("gspmd", dict()), ("tp", dict(t=2)),
           ("pp", dict(t=1, p=2)), ("pp", dict(t=2, p=2))]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _ragged_requests(cfg, eos_id=None):
    rng = np.random.default_rng(0)
    lens = [(7, 6), (11, 4), (5, 8), (9, 3)]
    return [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=n, eos_id=eos_id)
            for i, (s, n) in enumerate(lens)]


def _solo_reference(cfg, params, req):
    """Serve one request alone through the pre-refactor InferenceEngine."""
    eng = InferenceEngine(cfg, params, max_len=MAX_LEN, decode_chunk=1)
    out = eng.generate(jnp.asarray(req.prompt)[None, :],
                       max_new_tokens=req.max_new_tokens)
    return np.asarray(out)[0].tolist()


# ---------------------------------------------------------------------------
# acceptance 1: same-length batch parity with the pre-refactor paths
# ---------------------------------------------------------------------------


def test_model_backend_matches_inference_engine(setup):
    """ModelBackend (slot cache, vector pos) == InferenceEngine.generate
    for a same-length batch — the GSPMD path regression assertion."""
    cfg, params = setup
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 2,
                                 cfg.vocab_size)
    n = 6
    ref = np.asarray(InferenceEngine(cfg, params, max_len=MAX_LEN,
                                     decode_chunk=1)
                     .generate(prompts, max_new_tokens=n))
    backend = ModelBackend(cfg, params, num_slots=3, max_len=MAX_LEN)
    reqs = [Request(rid=i, prompt=np.asarray(prompts[i]), max_new_tokens=n)
            for i in range(3)]
    got = serve(backend, reqs, clock=VirtualClock()).tokens_by_rid()
    for i in range(3):
        assert got[i] == ref[i].tolist()


@needs_mesh
def test_tp_backend_matches_tp_generate(setup):
    """TPBackend == fused tp_generate for a same-length batch."""
    cfg, params = setup
    mesh = px.make_tp_mesh(2)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 2,
                                 cfg.vocab_size)
    logits, cache = px.tp_prefill(cfg, mesh, cache_w=MAX_LEN,
                                  unroll=False)(params, prompts)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    ref, _ = px.tp_generate(cfg, mesh, 5)(params, cache, tok0, jnp.int32(10))
    ref = np.concatenate([np.asarray(tok0)[:, None], np.asarray(ref)], 1)

    backend = TPBackend(cfg, params, num_slots=2, max_len=MAX_LEN, t=2)
    reqs = [Request(rid=i, prompt=np.asarray(prompts[i]), max_new_tokens=6)
            for i in range(2)]
    got = serve(backend, reqs, clock=VirtualClock()).tokens_by_rid()
    for i in range(2):
        assert got[i] == ref[i].tolist()


@needs_mesh
def test_tp_generate_vector_pos_matches_solo(setup):
    """Fused ragged decode: tp_generate(vector_pos=True) advances each slot
    from its own depth inside one fori_loop dispatch, token-identical to
    serving each request alone."""
    cfg, params = setup
    reqs = _ragged_requests(cfg)[:2]
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    backend = TPBackend(cfg, params, num_slots=2, max_len=MAX_LEN, t=2)
    first = backend.prefill_into_slots([r.prompt for r in reqs], [0, 1])
    n = min(r.max_new_tokens for r in reqs) - 1
    gen = px.tp_generate(cfg, backend.mesh, n, vector_pos=True)
    pos = jnp.asarray([r.prompt_len for r in reqs], jnp.int32)
    out, _ = gen(backend.params, backend.cache,
                 jnp.asarray(first, jnp.int32), pos)
    for i, r in enumerate(reqs):
        got = [int(first[i])] + np.asarray(out)[i].tolist()
        assert got == refs[r.rid][:n + 1]


@needs_mesh
@pytest.mark.parametrize("t,p", [(1, 2), (2, 2)])
def test_pp_backend_matches_pipeline_generate(setup, t, p):
    """PPBackend == PipelineEngine.generate for a same-length batch."""
    cfg, params = setup
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 2,
                                 cfg.vocab_size)
    eng = px.PipelineEngine(cfg, t=t, p=p, unroll=False)
    staged = eng.prepare(params)
    logits, caches = eng.prefill_with_cache(staged, prompts, cache_w=MAX_LEN)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    gen, _ = eng.generate(staged, caches, tok0, 10, 5)
    ref = np.concatenate([np.asarray(tok0)[:, None], np.asarray(gen)], 1)

    backend = PPBackend(cfg, params, num_slots=2, max_len=MAX_LEN, t=t, p=p)
    reqs = [Request(rid=i, prompt=np.asarray(prompts[i]), max_new_tokens=6)
            for i in range(2)]
    got = serve(backend, reqs, clock=VirtualClock()).tokens_by_rid()
    for i in range(2):
        assert got[i] == ref[i].tolist()


# ---------------------------------------------------------------------------
# acceptance 2: ragged scheduler run == serving each request alone
# ---------------------------------------------------------------------------


def test_ragged_gspmd_matches_solo(setup):
    cfg, params = setup
    reqs = _ragged_requests(cfg)
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    backend = ModelBackend(cfg, params, num_slots=2, max_len=MAX_LEN)
    sched = Scheduler(backend, clock=VirtualClock())
    report = sched.run(reqs)
    got = report.tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid], f"request {r.rid} diverged"
    # 2 slots for 4 requests: admission must have happened mid-decode
    assert max(s.n_active for s in report.steps) == 2
    assert all(m.finish_reason == "length" for m in report.metrics)
    assert all(m.num_generated == r.max_new_tokens
               for m, r in zip(report.metrics, reqs))


@needs_mesh
@pytest.mark.parametrize("kind,kw", LAYOUTS[1:])
def test_ragged_explicit_engines_match_solo(setup, kind, kw):
    cfg, params = setup
    reqs = _ragged_requests(cfg)
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    backend = make_backend(kind, cfg, params, num_slots=2, max_len=MAX_LEN,
                           **kw)
    got = serve(backend, reqs, clock=VirtualClock()).tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid], \
            f"{kind}{kw}: request {r.rid} diverged"


def test_ragged_ssm_family_matches_solo():
    """ModelBackend is family-generic (slot write scatters any cache pytree
    with batch on axis 1): the RWKV state cache serves ragged too."""
    cfg = get_config("rwkv6-7b").reduced()
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=n)
            for i, (s, n) in enumerate([(6, 5), (10, 4), (4, 6)])]
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    backend = ModelBackend(cfg, params, num_slots=2, max_len=MAX_LEN)
    got = serve(backend, reqs, clock=VirtualClock()).tokens_by_rid()
    for r in reqs:
        assert got[r.rid] == refs[r.rid]


def test_eos_eviction_frees_slot_for_queued_request(setup):
    """EOS mid-decode evicts the sequence and the freed slot admits the
    next queued request; the survivor's tokens are unaffected."""
    cfg, params = setup
    reqs = _ragged_requests(cfg)
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    # cut request 0 at its 3rd generated token by making that token its EOS
    eos = refs[0][2]
    reqs[0].eos_id = eos
    backend = ModelBackend(cfg, params, num_slots=1, max_len=MAX_LEN)
    report = serve(backend, reqs, clock=VirtualClock())
    by = {m.rid: m for m in report.metrics}
    assert by[0].finish_reason == "eos"
    assert by[0].tokens == refs[0][:3]
    # the single slot was reused for every later request, tokens intact
    for r in reqs[1:]:
        expect = refs[r.rid]
        if r.eos_id is not None and r.eos_id in expect:
            expect = expect[:expect.index(r.eos_id) + 1]
        assert by[r.rid].tokens == expect


def test_arrival_times_gate_admission(setup):
    """A request that arrives later is not admitted before its arrival
    time even when a slot is free (virtual clock)."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    r0 = Request(rid=0, prompt=rng.integers(2, cfg.vocab_size, 6),
                 max_new_tokens=3, arrival=0.0)
    r1 = Request(rid=1, prompt=rng.integers(2, cfg.vocab_size, 8),
                 max_new_tokens=3, arrival=100.0)
    backend = ModelBackend(cfg, params, num_slots=2, max_len=MAX_LEN)
    clock = VirtualClock()
    report = serve(backend, [r0, r1], clock=clock)
    by = {m.rid: m for m in report.metrics}
    assert by[1].admitted >= 100.0
    assert by[1].queue_delay >= 0.0
    assert clock.now() >= 100.0
    # solo-parity still holds across the idle gap
    assert by[1].tokens == _solo_reference(cfg, params, r1)


def test_scheduler_rejects_oversized_request(setup):
    cfg, params = setup
    backend = ModelBackend(cfg, params, num_slots=1, max_len=16)
    sched = Scheduler(backend, clock=VirtualClock())
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=np.arange(2, 14, dtype=np.int32),
                             max_new_tokens=8))


# ---------------------------------------------------------------------------
# acceptance 3: per-step collective counts == commodel.comm_ops_for
# ---------------------------------------------------------------------------


def _predicted_decode_counts(cfg, t, p):
    """Decode-phase per-step counts from the analytical model (s_d=2 →
    exactly one decode step past the prefill token)."""
    ops = cm.comm_ops_for(cfg, 1, 2, t, p, gather_mode="allgather")
    counts = {}
    for o in ops:
        if o.phase == "decode":
            counts[o.collective] = counts.get(o.collective, 0) + o.count
    return counts


def test_step_records_match_comm_model_t1p1(setup):
    cfg, params = setup
    backend = ModelBackend(cfg, params, num_slots=2, max_len=MAX_LEN)
    assert backend.decode_comm_ops(batch=2) == []
    report = serve(backend, _ragged_requests(cfg)[:2], clock=VirtualClock())
    assert all(s.collective_counts == {} for s in report.steps)
    assert all(s.measured_transfers["count"] == 0 for s in report.steps)


@needs_mesh
def test_step_records_match_comm_model_t2p1(setup):
    """(2,1): predicted step counts == commodel == compiled HLO of the
    slot decode step ((2L+1) allreduce + 1 logits all-gather)."""
    cfg, params = setup
    backend = TPBackend(cfg, params, num_slots=2, max_len=MAX_LEN, t=2)
    want = _predicted_decode_counts(cfg, 2, 1)
    assert want == {"allreduce": 2 * cfg.num_layers + 1, "allgather": 1}
    assert step_collective_counts(backend) == want
    got_hlo = {k: v["count"] for k, v in summarize(
        parse_hlo_collectives(backend.decode_step_hlo())).items()}
    assert got_hlo == want
    report = serve(backend, _ragged_requests(cfg)[:2], clock=VirtualClock())
    assert all(s.collective_counts == want for s in report.steps)


@needs_mesh
@pytest.mark.parametrize("t,p", [(1, 2), (2, 2)])
def test_step_records_match_comm_model_pp(setup, t, p):
    """(1,2)/(2,2): per-step boundary transfers measured by the engine ==
    the pp/hybrid decode send rows ((p-1)·2 per step, exact bytes); hybrid
    stage HLO == hybrid_stage_collectives; t=1 stages have no collectives."""
    cfg, params = setup
    backend = PPBackend(cfg, params, num_slots=2, max_len=MAX_LEN, t=t, p=p)
    want = _predicted_decode_counts(cfg, t, p)
    assert step_collective_counts(backend) == want
    assert want["send"] == (p - 1) * 2

    report = serve(backend, _ragged_requests(cfg)[:2], clock=VirtualClock())
    # every decode step shipped exactly the predicted boundary tensors
    ops = cm.comm_ops_for(cfg, 1, 2, t, p, b=4, batch=backend.num_slots,
                          gather_mode="allgather")
    send = [o for o in ops
            if o.collective == "send" and o.phase == "decode"][0]
    for s in report.steps:
        assert s.collective_counts == want
        assert s.measured_transfers["count"] == send.count
        assert s.measured_transfers["bytes"] == send.total_msg_bytes

    # per-stage compiled decode modules (vector-pos path)
    for stage in range(p):
        got = {k: v["count"] for k, v in summarize(
            parse_hlo_collectives(backend.stage_decode_hlo(stage))).items()}
        assert got == cm.hybrid_stage_collectives(cfg, t, p, stage)


# ---------------------------------------------------------------------------
# the asserted batch-invariance property + trace plumbing
# ---------------------------------------------------------------------------


def test_batch_invariance_asserted_at_construction(setup):
    cfg, params = setup
    backend = ModelBackend(cfg, params, num_slots=4, max_len=MAX_LEN)
    assert_counts_batch_invariant(backend)        # must not raise
    Scheduler(backend, clock=VirtualClock())      # runs the assert itself


def test_poisson_trace_shapes():
    trace = make_poisson_trace(16, rate=4.0, vocab_size=512,
                               prompt_lens=(4, 12), decode_lens=(2, 6),
                               seed=3)
    assert len(trace) == 16
    arr = [r.arrival for r in trace]
    assert arr == sorted(arr) and arr[-1] > 0
    assert all(4 <= r.prompt_len <= 12 for r in trace)
    assert all(2 <= r.max_new_tokens <= 6 for r in trace)
    closed = make_poisson_trace(4, rate=0, vocab_size=512)
    assert all(r.arrival == 0.0 for r in closed)


# ---------------------------------------------------------------------------
# submit hardening (ISSUE 6 satellites)
# ---------------------------------------------------------------------------


def test_submit_fifo_for_equal_arrivals(setup):
    """The queue is a stable sorted insert: same-arrival requests are
    admitted in submission order, and a later-arriving request submitted
    first still sorts behind earlier arrivals."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    backend = ModelBackend(cfg, params, num_slots=1, max_len=MAX_LEN)
    sched = Scheduler(backend, clock=VirtualClock())
    # submit out of arrival order, with a 4-way tie at t=0
    late = Request(rid=99, prompt=rng.integers(2, cfg.vocab_size, 5),
                   max_new_tokens=2, arrival=50.0)
    ties = [Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, 5),
                    max_new_tokens=2, arrival=0.0) for i in range(4)]
    sched.submit(late)
    for r in ties:
        sched.submit(r)
    assert [r.rid for r in sched.queue] == [0, 1, 2, 3, 99]
    report = sched.run()
    order = sorted(report.metrics, key=lambda m: m.admitted)
    assert [m.rid for m in order] == [0, 1, 2, 3, 99], \
        "equal arrivals must be served FIFO in submission order"


def test_submit_rejects_duplicate_rid(setup):
    """Duplicate rids would silently merge streams in tokens_by_rid()."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    backend = ModelBackend(cfg, params, num_slots=1, max_len=MAX_LEN)
    sched = Scheduler(backend, clock=VirtualClock())
    mk = lambda rid: Request(rid=rid,
                             prompt=rng.integers(2, cfg.vocab_size, 5),
                             max_new_tokens=2)
    sched.submit(mk(0))
    with pytest.raises(ValueError, match="duplicate rid 0"):
        sched.submit(mk(0))
    with pytest.raises(ValueError, match="duplicate rid 7"):
        sched.submit([mk(7), mk(7)])         # dup within one batch too
    # the failed batch must not have been partially enqueued
    assert [r.rid for r in sched.queue] == [0]
    sched.run()
    # a fresh run() resets the seen set: rid 0 is usable again
    sched.submit(mk(0))
    report = sched.run()
    assert [m.rid for m in report.metrics] == [0]


def test_poisson_trace_eos_prob():
    """eos_prob draws a geometric early stop into Request.eos_pos — the
    EOS-heavy mix knob the overload bench series uses."""
    trace = make_poisson_trace(64, rate=0, vocab_size=512,
                               decode_lens=(8, 16), eos_prob=0.4, seed=5)
    stops = [r.eos_pos for r in trace if r.eos_pos is not None]
    assert len(stops) > 32, "p=0.4 should stop most requests early"
    assert all(1 <= s < r.max_new_tokens
               for s, r in zip(stops, [t for t in trace
                                       if t.eos_pos is not None]))
    # deterministic in the seed, and off by default
    again = make_poisson_trace(64, rate=0, vocab_size=512,
                               decode_lens=(8, 16), eos_prob=0.4, seed=5)
    assert [r.eos_pos for r in again] == [r.eos_pos for r in trace]
    assert all(r.eos_pos is None
               for r in make_poisson_trace(8, rate=0, vocab_size=512))
    with pytest.raises(ValueError):
        make_poisson_trace(4, rate=0, vocab_size=512, eos_prob=1.0)


def test_eos_pos_finishes_early(setup):
    """The emulated early stop evicts with reason "eos" after exactly
    eos_pos tokens, prefix-identical to the full run."""
    cfg, params = setup
    reqs = _ragged_requests(cfg)
    refs = {r.rid: _solo_reference(cfg, params, r) for r in reqs}
    reqs[1].eos_pos = 2
    backend = ModelBackend(cfg, params, num_slots=2, max_len=MAX_LEN)
    report = serve(backend, reqs, clock=VirtualClock())
    by = {m.rid: m for m in report.metrics}
    assert by[1].finish_reason == "eos"
    assert by[1].tokens == refs[1][:2]
    for r in reqs:
        if r.rid != 1:
            assert by[r.rid].tokens == refs[r.rid]
