"""Force a multi-device CPU host platform before jax initializes.

The explicit TP/PP engine tests (tests/test_decode_fastpath.py) shard over a
real mesh, so the suite runs with 8 host-platform devices — the same setting
CI exports.  An operator-provided XLA_FLAGS with an explicit device count is
left untouched.
"""
import os

_FLAGS = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _FLAGS:
    os.environ["XLA_FLAGS"] = (
        _FLAGS + " --xla_force_host_platform_device_count=8").strip()
