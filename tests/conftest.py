"""Force a multi-device CPU host platform before jax initializes.

The explicit TP/PP engine tests (tests/test_decode_fastpath.py) shard over a
real mesh, so the suite runs with 8 host-platform devices — the same setting
CI exports.  An operator-provided XLA_FLAGS with an explicit device count is
left untouched.

Also registers the ``multidevice`` marker: suites that need the full
8-device mesh (e.g. the 3-axis (t, c, p) = (2, 2, 2) dynamic-schedule
tests) carry it, and the CI matrix leg that pins 2 devices skips them
cleanly instead of failing on mesh construction.
"""
import os

_FLAGS = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _FLAGS:
    os.environ["XLA_FLAGS"] = (
        _FLAGS + " --xla_force_host_platform_device_count=8").strip()

import pytest  # noqa: E402  (after the XLA_FLAGS export on purpose)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: needs the full 8-device host platform "
        "(skipped automatically when fewer devices are configured)")


def pytest_collection_modifyitems(config, items):
    import jax
    if len(jax.devices()) >= 8:
        return
    skip = pytest.mark.skip(reason="needs 8 host-platform devices")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)
