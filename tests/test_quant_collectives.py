"""Quantized collectives (DESIGN.md §12): kernels, the two-step psum, and
the predicted == compiled invariant under quantization.

Layers under test, bottom up:
  * kernels/quant_collective — per-chunk amax/quantize/dequantize: jnp ref
    vs Pallas-interpret bitwise, odd chunk remainders, the zero-chunk scale
    guard, and the summation-headroom qmax table;
  * core/parallel_exec.quantized_psum — exact agreement with a numpy
    simulation of the shared-scale two-step (the int8 reduce-scatter sum is
    EXACT by the qmax headroom), bounded drift vs the full-width psum, and
    bitwise identity + zero quant ops at t=1;
  * predicted == compiled: ``comm_ops_for(quant=...)`` must match the
    decode-step HLO in counts AND wire bytes for TP layouts in both unroll
    modes, and ``hybrid_stage_collectives(quant=...)`` must match every
    stage of the quantized hybrid engine;
  * runtime/backends + slo/planner: decomposed decode rows, the
    paged/gspmd rejections, strictly-lower predicted volume, and the
    volume-budget frontier re-entry the planner docstring promises.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core import parallel_exec as px
from repro.core.hlo_comm import parse_hlo_collectives, summarize
from repro.kernels.quant_collective import (QUANT_DTYPES, QUANT_TOLERANCE,
                                            chunk_amax, chunk_dequantize,
                                            chunk_quantize, collective_qmax,
                                            nibble_pack, nibble_unpack,
                                            scales_from_amax)
from repro.kernels.quant_collective.ref import (chunk_amax_ref,
                                                chunk_dequantize_ref,
                                                chunk_quantize_ref,
                                                nibble_pack_ref,
                                                nibble_unpack_ref)
from repro.models.transformer import get_model

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")
needs_pair = pytest.mark.skipif(len(jax.devices()) < 2,
                                reason="needs 2 host-platform devices")


# ---------------------------------------------------------------------------
# kernel package: ref vs Pallas-interpret, remainders, guards
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape,chunk", [((4, 3072), 128), ((3, 100), 32),
                                         ((2, 5, 257), 128)])
@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_roundtrip_error_bounded_per_chunk(shape, chunk, quant):
    """|x − dequant(quantize(x))| ≤ scale/2 (int8) / one e4m3 mantissa step
    (fp8), per chunk — including ragged tails where h % chunk != 0."""
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32) * 3.0
    qmax = collective_qmax(quant, 1)
    scales = scales_from_amax(chunk_amax(x, chunk), qmax)
    q = chunk_quantize(x, scales, chunk, quant)
    assert q.dtype == QUANT_DTYPES[quant]
    back = chunk_dequantize(q, scales, chunk, jnp.float32)
    assert back.shape == x.shape
    K = cm.quant_chunks(shape[-1], chunk)
    err = np.abs(np.asarray(x) - np.asarray(back))
    s = np.asarray(scales)
    for k in range(K):
        sl = err[..., k * chunk:(k + 1) * chunk]
        bound = s[..., k] * (0.5 if quant == "int8" else 2.0 ** -3 * qmax)
        assert (sl <= bound[..., None] + 1e-6).all()


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_interpret_kernels_match_ref_bitwise(monkeypatch, quant):
    """The Pallas kernels (interpret mode on CPU) and the jnp oracle are
    the same function, bit for bit, for every entry point."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 257), jnp.float32)
    chunk = 64
    amax_p = chunk_amax(x, chunk)
    np.testing.assert_array_equal(np.asarray(amax_p),
                                  np.asarray(chunk_amax_ref(x, chunk)))
    scales = scales_from_amax(amax_p, collective_qmax(quant, 2))
    q_p = chunk_quantize(x, scales, chunk, quant)
    q_r = chunk_quantize_ref(x, scales, chunk, QUANT_DTYPES[quant])
    np.testing.assert_array_equal(np.asarray(q_p).view(np.uint8),
                                  np.asarray(q_r).view(np.uint8))
    np.testing.assert_array_equal(
        np.asarray(chunk_dequantize(q_p, scales, chunk, jnp.float32)),
        np.asarray(chunk_dequantize_ref(q_r, scales, chunk, jnp.float32)))


def test_zero_chunk_guard():
    """An all-zero chunk quantizes through scale 1.0 and round-trips to
    exact zeros — no 0/0 anywhere."""
    x = jnp.zeros((2, 256), jnp.float32).at[:, 128:].set(1.5)
    scales = scales_from_amax(chunk_amax(x, 128), collective_qmax("int8", 2))
    assert np.asarray(scales)[0, 0] == 1.0
    back = chunk_dequantize(chunk_quantize(x, scales, 128, "int8"),
                            scales, 128, jnp.float32)
    assert np.isfinite(np.asarray(back)).all()
    np.testing.assert_array_equal(np.asarray(back)[:, :128], 0.0)


def test_nibble_pack_unpack_roundtrip_every_value():
    """Every int4 value pair survives pack -> unpack bitwise, in every
    lane position, and the packed form is half the bytes."""
    vals = np.arange(-8, 8, dtype=np.int8)           # full 4-bit range
    q = jnp.asarray(np.stack(np.meshgrid(vals, vals), -1).reshape(16, 32))
    packed = nibble_pack(q)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (16, 16)
    np.testing.assert_array_equal(np.asarray(nibble_unpack(packed)),
                                  np.asarray(q))
    with pytest.raises(ValueError):
        nibble_pack(jnp.zeros((2, 3), jnp.int8))     # odd last axis


def test_nibble_kernels_match_ref_bitwise(monkeypatch):
    """Pallas pack/unpack (interpret mode) == the jnp oracle, bit for bit,
    including ragged row counts that exercise the row padding."""
    q = jnp.asarray(np.random.default_rng(0).integers(
        -7, 8, size=(5, 38), dtype=np.int8))
    want_packed = np.asarray(nibble_pack_ref(q))
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    got_packed = np.asarray(nibble_pack(q))
    np.testing.assert_array_equal(got_packed, want_packed)
    np.testing.assert_array_equal(
        np.asarray(nibble_unpack(jnp.asarray(got_packed))),
        np.asarray(nibble_unpack_ref(jnp.asarray(want_packed))))


def test_collective_qmax_headroom_table():
    """qmax · t never exceeds the wire dtype's range — the property that
    makes the int8 reduce-scatter sum exact and the fp8 one unsaturated."""
    for t in (1, 2, 4, 8):
        assert collective_qmax("int8", t) * t <= 127
        assert collective_qmax("fp8", t) * t <= 448.0
        # int4 keeps the full grid at every t: headroom comes from the
        # packed path's exact int32 accumulation, not the qmax table
        assert collective_qmax("int4", t) == 7.0
    assert collective_qmax("int8", 4) == 31.0
    assert collective_qmax("fp8", 4) == 112.0
    with pytest.raises(ValueError):
        collective_qmax("int2", 2)
    with pytest.raises(ValueError):
        collective_qmax("int8", 0)


def test_quant_tolerance_contract_shape():
    """The numerics contract is explicit and single-homed: both wire modes
    carry a match floor and a drift ceiling, and fp8 (3 mantissa bits) is
    never promised tighter than int8."""
    assert set(QUANT_TOLERANCE) == set(QUANT_DTYPES) == \
        {"int8", "fp8", "int4"}
    for mode, tol in QUANT_TOLERANCE.items():
        assert set(tol) == {"token_match_floor", "logit_drift_ceiling"}
        assert 0.0 < tol["token_match_floor"] <= 1.0
        assert tol["logit_drift_ceiling"] > 0.0
    assert QUANT_TOLERANCE["fp8"]["token_match_floor"] <= \
        QUANT_TOLERANCE["int8"]["token_match_floor"]
    assert QUANT_TOLERANCE["fp8"]["logit_drift_ceiling"] >= \
        QUANT_TOLERANCE["int8"]["logit_drift_ceiling"]
    # a 4-bit grid is never promised tighter than the 8-bit one
    assert QUANT_TOLERANCE["int4"]["token_match_floor"] <= \
        QUANT_TOLERANCE["fp8"]["token_match_floor"]
    assert QUANT_TOLERANCE["int4"]["logit_drift_ceiling"] >= \
        QUANT_TOLERANCE["fp8"]["logit_drift_ceiling"]


# ---------------------------------------------------------------------------
# quantized_psum: exact numpy simulation + drift bound + t=1 identity
# ---------------------------------------------------------------------------


def _run_quantized_psum(x_ranks, t, quant, chunk):
    """shard_map quantized_psum over the first axis of [t, rows, h]."""
    mesh = px.make_tp_mesh(t)
    fn = jax.jit(shard_map(
        lambda xs: px.quantized_psum(xs, "tp", t, quant=quant, chunk=chunk),
        mesh=mesh, in_specs=P("tp"), out_specs=P("tp"), check_rep=False))
    out = np.asarray(fn(x_ranks))
    # every rank must hold the identical dequantized sum
    for r in range(1, t):
        np.testing.assert_array_equal(out[r], out[0])
    return out[0]


def _sim_scales(x_ranks, t, quant, chunk):
    """Shared per-chunk scales from the globally pmax'ed abs-max."""
    x = np.asarray(x_ranks, np.float32)          # [t, rows, h]
    h = x.shape[-1]
    K = cm.quant_chunks(h, chunk)
    pad = np.zeros(x.shape[:-1] + (K * chunk - h,), np.float32)
    xp = np.concatenate([x, pad], -1).reshape(x.shape[:-1] + (K, chunk))
    amax = np.abs(xp).max(-1).max(0)             # global (pmax) per chunk
    qmax = collective_qmax(quant, t)
    return np.where(amax > 0, amax / qmax, 1.0).astype(np.float32)


def _simulate(x_ranks, t, quant, chunk):
    """Numpy oracle of the shared-scale two-step."""
    x = np.asarray(x_ranks, np.float32)          # [t, rows, h]
    h = x.shape[-1]
    K = cm.quant_chunks(h, chunk)
    pad = np.zeros(x.shape[:-1] + (K * chunk - h,), np.float32)
    xp = np.concatenate([x, pad], -1).reshape(x.shape[:-1] + (K, chunk))
    scales = _sim_scales(x_ranks, t, quant, chunk)
    if quant == "int8":
        q = np.clip(np.rint(xp / scales[None, ..., None]), -127, 127)
        total = q.sum(0)                         # exact: |sum| ≤ t·qmax ≤ 127
    elif quant == "int4":
        q = np.rint(xp / scales[None, ..., None])    # |q| ≤ 7 by the scales
        r = q.sum(0)                             # exact int32 block sum
        total = np.clip(np.rint(r / t), -7, 7) * t   # requantize-by-t
    else:
        q = (xp / scales[None, ..., None]).astype(jnp.float8_e4m3fn)
        total = q[0].astype(np.float32)
        for r in range(1, t):                    # fp8 ring adds in f32 here
            total = total + q[r].astype(np.float32)
    out = (total * scales[..., None]).reshape(x.shape[1:-1] + (K * chunk,))
    return out[..., :h].astype(np.float32)


@needs_pair
@pytest.mark.parametrize("h,chunk", [(256, 128), (160, 64)])
def test_quantized_psum_matches_numpy_simulation_int8(h, chunk):
    """t=2, even and ragged (160 = 2.5 × 64) hidden chunking: the compiled
    two-step equals the numpy oracle — the summed int8 payload recovered
    from the result is bitwise the oracle's (the reduce-scatter sum is
    exact by the qmax headroom); the final f32 dequant multiply is allowed
    one ULP of XLA-vs-numpy slack."""
    t = 2
    x = jax.random.normal(jax.random.PRNGKey(2), (t, 3, h), jnp.float32) * 2
    got = _run_quantized_psum(x, t, "int8", chunk)
    sim = _simulate(x, t, "int8", chunk)
    np.testing.assert_allclose(got, sim, rtol=2e-6, atol=2e-6)
    K = cm.quant_chunks(h, chunk)
    pad = ((0, 0), (0, K * chunk - h))
    scales = _sim_scales(x, t, "int8", chunk)

    def ints(arr):
        return np.rint(np.pad(arr, pad).reshape(3, K, chunk)
                       / scales[..., None])
    np.testing.assert_array_equal(ints(got), ints(sim))


@needs_pair
@pytest.mark.parametrize("h,chunk", [(256, 128), (192, 64)])
def test_quantized_psum_matches_numpy_simulation_int4(h, chunk):
    """t=2 packed-nibble path: the compiled a2a two-step equals the numpy
    oracle (quantize ±7 → exact block sum → requantize by t → dequant at
    scales·t) — the requantized int payload recovered from the result is
    bitwise the oracle's."""
    t = 2
    x = jax.random.normal(jax.random.PRNGKey(7), (t, 3, h), jnp.float32) * 2
    got = _run_quantized_psum(x, t, "int4", chunk)
    sim = _simulate(x, t, "int4", chunk)
    np.testing.assert_allclose(got, sim, rtol=2e-6, atol=2e-6)
    K = cm.quant_chunks(h, chunk)
    pad = ((0, 0), (0, K * chunk - h))
    scales = _sim_scales(x, t, "int4", chunk)

    def ints(arr):
        return np.rint(np.pad(arr, pad).reshape(3, K, chunk)
                       / (t * scales[..., None]))
    np.testing.assert_array_equal(ints(got), ints(sim))


@needs_pair
def test_quantized_psum_int4_rejects_unaligned_hidden():
    """h must divide 2t — the packed a2a ships byte-aligned h/t blocks."""
    t = 2
    x = jnp.zeros((t, 2, 130), jnp.float32)      # 130 % 4 != 0
    with pytest.raises(ValueError, match="2t"):
        _run_quantized_psum(x, t, "int4", 64)


@needs_pair
def test_quantized_psum_drift_bounded_vs_full_psum():
    """|quantized − full psum| ≤ t · scale/2 per chunk (each rank rounds
    at most half a step, summed across t ranks)."""
    t, h, chunk = 2, 256, 128
    x = jax.random.normal(jax.random.PRNGKey(3), (t, 4, h), jnp.float32)
    got = _run_quantized_psum(x, t, "int8", chunk)
    full = np.asarray(x, np.float32).sum(0)
    amax = np.abs(np.asarray(x)).reshape(t, 4, h // chunk, chunk) \
        .max(-1).max(0)
    scales = amax / collective_qmax("int8", t)
    err = np.abs(got - full).reshape(4, h // chunk, chunk)
    assert (err <= t * scales[..., None] / 2 + 1e-6).all()


def test_t1_is_identity_with_zero_quant_ops():
    """quant at t=1 must be a no-op: bitwise-identical logits and a decode
    module containing neither collectives nor any s8 op."""
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    mesh = px.make_tp_mesh(1)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 2,
                              cfg.vocab_size)
    _, cache = px.tp_prefill(cfg, mesh, cache_w=12, unroll=True)(params, toks)
    tok = jnp.zeros((2,), jnp.int32)
    base = px.tp_decode_step(cfg, mesh, unroll=True)
    quant = px.tp_decode_step(cfg, mesh, unroll=True,
                              quant_collectives="int8")
    lb, _ = base(params, jax.tree.map(jnp.copy, cache), tok, jnp.int32(8))
    lq, _ = quant(params, jax.tree.map(jnp.copy, cache), tok, jnp.int32(8))
    np.testing.assert_array_equal(np.asarray(lb), np.asarray(lq))
    hlo = quant.lower(params, cache, tok, jnp.int32(8)).compile().as_text()
    assert parse_hlo_collectives(hlo) == []
    assert " s8[" not in hlo


# ---------------------------------------------------------------------------
# predicted == compiled under quantization (the house invariant)
# ---------------------------------------------------------------------------


def _decode_hlo(cfg, mesh, params, toks, t, unroll, quant):
    _, cache = px.tp_prefill(cfg, mesh, cache_w=12,
                             unroll=True)(params, toks)
    step = px.tp_decode_step(cfg, mesh, unroll=unroll,
                             quant_collectives=quant)
    tok = jnp.zeros((toks.shape[0],), jnp.int32)
    return step.lower(params, cache, tok,
                      jnp.int32(toks.shape[1])).compile().as_text()


def _predicted_decode(cfg, t, batch, quant):
    ops = cm.comm_ops_for(cfg, 1, 2, t, 1, b=4, batch=batch,
                          gather_mode="allgather", quant=quant)
    counts, wires = {}, {}
    for o in ops:
        if o.phase != "decode":
            continue
        counts[o.collective] = counts.get(o.collective, 0) + o.count
        wires[o.collective] = wires.get(o.collective, 0.0) + o.wire_bytes
    return counts, wires


@needs_mesh
@pytest.mark.parametrize("t", [2, 4])
@pytest.mark.parametrize("unroll", [True, False])
def test_tp_decode_hlo_counts_and_wire_bytes_match_prediction(t, unroll):
    """(t,1) both unroll modes: compiled decode-step collectives == the
    quantized commodel rows in COUNTS and WIRE BYTES (f32 configs, b=4).
    The scanned mode goes through hlo_comm's trip expansion, the unrolled
    one through the scatter-form reclassification — same answer."""
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    mesh = px.make_tp_mesh(t)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 2,
                              cfg.vocab_size)
    hlo = _decode_hlo(cfg, mesh, params, toks, t, unroll, "int8")
    s = summarize(parse_hlo_collectives(hlo))
    got_counts = {k: v["count"] for k, v in s.items()}
    got_wires = {k: v["wire_bytes"] for k, v in s.items()}
    want_counts, want_wires = _predicted_decode(cfg, t, 2, "int8")
    assert got_counts == want_counts
    assert set(got_wires) == set(want_wires)
    for k in want_wires:
        assert got_wires[k] == pytest.approx(want_wires[k]), k
    # the decomposition itself: 2L 1-byte RS/AG pairs + 2L amax ARs + embed
    L = cfg.num_layers
    assert want_counts["reducescatter"] == 2 * L
    assert want_counts["allgather"] == 2 * L + 1
    assert want_counts["allreduce"] == 2 * L + 1


@needs_mesh
def test_tp_decode_hlo_counts_match_prediction_fp8():
    """fp8 keeps the same collective SCHEDULE; wire bytes are excluded on
    host CPU, where XLA upcasts the f8 payload (commodel models the
    accelerator's nominal 1-byte wire — DESIGN.md §12)."""
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    mesh = px.make_tp_mesh(2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 2,
                              cfg.vocab_size)
    hlo = _decode_hlo(cfg, mesh, params, toks, 2, True, "fp8")
    got = {k: v["count"]
           for k, v in summarize(parse_hlo_collectives(hlo)).items()}
    assert got == _predicted_decode(cfg, 2, 2, "fp8")[0]


@needs_mesh
@pytest.mark.parametrize("t", [2, 4])
def test_tp_decode_hlo_counts_and_wire_bytes_match_prediction_int4(t):
    """int4 (t,1): the compiled module shows the packed-nibble schedule —
    2L u8 all-to-alls + 2L u8 all-gathers at HALF-byte wire width, 2L f32
    amax ARs + the full-width embed AR — matching the commodel rows in
    counts AND wire bytes (the u8 payload needs no upcast, so bytes check
    exactly even on host CPU, unlike fp8)."""
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    mesh = px.make_tp_mesh(t)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 2,
                              cfg.vocab_size)
    hlo = _decode_hlo(cfg, mesh, params, toks, t, True, "int4")
    s = summarize(parse_hlo_collectives(hlo))
    got_counts = {k: v["count"] for k, v in s.items()}
    got_wires = {k: v["wire_bytes"] for k, v in s.items()}
    want_counts, want_wires = _predicted_decode(cfg, t, 2, "int4")
    assert got_counts == want_counts
    for k in want_wires:
        assert got_wires[k] == pytest.approx(want_wires[k]), k
    L = cfg.num_layers
    assert want_counts["alltoall"] == 2 * L
    assert want_counts["allgather"] == 2 * L + 1
    assert want_counts["allreduce"] == 2 * L + 1
    assert "reducescatter" not in want_counts


def test_closed_form_ratio_int4_flash_communication_target():
    """Production configs at bf16: the packed 4-bit payload lands the
    Flash-Communication ~0.28× headline — always < 0.35× and strictly
    below the int8 two-step's ratio."""
    for arch in ("llama32-3b", "llama31-8b", "llama2-13b"):
        h = get_config(arch).d_model
        for t in (2, 4, 8):
            r4 = cm.quant_ar_wire_ratio(h, t, quant="int4", b=2)
            assert r4 < 0.35, (arch, t, r4)
            assert r4 < cm.quant_ar_wire_ratio(h, t, quant="int8", b=2)
    assert cm.quant_ar_wire_ratio(3072, 2, quant="int4", b=2) == \
        pytest.approx(0.265625)


@needs_mesh
@pytest.mark.parametrize("unroll", [True, False])
def test_quant_hybrid_stage_hlo_matches_prediction(unroll):
    """(2,2) both unroll modes: every stage of the quantized hybrid engine
    compiles to exactly hybrid_stage_collectives(quant='int8')."""
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 2,
                              cfg.vocab_size)
    eng = px.PipelineEngine(cfg, t=2, p=2, unroll=unroll,
                            quant_collectives="int8")
    staged = eng.prepare(params)
    _, caches = eng.prefill_with_cache(staged, toks, 12)
    tok0 = jnp.zeros((2,), jnp.int32)
    for s in range(2):
        hlo = eng.stage_decode_hlo(staged, caches, tok0, 8, s)
        got = {k: v["count"]
               for k, v in summarize(parse_hlo_collectives(hlo)).items()}
        assert got == cm.hybrid_stage_collectives(cfg, 2, 2, s,
                                                  quant="int8"), (s, unroll)


def test_closed_form_ratio_under_acceptance_bound_full_configs():
    """Production configs at bf16: int8 payload + f32 scales < 0.6× the
    bf16 allreduce wire for every TP degree — and t-invariant."""
    for arch in ("llama32-3b", "llama31-8b", "llama2-13b"):
        h = get_config(arch).d_model
        ratios = [cm.quant_ar_wire_ratio(h, t, quant="int8", b=2)
                  for t in (2, 4, 8)]
        assert all(r < 0.6 for r in ratios), (arch, ratios)
        assert ratios[0] == ratios[1] == ratios[2]
    assert cm.quant_ar_wire_ratio(3072, 2, quant="int8", b=2) == \
        pytest.approx(0.515625)


# ---------------------------------------------------------------------------
# runtime + slo + planner threading
# ---------------------------------------------------------------------------


@needs_pair
def test_backend_decode_comm_ops_decomposed():
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    from repro.runtime.backends import make_backend
    be = make_backend("tp", cfg, params, num_slots=2, max_len=16, t=2,
                      quant_collectives="int8")
    kinds = {o.collective for o in be.decode_comm_ops()}
    assert {"allreduce", "reducescatter", "allgather"} <= kinds
    one_byte = [o for o in be.decode_comm_ops()
                if o.dtype_bytes == 1
                and o.collective in ("reducescatter", "allgather")]
    assert sum(o.count for o in one_byte) == 2 * 2 * cfg.num_layers


def test_backend_rejections():
    """quant composes with the explicit engines only: paged attention and
    the gspmd backend both refuse the knob loudly."""
    cfg = get_config("llama32-3b").reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    from repro.runtime.backends import make_backend
    with pytest.raises(ValueError, match="paged"):
        make_backend("tp", cfg, params, num_slots=2, max_len=16, t=2,
                     paged=True, quant_collectives="int8")
    with pytest.raises(ValueError, match="GSPMD"):
        make_backend("gspmd", cfg, params, num_slots=2, max_len=16,
                     quant_collectives="int8")
    with pytest.raises(ValueError, match="unknown quant"):
        make_backend("tp", cfg, params, num_slots=2, max_len=16, t=2,
                     quant_collectives="int2")


def test_slo_quant_lowers_volume_never_hurts_tpot():
    """For every TP degree the quantized prediction moves strictly fewer
    decode bytes and never predicts a slower effective tpot (the two-step
    is charged one α — Flash Communication's fused launch, DESIGN.md §12)."""
    from repro.core.slo import predict_slo
    cfg = get_config("llama31-8b")
    for t in (2, 4, 8):
        base = predict_slo(cfg, 64, 256, t=t, p=1)
        q = predict_slo(cfg, 64, 256, t=t, p=1, quant="int8")
        assert q.comm_volume < base.comm_volume, t
        assert q.breakdown["tpot_effective"] <= \
            base.breakdown["tpot_effective"] + 1e-9, t
    assert predict_slo(cfg, 64, 256, t=1, p=1, quant="int8").comm_volume \
        == predict_slo(cfg, 64, 256, t=1, p=1).comm_volume


def test_planner_quant_reenters_volume_budget_frontier():
    """A 250 MiB fabric budget prices TP=8 off the frontier at full width
    (≈291 MiB) — quantized (≈183 MiB) it re-enters and wins TTFT, the
    Flash-Communication shape the planner docstring promises."""
    from repro.core.planner import plan
    cfg = get_config("llama31-8b")
    budget = 250 * 2 ** 20
    base = plan(cfg, 8, 64, 256, objective="ttft", volume_budget=budget)
    quant = plan(cfg, 8, 64, 256, objective="ttft", volume_budget=budget,
                 quant="int8")
    base_tp8 = next(c for c in base if c.tensor_parallel == 8)
    assert base_tp8.score == float("inf")
    assert quant[0].tensor_parallel == 8
    assert quant[0].score < float("inf")
    # and quant never *adds* volume on any candidate
    qvol = {c.name: c.slo.comm_volume for c in quant}
    for c in base:
        assert qvol[c.name] <= c.slo.comm_volume + 1e-6
