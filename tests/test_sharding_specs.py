"""Structural sharding-rule checks for every assigned architecture on the
production mesh shape — catches divisibility bugs without compiling."""
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.config.base import INPUT_SHAPES
from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import skip_reason
from repro.models.transformer import get_model
from repro.runtime import sharding as sh

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("llama")]
MODEL_AXIS_SIZE = 16


def _check_divisible(shapes, specs, where):
    def walk(s_tree, p_tree, path=""):
        if isinstance(s_tree, dict):
            for k in s_tree:
                walk(s_tree[k], p_tree[k], path + "/" + k)
            return
        for dim, ax in zip(s_tree.shape, tuple(p_tree)):
            if ax == "model":
                assert dim % MODEL_AXIS_SIZE == 0, \
                    f"{where}{path}: dim {dim} not divisible by 16 ({p_tree})"
    walk(shapes, specs)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_divisible_on_16way_model_axis(arch):
    cfg = get_config(arch)
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = sh.param_specs(cfg, shapes, axis_size=MODEL_AXIS_SIZE)
    _check_divisible(shapes, specs, arch)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_every_param_leaf_has_a_spec(arch):
    cfg = get_config(arch)
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = sh.param_specs(cfg, shapes)
    n_shapes = len(jax.tree.leaves(shapes))
    n_specs = len(jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_shapes == n_specs


@pytest.mark.parametrize("arch", ASSIGNED)
def test_big_weights_are_sharded(arch):
    """No ≥64 MiB (bf16) weight may be fully replicated across the mesh."""
    cfg = get_config(arch)
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = sh.param_specs(cfg, shapes)

    def walk(s_tree, p_tree, path=""):
        if isinstance(s_tree, dict):
            for k in s_tree:
                walk(s_tree[k], p_tree[k], path + "/" + k)
            return
        nbytes = 2
        for d in s_tree.shape:
            nbytes *= d
        if nbytes >= 64 * 2**20:
            assert any(ax == "model" for ax in tuple(p_tree)), \
                f"{arch}{path}: {s_tree.shape} ({nbytes/2**20:.0f} MiB) replicated"
    walk(shapes, specs)


def test_skip_matrix_matches_design():
    """The documented (arch × shape) skip set — DESIGN.md §4."""
    live, skipped = [], []
    for a in ASSIGNED:
        cfg = get_config(a)
        for s in INPUT_SHAPES.values():
            (skipped if skip_reason(cfg, s) else live).append((a, s.name))
    assert len(live) == 32
    assert ("hubert-xlarge", "decode_32k") in skipped
    assert ("hubert-xlarge", "long_500k") in skipped
    assert ("granite-8b", "long_500k") in skipped
    assert ("rwkv6-7b", "long_500k") in live
    assert ("hymba-1.5b", "long_500k") in live
    assert ("mixtral-8x22b", "long_500k") in live       # SWA
    assert len(skipped) == 8


def test_cache_specs_mqa_falls_back_to_seq_sharding():
    """paligemma kv=1 can't shard heads 16-way: the cache length axis is
    sharded instead (sequence-parallel decode)."""
    cfg = get_config("paligemma-3b")
    model = get_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    cache_shapes = jax.eval_shape(lambda: model.init_cache(128, 32768))
    specs = sh.cache_specs(cfg, FakeMesh(), 128)(cache_shapes)
    assert specs["k"] == P(None, ("data",), "model", None, None) or \
        specs["k"][2] == "model"
