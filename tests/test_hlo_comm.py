"""Unit tests for the HLO collective extractor (canned HLO snippets)."""
from repro.core.hlo_comm import (collective_wire_bytes,
                                 parse_hlo_collectives, summarize)
from repro.core.hlo_cost import analyze_flops_bytes

MODULE = """\
HloModule jit_f, is_scheduled=true

%body (param: (s32[], f32[8,64])) -> (s32[], f32[8,64]) {
  %all-gather = f32[8,256]{0,1} all-gather(%copy), channel_id=1, replica_groups=[1,4]<=[4], dimensions={1}
  %dot = f32[8,64]{1,0} dot(%all-gather, %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,64]{1,0} all-reduce(%dot), channel_id=2, replica_groups={{0,1},{2,3}}, to_apply=%add
}

%cond (param.1: (s32[], f32[8,64])) -> pred[] {
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

ENTRY %main_spmd (p0: f32[8,64]) -> f32[] {
  %while.8 = (s32[], f32[8,64]{1,0}) while(%tuple.4), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %cp = f32[2,64]{1,0} collective-permute(%slice), channel_id=3, source_target_pairs={{0,1},{1,2},{2,3}}
  ROOT %all-reduce.9 = f32[] all-reduce(%sum), channel_id=4, replica_groups=[1,4]<=[4], to_apply=%add
}
"""


def test_trip_count_expansion():
    colls = parse_hlo_collectives(MODULE)
    s = summarize(colls)
    assert s["allgather"]["count"] == 5          # 1 op × trip 5
    assert s["allreduce"]["count"] == 6          # 5 in body + 1 entry
    assert s["collectivepermute"]["count"] == 1


def test_group_sizes_and_wire_factors():
    colls = {c.op_name: c for c in parse_hlo_collectives(MODULE)}
    ag = colls["all-gather"]
    assert ag.group_size == 4                    # iota [1,4]<=[4]
    assert ag.out_bytes == 8 * 256 * 4
    assert ag.wire_bytes == ag.total_bytes * 3 / 4
    ar = colls["ar"]
    assert ar.group_size == 2                    # {{0,1},{2,3}}
    assert ar.wire_bytes == ar.total_bytes * 2 * (2 - 1) / 2
    cp = colls["cp"]
    assert cp.wire_bytes == cp.total_bytes       # permute: 1×


def test_async_start_counted_once():
    text = """\
ENTRY %main (p: f32[4,4]) -> f32[4,4] {
  %ar-start = (f32[4,4], f32[4,4]) all-reduce-start(%p), replica_groups=[1,2]<=[2]
  ROOT %ar-done = f32[4,4] all-reduce-done(%ar-start)
}
"""
    colls = parse_hlo_collectives(text)
    assert len(colls) == 1
    assert colls[0].out_bytes == 64


def test_flops_trip_expansion():
    flops, hbm = analyze_flops_bytes(MODULE)
    # dot: 2 · (8·64) · 256 per iteration × trip 5
    assert flops == 2 * 8 * 64 * 256 * 5


def test_conditional_charged_at_heaviest_branch():
    text = """\
HloModule jit_cond

%cheap (p: f32[4,4]) -> f32[4,4] {
  ROOT %ar.small = f32[4,4] all-reduce(%p), replica_groups=[1,2]<=[2], to_apply=%add
}

%heavy (p: f32[4,4]) -> f32[4,4] {
  %ar.big1 = f32[4,4] all-reduce(%p), replica_groups=[1,2]<=[2], to_apply=%add
  ROOT %ar.big2 = f32[4,4] all-reduce(%ar.big1), replica_groups=[1,2]<=[2], to_apply=%add
}

ENTRY %main (pred: pred[], p: f32[4,4]) -> f32[4,4] {
  ROOT %c = f32[4,4] conditional(%pred, %p, %p), true_computation=%heavy, false_computation=%cheap
}
"""
    s = summarize(parse_hlo_collectives(text))
    assert s["allreduce"]["count"] == 2          # heavy branch only


def test_conditional_branch_list_form():
    text = """\
HloModule jit_switch

%b0 (p: f32[4]) -> f32[4] {
  ROOT %nop = f32[4] copy(%p)
}

%b1 (p: f32[4]) -> f32[4] {
  ROOT %ag = f32[16] all-gather(%p), replica_groups=[1,4]<=[4], dimensions={0}
}

ENTRY %main (i: s32[], p: f32[4]) -> f32[4] {
  ROOT %c = f32[4] conditional(%i, %p, %p), branch_computations={%b0, %b1}
}
"""
    s = summarize(parse_hlo_collectives(text))
    assert s["allgather"]["count"] == 1          # b1 moves bytes, b0 none


def test_empty_module():
    assert parse_hlo_collectives("HloModule empty") == []
    assert collective_wire_bytes("HloModule empty") == 0.0


# ---------------------------------------------------------------------------
# scatter-form reclassification (DESIGN.md §12): psum_scatter sometimes
# compiles as all-reduce + dynamic-slice of the 1/d rank shard; the parser
# must charge it at the reducescatter factor, and must NOT touch an
# all-reduce whose result is consumed more than once (a genuine allreduce).
# ---------------------------------------------------------------------------

SCATTER_FORM = """\
HloModule jit_qstep, is_scheduled=true

ENTRY %main_spmd (p0: s8[4,512]) -> s8[4,128] {
  %ar.q = s8[4,512]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[1,4]<=[4], to_apply=%add
  ROOT %ds = s8[4,128]{1,0} dynamic-slice(%ar.q, %c0, %off), dynamic_slice_sizes={4,128}
}
"""

TWO_CONSUMER = """\
HloModule jit_real_ar, is_scheduled=true

ENTRY %main_spmd (p0: s8[4,512]) -> s8[4,128] {
  %ar.q = s8[4,512]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[1,4]<=[4], to_apply=%add
  %use = s8[4,512]{1,0} add(%ar.q, %ar.q)
  ROOT %ds = s8[4,128]{1,0} dynamic-slice(%use, %c0, %off), dynamic_slice_sizes={4,128}
}
"""


def test_scatter_form_reclassified_to_reducescatter():
    """Op-kind → wire-factor pinned: the slice-form lowering is charged as
    a reduce-scatter — out_bytes = the 1/d shard, wire = (d-1) × shard —
    identical to a native reduce-scatter op of the same shard."""
    colls = {c.op_name: c for c in parse_hlo_collectives(SCATTER_FORM)}
    rs = colls["ar.q"]
    assert rs.kind == "reducescatter"
    assert rs.out_bytes == 4 * 128                 # the rank's shard, s8
    assert rs.group_size == 4
    assert rs.wire_bytes == 4 * 128 * (4 - 1)      # (d-1) × shard
    s = summarize(parse_hlo_collectives(SCATTER_FORM))
    assert "allreduce" not in s
    assert s["reducescatter"]["count"] == 1


def test_multi_consumer_allreduce_not_reclassified():
    """An all-reduce whose full result is live stays an allreduce at the
    2(d-1)/d factor even if one consumer is a dynamic-slice of 1/d."""
    colls = {c.op_name: c for c in parse_hlo_collectives(TWO_CONSUMER)}
    ar = colls["ar.q"]
    assert ar.kind == "allreduce"
    assert ar.out_bytes == 4 * 512
    assert ar.wire_bytes == 4 * 512 * 2 * (4 - 1) / 4
