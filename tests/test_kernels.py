"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
shape/dtype sweeps via hypothesis + parametrized grids."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.kernels.decode_attention.decode_kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.flash_kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.rmsnorm_kernel import rms_norm_pallas
from repro.kernels.rmsnorm.ref import rms_norm_ref
from repro.kernels.rwkv6_scan.ref import wkv6_ref
from repro.kernels.rwkv6_scan.wkv6_kernel import wkv6_pallas

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(rng, shape, dtype, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,hq,hkv,d,bq,bk,window", [
    (128, 4, 2, 64, 64, 64, None),
    (256, 8, 8, 32, 128, 64, None),     # MHA
    (256, 4, 1, 64, 64, 128, None),     # MQA
    (256, 4, 2, 64, 64, 64, 96),        # sliding window
    (128, 2, 2, 128, 128, 128, 64),     # single block + window
])
def test_flash_attention_sweep(dtype, s, hq, hkv, d, bq, bk, window):
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, s, hq, d), dtype)
    k = _rand(rng, (2, s, hkv, d), dtype)
    v = _rand(rng, (2, s, hkv, d), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=bq, block_kv=bk, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@given(s_blocks=st.integers(1, 4), hkv=st.sampled_from([1, 2, 4]),
       groups=st.sampled_from([1, 2, 4]), d=st.sampled_from([32, 64]))
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(s_blocks, hkv, groups, d):
    rng = np.random.default_rng(s_blocks * 131 + hkv * 7 + groups * 3 + d)
    s = 64 * s_blocks
    q = _rand(rng, (1, s, hkv * groups, d), jnp.float32)
    k = _rand(rng, (1, s, hkv, d), jnp.float32)
    v = _rand(rng, (1, s, hkv, d), jnp.float32)
    got = flash_attention_pallas(q, k, v, block_q=64, block_kv=64,
                                 interpret=True)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("w,hq,hkv,d,pos,bkv", [
    (256, 4, 2, 64, 100, 64),
    (512, 8, 1, 64, 512, 128),          # MQA, full cache
    (128, 4, 4, 32, 1, 128),            # single valid slot
])
def test_decode_attention_sweep(dtype, w, hq, hkv, d, pos, bkv):
    rng = np.random.default_rng(1)
    q = _rand(rng, (2, hq, d), dtype)
    k = _rand(rng, (2, w, hkv, d), dtype)
    v = _rand(rng, (2, w, hkv, d), dtype)
    valid = jnp.arange(w) < pos
    got = decode_attention_pallas(q, k, v, valid, block_kv=bkv, interpret=True)
    want = decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_decode_attention_masks_invalid_slots():
    """Changing masked-out cache entries must not change the output."""
    rng = np.random.default_rng(2)
    q = _rand(rng, (1, 4, 32), jnp.float32)
    k = _rand(rng, (1, 128, 2, 32), jnp.float32)
    v = _rand(rng, (1, 128, 2, 32), jnp.float32)
    valid = jnp.arange(128) < 40
    out1 = decode_attention_pallas(q, k, v, valid, block_kv=64, interpret=True)
    k2 = k.at[:, 40:].set(99.0)
    out2 = decode_attention_pallas(q, k2, v, valid, block_kv=64,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,h", [((4, 100), 512), ((2, 7, 33), 256),
                                     ((1,), 128)])
def test_rmsnorm_sweep(dtype, shape, h):
    rng = np.random.default_rng(3)
    x = _rand(rng, shape + (h,), dtype)
    w = _rand(rng, (h,), dtype, scale=0.1)
    got = rms_norm_pallas(x, w, interpret=True, block_rows=64)
    want = rms_norm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


# ---------------------------------------------------------------------------
# wkv6 recurrence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hs,chunk", [
    (2, 64, 3, 32, 16), (1, 128, 2, 64, 128), (3, 32, 1, 16, 8)])
def test_wkv6_sweep(dtype, b, s, h, hs, chunk):
    rng = np.random.default_rng(4)
    r = _rand(rng, (b, s, h, hs), dtype, 0.5)
    k = _rand(rng, (b, s, h, hs), dtype, 0.5)
    v = _rand(rng, (b, s, h, hs), dtype, 0.5)
    w = jnp.asarray(rng.uniform(0.8, 0.999, (b, s, h, hs)), dtype)
    u = _rand(rng, (h, hs), jnp.float32, 0.3)
    st0 = _rand(rng, (b, h, hs, hs), jnp.float32, 0.1)
    got_y, got_s = wkv6_pallas(r, k, v, w, u, st0, chunk=chunk, interpret=True)
    want_y, want_s = wkv6_ref(r, k, v, w, u, st0)
    np.testing.assert_allclose(np.asarray(got_y, np.float32),
                               np.asarray(want_y, np.float32),
                               atol=TOL[dtype] * 4, rtol=TOL[dtype] * 4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=TOL[dtype] * 4, rtol=TOL[dtype] * 4)


def test_wkv6_chunk_invariance():
    """Chunked streaming must equal one-shot processing (state hand-off)."""
    rng = np.random.default_rng(5)
    b, s, h, hs = 1, 64, 2, 32
    args = [_rand(rng, (b, s, h, hs), jnp.float32, 0.5) for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.9, 0.999, (b, s, h, hs)), jnp.float32)
    u = _rand(rng, (h, hs), jnp.float32, 0.3)
    st0 = jnp.zeros((b, h, hs, hs), jnp.float32)
    y_full, s_full = wkv6_ref(*args, w, u, st0)
    y1, s_mid = wkv6_ref(*[a[:, :32] for a in args], w[:, :32], u, st0)
    y2, s_end = wkv6_ref(*[a[:, 32:] for a in args], w[:, 32:], u, s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               atol=1e-5)
