"""Decode fast path: scanned layers, fused multi-token generation, and cache
donation must be numerically indistinguishable from the unrolled paper-parity
engines — and report the *same* collective schedule through hlo_comm."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import parallel_exec as px
from repro.core.hlo_comm import parse_hlo_collectives, summarize
from repro.models.transformer import get_model
from repro.runtime.engine import InferenceEngine

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 4,
                                reason="needs 4 host-platform devices")

T = 4


def _setup(num_layers=3, cache_w=32):
    cfg = get_config("llama32-3b").reduced(num_layers=num_layers)
    mesh = px.make_tp_mesh(T)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 2,
                              cfg.vocab_size)
    return cfg, mesh, params, toks


@needs_mesh
def test_scanned_prefill_matches_unrolled():
    cfg, mesh, params, toks = _setup()
    lu, cu = px.tp_prefill(cfg, mesh, cache_w=32, unroll=True)(params, toks)
    ls, cs = px.tp_prefill(cfg, mesh, cache_w=32, unroll=False)(params, toks)
    np.testing.assert_allclose(np.asarray(lu), np.asarray(ls), atol=1e-4)
    for key in ("k", "v"):
        np.testing.assert_allclose(np.asarray(cu[key]), np.asarray(cs[key]),
                                   atol=1e-5)


@needs_mesh
def test_scanned_decode_matches_unrolled():
    cfg, mesh, params, toks = _setup()
    logits, cache = px.tp_prefill(cfg, mesh, cache_w=32,
                                  unroll=True)(params, toks)
    step_u = px.tp_decode_step(cfg, mesh, unroll=True)
    step_s = px.tp_decode_step(cfg, mesh, unroll=False)
    cache_u, cache_s = cache, jax.tree.map(jnp.copy, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = toks.shape[1]
    for i in range(4):
        lu, cache_u = step_u(params, cache_u, tok, jnp.int32(pos + i))
        ls, cache_s = step_s(params, cache_s, tok, jnp.int32(pos + i))
        np.testing.assert_allclose(np.asarray(lu), np.asarray(ls), atol=1e-4)
        tok = jnp.argmax(lu, -1).astype(jnp.int32)


@needs_mesh
def test_fused_generate_matches_stepwise():
    cfg, mesh, params, toks = _setup()
    logits, cache = px.tp_prefill(cfg, mesh, cache_w=32,
                                  unroll=True)(params, toks)
    step = px.tp_decode_step(cfg, mesh, unroll=True)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = toks.shape[1]
    want, tok = [], tok0
    cache_ref = jax.tree.map(jnp.copy, cache)
    for i in range(6):
        l, cache_ref = step(params, cache_ref, tok, jnp.int32(pos + i))
        tok = jnp.argmax(l, -1).astype(jnp.int32)
        want.append(np.asarray(tok))
    got, _ = px.tp_generate(cfg, mesh, 6)(params, cache, tok0,
                                          jnp.int32(pos))
    np.testing.assert_array_equal(np.asarray(got), np.stack(want, axis=1))


@needs_mesh
def test_decode_cache_donated_and_reused():
    cfg, mesh, params, toks = _setup()
    logits, cache = px.tp_prefill(cfg, mesh, cache_w=32,
                                  unroll=False)(params, toks)
    step = px.tp_decode_step(cfg, mesh, unroll=False)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    ptrs = {key: sorted(s.data.unsafe_buffer_pointer()
                        for s in cache[key].addressable_shards)
            for key in ("k", "v")}
    _, new_cache = step(params, cache, tok, jnp.int32(toks.shape[1]))
    assert cache["k"].is_deleted() and cache["v"].is_deleted()
    for key in ("k", "v"):    # the donated buffers back the new cache
        assert sorted(s.data.unsafe_buffer_pointer()
                      for s in new_cache[key].addressable_shards) == ptrs[key]


@needs_mesh
def test_scanned_hlo_collective_counts_match_unrolled():
    """hlo_comm trip-expansion: scan reports the exact unrolled schedule."""
    cfg, mesh, _, _ = _setup()
    params = jax.eval_shape(
        lambda: get_model(cfg).init(jax.random.PRNGKey(0)))
    toks = jax.ShapeDtypeStruct((2, 12), jnp.int32)
    counts = {}
    for unroll in (True, False):
        hlo = px.tp_prefill(cfg, mesh, unroll=unroll).lower(
            params, toks).compile().as_text()
        counts[unroll] = {k: v["count"]
                          for k, v in summarize(
                              parse_hlo_collectives(hlo)).items()}
    # Eq. 1 / Table III: (2L+1) allreduce + 1 allgather, in both modes
    assert counts[True]["allreduce"] == 2 * cfg.num_layers + 1
    assert counts[True]["allgather"] == 1
    assert counts[False] == counts[True]


@needs_mesh
def test_pipeline_engine_scanned_matches_unrolled():
    cfg, _, params, toks = _setup(num_layers=4)
    outs = []
    for unroll in (True, False):
        eng = px.PipelineEngine(cfg, t=2, p=2, unroll=unroll)
        staged = eng.prepare(params)
        outs.append(np.asarray(eng.forward(staged, toks)))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)


def test_model_decode_steps_matches_stepwise():
    """Fused Model.decode_steps == chain of decode_step + argmax."""
    cfg = get_config("internlm2-1.8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 2,
                              cfg.vocab_size)
    logits, cache, _ = model.prefill(params, toks, max_len=64)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    want, tok = [], tok0
    cache_ref = jax.tree.map(jnp.copy, cache)
    for i in range(5):
        l, cache_ref = model.decode_step(params, cache_ref, tok,
                                         jnp.int32(10 + i))
        tok = jnp.argmax(l, -1).astype(jnp.int32)
        want.append(np.asarray(tok))
    got, _ = model.decode_steps(params, cache, tok0, jnp.int32(10), 5)
    np.testing.assert_array_equal(np.asarray(got), np.stack(want, axis=1))


def test_engine_fused_generation_matches_per_token():
    """InferenceEngine output is decode_chunk-invariant (incl. ragged tail)."""
    cfg = get_config("internlm2-1.8b").reduced()
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 2,
                                 cfg.vocab_size)
    ref = np.asarray(InferenceEngine(cfg, params, max_len=64, decode_chunk=1)
                     .generate(prompts, max_new_tokens=11))
    for chunk in (4, 8, 32):
        out = np.asarray(
            InferenceEngine(cfg, params, max_len=64, decode_chunk=chunk)
            .generate(prompts, max_new_tokens=11))
        np.testing.assert_array_equal(out, ref)
