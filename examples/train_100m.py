"""Train a ~100M-parameter model for a few hundred steps on the synthetic
pipeline — the end-to-end training driver at example scale.

    PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import get_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.train import make_train_step
from repro.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    # ~100M params: a 12-layer, d=512 llama-family model with an 8k vocab
    base = get_config("internlm2-1.8b")
    cfg = dataclasses.replace(
        base, name="repro-100m", num_layers=12, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=8192,
        dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps")

    opt = AdamW(lr=cosine_schedule(3e-4, 20, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    data = SyntheticTokens(cfg.vocab_size, args.seq + 1, args.batch, seed=0)

    t0, first_loss = time.time(), None
    for step, tokens in enumerate(data):
        if step >= args.steps:
            break
        params, opt_state, m = step_fn(params, opt_state,
                                       {"tokens": jnp.asarray(tokens)})
        if first_loss is None:
            first_loss = float(m["loss"])
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):7.4f}  "
                  f"lr {float(m['lr']):.2e}")
    dt = time.time() - t0
    print(f"done: loss {first_loss:.3f} -> {float(m['loss']):.3f} "
          f"({args.steps*args.batch*args.seq/dt:.0f} tok/s)")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, {"params": params}, args.steps)


if __name__ == "__main__":
    main()
