"""Chaos demo: the serving layer surviving a seeded fault schedule.

Runs the continuous-batching scheduler (runtime/scheduler.py) in
``admission="optimistic"`` mode on an oversubscribed KV page pool with a
seeded ``runtime.faults.FaultInjector`` attached — transient decode/prefill
failures (retried with backoff), injected pool exhaustion (recovered by
preemption-by-recompute), and, for verification, the same trace served
fault-free.  Prints which faults were injected, how each request finished,
and checks the two robustness invariants end to end (DESIGN.md §10):

  * every request that finished normally has a token stream bitwise
    identical to the undisturbed run (greedy determinism + recompute);
  * the page pool drains to zero leaked pages whatever the fault schedule
    did.

    PYTHONPATH=src python examples/chaos_demo.py --seed 3 --requests 6
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.faults import FaultInjector
from repro.runtime.request import make_poisson_trace
from repro.runtime.scheduler import Scheduler, VirtualClock


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--seed", type=int, default=0,
                    help="fault-schedule seed (the run is a pure function "
                         "of it — rerun with the same seed to reproduce)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--decode-rate", type=float, default=0.05)
    ap.add_argument("--pool-rate", type=float, default=0.10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    trace = make_poisson_trace(args.requests, 0.0, cfg.vocab_size,
                               prompt_lens=(5, 12), decode_lens=(4, 12),
                               seed=0)

    def paged_backend():
        # ~60% of worst-case page parity: optimistic admission must preempt
        per_slot = -(-args.max_len // args.page_size)
        return make_backend("gspmd", cfg, params, num_slots=args.slots,
                            max_len=args.max_len, paged=True,
                            page_size=args.page_size,
                            num_pages=1 + args.slots * per_slot * 3 // 5)

    # fault-free reference run (same trace, same backend shape)
    ref = Scheduler(paged_backend(), clock=VirtualClock(),
                    admission="optimistic").run(
        make_poisson_trace(args.requests, 0.0, cfg.vocab_size,
                           prompt_lens=(5, 12), decode_lens=(4, 12), seed=0))
    refs = ref.tokens_by_rid()

    inj = FaultInjector(seed=args.seed,
                        rates={"decode": args.decode_rate,
                               "prefill": args.decode_rate,
                               "pool": args.pool_rate},
                        transient_frac=0.7, max_faults=16)
    backend = paged_backend()
    sched = Scheduler(backend, clock=VirtualClock(),
                      admission="optimistic", faults=inj,
                      retry_backoff=1e-3)
    report = sched.run(trace)

    print(f"{cfg.name}: {args.requests} requests, {args.slots} slots, "
          f"oversubscribed pool ({backend.pool.num_pages} pages × "
          f"{args.page_size}), fault seed {args.seed}")
    print(f"injected {len(inj.injected)} faults: " + (", ".join(
        f"{site}@{idx}:{f.kind}" for site, idx, f in inj.injected) or "none"))
    for m in report.metrics:
        print("  " + m.row())
    s = report.summary()
    print(f"preemptions {s['preemptions']}  retries {s['retries']}  "
          f"shed {s['shed']}  total tokens {s['total_tokens']}")

    survivors = [m for m in report.metrics
                 if m.finish_reason in ("length", "eos")]
    diverged = [m.rid for m in survivors if m.tokens != refs[m.rid]]
    assert not diverged, f"survivor streams diverged: {diverged}"
    stats = backend.pool.stats()
    assert stats.used_tokens == 0 and not backend.pool.owners(), \
        "pool leaked pages"
    print(f"OK: {len(survivors)}/{args.requests} survivors bitwise "
          f"identical to the fault-free run; pool drained clean "
          f"({stats.free_pages}/{stats.num_pages - 1} usable pages free)")


if __name__ == "__main__":
    np.random.seed(0)
    main()
