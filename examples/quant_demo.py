"""Quantized-collective demo: the decode hot path on an int8 wire.

Runs the same greedy decode twice on a TP mesh — once with full-width bf16
all-reduces (the paper's §V-B hot path) and once with the quantized
two-step (DESIGN.md §12: per-chunk quantize → reduce-scatter int8 →
all-gather int8 → dequantize) — and prints what the swap costs and saves:

  * predicted decode wire bytes per step, both ways, from the commodel
    closed form (the int8 payload + f32 scale exchange lands ≈ 0.52× the
    bf16 all-reduce wire);
  * greedy token-match rate and max logit drift, measured teacher-forced
    against the full-width run;
  * decode tokens/sec, both ways.

The ``QUANT_TOLERANCE`` numerics contract is asserted at the end — the
demo fails loudly if the quantized path stops agreeing with bf16.

    PYTHONPATH=src python examples/quant_demo.py --tp 2 --tokens 16
"""
import argparse
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4"
                               ).strip()

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core import parallel_exec as px
from repro.kernels.quant_collective import QUANT_TOLERANCE
from repro.models.transformer import get_model

PREFILL = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--quant", default="int8", choices=["int8", "fp8"])
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(num_layers=2)
    mesh = px.make_tp_mesh(args.tp)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (args.batch, PREFILL),
                              2, cfg.vocab_size)
    prefill = px.tp_prefill(cfg, mesh, cache_w=PREFILL + args.tokens,
                            unroll=True)
    logits, cache0 = prefill(params, toks)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)

    step_b = px.tp_decode_step(cfg, mesh, unroll=True)
    step_q = px.tp_decode_step(cfg, mesh, unroll=True,
                               quant_collectives=args.quant)

    def run(step, forced=None):
        cache, tok = jax.tree.map(jnp.copy, cache0), tok0
        logits_all, toks_all = [], []
        for i in range(args.tokens):
            lg, cache = step(params, cache, tok, jnp.int32(PREFILL + i))
            choice = jnp.argmax(lg, -1).astype(jnp.int32)
            logits_all.append(lg)
            toks_all.append(choice)
            tok = choice if forced is None else forced[i]
        jax.block_until_ready(toks_all[-1])
        return jnp.stack(logits_all), jnp.stack(toks_all)

    def tokens_per_s(step):
        run(step)                                     # warmup / compile
        t0 = time.perf_counter()
        run(step)
        return args.tokens * args.batch / (time.perf_counter() - t0)

    # predicted per-step decode wire bytes (commodel closed form; the
    # reduced configs run f32, so b=4 — production bf16 halves both sides
    # and keeps the ratio)
    def decode_wire(quant):
        return sum(o.wire_bytes
                   for o in cm.comm_ops_for(cfg, 1, 2, args.tp, 1, b=4,
                                            batch=args.batch,
                                            gather_mode="allgather",
                                            quant=quant)
                   if o.phase == "decode")

    wire_b, wire_q = decode_wire(None), decode_wire(args.quant)
    ratio = cm.quant_ar_wire_ratio(cfg.d_model, args.tp, quant=args.quant,
                                   b=4)
    print(f"{cfg.name} reduced, TP={args.tp}, B={args.batch}, "
          f"{args.tokens} greedy tokens, quant={args.quant}")
    print(f"  predicted decode wire/step: {wire_b / 1024:.1f} KiB bf16-path "
          f"-> {wire_q / 1024:.1f} KiB quantized "
          f"({100 * (1 - wire_q / wire_b):.1f}% saved; per-layer AR ratio "
          f"{ratio:.4f})")

    ref = run(step_b)
    quant = run(step_q, forced=ref[1])
    match = float(jnp.mean((quant[1] == ref[1]).astype(jnp.float32)))
    drift = float(jnp.max(jnp.abs(quant[0] - ref[0])))
    tps_b, tps_q = tokens_per_s(step_b), tokens_per_s(step_q)
    print(f"  token_match_rate {match:.4f}   max_logit_drift {drift:.4f}")
    print(f"  tokens/sec: {tps_b:.1f} full-width -> {tps_q:.1f} quantized")

    tol = QUANT_TOLERANCE[args.quant]
    assert match >= tol["token_match_floor"], \
        f"token match {match:.4f} below contract {tol['token_match_floor']}"
    assert drift <= tol["logit_drift_ceiling"], \
        f"logit drift {drift:.4f} above contract {tol['logit_drift_ceiling']}"
    assert wire_q < 0.6 * wire_b, \
        f"quantized wire {wire_q:.0f} not < 0.6x full-width {wire_b:.0f}"
    print(f"  OK: within QUANT_TOLERANCE[{args.quant!r}] "
          f"(floor {tol['token_match_floor']}, "
          f"ceiling {tol['logit_drift_ceiling']}) and wire < 0.6x bf16-path")


if __name__ == "__main__":
    main()
