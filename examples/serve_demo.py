"""Serving demo: continuous batching over a mixed-length request trace.

Drives the request-level serving layer the paper's SLO study implies
(runtime/scheduler.py over a DecodeBackend): Poisson arrivals, distinct
prompt/decode lengths per request, admission into freed KV-cache slots
mid-decode, EOS/length eviction — with measured per-request TTFT / TPOT /
E2E printed next to the analytical ``core.slo.predict_slo`` prediction for
the same layout.

    PYTHONPATH=src python examples/serve_demo.py --backend gspmd \
        --requests 8 --slots 4 --rate 4
    PYTHONPATH=src python examples/serve_demo.py --backend pp --pp 2
    PYTHONPATH=src python examples/serve_demo.py --backend tp --tp 1 --cp 2
        (explicit engines need devices: XLA_FLAGS=--xla_force_host_platform_device_count=4;
         --cp > 1 sequence-shards each prefill over the cp mesh axis, DESIGN.md §9)
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.slo import predict_slo
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.request import Request, make_poisson_trace
from repro.runtime.scheduler import Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--backend", default="gspmd",
                    choices=["gspmd", "tp", "pp"])
    ap.add_argument("--tp", type=int, default=None,
                    help="TP degree (default: 2 for --backend tp, else 1)")
    ap.add_argument("--cp", type=int, default=1,
                    help="context-parallel degree (explicit backends only): "
                         "prefill is sequence-sharded over cp workers, "
                         "decode untouched — DESIGN.md §9")
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate (req/s); 0 = closed batch")
    ap.add_argument("--prompt-lens", type=int, nargs=2, default=(8, 40))
    ap.add_argument("--decode-lens", type=int, nargs=2, default=(4, 16))
    ap.add_argument("--max-len", type=int, default=96)
    args = ap.parse_args()

    t = args.tp if args.tp is not None else \
        (2 if args.backend == "tp" and args.cp < 2 else 1)
    cfg = get_config(args.arch).reduced(num_layers=4)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    backend = make_backend(args.backend, cfg, params, num_slots=args.slots,
                           max_len=args.max_len, t=t, c=args.cp, p=args.pp)
    trace = make_poisson_trace(args.requests, args.rate, cfg.vocab_size,
                               prompt_lens=tuple(args.prompt_lens),
                               decode_lens=tuple(args.decode_lens),
                               seed=0, quantum=8)
    print(f"{cfg.name}: backend={args.backend} t={backend.t} c={backend.c} "
          f"p={backend.p} slots={args.slots} requests={args.requests} "
          f"rate={args.rate or 'closed'}")

    # warm the compile caches (one 2-token request per distinct bucketed
    # prompt length + the decode step) so the measured TTFT/TPOT below is
    # serving time, not XLA compile time — comparable to predict_slo
    wrng = np.random.default_rng(1)
    Scheduler(backend).run(
        [Request(rid=10_000 + j, prompt=wrng.integers(2, cfg.vocab_size, s),
                 max_new_tokens=2)
         for j, s in enumerate(sorted({r.prompt_len for r in trace}))])

    report = Scheduler(backend).run(trace)
    for m in report.metrics:
        print("  " + m.row())
    s = report.summary()
    print(f"throughput {s['throughput_tok_s']:.1f} tok/s over "
          f"{s['wall_time_s']:.2f} s;  mean TTFT {s['ttft_mean_s']*1e3:.1f} "
          f"ms  TPOT {s['tpot_mean_s']*1e3:.2f} ms  E2E "
          f"{s['e2e_mean_s']:.2f} s")
    if report.steps:
        st = report.steps[0]
        print(f"per decode step: collectives {st.collective_counts} "
              f"(batch-invariant, asserted against commodel.comm_ops_for); "
              f"predicted wire {st.predicted_wire_bytes/1024:.1f} KiB @ "
              f"batch={args.slots}")

    sp = sum(args.prompt_lens) // 2
    sd = sum(args.decode_lens) // 2
    pred = predict_slo(cfg, sp, sd, t=backend.t, p=backend.p, c=backend.c)
    print(f"analytical single-request prediction (s_p={sp}, s_d={sd}): "
          + pred.row())


if __name__ == "__main__":
    main()
