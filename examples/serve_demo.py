"""End-to-end serving driver: batched requests, prefill + KV-cache decode,
per-phase timing — the inference analogue the paper's workload implies.

    PYTHONPATH=src python examples/serve_demo.py --arch hymba-1.5b --batch 8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import get_model
from repro.runtime.engine import InferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params,
                             max_len=args.prompt_len + args.new_tokens + 8)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(2, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    # TTFT: prefill + first token
    t0 = time.time()
    logits, cache, _ = jax.block_until_ready(
        engine._prefill(params, prompts))
    ttft = time.time() - t0
    # TPOT: steady-state decode
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = args.prompt_len
    t1 = time.time()
    for _ in range(args.new_tokens - 1):
        tok, cache = engine._step(params, cache, tok, jnp.int32(pos))
        pos += 1
    tok.block_until_ready()
    tpot = (time.time() - t1) / (args.new_tokens - 1)
    print(f"{cfg.name}: batch={args.batch} "
          f"TTFT={ttft*1e3:.1f}ms TPOT={tpot*1e3:.2f}ms "
          f"throughput={args.batch/tpot:.1f} tok/s")


if __name__ == "__main__":
    main()
