"""Quickstart: build a reduced architecture, run a forward pass, one train
step, and a short greedy generation — the whole public API in 40 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch granite-8b]
"""
import argparse

import jax

from repro.configs import get_config
from repro.models.transformer import get_model
from repro.optim.adamw import AdamW
from repro.runtime.engine import InferenceEngine
from repro.runtime.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()          # 2 layers, CPU-sized
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} family={cfg.family} d_model={cfg.d_model}")

    # forward
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 2,
                                cfg.vocab_size)
    logits, aux = model.forward(params, tokens)
    print(f"forward: logits {logits.shape}, aux={float(aux):.4f}")

    # one train step
    opt = AdamW()
    step = jax.jit(make_train_step(model, opt))
    params, _, metrics = step(params, opt.init(params), {"tokens": tokens})
    print(f"train step: loss={float(metrics['loss']):.4f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    # greedy generation through the KV-cache engine
    engine = InferenceEngine(cfg, params, max_len=64)
    out = engine.generate(tokens[:, :16], max_new_tokens=8)
    print(f"generated: {out.shape} -> {out[0].tolist()}")


if __name__ == "__main__":
    main()
