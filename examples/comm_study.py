"""The paper, end-to-end: predict communication for a deployment, compare
parallelism layouts, and get a recommendation — Sections III + V-C as an API.

    PYTHONPATH=src python examples/comm_study.py --arch llama31-8b --world 8

``--measure`` additionally runs the explicit PipelineEngine (reduced config,
host-platform devices) through prefill + decode and prints the logged
boundary transfers next to the Eq. 2 / Table V predictions — the measured
counterpart of the analytical decode rows.
"""
import argparse
import os

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core.planner import plan
from repro.core.slo import predict_slo


def measure_pp_decode(arch: str, p: int = 2, s_p: int = 8, n_gen: int = 5):
    """Measured vs predicted PP decode transfers on a reduced config."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={p}").strip()
    import jax
    import jax.numpy as jnp
    from repro.core import parallel_exec as px
    from repro.models.transformer import get_model

    cfg = get_config(arch).reduced(num_layers=4)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, s_p), 2,
                              cfg.vocab_size)
    eng = px.PipelineEngine(cfg, t=1, p=p, unroll=False)
    staged = eng.prepare(params)
    logits, caches = eng.prefill_with_cache(staged, toks, s_p + n_gen)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    eng.generate(staged, caches, tok0, s_p, n_gen)

    ops = cm.pp_comm_ops(cfg, s_p, n_gen + 1, p, b=4, batch=1)
    print(f"\n=== measured PP decode, {cfg.name} (t=1, p={p}, "
          f"S_p={s_p}, {n_gen} generated tokens → s_d={n_gen + 1})")
    for phase in ("prefill", "decode"):
        got = eng.transfer_summary(phase=phase)
        want = [o for o in ops
                if o.collective == "send" and o.phase == phase][0]
        tag = "OK" if (got["count"], got["bytes"]) == \
            (want.count, want.total_msg_bytes) else "MISMATCH"
        print(f"  {phase:8s} measured count={got['count']:3d} "
              f"bytes={got['bytes']:7d} | predicted count={want.count:3d} "
              f"bytes={want.total_msg_bytes:7d}  [{tag}]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31-8b")
    ap.add_argument("--world", type=int, default=8)
    ap.add_argument("--prefill", type=int, default=128)
    ap.add_argument("--decode", type=int, default=512)
    ap.add_argument("--measure", action="store_true",
                    help="run the reduced explicit PP engine and compare "
                         "logged decode transfers to Eq. 2")
    args = ap.parse_args()
    cfg = get_config(args.arch)

    print(f"=== communication breakdown, {cfg.name}, "
          f"S_p={args.prefill} S_d={args.decode}")
    for name, t, p in [("TP", args.world, 1), ("PP", 1, args.world),
                       ("hybrid", 2, args.world // 2)]:
        ops = cm.comm_ops_for(cfg, args.prefill, args.decode, t, p)
        vol = cm.total_volume(ops)
        print(f"\n{name} (t={t}, p={p}): wire volume {vol/2**20:.1f} MiB")
        for o in ops:
            print(f"  {o.phase:8s} {o.collective:10s} count={o.count:7d} "
                  f"shape={list(o.shape)}")

    print("\n=== SLO predictions (H100-node profile)")
    for name, t, p in [("TP", args.world, 1), ("PP", 1, args.world),
                       ("hybrid", 2, args.world // 2)]:
        r = predict_slo(cfg, args.prefill, args.decode, t=t, p=p)
        print(f"  {name:7s} {r.row()}")

    print("\n=== planner recommendation (objective=e2e)")
    for c in plan(cfg, args.world, args.prefill, args.decode)[:3]:
        print(f"  {c.name:14s} {c.slo.row()}")

    if args.measure:
        measure_pp_decode(args.arch)


if __name__ == "__main__":
    main()
