"""The paper, end-to-end: predict communication for a deployment, compare
parallelism layouts, and get a recommendation — Sections III + V-C as an API.

    PYTHONPATH=src python examples/comm_study.py --arch llama31-8b --world 8
"""
import argparse

from repro.configs import get_config
from repro.core import commodel as cm
from repro.core.planner import plan
from repro.core.slo import predict_slo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31-8b")
    ap.add_argument("--world", type=int, default=8)
    ap.add_argument("--prefill", type=int, default=128)
    ap.add_argument("--decode", type=int, default=512)
    args = ap.parse_args()
    cfg = get_config(args.arch)

    print(f"=== communication breakdown, {cfg.name}, "
          f"S_p={args.prefill} S_d={args.decode}")
    for name, t, p in [("TP", args.world, 1), ("PP", 1, args.world),
                       ("hybrid", 2, args.world // 2)]:
        ops = cm.comm_ops_for(cfg, args.prefill, args.decode, t, p)
        vol = cm.total_volume(ops)
        print(f"\n{name} (t={t}, p={p}): wire volume {vol/2**20:.1f} MiB")
        for o in ops:
            print(f"  {o.phase:8s} {o.collective:10s} count={o.count:7d} "
                  f"shape={list(o.shape)}")

    print("\n=== SLO predictions (H100-node profile)")
    for name, t, p in [("TP", args.world, 1), ("PP", 1, args.world),
                       ("hybrid", 2, args.world // 2)]:
        r = predict_slo(cfg, args.prefill, args.decode, t=t, p=p)
        print(f"  {name:7s} {r.row()}")

    print("\n=== planner recommendation (objective=e2e)")
    for c in plan(cfg, args.world, args.prefill, args.decode)[:3]:
        print(f"  {c.name:14s} {c.slo.row()}")


if __name__ == "__main__":
    main()
