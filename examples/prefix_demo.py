"""Prefix-cache demo: cross-request KV reuse with copy-on-write pages.

Serves a zipf-distributed template-heavy trace (``make_template_trace`` —
the production shape where thousands of users share a handful of system
prompts) twice through the SAME paged backend + scheduler: a cold pass
that writes and indexes every template, then a hit pass whose requests
adopt the cached template pages and chunk-prefill only their novel
suffixes (DESIGN.md §13).  Prints the hit rate, the prefill chunks and
collectives actually executed vs what a cold serve would have issued
(``commodel.prefix_cache_ops``), and cold-vs-hit TTFT, then checks the
three invariants end to end:

  * every hit stream is bitwise identical to an undisturbed solo run of
    the same request (adopted KV == recomputed KV, COW included);
  * executed prefill chunks equal the per-request suffix arithmetic;
  * clearing the index drains the pool to zero leaked pages.

    PYTHONPATH=src python examples/prefix_demo.py --requests 8 --slots 2
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import commodel as cm
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.engine import InferenceEngine
from repro.runtime.request import make_template_trace
from repro.runtime.scheduler import Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--templates", type=int, default=2)
    ap.add_argument("--template-len", type=int, default=24,
                    help="shared system-prompt length (3 pages at the "
                         "default page size)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))

    def trace(rid_base=0):
        reqs = make_template_trace(
            args.requests, 0.0, cfg.vocab_size,
            n_templates=args.templates, template_len=args.template_len,
            suffix_lens=(3, 7), decode_lens=(3, 6), seed=args.seed)
        for r in reqs:
            r.rid += rid_base
        return reqs

    backend = make_backend("gspmd", cfg, params, num_slots=args.slots,
                           max_len=args.max_len, paged=True,
                           page_size=args.page_size, prefix_cache=True)
    # wall clock (not VirtualClock): the cold-vs-hit TTFT delta is the
    # demo's headline number and only exists in real time
    sched = Scheduler(backend, chunk_size=args.chunk)

    # cold pass: writes the template pages and indexes every full block
    cold = sched.run(trace(rid_base=0))
    cold_chunks = [s for s in cold.steps if s.phase == "prefill"]

    # hit pass: identical prompt distribution, fresh rids — every request
    # now finds its whole template in the index
    reqs = trace(rid_base=1000)
    report = sched.run(reqs)
    hit_chunks = [s for s in report.steps if s.phase == "prefill"]
    hits = {m.rid: m.cached_prefix_len for m in report.metrics
            if m.cached_prefix_len > 0}

    print(f"prefix cache over {args.requests} requests, "
          f"{args.templates} templates × {args.template_len} tokens, "
          f"page {args.page_size}, chunk {args.chunk}:")
    print(f"  hit rate        {len(hits)}/{len(reqs)} "
          f"({100.0 * len(hits) / len(reqs):.0f}%), "
          f"{sum(hits.values())} prompt positions adopted")
    print(f"  prefill chunks  cold pass {len(cold_chunks)}, "
          f"hit pass {len(hit_chunks)}")

    # skipped collectives at the modal request shape (whole template hit)
    mean_suffix = int(np.mean(
        [m.prompt_len - m.cached_prefix_len for m in report.metrics]))
    ops = cm.prefix_cache_ops(cfg, args.template_len, max(1, mean_suffix),
                              chunk=args.chunk)
    print(f"  per-hit comm    skipped {ops.skipped_bytes:,.0f} wire bytes "
          f"({ops.skipped_counts or 'no collectives at t=1'}), executed "
          f"{ops.executed_bytes:,.0f}")
    # TTFT relative to each pass's own epoch: the scheduler's wall clock
    # keeps running between run() calls while the trace's arrival=0 does
    # not, so raw m.ttft would charge the hit pass for the cold pass's
    # wall time
    def pass_ttfts(rep, rids=None):
        epoch = min(m.admitted for m in rep.metrics)
        return [m.first_token - epoch for m in rep.metrics
                if rids is None or m.rid in rids]

    print(f"  TTFT mean       cold "
          f"{1e3 * np.mean(pass_ttfts(cold)):.1f} ms, hit "
          f"{1e3 * np.mean(pass_ttfts(report, hits)):.1f} ms")

    # invariant 1: bitwise token identity vs undisturbed solo serving
    eng = InferenceEngine(cfg, params, max_len=args.max_len, decode_chunk=1)
    got = report.tokens_by_rid()
    for r in reqs:
        solo = np.asarray(eng.generate(
            np.asarray(r.prompt)[None, :],
            max_new_tokens=r.max_new_tokens))[0].tolist()
        assert got[r.rid] == solo, \
            f"request {r.rid}: cache-hit stream diverged from solo run"

    # invariant 2: executed chunks == per-request suffix arithmetic
    want = sum(-(-(m.prompt_len - m.cached_prefix_len) // args.chunk)
               for m in report.metrics)
    assert len(hit_chunks) == want, \
        f"{len(hit_chunks)} prefill chunks executed, suffix math says {want}"

    # invariant 3: zero-leak drain once the index lets go
    evicted = backend.prefix_index.clear()
    stats = backend.pool.stats()
    assert stats.used_tokens == 0 and \
        backend.pool.free_pages == backend.pool.num_pages - 1, \
        f"pool leaked pages after draining the index: {stats}"
    print(f"  drained         {evicted} index entries evicted, "
          f"0 pages leaked, {stats.cow_copies} COW copies over the run")
    print("OK: hit streams bitwise identical, suffix-only prefill, "
          "zero-leak drain")


if __name__ == "__main__":
    main()
