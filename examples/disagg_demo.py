"""Disaggregated prefill/decode demo: two pools, one modeled KV handoff.

Serves a mixed chat+summarize trace two ways through the SAME model
(DESIGN.md §14): colocated — one scheduler where every long prompt's
prefill chunks steal decode steps from the chat requests (head-of-line
blocking) — and disaggregated, where a 1-slot prefill pool absorbs the
long prompts and ships their finished KV pages into the decode pool's
shared ``KVPool`` over the modeled interconnect.  Prints each handoff
(pages, bytes, and the ``commodel.kv_handoff_ops`` prediction the
scheduler asserts against), chat-request TPOT under both schedules, and
the §14 planner's colocated-vs-disagg decision for the same workload
shape, then checks the invariants end to end:

  * every stream — chat and long, both schedules — is bitwise identical
    to an undisturbed solo run of the same request;
  * measured handoff bytes equal the closed form exactly (the scheduler
    raises on any drift, so the demo finishing is itself the check);
  * clearing the prefix index drains the shared pool to zero leaked
    pages.

    PYTHONPATH=src python examples/disagg_demo.py --chat 6 --longs 2
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.planner import TrafficClass, plan_disagg
from repro.models.transformer import get_model
from repro.runtime.backends import make_backend
from repro.runtime.engine import InferenceEngine
from repro.runtime.request import make_poisson_trace
from repro.runtime.scheduler import DisaggScheduler, Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--chat", type=int, default=6)
    ap.add_argument("--longs", type=int, default=2)
    ap.add_argument("--slots", type=int, default=3,
                    help="decode-pool slots (the prefill pool gets 1)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--route", type=int, default=32,
                    help="prompts >= this route through the prefill pool")
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(num_layers=2)
    params = get_model(cfg).init(jax.random.PRNGKey(0))

    def trace():
        chat = make_poisson_trace(
            args.chat, 0.0, cfg.vocab_size, prompt_lens=(6, 14),
            decode_lens=(4, 8), seed=args.seed, quantum=2)
        longs = make_poisson_trace(
            args.longs, 0.0, cfg.vocab_size,
            prompt_lens=(args.route, args.max_len - 12),
            decode_lens=(3, 6), seed=args.seed + 1, quantum=4)
        for r in longs:
            r.rid += 100                     # chat rids < 100
        return chat + longs

    # colocated: one pool, one scheduler, chunked prefill interleaved
    colo_backend = make_backend("gspmd", cfg, params, num_slots=args.slots,
                                max_len=args.max_len, paged=True,
                                page_size=args.page_size)
    colo = Scheduler(colo_backend, chunk_size=args.chunk).run(trace())

    # disaggregated: decode pool + 1-slot prefill pool on ONE KVPool,
    # disjoint owner ranges; the decode pool's prefix index receives the
    # shipped prompt blocks
    dec = make_backend("gspmd", cfg, params, num_slots=args.slots,
                       max_len=args.max_len, paged=True,
                       page_size=args.page_size, prefix_cache=True)
    pre = make_backend("gspmd", cfg, params, num_slots=1,
                       max_len=args.max_len, paged=True,
                       page_size=args.page_size, pool=dec.pool,
                       owner_base=args.slots)
    sched = DisaggScheduler(pre, dec, chunk_size=args.chunk,
                            route_prompt_len=args.route)
    reqs = trace()
    report = sched.run(reqs)

    print(f"disaggregated serve, {args.chat} chat + {args.longs} long "
          f"requests, route >= {args.route}, page {args.page_size}:")
    for h in report.handoffs:
        print(f"  handoff rid {h.rid:<4d} {h.pages} pages  "
              f"{h.bytes:>9,d} B measured == {int(h.predicted_bytes):,d} B "
              f"predicted  (prefill {1e3 * h.prefill_s:.1f} ms)")

    def chat_tpot(rep):
        return float(np.mean([m.tpot for m in rep.metrics
                              if m.rid < 100 and m.num_generated > 1]))

    print(f"  chat TPOT       colocated {1e3 * chat_tpot(colo):.2f} ms, "
          f"disagg decode pool {1e3 * chat_tpot(report):.2f} ms "
          f"(decode-pool clock: long prefills run elsewhere)")

    # invariant 1: bitwise identity vs undisturbed solo serving, both ways
    eng = InferenceEngine(cfg, params, max_len=args.max_len, decode_chunk=1)
    got_colo, got_dis = colo.tokens_by_rid(), report.tokens_by_rid()
    for r in reqs:
        solo = np.asarray(eng.generate(
            np.asarray(r.prompt)[None, :],
            max_new_tokens=r.max_new_tokens))[0].tolist()
        assert got_colo[r.rid] == solo, f"rid {r.rid}: colocated diverged"
        assert got_dis[r.rid] == solo, f"rid {r.rid}: disagg diverged"

    # invariant 2: the handoff volume sits exactly on the closed form
    # (the scheduler asserts per ship; re-check the totals here)
    assert report.handoff_bytes == int(sum(h.predicted_bytes
                                           for h in report.handoffs))
    assert len(report.handoffs) == args.longs

    # invariant 3: zero-leak drain of the SHARED pool
    evicted = dec.prefix_index.clear()
    stats = dec.pool.stats()
    assert stats.used_tokens == 0 and \
        dec.pool.free_pages == dec.pool.num_pages - 1, \
        f"shared pool leaked pages after draining the index: {stats}"
    print(f"  drained         {evicted} index entries evicted, "
          f"0 pages leaked across the pool boundary")

    # the §14 decision rule at serving scale (closed form, full config)
    full = get_config(args.arch)
    classes = [TrafficClass("chat", 24, 128, 4.0),
               TrafficClass("summarize", 2048, 32, 0.6)]
    best = plan_disagg(full, 8, classes)[0]
    print(f"  planner         mixed workload on 8 chips -> {best.name}")
    print("OK: streams bitwise identical under both schedules, handoff "
          "bytes == kv_handoff_ops closed form, zero-leak drain")


if __name__ == "__main__":
    main()
