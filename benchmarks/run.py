"""Benchmark aggregator: one module per paper table/figure + assigned-scope
benches.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback

MODULES = [
    "benchmarks.table3_tp",
    "benchmarks.table4_models",
    "benchmarks.table5_pp",
    "benchmarks.table6_hybrid",
    "benchmarks.fig6_volume",
    "benchmarks.fig7_scaling",
    "benchmarks.fig8_9_10_slo",
    "benchmarks.fig4_validation",
    "benchmarks.planner_bench",
    "benchmarks.kernel_bench",
    "benchmarks.roofline_table",
    "benchmarks.perf_variants",
    "benchmarks.decode_bench",
]


def main() -> None:
    import importlib
    print("name,us_per_call,derived")
    failures = []
    for modname in MODULES:
        try:
            mod = importlib.import_module(modname)
            for name, us, derived in mod.rows():
                print(f"{name},{us:.2f},{derived}")
        except Exception:
            failures.append(modname)
            traceback.print_exc(file=sys.stderr)
    if failures:
        print(f"# FAILED modules: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
