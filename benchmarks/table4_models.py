"""Paper Table IV: Allreduce message size & count across model scales."""
from benchmarks.common import timed
from repro.configs import get_config
from repro.core import commodel as cm

MODELS = ["llama32-3b", "llama31-8b", "llama2-13b"]


def rows():
    out = []
    for arch in MODELS:
        cfg = get_config(arch)
        ops, us = timed(lambda c=cfg: cm.tp_comm_ops(c, 128, 128, 4))
        ar = [o for o in ops if o.collective == "allreduce"]
        out.append((f"table4/{arch}/prefill_allreduce", us,
                    f"msg_bytes={ar[0].msg_bytes};count={ar[0].count}"))
        out.append((f"table4/{arch}/decode_allreduce", us,
                    f"msg_bytes={ar[1].msg_bytes};count={ar[1].count}"))
    return out


def main():
    print("Table IV — Allreduce size/count across models (TP=4, 128/128)")
    for r in rows():
        print(f"  {r[0]:45s} {r[2]}")


if __name__ == "__main__":
    main()
