"""Paper Fig 7: communication volume vs decode sequence length."""
from benchmarks.common import timed
from repro.configs import get_config
from repro.core import commodel as cm

MODELS = ["llama32-3b", "llama31-8b", "llama2-13b"]
LAYOUTS = [("tp4", 4, 1), ("pp4", 1, 4), ("tp2pp2", 2, 2)]
SD = [128, 256, 512]


def rows():
    out = []
    for arch in MODELS:
        cfg = get_config(arch)
        for name, t, p in LAYOUTS:
            vols = {}
            for sd in SD:
                vols[sd], us = timed(lambda c=cfg, t=t, p=p, sd=sd:
                                     cm.total_volume(
                                         cm.hybrid_comm_ops(c, 128, sd, t, p)))
            g1 = vols[256] / vols[128]
            g2 = vols[512] / vols[256]
            out.append((f"fig7/{arch}/{name}", us,
                        f"v128={vols[128]:.0f};v256={vols[256]:.0f};"
                        f"v512={vols[512]:.0f};growth={g1:.2f}x/{g2:.2f}x"))
    return out


def main():
    print("Fig 7 — decode-length scaling (S_p=128, bf16)")
    for r in rows():
        print(f"  {r[0]:34s} {r[2]}")
    cfg = get_config("llama31-8b")
    v = {sd: cm.v_tp(cfg, 128, sd, 4) for sd in SD}
    print(f"  growth factors (TP4, 8B): {v[256]/v[128]:.3f} (paper ~1.50), "
          f"{v[512]/v[256]:.3f} (paper ~1.67)")


if __name__ == "__main__":
    main()
