"""Paper Fig 6: total communication volume per parallelism strategy."""
from benchmarks.common import fmt_bytes, timed
from repro.configs import get_config
from repro.core import commodel as cm

MODELS = ["llama32-3b", "llama31-8b", "llama2-13b"]
LAYOUTS = [("tp4", 4, 1), ("pp4", 1, 4), ("tp2pp2", 2, 2)]


def rows():
    out = []
    for arch in MODELS:
        cfg = get_config(arch)
        for name, t, p in LAYOUTS:
            vol, us = timed(lambda c=cfg, t=t, p=p: cm.total_volume(
                cm.hybrid_comm_ops(c, 128, 128, t, p)))
            out.append((f"fig6/{arch}/{name}", us,
                        f"wire_bytes={vol:.0f};{fmt_bytes(vol)}"))
    return out


def main():
    print("Fig 6 — communication volume by strategy (128/128, bf16)")
    for r in rows():
        print(f"  {r[0]:34s} {r[2]}")
    # invariant highlighted in the paper
    for arch in MODELS:
        cfg = get_config(arch)
        v = {n: cm.total_volume(cm.hybrid_comm_ops(cfg, 128, 128, t, p))
             for n, t, p in LAYOUTS}
        assert v["pp4"] < v["tp2pp2"] < v["tp4"]
    print("  ordering PP < hybrid < TP holds for all models ✓")


if __name__ == "__main__":
    main()
