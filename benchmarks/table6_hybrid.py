"""Paper Table VI: hybrid TP=2 × PP=2 breakdown, Llama-3.1-8B."""
from benchmarks.common import timed
from repro.configs import get_config
from repro.core import commodel as cm


def rows():
    cfg = get_config("llama31-8b")
    ops, us = timed(lambda: cm.hybrid_comm_ops(cfg, 128, 128, 2, 2))
    return [(f"table6/tp2pp2/{o.phase}/{o.collective}", us,
             f"count={o.count};shape={list(o.shape)}") for o in ops]


def main():
    print("Table VI — hybrid TP=2 PP=2 breakdown (Llama-3.1-8B, 128/128)")
    for r in rows():
        print(f"  {r[0]:42s} {r[2]}")


if __name__ == "__main__":
    main()
