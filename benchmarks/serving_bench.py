"""Serving benchmark: continuous batching under Poisson traffic (paper §V-C).

Drives the continuous-batching scheduler (runtime/scheduler.py) over each
DecodeBackend with mixed-length request traces at increasing arrival rates,
producing the throughput-vs-latency curves the paper's SLO section draws from
measurement — measured TTFT / TPOT / E2E sit next to the analytical
``core.slo.predict_slo`` prediction for the same layout, so the two sides of
the paper's methodology (measure + model) face each other at request level.

Backends × layouts (4-device host-platform mesh):

  gspmd    ModelBackend, t=1 p=1 — the GSPMD Model path
  tp2      TPBackend, explicit TP over 2 devices
  pp2      PPBackend, explicit PP over 2 single-device stages

Emits ``BENCH_serve.json`` at the repo root (per backend × rate: throughput,
mean/p95 TTFT/TPOT/E2E, queue delay).  Runs in a subprocess so the device
flag stays contained.  ``--dry-run`` serves one tiny closed trace per
backend and skips the JSON write — the CI smoke mode that keeps every
serving entrypoint compiling.
"""
import json
import os
import subprocess
import sys

ARCH = "llama32-3b"
REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_PATH = os.path.join(REPO, "BENCH_serve.json")

N_REQUESTS = 24
NUM_SLOTS = 4
DRY_REQUESTS = 4
DRY_SLOTS = 2
MAX_LEN = 96
RATES = [2.0, 8.0, 0.0]          # req/s; 0 = closed batch (all at t=0)
PROMPT_LENS = (8, 48)
DECODE_LENS = (4, 24)


def _measure(dry_run: bool = False):
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4").strip()
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.slo import predict_slo
    from repro.models.transformer import get_model
    from repro.runtime.backends import make_backend
    from repro.runtime.request import Request, make_poisson_trace
    from repro.runtime.scheduler import Scheduler

    cfg = get_config(ARCH).reduced(num_layers=4)
    params = get_model(cfg).init(jax.random.PRNGKey(0))

    n_requests = DRY_REQUESTS if dry_run else N_REQUESTS
    num_slots = DRY_SLOTS if dry_run else NUM_SLOTS
    rates = [0.0] if dry_run else RATES
    backends = [("gspmd", dict()), ("tp2", dict(t=2)),
                ("pp2", dict(t=1, p=2))]

    # analytical counterpart: one mean-shape request on an idle engine
    sp_mean = sum(PROMPT_LENS) // 2
    sd_mean = sum(DECODE_LENS) // 2
    results = []
    for name, kw in backends:
        kind = {"gspmd": "gspmd", "tp2": "tp", "pp2": "pp"}[name]
        t, p = kw.get("t", 1), kw.get("p", 1)
        pred = predict_slo(cfg, sp_mean, sd_mean, t=t, p=p)
        # ONE backend per kind, reused across rates — the jits live on it,
        # so the compile caches warm once; admission fully overwrites slot
        # rows, making reuse across runs safe
        backend = make_backend(kind, cfg, params, num_slots=num_slots,
                               max_len=MAX_LEN, **kw)
        traces = {rate: make_poisson_trace(
            n_requests, rate, cfg.vocab_size, prompt_lens=PROMPT_LENS,
            decode_lens=DECODE_LENS, seed=7, quantum=8) for rate in rates}
        # warm the compile caches off the clock: one 2-token request per
        # distinct bucketed prompt length, plus the decode step itself
        wrng = np.random.default_rng(1)
        warm = [Request(rid=10_000 + j,
                        prompt=wrng.integers(2, cfg.vocab_size, s),
                        max_new_tokens=2)
                for j, s in enumerate(
                    sorted({r.prompt_len for t in traces.values()
                            for r in t}))]
        Scheduler(backend).run(warm)
        for rate in rates:
            report = Scheduler(backend).run(traces[rate])
            s = report.summary()
            results.append({
                "arch": cfg.name, "backend": name, "tp": t, "pp": p,
                "num_slots": num_slots, "rate_req_s": rate,
                **s,
                "queue_delay_mean_s": float(
                    sum(m.queue_delay for m in report.metrics)
                    / len(report.metrics)),
                "decode_steps": len(report.steps),
                "predicted_ttft_s": pred.ttft,
                "predicted_tpot_s": pred.tpot,
                "predicted_e2e_s": pred.e2e,
            })
    print("SERVEJSON:" + json.dumps(results))


def _run_subprocess(dry_run: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    cmd = [sys.executable, "-m", "benchmarks.serving_bench", "--measure"]
    if dry_run:
        cmd.append("--dry-run")
    try:
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=REPO, timeout=1800)
    except subprocess.TimeoutExpired:
        return None, "timeout after 1800s"
    for line in r.stdout.splitlines():
        if line.startswith("SERVEJSON:"):
            return json.loads(line[len("SERVEJSON:"):]), None
    return None, r.stderr[-300:]


def rows(dry_run: bool = False):
    recs, err = _run_subprocess(dry_run)
    if recs is None:
        return [("serve/bench", 0.0, f"subprocess_failed;stderr={err}")]
    if not dry_run:
        with open(OUT_PATH, "w") as f:
            json.dump(recs, f, indent=2, sort_keys=True)
    out = []
    for r in recs:
        rate = "closed" if not r["rate_req_s"] else f"{r['rate_req_s']:g}rps"
        out.append((
            f"serve/{r['arch']}/t{r['tp']}p{r['pp']}/{r['backend']}/{rate}",
            r["throughput_tok_s"],
            f"tok_per_s={r['throughput_tok_s']:.1f};"
            f"ttft_p95={r['ttft_p95_s']*1e3:.0f}ms;"
            f"tpot_mean={r['tpot_mean_s']*1e3:.1f}ms;"
            f"e2e_p95={r['e2e_p95_s']:.2f}s"))
    return out


def main(dry_run: bool = False):
    # mirror the knobs _measure actually uses in each mode
    mode = (f"dry-run smoke, {DRY_REQUESTS} reqs, {DRY_SLOTS} slots"
            if dry_run
            else f"{N_REQUESTS} reqs × {RATES}, {NUM_SLOTS} slots")
    print(f"Continuous-batching serving — gspmd vs tp2 vs pp2 "
          f"({mode}, Poisson arrivals)")
    rs = rows(dry_run)
    for r in rs:
        print(f"  {r[0]:52s} {r[2]}")
    if dry_run and any(r[0] == "serve/bench" for r in rs):
        raise SystemExit("serving_bench smoke failed")
    if not dry_run and os.path.exists(OUT_PATH):
        print(f"  wrote {OUT_PATH}")


if __name__ == "__main__":
    if "--measure" in sys.argv:
        _measure(dry_run="--dry-run" in sys.argv)
    else:
        main(dry_run="--dry-run" in sys.argv)
