"""Serving benchmark: continuous batching under Poisson traffic (paper §V-C).

Drives the continuous-batching scheduler (runtime/scheduler.py) over each
DecodeBackend with mixed-length request traces at increasing arrival rates,
producing the throughput-vs-latency curves the paper's SLO section draws from
measurement — measured TTFT / TPOT / E2E sit next to the analytical
``core.slo.predict_slo`` prediction for the same layout, so the two sides of
the paper's methodology (measure + model) face each other at request level.

Seven series (4-device host-platform mesh):

  short       gspmd / tp2 / pp2, contiguous slots, prompts 8–48 at three
              arrival rates — the original throughput-vs-latency sweep
  longctx     prompts spanning 16–512 (the regime where a contiguous
              ``max_len`` slot pool wastes most of its memory): contiguous
              vs ``paged=True`` + chunked prefill on the same trace — the
              paged-vs-contiguous throughput series (DESIGN.md §8)
  cp-longctx  the same long-context trace through the explicit
              single-stage engine at cp ∈ {1, 2, 4} (DESIGN.md §9):
              per-prompt-length mean TTFT (``ttft_by_prompt_len``) shows
              where sequence-sharded prefill starts paying for its ring
  overload    an EOS-heavy closed trace (``eos_prob``) on an oversubscribed
              page pool, conservative vs optimistic admission (DESIGN.md
              §10): optimistic packs more requests per fused decode step
              and pays with preemption-by-recompute — check_baselines
              gates ``tokens_per_decode_step`` (optimistic ≥ conservative,
              compared within the dry-run file: it is trace-dependent, so
              it is not diffed against the full-series baseline) and the
              recompute collective counts; the run completing at all is
              the zero-MemoryError-escapes assertion
  prefix-cache  a template-heavy closed trace (``make_template_trace``,
              DESIGN.md §13) served twice through tp2 paged + chunked
              prefill: once cold, once with the cross-request prefix
              index live — ``check_baselines.check_prefix_cache`` gates
              bitwise token identity between the two (checksum), executed
              prefill chunks/counts == the per-request suffix arithmetic
              (``commodel.prefix_cache_ops``'s executed column), hit TTFT
              strictly below the cold run's on the same rids, and a
              zero-leak pool drain once the index is cleared
  disagg-mixed  the §14 acceptance bench: one seeded chat+summarize trace
              served three ways — the chat subset alone (the decode
              pool's gate baseline), the full mix colocated (long
              prefill chunks steal decode steps: head-of-line blocking),
              and the full mix through ``DisaggScheduler`` (longs
              prefill in a 1-slot prefill pool sharing the decode
              pool's KVPool, finished pages ship on the modeled
              interconnect).  ``check_baselines.check_disagg`` gates
              bitwise chat-stream identity across all three, measured
              handoff bytes == the ``kv_handoff_ops`` closed form, a
              zero-leak drain, the §14 planner's decision rule, and (on
              the full series) decode-pool chat p99 TPOT within 1.10×
              of the baseline while colocated degrades ≥ 1.5×
  pp-occupancy  the dynamic-schedule payoff curve (DESIGN.md §11): the SAME
              closed request set through pp2/pp4 at in-flight depth
              d ∈ 1..p (``num_slots = 2·d`` so depth adds concurrent
              groups, never shrinks them).  Every quantity gated here is
              on the deterministic schedule clock — decode ticks, tokens
              per tick and per-stage busy fractions land EXACTLY on
              ``commodel.pp_schedule_stats`` (single-process hosts cannot
              overlap stages in wall time, so wall tokens/s is reported
              but not gated), per-round boundary bytes land exactly on the
              PP closed form, and the token checksum is depth-invariant —
              the bitwise-identity acceptance across schedules

Every record carries the *predicted* per-step decode collective counts (and,
for paged runs, the per-chunk prefill counts; for CP runs, the per-prefill
counts with the ring rows) from ``commodel`` — these are deterministic and
machine-independent, so CI's bench-regression gate
(`benchmarks/check_baselines.py`) can diff them against the checked-in
``BENCH_serve.json`` without chasing timing noise.

Emits ``BENCH_serve.json`` at the repo root.  Runs in a subprocess so the
device flag stays contained.  ``--dry-run`` serves one tiny closed trace per
backend (including a paged one) and writes ``results/BENCH_serve.dryrun.json``
for the CI artifact + drift gate instead of the full series.
"""
import json
import os
import subprocess
import sys

ARCH = "llama32-3b"
REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_PATH = os.path.join(REPO, "BENCH_serve.json")
DRY_PATH = os.path.join(REPO, "results", "BENCH_serve.dryrun.json")

N_REQUESTS = 24
NUM_SLOTS = 4
DRY_REQUESTS = 4
DRY_SLOTS = 2
MAX_LEN = 96
RATES = [2.0, 8.0, 0.0]          # req/s; 0 = closed batch (all at t=0)
PROMPT_LENS = (8, 48)
DECODE_LENS = (4, 24)

# long-context mixed trace: prompts up to 512 tokens (paged vs contiguous)
LONG_PROMPT_LENS = (16, 512)
LONG_DECODE_LENS = (4, 16)
LONG_MAX_LEN = 544
LONG_REQUESTS = 8
LONG_QUANTUM = 32
CHUNK_SIZE = 64
PAGE_SIZE = 16

# overload series: EOS-heavy mix on a pool that cannot hold every slot's
# worst case at once (DESIGN.md §10)
OV_REQUESTS = 16
OV_PROMPT_LENS = (8, 32)
OV_DECODE_LENS = (6, 20)
OV_MAX_LEN = 64
OV_EOS_PROB = 0.3

# prefix-cache series: template-heavy trace on tp2 paged + chunked with
# the cross-request prefix index (DESIGN.md §13).  Two-page templates so
# every hit adopts full blocks; suffixes stay under one chunk.
PC_REQUESTS = 16
PC_TEMPLATE_PAGES = 2
PC_SUFFIX_LENS = (4, 12)
PC_DECODE_LENS = (4, 8)
PC_MAX_LEN = 96

# disagg-mixed series (DESIGN.md §14): chat + summarize traffic, three
# ways — the chat subset alone (the decode pool's gate baseline), the
# full mix colocated (long prefill chunks steal decode steps: the
# head-of-line blocking the paper's mixed traces measure), and the full
# mix through DisaggScheduler (longs prefill in a 1-slot prefill pool
# sharing the decode pool's KVPool; finished pages ship on the modeled
# interconnect and chat TPOT is measured on the decode pool's clock).
DM_CHAT_REQUESTS = 18
DM_LONG_REQUESTS = 4
DM_CHAT_PROMPTS = (8, 24)        # strictly under DM_ROUTE: never routed
DM_CHAT_DECODE = (8, 16)
DM_LONG_PROMPTS = (192, 320)
DM_LONG_DECODE = (4, 8)
DM_CHAT_RATE = 4.0
DM_LONG_RATE = 1.0
DM_ROUTE = 48
DM_MAX_LEN = 352
DM_PAGES = 128
DM_SLOTS = 4

# pp-occupancy series: dynamic-schedule depth sweep (DESIGN.md §11).  A
# request group is OCC_GROUP slots; depth d runs d groups in flight on
# num_slots = OCC_GROUP·d, and every depth serves the same seeded
# OCC_GROUP·p-request closed set so tokens are comparable bitwise.
OCC_GROUP = 2
OCC_PROMPT_LEN = 8
OCC_NEW_TOKENS = 6


def _measure(dry_run: bool = False):
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4").strip()
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.slo import predict_slo
    from repro.models.transformer import get_model
    from repro.runtime.backends import make_backend
    from repro.runtime.request import Request, make_poisson_trace
    from repro.runtime.scheduler import Scheduler, step_collective_counts

    cfg = get_config(ARCH).reduced(num_layers=4)
    params = get_model(cfg).init(jax.random.PRNGKey(0))

    def _count(ops):
        counts = {}
        for o in ops:
            counts[o.collective] = counts.get(o.collective, 0) + o.count
        return counts

    def chunk_counts(backend, chunk):
        return _count(backend.chunk_comm_ops(chunk))

    def run_series(series, kind, name, t, p, paged, chunk, num_slots,
                   max_len, traces, warm_lens, rates, sp_mean, sd_mean):
        backend = make_backend(kind, cfg, params, num_slots=num_slots,
                               max_len=max_len, t=t, p=p, paged=paged,
                               page_size=PAGE_SIZE)
        sched = lambda: Scheduler(backend,
                                  chunk_size=chunk if paged else None)
        # warm the compile caches off the clock: one 2-token request per
        # distinct bucketed prompt length, plus the decode step itself
        wrng = np.random.default_rng(1)
        warm = [Request(rid=10_000 + j,
                        prompt=wrng.integers(2, cfg.vocab_size, s),
                        max_new_tokens=2)
                for j, s in enumerate(sorted(warm_lens))]
        sched().run(warm)
        # analytical counterpart at THIS series' mean request shape
        pred = predict_slo(cfg, sp_mean, sd_mean, t=t, p=p)
        out = []
        for rate in rates:
            report = sched().run(traces[rate])
            s = report.summary()
            out.append({
                "series": series, "arch": cfg.name, "backend": name,
                "tp": t, "cp": 1, "pp": p, "paged": paged,
                "chunk_size": chunk if paged else None,
                "inflight": 1, "num_slots": num_slots, "rate_req_s": rate,
                **s,
                "queue_delay_mean_s": float(
                    sum(m.queue_delay for m in report.metrics)
                    / len(report.metrics)),
                "decode_steps": len([r for r in report.steps
                                     if r.phase == "decode"]),
                "prefill_chunks": len([r for r in report.steps
                                       if r.phase == "prefill"]),
                "decode_collective_counts":
                    step_collective_counts(backend, 1),
                "prefill_chunk_counts":
                    chunk_counts(backend, chunk) if paged else None,
                "predicted_ttft_s": pred.ttft,
                "predicted_tpot_s": pred.tpot,
                "predicted_e2e_s": pred.e2e,
            })
        return out

    n_requests = DRY_REQUESTS if dry_run else N_REQUESTS
    num_slots = DRY_SLOTS if dry_run else NUM_SLOTS
    rates = [0.0] if dry_run else RATES

    results = []
    # -- short series: gspmd vs tp2 vs pp2 (contiguous, as before) + a
    #    paged gspmd point so paged-vs-contiguous exists at every scale
    short_backends = [("gspmd", "gspmd", 1, 1, False),
                      ("tp", "tp2", 2, 1, False),
                      ("pp", "pp2", 1, 2, False),
                      ("gspmd", "gspmd-paged", 1, 1, True)]
    traces = {rate: make_poisson_trace(
        n_requests, rate, cfg.vocab_size, prompt_lens=PROMPT_LENS,
        decode_lens=DECODE_LENS, seed=7, quantum=8) for rate in rates}
    warm_lens = {r.prompt_len for t in traces.values() for r in t}
    for kind, name, t, p, paged in short_backends:
        results += run_series("short", kind, name, t, p, paged,
                              8 if dry_run else CHUNK_SIZE // 4, num_slots,
                              MAX_LEN, traces, warm_lens, rates,
                              sum(PROMPT_LENS) // 2, sum(DECODE_LENS) // 2)

    # -- long-context series: prompts 16–512, paged vs contiguous on the
    #    same closed trace (arrival rate stresses nothing new here)
    long_n = 3 if dry_run else LONG_REQUESTS
    long_lens = (16, 96) if dry_run else LONG_PROMPT_LENS
    long_max = 128 if dry_run else LONG_MAX_LEN
    ltraces = {0.0: make_poisson_trace(
        long_n, 0.0, cfg.vocab_size, prompt_lens=long_lens,
        decode_lens=LONG_DECODE_LENS, seed=11, quantum=LONG_QUANTUM)}
    lwarm = {r.prompt_len for t in ltraces.values() for r in t}
    for name, paged in [("gspmd", False), ("gspmd-paged", True)]:
        results += run_series("longctx", "gspmd", name, 1, 1, paged,
                              16 if dry_run else CHUNK_SIZE, num_slots,
                              long_max, ltraces, lwarm, [0.0],
                              sum(long_lens) // 2,
                              sum(LONG_DECODE_LENS) // 2)

    # -- CP prefill series: the same long-context closed trace through the
    #    explicit single-stage engine at cp ∈ {1, 2, 4} — TTFT vs prompt
    #    length is the payoff curve of sequence-sharded prefill
    #    (DESIGN.md §9).  TPBackend at t=1, c=1 is the 1-device explicit
    #    engine: the same code path as the c>1 points, so the TTFT deltas
    #    are the ring's, not an engine swap's.
    from repro.runtime.backends import TPBackend

    for cdeg in ([1, 2] if dry_run else [1, 2, 4]):
        backend = TPBackend(cfg, params, num_slots=num_slots,
                            max_len=long_max, t=1, c=cdeg)
        sched = lambda: Scheduler(backend)
        wrng = np.random.default_rng(1)
        sched().run([Request(rid=10_000 + j,
                             prompt=wrng.integers(2, cfg.vocab_size, s),
                             max_new_tokens=2)
                     for j, s in enumerate(sorted(lwarm))])
        report = sched().run(ltraces[0.0])
        by_len = {}
        for m in report.metrics:
            by_len.setdefault(m.prompt_len, []).append(m.ttft)
        pred = predict_slo(cfg, sum(long_lens) // 2,
                           sum(LONG_DECODE_LENS) // 2, t=1, c=cdeg)
        s = report.summary()
        results.append({
            "series": "cp-longctx", "arch": cfg.name,
            "backend": f"cp{cdeg}", "tp": 1, "cp": cdeg, "pp": 1,
            "paged": False, "chunk_size": None, "inflight": 1,
            "num_slots": num_slots, "rate_req_s": 0.0, **s,
            "ttft_by_prompt_len_s": {
                str(k): float(np.mean(v))
                for k, v in sorted(by_len.items())},
            "decode_collective_counts":
                step_collective_counts(backend, 1),
            "prefill_collective_counts":
                _count(backend.prefill_comm_ops(64)),
            "predicted_ttft_s": pred.ttft,
            "predicted_tpot_s": pred.tpot,
            "predicted_e2e_s": pred.e2e,
        })
    # -- pp-occupancy series: the dynamic instruction-queue schedule
    #    (DESIGN.md §11) at in-flight depth 1..p.  One request group is
    #    OCC_GROUP slots; depth d serves d groups concurrently
    #    (num_slots = OCC_GROUP·d), and every depth serves the IDENTICAL
    #    seeded request set, so tokens must be bitwise depth-invariant.
    #    All gated quantities are schedule-clock (tick) exact:
    #    check_baselines diffs them against commodel.pp_schedule_stats.
    import hashlib

    from repro.core.commodel import pp_schedule_stats

    occ_m = 4 if dry_run else OCC_NEW_TOKENS       # tokens per request
    occ_rounds = occ_m - 1                         # decode rounds after prefill
    for p in ([2] if dry_run else [2, 4]):
        n_req = OCC_GROUP * p
        prng = np.random.default_rng(23)
        prompts = [prng.integers(2, cfg.vocab_size, OCC_PROMPT_LEN)
                   .astype(np.int32) for _ in range(n_req)]
        checksums = {}
        for d in range(1, p + 1):
            slots = OCC_GROUP * d
            backend = make_backend("pp", cfg, params, num_slots=slots,
                                   max_len=MAX_LEN, t=1, p=p, inflight=d)
            sched = lambda: Scheduler(backend)
            wrng = np.random.default_rng(1)
            sched().run([Request(rid=10_000,
                                 prompt=wrng.integers(2, cfg.vocab_size,
                                                      OCC_PROMPT_LEN),
                                 max_new_tokens=2)])
            report = sched().run([
                Request(rid=i, prompt=prompts[i], max_new_tokens=occ_m)
                for i in range(n_req)])
            s = report.summary()
            occ = report.occupancy()
            toks = report.tokens_by_rid()
            checksum = hashlib.sha256(
                json.dumps(toks, sort_keys=True).encode()).hexdigest()
            checksums[d] = checksum
            # the scheduler admits in waves of `slots` requests (admission
            # syncs the queue), so predicted ticks compose per wave
            pred_ticks, pred_busy_rounds, left = 0, 0, n_req
            while left > 0:
                wave = min(left, slots)
                left -= wave
                st = pp_schedule_stats(p, wave // OCC_GROUP, occ_rounds)
                pred_ticks += st.ticks
                pred_busy_rounds += st.stage_forwards[0]
            send = [o for o in backend.decode_comm_ops(batch=OCC_GROUP)
                    if o.collective == "send"]
            dec = [r for r in report.steps if r.phase == "decode"]
            results.append({
                "series": "pp-occupancy", "arch": cfg.name,
                "backend": f"pp{p}-inflight{d}", "tp": 1, "cp": 1,
                "pp": p, "paged": False, "chunk_size": None,
                "inflight": d, "num_slots": slots, "rate_req_s": 0.0,
                **s,
                "decode_ticks": occ["ticks"],
                "decode_tokens": occ["decode_tokens"],
                "tokens_per_tick": occ["tokens_per_tick"],
                "stage_busy_fraction": occ["stage_busy_fraction"],
                "busy_fraction_mean": occ["busy_fraction_mean"],
                "decode_rounds": len(dec),
                "predicted_ticks": pred_ticks,
                "predicted_busy_fraction":
                    pred_busy_rounds / pred_ticks if pred_ticks else 0.0,
                "boundary_bytes_per_round_measured":
                    sum(r.measured_transfers.get("bytes", 0) for r in dec)
                    / max(len(dec), 1),
                "boundary_bytes_per_round_predicted":
                    float(sum(o.total_msg_bytes for o in send)),
                "decode_collective_counts":
                    step_collective_counts(backend, OCC_GROUP),
                "token_checksum": checksum,
                "token_checksum_matches_depth1":
                    checksum == checksums[1],
            })

    # -- overload series: conservative vs optimistic admission on an
    #    oversubscribed pool, EOS-heavy closed trace (DESIGN.md §10).  Both
    #    policies serve the identical trace to completion (greedy decode is
    #    deterministic, so both produce identical token streams — the bench
    #    finishing IS the zero-MemoryError-escapes check); optimistic packs
    #    more live requests per fused step and pays in recompute passes.
    from repro.core.commodel import preemption_recompute_ops
    from repro.core.slo import predict_goodput

    ov_n = DRY_REQUESTS if dry_run else OV_REQUESTS
    otrace = make_poisson_trace(ov_n, 0.0, cfg.vocab_size,
                                prompt_lens=OV_PROMPT_LENS,
                                decode_lens=OV_DECODE_LENS, seed=13,
                                quantum=8, eos_prob=OV_EOS_PROB)
    pages_worst = -(-(OV_PROMPT_LENS[1] + OV_DECODE_LENS[1] - 1)
                    // PAGE_SIZE)
    # ~40% of worst-case parity: each request still fits alone (the
    # max() floor is the livelock-freedom condition — a lone survivor can
    # always finish), but the full slot set cannot, so optimistic
    # admission must actually preempt when the EOS-heavy mix's tail
    # requests run their whole budget
    ov_pages = 1 + max(pages_worst, num_slots * pages_worst * 2 // 5)
    owarm = sorted({r.prompt_len for r in otrace})
    eos_mean = float(np.mean([r.eos_pos if r.eos_pos is not None
                              else r.max_new_tokens for r in otrace]))
    for admission in ("conservative", "optimistic"):
        backend = make_backend("gspmd", cfg, params, num_slots=num_slots,
                               max_len=OV_MAX_LEN, paged=True,
                               page_size=PAGE_SIZE, num_pages=ov_pages)
        sched = lambda: Scheduler(backend, admission=admission)
        wrng = np.random.default_rng(1)
        sched().run([Request(rid=10_000 + j,
                             prompt=wrng.integers(2, cfg.vocab_size, s),
                             max_new_tokens=2)
                     for j, s in enumerate(owarm)])
        report = sched().run(otrace)
        s = report.summary()
        decode_steps = len([r for r in report.steps
                            if r.phase == "decode"])
        gp = predict_goodput(
            cfg, sum(OV_PROMPT_LENS) // 2, sum(OV_DECODE_LENS) // 2,
            num_slots=num_slots,
            capacity_tokens=(ov_pages - 1) * PAGE_SIZE,
            eos_mean=eos_mean, admission=admission)
        results.append({
            "series": "overload", "arch": cfg.name,
            "backend": f"gspmd-paged-{admission}", "tp": 1, "cp": 1,
            "pp": 1, "paged": True, "chunk_size": None, "inflight": 1,
            "admission": admission, "num_slots": num_slots,
            "rate_req_s": 0.0, **s,
            "pool_pages": ov_pages, "eos_prob": OV_EOS_PROB,
            "decode_steps": decode_steps,
            "recompute_steps": len([r for r in report.steps
                                    if r.phase == "recompute"]),
            # deterministic packing metric: counts are clock-independent on
            # a closed trace, so this is gatable (within one file) while
            # wall-clock throughput is not
            "tokens_per_decode_step":
                s["total_tokens"] / max(decode_steps, 1),
            "decode_collective_counts":
                step_collective_counts(backend, 1),
            # recompute collectives == a prefill's (counts are prefix-
            # length-invariant; only bytes scale)
            "recompute_collective_counts":
                _count(preemption_recompute_ops(cfg, 32, 1, 1,
                                                gather_mode="allgather")),
            "predicted_goodput_tok_s": gp.goodput_tok_s,
            "predicted_preempt_rate": gp.preempt_rate,
        })

    # -- prefix-cache series: the SAME template-heavy closed trace served
    #    cold and with the cross-request prefix index (DESIGN.md §13).
    #    The warm pass (rids 10_000+, identical prompts) compiles every
    #    chunk shape off the clock AND — on the cached backend — populates
    #    the index, so the measured pass hits on every request: the clean
    #    executed-vs-skipped comparison.  All gated quantities are either
    #    deterministic counts or within-file TTFT orderings.
    import hashlib

    from repro.core.commodel import prefix_cache_ops
    from repro.runtime.request import make_template_trace

    pc_n = DRY_REQUESTS if dry_run else PC_REQUESTS
    pc_tmpl = PC_TEMPLATE_PAGES * PAGE_SIZE
    pc_chunk = PAGE_SIZE
    pc_trace = make_template_trace(
        pc_n, 0.0, cfg.vocab_size, n_templates=2, template_len=pc_tmpl,
        suffix_lens=PC_SUFFIX_LENS, decode_lens=PC_DECODE_LENS, seed=17)
    pc_checksum = {}
    pc_ttft = {}
    # canonical closed form at the modal request shape (hit = the whole
    # template, suffix = mean suffix): drift-gated against the baseline
    pc_ops = prefix_cache_ops(cfg, pc_tmpl, sum(PC_SUFFIX_LENS) // 2,
                              chunk=pc_chunk, t=2, gather_mode="allgather")
    for cached in (False, True):
        backend = make_backend("tp", cfg, params, num_slots=num_slots,
                               max_len=PC_MAX_LEN, t=2, paged=True,
                               page_size=PAGE_SIZE, prefix_cache=cached)
        sched = lambda: Scheduler(backend, chunk_size=pc_chunk)
        sched().run([Request(rid=10_000 + i, prompt=r.prompt.copy(),
                             max_new_tokens=2) for i, r in
                     enumerate(pc_trace)])
        report = sched().run(pc_trace)
        s = report.summary()
        toks = report.tokens_by_rid()
        pc_checksum[cached] = hashlib.sha256(
            json.dumps(toks, sort_keys=True).encode()).hexdigest()
        pc_ttft[cached] = {m.rid: m.ttft for m in report.metrics}
        hits = {m.rid: m.cached_prefix_len for m in report.metrics
                if m.cached_prefix_len > 0}
        chunks = [r for r in report.steps if r.phase == "prefill"]
        executed = {}
        for r in chunks:
            for k, v in r.collective_counts.items():
                executed[k] = executed.get(k, 0) + v
        # per-request suffix arithmetic: ceil((s_p - hit) / chunk) passes
        pred_chunks = sum(
            -(-(m.prompt_len - m.cached_prefix_len) // pc_chunk)
            for m in report.metrics)
        per_chunk = chunk_counts(backend, pc_chunk)
        hit_rids = sorted(hits)
        drained = True
        if cached:
            backend.prefix_index.clear()
            drained = (backend.pool.stats().used_tokens == 0
                       and backend.pool.free_pages
                       == backend.pool.num_pages - 1)
        results.append({
            "series": "prefix-cache", "arch": cfg.name,
            "backend": "tp2-paged-prefix" if cached else "tp2-paged",
            "tp": 2, "cp": 1, "pp": 1, "paged": True,
            "chunk_size": pc_chunk, "inflight": 1,
            "num_slots": num_slots, "rate_req_s": 0.0, **s,
            "prefix_cache": cached, "template_len": pc_tmpl,
            "hits": len(hits),
            "hit_rate_measured": len(hits) / len(pc_trace),
            "cached_prefix_tokens": sum(hits.values()),
            "prefill_chunks": len(chunks),
            "predicted_prefill_chunks": pred_chunks,
            "executed_prefill_counts": executed,
            "predicted_executed_prefill_counts":
                {k: v * pred_chunks for k, v in per_chunk.items()},
            "prefill_chunk_counts": per_chunk,
            "decode_collective_counts":
                step_collective_counts(backend, 1),
            "prefix_cache_ops_executed_counts": pc_ops.executed_counts,
            "prefix_cache_ops_skipped_counts": pc_ops.skipped_counts,
            "ttft_hit_mean_s": float(np.mean(
                [pc_ttft[cached][r] for r in hit_rids]))
                if cached and hit_rids else None,
            "ttft_cold_mean_s": float(np.mean(
                [pc_ttft[False][r] for r in hit_rids]))
                if cached and hit_rids else None,
            "token_checksum": pc_checksum[cached],
            "token_checksum_matches_uncached":
                pc_checksum[cached] == pc_checksum[False],
            "pool_drained": drained,
            "index_stats":
                backend.prefix_index.stats() if cached else None,
        })

    # -- disagg-mixed series: the §14 acceptance bench.  The SAME seeded
    #    mixed trace three ways; every checksum below is over token
    #    streams, so "disagg changes nothing but the schedule" is gated
    #    bitwise, and the handoff volume is gated against the closed form
    #    (the scheduler itself asserts measured == predicted per ship).
    from repro.core.planner import TrafficClass, recommend_disagg
    from repro.runtime.scheduler import DisaggScheduler

    dm_chat_n = DRY_REQUESTS if dry_run else DM_CHAT_REQUESTS
    dm_long_n = 2 if dry_run else DM_LONG_REQUESTS
    dm_long_lens = (96, 128) if dry_run else DM_LONG_PROMPTS
    dm_long_quantum = 32 if dry_run else 64
    dm_max = 160 if dry_run else DM_MAX_LEN
    dm_rates = (0.0, 0.0) if dry_run else (DM_CHAT_RATE, DM_LONG_RATE)
    dm_chat = make_poisson_trace(dm_chat_n, dm_rates[0], cfg.vocab_size,
                                 prompt_lens=DM_CHAT_PROMPTS,
                                 decode_lens=DM_CHAT_DECODE, seed=29,
                                 quantum=8)
    dm_long = make_poisson_trace(dm_long_n, dm_rates[1], cfg.vocab_size,
                                 prompt_lens=dm_long_lens,
                                 decode_lens=DM_LONG_DECODE, seed=31,
                                 quantum=dm_long_quantum)
    for r in dm_long:
        r.rid += 100                         # chat rids < 100, longs >= 100
    dm_mixed = sorted(dm_chat + dm_long, key=lambda r: (r.arrival, r.rid))
    dm_warm = sorted({r.prompt_len for r in dm_mixed})

    def dm_backend(slots, owner_base=0, prefix=False, pool=None):
        return make_backend("gspmd", cfg, params, num_slots=slots,
                            max_len=dm_max, paged=True,
                            page_size=PAGE_SIZE, num_pages=DM_PAGES,
                            prefix_cache=prefix, pool=pool,
                            owner_base=owner_base)

    def dm_warm_reqs():
        wrng = np.random.default_rng(1)
        return [Request(rid=10_000 + j,
                        prompt=wrng.integers(2, cfg.vocab_size, s),
                        max_new_tokens=2)
                for j, s in enumerate(dm_warm)]

    def dm_stats(metrics, chat_only=False):
        ms = [m for m in metrics if not chat_only or m.rid < 100]
        tpots = [m.tpot for m in ms if m.num_generated > 1]
        return {
            "chat_tpot_mean_s": float(np.mean(tpots)),
            "chat_tpot_p99_s": float(np.percentile(tpots, 99)),
            "chat_ttft_p95_s": float(np.percentile(
                [m.ttft for m in ms], 95)),
        }

    def dm_checksum(toks, chat_only=False):
        sub = {k: v for k, v in toks.items()
               if not chat_only or int(k) < 100}
        return hashlib.sha256(
            json.dumps(sub, sort_keys=True).encode()).hexdigest()

    dm_records = {}
    for mode in ("chat-only", "colocated", "disagg"):
        trace = dm_chat if mode == "chat-only" else dm_mixed
        if mode == "disagg":
            dec = dm_backend(DM_SLOTS, prefix=True)
            pre = dm_backend(1, owner_base=DM_SLOTS, pool=dec.pool)
            sched = lambda: DisaggScheduler(pre, dec,
                                            chunk_size=CHUNK_SIZE,
                                            route_prompt_len=DM_ROUTE)
            sched().run(dm_warm_reqs())
            dec.prefix_index.clear()         # warm entries must not hit
        else:
            backend = dm_backend(DM_SLOTS)
            sched = lambda: Scheduler(backend, chunk_size=CHUNK_SIZE)
            sched().run(dm_warm_reqs())
        report = sched().run(trace)
        s = report.summary()
        toks = report.tokens_by_rid()
        rec = {
            "series": "disagg-mixed", "arch": cfg.name, "backend": mode,
            "tp": 1, "cp": 1, "pp": 1, "paged": True,
            "chunk_size": CHUNK_SIZE, "inflight": 1,
            "num_slots": DM_SLOTS, "rate_req_s": dm_rates[0], **s,
            **dm_stats(report.metrics, chat_only=True),
            "decode_collective_counts": step_collective_counts(
                dec if mode == "disagg" else backend, 1),
            "prefill_chunk_counts": chunk_counts(
                dec if mode == "disagg" else backend, CHUNK_SIZE),
            "token_checksum": dm_checksum(toks),
            "chat_token_checksum": dm_checksum(toks, chat_only=True),
        }
        if mode == "disagg":
            dm = s["disagg"]
            drained_ok = True
            dec.prefix_index.clear()
            drained_ok = (dec.pool.stats().used_tokens == 0
                          and dec.pool.free_pages
                          == dec.pool.num_pages - 1)
            # the decision rule the bench motivates, scored by the
            # analytical §14 planner at serving scale (closed form —
            # deterministic, drift-gated)
            full = get_config(ARCH)
            mixed_cls = [TrafficClass("chat", 24, 128, 4.0),
                         TrafficClass("summarize", 2048, 32, 0.6)]
            best_mixed = recommend_disagg(full, 8, mixed_cls)
            best_chat = recommend_disagg(full, 8, mixed_cls[:1])
            rec.update({
                "handoffs": dm["handoffs"],
                "handoff_pages": dm["handoff_pages"],
                "handoff_bytes": dm["handoff_bytes"],
                "predicted_handoff_bytes": dm["predicted_handoff_bytes"],
                "pool_drained": drained_ok,
                "planner_mixed_mode": best_mixed.mode,
                "planner_chat_mode": best_chat.mode,
            })
        dm_records[mode] = rec
        results.append(rec)
    print("SERVEJSON:" + json.dumps(results))


def _run_subprocess(dry_run: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    cmd = [sys.executable, "-m", "benchmarks.serving_bench", "--measure"]
    if dry_run:
        cmd.append("--dry-run")
    try:
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=REPO, timeout=1800)
    except subprocess.TimeoutExpired:
        return None, "timeout after 1800s"
    for line in r.stdout.splitlines():
        if line.startswith("SERVEJSON:"):
            return json.loads(line[len("SERVEJSON:"):]), None
    return None, r.stderr[-300:]


def rows(dry_run: bool = False):
    recs, err = _run_subprocess(dry_run)
    if recs is None:
        return [("serve/bench", 0.0, f"subprocess_failed;stderr={err}")]
    path = DRY_PATH if dry_run else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(recs, f, indent=2, sort_keys=True)
    out = []
    for r in recs:
        rate = "closed" if not r["rate_req_s"] else f"{r['rate_req_s']:g}rps"
        out.append((
            f"serve/{r['series']}/{r['arch']}/t{r['tp']}p{r['pp']}/"
            f"{r['backend']}/{rate}",
            r["throughput_tok_s"],
            f"tok_per_s={r['throughput_tok_s']:.1f};"
            f"ttft_p95={r['ttft_p95_s']*1e3:.0f}ms;"
            f"tpot_mean={r['tpot_mean_s']*1e3:.1f}ms;"
            f"e2e_p95={r['e2e_p95_s']:.2f}s"))
    return out


def main(dry_run: bool = False):
    # mirror the knobs _measure actually uses in each mode
    mode = (f"dry-run smoke, {DRY_REQUESTS} reqs, {DRY_SLOTS} slots"
            if dry_run
            else f"{N_REQUESTS} reqs × {RATES}, {NUM_SLOTS} slots")
    print(f"Continuous-batching serving — gspmd/tp2/pp2 + paged, short, "
          f"long-context & overload-admission traces ({mode}, "
          f"Poisson arrivals)")
    rs = rows(dry_run)
    for r in rs:
        print(f"  {r[0]:60s} {r[2]}")
    if dry_run and any(r[0] == "serve/bench" for r in rs):
        raise SystemExit("serving_bench smoke failed")
    out = DRY_PATH if dry_run else OUT_PATH
    if os.path.exists(out):
        print(f"  wrote {out}")


if __name__ == "__main__":
    if "--measure" in sys.argv:
        _measure(dry_run="--dry-run" in sys.argv)
    else:
        main(dry_run="--dry-run" in sys.argv)
