"""Roofline table from the dry-run campaign (results/dryrun/*.json).

One row per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, and the MODEL_FLOPS/HLO_FLOPs useful ratio — EXPERIMENTS.md
§Roofline is generated from this module.
"""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def records(variant_filter=None):
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        if variant_filter is not None and r.get("variant",
                                                "baseline") != variant_filter:
            continue
        recs.append(r)
    return recs


def rows():
    out = []
    for r in records("baseline"):
        rf = r["roofline"]
        step_ms = max(rf["compute_s"], rf["memory_s"],
                      rf["collective_s"]) * 1e3
        out.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                    step_ms * 1e3,
                    f"compute_ms={rf['compute_s']*1e3:.2f};"
                    f"memory_ms={rf['memory_s']*1e3:.2f};"
                    f"collective_ms={rf['collective_s']*1e3:.2f};"
                    f"dominant={rf['dominant']};"
                    f"useful={rf['useful_ratio']:.3f}"))
    return out


def main():
    print("Roofline terms per (arch × shape × mesh) — from compiled dry-runs")
    recs = records("baseline")
    if not recs:
        print("  (no dry-run records yet: run python -m repro.launch.dryrun --all)")
        return
    hdr = (f"  {'arch':18s} {'shape':12s} {'mesh':12s} {'compute':>10s} "
           f"{'memory':>10s} {'collective':>11s}  dominant   useful  GB/dev")
    print(hdr)
    for r in recs:
        rf = r["roofline"]
        print(f"  {r['arch']:18s} {r['shape']:12s} {r['mesh']:12s} "
              f"{rf['compute_s']*1e3:9.2f}ms {rf['memory_s']*1e3:9.2f}ms "
              f"{rf['collective_s']*1e3:10.2f}ms  {rf['dominant']:10s} "
              f"{rf['useful_ratio']:6.3f} "
              f"{r.get('bytes_per_device', 0)/2**30:7.2f}")


if __name__ == "__main__":
    main()
