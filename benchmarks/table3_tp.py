"""Paper Table III: TP message size & frequency, Llama-3.1-8B, S_p=S_d=128."""
from benchmarks.common import timed
from repro.configs import get_config
from repro.core import commodel as cm


def rows():
    cfg = get_config("llama31-8b")
    out = []
    for t in (2, 4):
        ops, us = timed(lambda t=t: cm.tp_comm_ops(cfg, 128, 128, t))
        for o in ops:
            out.append((f"table3/tp{t}/{o.phase}/{o.collective}", us,
                        f"count={o.count};shape={list(o.shape)};"
                        f"msg_bytes={o.msg_bytes}"))
    return out


def main():
    cfg = get_config("llama31-8b")
    print("Table III — TP message size and frequency (Llama-3.1-8B, 128/128)")
    for t in (2, 4):
        print(f"  TP={t}")
        for o in cm.tp_comm_ops(cfg, 128, 128, t):
            print(f"    {o.phase:8s} {o.collective:10s} count={o.count:6d} "
                  f"shape={list(o.shape)} msg={o.msg_bytes}B")


if __name__ == "__main__":
    main()
