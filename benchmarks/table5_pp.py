"""Paper Table V: PP send/recv counts and shapes, Llama-3.1-8B."""
from benchmarks.common import timed
from repro.configs import get_config
from repro.core import commodel as cm


def rows():
    cfg = get_config("llama31-8b")
    out = []
    for p in (2, 4):
        ops, us = timed(lambda p=p: cm.pp_comm_ops(cfg, 128, 128, p))
        for o in ops:
            out.append((f"table5/pp{p}/{o.phase}/{o.collective}", us,
                        f"count={o.count};shape={list(o.shape)}"))
    return out


def main():
    print("Table V — PP point-to-point breakdown (Llama-3.1-8B, 128/128)")
    for r in rows():
        print(f"  {r[0]:40s} {r[2]}")


if __name__ == "__main__":
    main()
