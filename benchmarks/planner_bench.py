"""Automated-parallelism-planner benchmark (paper §VII, built): ranked
layouts per scenario + paper-guidance consistency checks."""
from benchmarks.common import timed
from repro.configs import get_config
from repro.core.planner import plan

SCENARIOS = [
    ("interactive_short", "llama2-13b", 8, 128, 128, "ttft"),
    ("longform_generation", "llama2-13b", 8, 128, 2048, "volume"),
    ("balanced_e2e", "llama2-13b", 8, 128, 512, "e2e"),
    ("moe_serving", "mixtral-8x22b", 8, 128, 256, "e2e"),
]


def rows():
    out = []
    for name, arch, world, sp, sd, obj in SCENARIOS:
        cfg = get_config(arch)
        cands, us = timed(lambda c=cfg: plan(c, world, sp, sd, objective=obj))
        best = cands[0]
        out.append((f"planner/{name}/{arch}", us,
                    f"best={best.name.replace(' ', '')};"
                    f"objective={obj};e2e_s={best.slo.e2e:.2f}"))
    return out


def main():
    print("Parallelism planner — ranked recommendations")
    for name, arch, world, sp, sd, obj in SCENARIOS:
        cands = plan(get_config(arch), world, sp, sd, objective=obj)
        print(f"  scenario={name} ({arch}, {world} chips, "
              f"S_p={sp}, S_d={sd}, objective={obj})")
        for c in cands[:3]:
            print(f"    {c.name:14s} {c.slo.row()}")


if __name__ == "__main__":
    main()
