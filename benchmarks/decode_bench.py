"""Decode hot-path benchmark: TP (unrolled/scanned/fused) vs PP vs TP×PP.

Times seven decode strategies on a 4-device host-platform mesh (reduced
configs, CPU-sized):

  unrolled   seed behaviour — one jit dispatch per token, Python-unrolled
             layer loop, cache re-stacked every step (paper-parity mode)
  scanned    one dispatch per token, lax.scan layers + donated cache
  fused      ``tp_generate`` — N tokens per dispatch (lax.fori_loop)
  pp4        PipelineEngine t=1 p=4 ``generate`` — per-stage caches, one
             dispatch per stage per token + 2 boundary transfers per hop
  tp2pp2     hybrid t=2 p=2 ``generate`` — per-stage TP collectives plus
             boundary shards (the paper's TP-vs-PP decode tradeoff, Fig. 9)
  fused-q8   ``tp_generate`` with int8 two-step collectives (DESIGN.md §12):
             every per-layer decode psum runs quantize → reduce-scatter →
             all-gather → dequant on the wire
  tp2pp2-q8  the hybrid engine with the same quantized decode collectives
             inside each stage's TP group
  fused-q4 / tp2pp2-q4
             the same two shapes with the nibble-packed int4 wire — half
             the int8 payload again, the aggressive end of the
             accuracy/bandwidth tradeoff

Each quant record carries an accuracy contract next to the timing:
``token_match_rate`` and ``max_logit_drift`` are measured teacher-forced —
the quantized path replays the bf16 greedy token stream, so every step sees
identical *inputs* and the drift is the quantization's alone (compounded
through the KV cache, which is the honest part), while ``token_match_rate``
is the fraction of (step, sequence) argmax choices that agree with the bf16
pick.  ``benchmarks/check_baselines.py`` gates both against
``kernels.quant_collective.QUANT_TOLERANCE`` and pins the deterministic
``predicted_decode_wire_ratio`` against a per-quant ceiling (closed form;
int8 must stay < 0.6 of the bf16 all-reduce wire, packed int4 < 0.35).

Emits ``BENCH_decode.json`` at the repo root (tokens/sec and ms/token per
arch × variant) so the perf trajectory is tracked across PRs.  Every record
also carries the *predicted* per-step decode collective counts from
``commodel`` — deterministic fields the CI bench-regression gate
(`benchmarks/check_baselines.py`) diffs against the checked-in baseline.
Runs in a subprocess so the device-count flag stays contained.  ``--dry-run``
times a single reduced arch with a short generation and writes
``results/BENCH_decode.dryrun.json`` (the CI artifact) instead of the
full series.
"""
import json
import os
import subprocess
import sys
import time

MODELS = ["llama32-3b", "llama31-8b", "internlm2-1.8b"]
REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_PATH = os.path.join(REPO, "BENCH_decode.json")
DRY_PATH = os.path.join(REPO, "results", "BENCH_decode.dryrun.json")

N_TOKENS = 32
BATCH = 4
PREFILL = 16
REPEAT = 3


def _measure(dry_run: bool = False):
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4").strip()
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import parallel_exec as px
    from repro.models.transformer import get_model

    models = MODELS[:1] if dry_run else MODELS
    n_tokens = 4 if dry_run else N_TOKENS
    repeat = 1 if dry_run else REPEAT
    cache_w = PREFILL + n_tokens

    def time_loop(step_fn, params, cache, tok, pos):
        """Per-token dispatch loop; returns (seconds, final cache)."""
        t0 = time.perf_counter()
        for i in range(n_tokens):
            logits, cache = step_fn(params, cache, tok, jnp.int32(pos + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        tok.block_until_ready()
        return time.perf_counter() - t0, cache

    results = []
    for arch in models:
        cfg = get_config(arch).reduced(num_layers=4)
        mesh = px.make_tp_mesh(4)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PREFILL), 2,
                                  cfg.vocab_size)
        prefill = px.tp_prefill(cfg, mesh, cache_w=cache_w, unroll=True)
        logits, cache0 = prefill(params, toks)
        tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = PREFILL

        variants = {}
        step_u = px.tp_decode_step(cfg, mesh, unroll=True)
        step_s = px.tp_decode_step(cfg, mesh, unroll=False)
        gen = px.tp_generate(cfg, mesh, n_tokens)

        def fresh():
            return jax.tree.map(jnp.copy, cache0)

        # warmup (compile) once per variant, then best-of-repeat
        time_loop(step_u, params, fresh(), tok0, pos)
        variants["unrolled"] = min(
            time_loop(step_u, params, fresh(), tok0, pos)[0]
            for _ in range(repeat))
        time_loop(step_s, params, fresh(), tok0, pos)
        variants["scanned"] = min(
            time_loop(step_s, params, fresh(), tok0, pos)[0]
            for _ in range(repeat))
        gen(params, fresh(), tok0, jnp.int32(pos))[0].block_until_ready()

        def fused_once():
            c = fresh()
            t0 = time.perf_counter()
            out, _ = gen(params, c, tok0, jnp.int32(pos))
            out.block_until_ready()
            return time.perf_counter() - t0
        variants["fused"] = min(fused_once() for _ in range(repeat))

        # pipelined decode: per-stage caches + fused per-stage decode steps
        pp_engines = {}
        layouts = {"pp4": (1, 4), "tp2pp2": (2, 2)}
        for name, (t, p) in layouts.items():
            eng = px.PipelineEngine(cfg, t=t, p=p, unroll=False)
            staged = eng.prepare(params)
            _, caches0 = eng.prefill_with_cache(staged, toks, cache_w)
            pp_engines[name] = (eng, staged, caches0)

            def pp_once(eng=eng, staged=staged, caches0=caches0):
                # generate donates the caches; run each repeat on copies
                caches = [jax.tree.map(jnp.copy, c) for c in caches0]
                t0 = time.perf_counter()
                out, _ = eng.generate(staged, caches, tok0, pos, n_tokens)
                out.block_until_ready()
                return time.perf_counter() - t0

            pp_once()                                  # warmup / compile
            variants[name] = min(pp_once() for _ in range(repeat))

        # accuracy: teacher-forced per-step logits vs the bf16 reference
        def record_tp(step_fn, forced=None):
            cache, tok = fresh(), tok0
            logits_all, toks_all = [], []
            for i in range(n_tokens):
                logits, cache = step_fn(params, cache, tok,
                                        jnp.int32(pos + i))
                choice = jnp.argmax(logits, -1).astype(jnp.int32)
                logits_all.append(logits)
                toks_all.append(choice)
                tok = choice if forced is None else forced[i]
            return jnp.stack(logits_all), jnp.stack(toks_all)

        def record_pp(eng_, staged_, caches_, forced=None):
            caches = [jax.tree.map(jnp.copy, c) for c in caches_]
            tok = tok0
            logits_all, toks_all = [], []
            for i in range(n_tokens):
                logits, caches = eng_.decode_once(staged_, caches, tok,
                                                  pos + i)
                choice = jnp.argmax(logits, -1).astype(jnp.int32)
                logits_all.append(logits)
                toks_all.append(choice)
                tok = choice if forced is None else forced[i]
            return jnp.stack(logits_all), jnp.stack(toks_all)

        def drift_metrics(ref, quant):
            """(token_match_rate, max_logit_drift) of a teacher-forced
            quant run against its bf16 reference."""
            (r_logits, r_toks), (q_logits, q_toks) = ref, quant
            match = float(jnp.mean((q_toks == r_toks).astype(jnp.float32)))
            drift = float(jnp.max(jnp.abs(q_logits - r_logits)))
            return round(match, 4), round(drift, 6)

        # ---- quant series (DESIGN.md §12): low-bit two-step collectives,
        # int8 and the packed int4 wire side by side ----
        ref_tp = record_tp(step_u)
        ref_pp = record_pp(*pp_engines["tp2pp2"])
        quant_metrics, variant_quant = {}, {}
        for quant, tag in (("int8", "q8"), ("int4", "q4")):
            gen_q = px.tp_generate(cfg, mesh, n_tokens,
                                   quant_collectives=quant)
            gen_q(params, fresh(), tok0,
                  jnp.int32(pos))[0].block_until_ready()

            def fused_q_once(gen_q=gen_q):
                c = fresh()
                t0 = time.perf_counter()
                out, _ = gen_q(params, c, tok0, jnp.int32(pos))
                out.block_until_ready()
                return time.perf_counter() - t0
            variants[f"fused-{tag}"] = min(
                fused_q_once() for _ in range(repeat))

            eng_q = px.PipelineEngine(cfg, t=2, p=2, unroll=False,
                                      quant_collectives=quant)
            staged_q = eng_q.prepare(params)
            _, qcaches0 = eng_q.prefill_with_cache(staged_q, toks, cache_w)

            def ppq_once(eng_q=eng_q, staged_q=staged_q, qcaches0=qcaches0):
                caches = [jax.tree.map(jnp.copy, c) for c in qcaches0]
                t0 = time.perf_counter()
                out, _ = eng_q.generate(staged_q, caches, tok0, pos,
                                        n_tokens)
                out.block_until_ready()
                return time.perf_counter() - t0

            ppq_once()                                 # warmup / compile
            variants[f"tp2pp2-{tag}"] = min(
                ppq_once() for _ in range(repeat))

            step_q = px.tp_decode_step(cfg, mesh, unroll=True,
                                       quant_collectives=quant)
            quant_metrics[f"fused-{tag}"] = drift_metrics(
                ref_tp, record_tp(step_q, forced=ref_tp[1]))
            quant_metrics[f"tp2pp2-{tag}"] = drift_metrics(
                ref_pp, record_pp(eng_q, staged_q, qcaches0,
                                  forced=ref_pp[1]))
            variant_quant[f"fused-{tag}"] = quant
            variant_quant[f"tp2pp2-{tag}"] = quant

        from repro.core import commodel as cm

        def decode_counts(t, p, quant=None):
            """Predicted per-step decode collective counts (drift-gate
            payload: deterministic, machine-independent)."""
            counts = {}
            for o in cm.comm_ops_for(cfg, 1, 2, t, p,
                                     gather_mode="allgather", quant=quant):
                if o.phase == "decode":
                    counts[o.collective] = counts.get(o.collective, 0) \
                        + o.count
            return counts

        parallelism = {"unrolled": (4, 1), "scanned": (4, 1), "fused": (4, 1),
                       "pp4": (1, 4), "tp2pp2": (2, 2),
                       "fused-q8": (4, 1), "tp2pp2-q8": (2, 2),
                       "fused-q4": (4, 1), "tp2pp2-q4": (2, 2)}
        for name, sec in variants.items():
            t, p = parallelism[name]
            quant = variant_quant.get(name)
            rec = {
                "arch": arch, "variant": name, "tp": t, "pp": p,
                "batch": BATCH, "n_tokens": n_tokens, "quant": quant,
                "tokens_per_s": n_tokens * BATCH / sec,
                "ms_per_token": sec / n_tokens * 1e3,
                "speedup_vs_unrolled": variants["unrolled"] / sec,
                "decode_collective_counts": decode_counts(t, p, quant),
            }
            if quant is not None:
                match, drift = quant_metrics[name]
                rec["token_match_rate"] = match
                rec["max_logit_drift"] = drift
                # closed form vs the bf16 (b=2) wire the two-step replaces;
                # t-invariant, pinned by the per-quant baseline ceiling
                rec["predicted_decode_wire_ratio"] = round(
                    cm.quant_ar_wire_ratio(cfg.d_model, t, quant=quant), 6)
            results.append(rec)
    print("DECODEJSON:" + json.dumps(results))


def _run_subprocess(dry_run: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    cmd = [sys.executable, "-m", "benchmarks.decode_bench", "--measure"]
    if dry_run:
        cmd.append("--dry-run")
    try:
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=REPO, timeout=1200)
    except subprocess.TimeoutExpired:
        return None, "timeout after 1200s"
    for line in r.stdout.splitlines():
        if line.startswith("DECODEJSON:"):
            return json.loads(line[len("DECODEJSON:"):]), None
    return None, r.stderr[-300:]


def rows(dry_run: bool = False):
    recs, err = _run_subprocess(dry_run)
    if recs is None:
        return [("decode/bench", 0.0, f"subprocess_failed;stderr={err}")]
    path = DRY_PATH if dry_run else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(recs, f, indent=2, sort_keys=True)
    out = []
    for r in recs:
        note = (f"tok_per_s={r['tokens_per_s']:.1f};"
                f"ms_per_token={r['ms_per_token']:.2f};"
                f"speedup_vs_unrolled={r['speedup_vs_unrolled']:.2f}x")
        if r.get("quant"):
            note += (f";token_match={r['token_match_rate']:.4f};"
                     f"logit_drift={r['max_logit_drift']:.4f};"
                     f"wire_ratio={r['predicted_decode_wire_ratio']:.4f}")
        out.append((f"decode/{r['arch']}/t{r['tp']}p{r['pp']}/{r['variant']}",
                    r["ms_per_token"] * 1e3, note))
    return out


def main(dry_run: bool = False):
    mode = "dry-run smoke" if dry_run else f"fused×{N_TOKENS}"
    print(f"Decode paths — TP unrolled/scanned/fused vs PP vs TP×PP "
          f"({mode}, 4-device host mesh, B={BATCH})")
    rs = rows(dry_run)
    for r in rs:
        print(f"  {r[0]:46s} {r[2]}")
    if dry_run and any(r[0] == "decode/bench" for r in rs):
        raise SystemExit("decode_bench smoke failed")
    if not dry_run and os.path.exists(OUT_PATH):
        print(f"  wrote {OUT_PATH}")


if __name__ == "__main__":
    if "--measure" in sys.argv:
        _measure(dry_run="--dry-run" in sys.argv)
    else:
        main(dry_run="--dry-run" in sys.argv)
