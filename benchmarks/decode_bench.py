"""Decode hot-path benchmark: unrolled vs scanned vs fused multi-token TP.

Times three decode strategies of the explicit TP engine on a 4-device
host-platform mesh (reduced configs, CPU-sized):

  unrolled   seed behaviour — one jit dispatch per token, Python-unrolled
             layer loop, cache re-stacked every step (paper-parity mode)
  scanned    one dispatch per token, lax.scan layers + donated cache
  fused      ``tp_generate`` — N tokens per dispatch (lax.fori_loop)

Emits ``BENCH_decode.json`` at the repo root (tokens/sec and ms/token per
arch × variant) so the perf trajectory is tracked across PRs.  Runs in a
subprocess so the device-count flag stays contained.
"""
import json
import os
import subprocess
import sys
import time

MODELS = ["llama32-3b", "llama31-8b", "internlm2-1.8b"]
REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_PATH = os.path.join(REPO, "BENCH_decode.json")

N_TOKENS = 32
BATCH = 4
PREFILL = 16
REPEAT = 3


def _measure():
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4").strip()
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import parallel_exec as px
    from repro.models.transformer import get_model

    def time_loop(step_fn, params, cache, tok, pos):
        """Per-token dispatch loop; returns (seconds, final cache)."""
        t0 = time.perf_counter()
        for i in range(N_TOKENS):
            logits, cache = step_fn(params, cache, tok, jnp.int32(pos + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        tok.block_until_ready()
        return time.perf_counter() - t0, cache

    results = []
    for arch in MODELS:
        cfg = get_config(arch).reduced(num_layers=4)
        mesh = px.make_tp_mesh(4)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PREFILL), 2,
                                  cfg.vocab_size)
        prefill = px.tp_prefill(cfg, mesh, cache_w=PREFILL + N_TOKENS,
                                unroll=True)
        logits, cache0 = prefill(params, toks)
        tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = PREFILL

        variants = {}
        step_u = px.tp_decode_step(cfg, mesh, unroll=True)
        step_s = px.tp_decode_step(cfg, mesh, unroll=False)
        gen = px.tp_generate(cfg, mesh, N_TOKENS)

        def fresh():
            return jax.tree.map(jnp.copy, cache0)

        # warmup (compile) once per variant, then best-of-REPEAT
        time_loop(step_u, params, fresh(), tok0, pos)
        variants["unrolled"] = min(
            time_loop(step_u, params, fresh(), tok0, pos)[0]
            for _ in range(REPEAT))
        time_loop(step_s, params, fresh(), tok0, pos)
        variants["scanned"] = min(
            time_loop(step_s, params, fresh(), tok0, pos)[0]
            for _ in range(REPEAT))
        gen(params, fresh(), tok0, jnp.int32(pos))[0].block_until_ready()

        def fused_once():
            c = fresh()
            t0 = time.perf_counter()
            out, _ = gen(params, c, tok0, jnp.int32(pos))
            out.block_until_ready()
            return time.perf_counter() - t0
        variants["fused"] = min(fused_once() for _ in range(REPEAT))

        for name, sec in variants.items():
            results.append({
                "arch": arch, "variant": name, "tp": 4,
                "batch": BATCH, "n_tokens": N_TOKENS,
                "tokens_per_s": N_TOKENS * BATCH / sec,
                "ms_per_token": sec / N_TOKENS * 1e3,
                "speedup_vs_unrolled": variants["unrolled"] / sec,
            })
    print("DECODEJSON:" + json.dumps(results))


def _run_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    try:
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.decode_bench", "--measure"],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=1200)
    except subprocess.TimeoutExpired:
        return None, "timeout after 1200s"
    for line in r.stdout.splitlines():
        if line.startswith("DECODEJSON:"):
            return json.loads(line[len("DECODEJSON:"):]), None
    return None, r.stderr[-300:]


def rows():
    recs, err = _run_subprocess()
    if recs is None:
        return [("decode/bench", 0.0, f"subprocess_failed;stderr={err}")]
    with open(OUT_PATH, "w") as f:
        json.dump(recs, f, indent=2, sort_keys=True)
    out = []
    for r in recs:
        out.append((f"decode/{r['arch']}/tp{r['tp']}/{r['variant']}",
                    r["ms_per_token"] * 1e3,
                    f"tok_per_s={r['tokens_per_s']:.1f};"
                    f"ms_per_token={r['ms_per_token']:.2f};"
                    f"speedup_vs_unrolled={r['speedup_vs_unrolled']:.2f}x"))
    return out


def main():
    print(f"Decode fast path — unrolled vs scanned vs fused×{N_TOKENS} "
          f"(TP=4 host mesh, B={BATCH})")
    for r in rows():
        print(f"  {r[0]:42s} {r[2]}")
    if os.path.exists(OUT_PATH):
        print(f"  wrote {OUT_PATH}")


if __name__ == "__main__":
    if "--measure" in sys.argv:
        _measure()
    else:
        main()
