"""§Perf hillclimb results: baseline vs optimized variants per selected pair
(reads the archived dry-run records; see EXPERIMENTS.md §Perf for the
hypothesis log).  Degrades to a single informational row when the
``results/dryrun`` archive is absent (fresh checkout)."""
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

PAIRS = [
    ("rwkv6-7b", "train_4k",
     ["baseline", "rwkv_chunked", "rwkv_chunked_remat", "remat",
      "rwkv_chunked_c32", "mesh64x4", "mesh64x4-rwkv_chunked_remat"]),
    ("mixtral-8x22b", "prefill_32k",
     ["baseline", "moe_local", "moe_local_fsdp", "moe_local_fsdp_chunked"]),
    ("granite-8b", "prefill_32k",
     ["baseline", "chunked_attn", "chunked_attn_c4096"]),
    ("hymba-1.5b", "prefill_32k",
     ["baseline", "rwkv_chunked", "ssm_attn_chunked"]),
    ("hymba-1.5b", "train_4k", ["baseline", "rwkv_chunked"]),
    ("rwkv6-7b", "prefill_32k", ["baseline", "rwkv_chunked"]),
    ("deepseek-moe-16b", "prefill_32k",
     ["baseline", "moe_local_fsdp_chunked"]),
]


def _load(arch, shape, variant):
    suffix = "" if variant == "baseline" else f"__{variant}"
    path = os.path.join(RESULTS, f"{arch}__{shape}__pod16x16{suffix}.json")
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            r = json.load(f)
    except (OSError, ValueError):
        return None
    return r if r.get("status") == "ok" else None


def rows():
    if not os.path.isdir(RESULTS):
        return [("perf/variants", 0.0,
                 "no_dryrun_archive;run launch/dryrun.py to populate "
                 "results/dryrun")]
    out = []
    for arch, shape, variants in PAIRS:
        base = _load(arch, shape, "baseline")
        for v in variants:
            r = _load(arch, shape, v)
            if r is None:
                continue
            rf = r["roofline"]
            dom_val = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
            speedup = ""
            if base and v != "baseline":
                b = base["roofline"]
                bdom = max(b["compute_s"], b["memory_s"], b["collective_s"])
                speedup = f";bound_speedup={bdom/dom_val:.2f}x"
            out.append((f"perf/{arch}/{shape}/{v}", dom_val * 1e6,
                        f"memory_s={rf['memory_s']:.2f};"
                        f"collective_s={rf['collective_s']:.2f};"
                        f"GB_dev={r['bytes_per_device']/2**30:.1f}{speedup}"))
    return out


def main():
    print("§Perf hillclimbs — roofline bound per variant")
    for r in rows():
        print(f"  {r[0]:56s} {r[2]}")


if __name__ == "__main__":
    main()
