"""Bench-regression gate: predicted collective counts must not drift.

Timing numbers in ``BENCH_decode.json`` / ``BENCH_serve.json`` are
machine-dependent, but the *predicted collective counts* each record carries
(``decode_collective_counts``, ``prefill_chunk_counts``) come straight from
``core.commodel`` and are exact program properties — if a refactor changes
them, either the engines' schedule changed (a real regression against the
paper's Tables III–VI) or the analytical model did.  Either way CI should
stop the merge until the baselines are regenerated deliberately.

CI runs ``decode_bench --dry-run`` / ``serving_bench --dry-run`` first (they
write ``results/BENCH_*.dryrun.json``), then this script diffs every dry-run
record's count fields against the checked-in baseline record with the same
key.  Run locally the same way:

    PYTHONPATH=src python -m benchmarks.decode_bench --dry-run
    PYTHONPATH=src python -m benchmarks.serving_bench --dry-run
    PYTHONPATH=src python -m benchmarks.check_baselines
"""
import json
import os
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

CHECKS = [
    # (baseline, dry-run output, key fields, compared count fields)
    (os.path.join(REPO, "BENCH_decode.json"),
     os.path.join(REPO, "results", "BENCH_decode.dryrun.json"),
     ("arch", "variant"),
     ("decode_collective_counts",)),
    (os.path.join(REPO, "BENCH_serve.json"),
     os.path.join(REPO, "results", "BENCH_serve.dryrun.json"),
     ("series", "arch", "backend", "tp", "cp", "pp", "paged", "admission"),
     ("decode_collective_counts", "prefill_chunk_counts",
      "prefill_collective_counts", "recompute_collective_counts")),
]

SERVE_DRY = os.path.join(REPO, "results", "BENCH_serve.dryrun.json")


def check_overload_ordering(dry_path=SERVE_DRY):
    """Gate the overload series (DESIGN.md §10) WITHIN the dry-run file:
    optimistic admission must pack at least as many tokens into each fused
    decode step as conservative on the same trace, conservative must never
    preempt, and every optimistic preemption must have logged exactly one
    recompute pass.  ``tokens_per_decode_step`` is trace-size-dependent, so
    it is compared between the two fresh records, never against the
    checked-in full-series baseline."""
    if not os.path.exists(dry_path):
        return [f"{dry_path} missing — run the --dry-run bench first"]
    with open(dry_path) as f:
        recs = [r for r in json.load(f) if r.get("series") == "overload"]
    by_adm = {r.get("admission"): r for r in recs}
    if set(by_adm) != {"conservative", "optimistic"}:
        return [f"overload series incomplete: got {sorted(by_adm)}"]
    cons, opt = by_adm["conservative"], by_adm["optimistic"]
    failures = []
    if opt["tokens_per_decode_step"] < cons["tokens_per_decode_step"]:
        failures.append(
            "overload: optimistic admission packs FEWER tokens per decode "
            f"step than conservative ({opt['tokens_per_decode_step']:.3f} "
            f"< {cons['tokens_per_decode_step']:.3f}) — preemption "
            "recovery is costing more steps than overcommit saves")
    if cons["preemptions"] != 0:
        failures.append(
            f"overload: conservative admission preempted "
            f"{cons['preemptions']} times — its worst-case page "
            "commitment should make mid-decode exhaustion impossible")
    if opt["recompute_steps"] != opt["preemptions"]:
        failures.append(
            f"overload: {opt['preemptions']} preemptions but "
            f"{opt['recompute_steps']} recompute StepRecords — every "
            "preemption must log exactly one recompute pass")
    for rec in (cons, opt):
        if rec["total_tokens"] != cons["total_tokens"]:
            failures.append(
                "overload: admission policies produced different token "
                "totals on the same trace — greedy determinism broken")
    return failures


def _index(records, key_fields):
    out = {}
    for r in records:
        out[tuple(r.get(k) for k in key_fields)] = r
    return out


def check(baseline_path, dry_path, key_fields, count_fields):
    failures = []
    if not os.path.exists(dry_path):
        return [f"{dry_path} missing — run the --dry-run bench first"]
    with open(baseline_path) as f:
        base = _index(json.load(f), key_fields)
    with open(dry_path) as f:
        dry = json.load(f)
    for rec in dry:
        key = tuple(rec.get(k) for k in key_fields)
        ref = base.get(key)
        if ref is None:
            failures.append(
                f"{os.path.basename(baseline_path)}: no baseline row for "
                f"{dict(zip(key_fields, key))} — regenerate the bench JSON")
            continue
        for field in count_fields:
            if rec.get(field) != ref.get(field):
                failures.append(
                    f"{os.path.basename(baseline_path)} "
                    f"{dict(zip(key_fields, key))}: {field} drifted\n"
                    f"    baseline: {ref.get(field)}\n"
                    f"    measured: {rec.get(field)}")
    return failures


def main():
    failures = []
    for baseline, dry, keys, counts in CHECKS:
        failures += check(baseline, dry, keys, counts)
    failures += check_overload_ordering()
    if failures:
        print("BASELINE DRIFT — predicted collective counts changed:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print("baseline check OK: predicted collective counts match "
          "BENCH_decode.json / BENCH_serve.json, overload ordering holds")


if __name__ == "__main__":
    main()
