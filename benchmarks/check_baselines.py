"""Bench-regression gate: predicted collective counts must not drift.

Timing numbers in ``BENCH_decode.json`` / ``BENCH_serve.json`` are
machine-dependent, but the *predicted collective counts* each record carries
(``decode_collective_counts``, ``prefill_chunk_counts``) come straight from
``core.commodel`` and are exact program properties — if a refactor changes
them, either the engines' schedule changed (a real regression against the
paper's Tables III–VI) or the analytical model did.  Either way CI should
stop the merge until the baselines are regenerated deliberately.

CI runs ``decode_bench --dry-run`` / ``serving_bench --dry-run`` first (they
write ``results/BENCH_*.dryrun.json``), then this script diffs every dry-run
record's count fields against the checked-in baseline record with the same
key.  Run locally the same way:

    PYTHONPATH=src python -m benchmarks.decode_bench --dry-run
    PYTHONPATH=src python -m benchmarks.serving_bench --dry-run
    PYTHONPATH=src python -m benchmarks.check_baselines

The pp-occupancy series (DESIGN.md §11) gets its own gate,
``check_pp_occupancy``: the dynamic-schedule tick counts, busy fractions
and per-round boundary bytes are EXACT schedule-clock properties, so both
the dry-run file and the checked-in full baseline must land bitwise on
``commodel.pp_schedule_stats``'s closed form, token streams must be
depth-invariant, and depth p must beat depth 1 by the acceptance ratio
(≥ 2× tokens/tick for pp4, ≥ 1.5× for the pp2-only dry run) with ≥ 0.8
stage busy fraction.

The prefix-cache series (DESIGN.md §13) gets its own gate,
``check_prefix_cache``: the cold and cached serves of the identical
template trace must be bitwise token-identical, the cached run's executed
prefill chunks/counts must equal the per-request suffix arithmetic
(``commodel.prefix_cache_ops``'s executed column), hit TTFT must sit
strictly below cold TTFT on the same rids, and clearing the index must
drain the pool to zero — the no-leak guarantee of the ref-counted pages.

The quantized decode records (DESIGN.md §12) get their own gate,
``check_quant``: every ``quant`` row must hold ``token_match_rate`` above
and ``max_logit_drift`` below the ``QUANT_TOLERANCE`` contract shipped in
``kernels.quant_collective``, and its ``predicted_decode_wire_ratio``
(deterministic closed form, also diffed as a count field) must stay under
its per-quant ceiling (int8 < 0.6×, packed int4 < 0.35× of the bf16
all-reduce wire it replaces).

The disagg-mixed series (DESIGN.md §14) gets its own gate,
``check_disagg``: chat streams bitwise identical across chat-only /
colocated / disagg, measured handoff bytes exactly on the
``kv_handoff_ops`` closed form, a zero-leak pool drain, the §14 planner
preferring disagg on mixed but colocated on chat-only traffic — and, on
the checked-in full series, the decode pool's chat p99 TPOT within
1.10× of the chat-only baseline while colocated degrades ≥ 1.5×.

``--write`` regenerates the checked-in count fields after a DELIBERATE
schedule change: it runs both --dry-run benches in-process, then copies
every compared count field from the fresh dry-run records into the
matching rows of ``BENCH_decode.json`` / ``BENCH_serve.json`` — one
command instead of a full bench rerun (timing fields keep their baseline
values; only the machine-independent counts move).
"""
import json
import os
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

CHECKS = [
    # (baseline, dry-run output, key fields, compared count fields)
    (os.path.join(REPO, "BENCH_decode.json"),
     os.path.join(REPO, "results", "BENCH_decode.dryrun.json"),
     ("arch", "variant"),
     ("decode_collective_counts", "quant", "predicted_decode_wire_ratio")),
    (os.path.join(REPO, "BENCH_serve.json"),
     os.path.join(REPO, "results", "BENCH_serve.dryrun.json"),
     ("series", "arch", "backend", "tp", "cp", "pp", "paged", "admission",
      "inflight"),
     ("decode_collective_counts", "prefill_chunk_counts",
      "prefill_collective_counts", "recompute_collective_counts",
      "prefix_cache_ops_executed_counts",
      "prefix_cache_ops_skipped_counts")),
]

SERVE_DRY = os.path.join(REPO, "results", "BENCH_serve.dryrun.json")
SERVE_FULL = os.path.join(REPO, "BENCH_serve.json")


def check_overload_ordering(dry_path=SERVE_DRY):
    """Gate the overload series (DESIGN.md §10) WITHIN the dry-run file:
    optimistic admission must pack at least as many tokens into each fused
    decode step as conservative on the same trace, conservative must never
    preempt, and every optimistic preemption must have logged exactly one
    recompute pass.  ``tokens_per_decode_step`` is trace-size-dependent, so
    it is compared between the two fresh records, never against the
    checked-in full-series baseline."""
    if not os.path.exists(dry_path):
        return [f"{dry_path} missing — run the --dry-run bench first"]
    with open(dry_path) as f:
        recs = [r for r in json.load(f) if r.get("series") == "overload"]
    by_adm = {r.get("admission"): r for r in recs}
    if set(by_adm) != {"conservative", "optimistic"}:
        return [f"overload series incomplete: got {sorted(by_adm)}"]
    cons, opt = by_adm["conservative"], by_adm["optimistic"]
    failures = []
    if opt["tokens_per_decode_step"] < cons["tokens_per_decode_step"]:
        failures.append(
            "overload: optimistic admission packs FEWER tokens per decode "
            f"step than conservative ({opt['tokens_per_decode_step']:.3f} "
            f"< {cons['tokens_per_decode_step']:.3f}) — preemption "
            "recovery is costing more steps than overcommit saves")
    if cons["preemptions"] != 0:
        failures.append(
            f"overload: conservative admission preempted "
            f"{cons['preemptions']} times — its worst-case page "
            "commitment should make mid-decode exhaustion impossible")
    if opt["recompute_steps"] != opt["preemptions"]:
        failures.append(
            f"overload: {opt['preemptions']} preemptions but "
            f"{opt['recompute_steps']} recompute StepRecords — every "
            "preemption must log exactly one recompute pass")
    for rec in (cons, opt):
        if rec["total_tokens"] != cons["total_tokens"]:
            failures.append(
                "overload: admission policies produced different token "
                "totals on the same trace — greedy determinism broken")
    return failures


def check_pp_occupancy(path, full):
    """Gate the pp-occupancy series (DESIGN.md §11) in ``path``.

    Exact gates (the schedule clock is deterministic, so these are
    equalities, not tolerances): measured decode ticks == the
    admission-wave composition of ``pp_schedule_stats``; measured busy
    fractions == the closed form, identical on every stage; per-round
    boundary bytes == the PP closed form; token checksums identical at
    every depth.  Threshold gates (the acceptance criteria): at depth p,
    tokens/tick ≥ 2× depth 1 when pp4 is present (``full``) or ≥ 1.5× on
    the pp2-only dry run, and stage busy fraction ≥ 0.8.
    """
    if not os.path.exists(path):
        return [f"{path} missing — run the --dry-run bench first"]
    with open(path) as f:
        recs = [r for r in json.load(f) if r.get("series") == "pp-occupancy"]
    name = os.path.basename(path)
    if not recs:
        return [f"{name}: pp-occupancy series missing — regenerate"]
    failures = []
    by_pp = {}
    for r in recs:
        by_pp.setdefault(r["pp"], {})[r["inflight"]] = r
    want_pps = {2, 4} if full else {2}
    if set(by_pp) != want_pps:
        failures.append(f"{name}: pp-occupancy has pp={sorted(by_pp)}, "
                        f"want {sorted(want_pps)}")
    for p, by_d in sorted(by_pp.items()):
        if set(by_d) != set(range(1, p + 1)):
            failures.append(f"{name}: pp{p} depths {sorted(by_d)}, "
                            f"want 1..{p}")
            continue
        for d, r in sorted(by_d.items()):
            tag = f"{name} pp{p} inflight{d}"
            if r["decode_ticks"] != r["predicted_ticks"]:
                failures.append(
                    f"{tag}: measured {r['decode_ticks']} schedule ticks, "
                    f"closed form predicts {r['predicted_ticks']}")
            if abs(r["busy_fraction_mean"]
                   - r["predicted_busy_fraction"]) > 1e-12:
                failures.append(
                    f"{tag}: busy fraction {r['busy_fraction_mean']} != "
                    f"closed form {r['predicted_busy_fraction']}")
            if any(abs(f - r["busy_fraction_mean"]) > 1e-12
                   for f in r["stage_busy_fraction"]):
                failures.append(
                    f"{tag}: per-stage busy fractions "
                    f"{r['stage_busy_fraction']} are not uniform — a "
                    "stage is starving")
            if (r["boundary_bytes_per_round_measured"]
                    != r["boundary_bytes_per_round_predicted"]):
                failures.append(
                    f"{tag}: per-round boundary bytes "
                    f"{r['boundary_bytes_per_round_measured']} != PP "
                    f"closed form {r['boundary_bytes_per_round_predicted']}")
            if r["token_checksum"] != by_d[1]["token_checksum"] \
                    or not r["token_checksum_matches_depth1"]:
                failures.append(
                    f"{tag}: token stream differs from depth 1 — the "
                    "dynamic schedule broke bitwise identity")
        d1, dp = by_d[1], by_d[p]
        ratio = dp["tokens_per_tick"] / d1["tokens_per_tick"]
        want = 2.0 if p == 4 else 1.5
        if ratio < want:
            failures.append(
                f"{name} pp{p}: depth-{p} tokens/tick is only {ratio:.3f}× "
                f"depth 1 (acceptance: ≥ {want}×)")
        if dp["busy_fraction_mean"] < 0.8:
            failures.append(
                f"{name} pp{p}: depth-{p} stage busy fraction "
                f"{dp['busy_fraction_mean']:.3f} < 0.8")
    return failures


def check_prefix_cache(path):
    """Gate the prefix-cache series (DESIGN.md §13) in ``path``.

    The bench serves the identical template-heavy trace cold and with the
    cross-request prefix index, so every gate is exact within one file:
    token checksums must match bitwise (adopted KV pages produce the same
    greedy streams as recomputed ones), the cached run must actually hit,
    executed prefill chunks must equal the per-request suffix arithmetic
    ``sum(ceil((s_p - hit) / chunk))`` with executed collective counts ==
    per-chunk counts × chunks (``prefix_cache_ops``'s executed column),
    the cached run must run strictly FEWER chunks than cold, mean hit
    TTFT must sit strictly below the cold run's on the same rids, and the
    pool must drain to zero once the index is cleared."""
    if not os.path.exists(path):
        return [f"{path} missing — run the --dry-run bench first"]
    with open(path) as f:
        recs = [r for r in json.load(f)
                if r.get("series") == "prefix-cache"]
    name = os.path.basename(path)
    by = {bool(r.get("prefix_cache")): r for r in recs}
    if set(by) != {False, True}:
        return [f"{name}: prefix-cache series incomplete — need a cold "
                f"and a cached record, got {len(recs)}"]
    cold, hot = by[False], by[True]
    failures = []
    if not hot["token_checksum_matches_uncached"] \
            or hot["token_checksum"] != cold["token_checksum"]:
        failures.append(
            f"{name}: prefix-cache token streams differ from the cold "
            "run — adopted KV pages broke bitwise identity")
    if hot["hits"] < 1 or hot["hit_rate_measured"] <= 0.0:
        failures.append(
            f"{name}: prefix-cache run recorded no hits — the index "
            "never matched the template trace")
    if cold["hits"] != 0:
        failures.append(
            f"{name}: the cold record claims {cold['hits']} hits but has "
            "no index — metrics plumbing is broken")
    for rec, tag in ((cold, "cold"), (hot, "cached")):
        if rec["prefill_chunks"] != rec["predicted_prefill_chunks"]:
            failures.append(
                f"{name} {tag}: {rec['prefill_chunks']} prefill chunks, "
                f"suffix arithmetic predicts "
                f"{rec['predicted_prefill_chunks']}")
        want = {k: v * rec["prefill_chunks"]
                for k, v in rec["prefill_chunk_counts"].items()}
        if rec["executed_prefill_counts"] != want \
                or rec["executed_prefill_counts"] \
                != rec["predicted_executed_prefill_counts"]:
            failures.append(
                f"{name} {tag}: executed prefill counts "
                f"{rec['executed_prefill_counts']} != per-chunk × chunks "
                f"{want} — the hit path issued unpredicted collectives")
    if hot["prefill_chunks"] >= cold["prefill_chunks"]:
        failures.append(
            f"{name}: cached run executed {hot['prefill_chunks']} chunks, "
            f"cold ran {cold['prefill_chunks']} — the cache skipped "
            "nothing")
    if hot["ttft_hit_mean_s"] is None \
            or hot["ttft_cold_mean_s"] is None \
            or hot["ttft_hit_mean_s"] >= hot["ttft_cold_mean_s"]:
        failures.append(
            f"{name}: mean hit TTFT {hot['ttft_hit_mean_s']} is not "
            f"strictly below the cold run's {hot['ttft_cold_mean_s']} on "
            "the same rids")
    if hot["total_tokens"] != cold["total_tokens"]:
        failures.append(
            f"{name}: prefix-cache token totals diverge "
            f"({hot['total_tokens']} vs {cold['total_tokens']})")
    if not hot["pool_drained"]:
        failures.append(
            f"{name}: pool did not drain to zero after the index was "
            "cleared — cached pages leaked")
    return failures


DECODE_DRY = os.path.join(REPO, "results", "BENCH_decode.dryrun.json")
DECODE_FULL = os.path.join(REPO, "BENCH_decode.json")

# predicted quantized-AR wire ratio must beat this fraction of the bf16
# all-reduce wire it replaces, per wire dtype (the ISSUEs' acceptance
# bounds; the int8 closed form lands ≈ 0.516 for every shipped config,
# the nibble-packed int4 form ≈ 0.27 — the amax sideband keeps it off
# the naive 0.25)
QUANT_WIRE_RATIO_CEILING = {"int8": 0.6, "fp8": 0.6, "int4": 0.35}


def _quant_tolerance():
    """The numerics contract lives in ``kernels.quant_collective`` (single
    home); pull it in whether or not PYTHONPATH=src is already set."""
    try:
        from repro.kernels.quant_collective import QUANT_TOLERANCE
    except ImportError:
        sys.path.insert(0, os.path.join(REPO, "src"))
        from repro.kernels.quant_collective import QUANT_TOLERANCE
    return QUANT_TOLERANCE


def check_quant(path):
    """Gate the quantized decode records (DESIGN.md §12) in ``path``.

    Threshold gates — accuracy numbers are floating-point properties of
    the machine's kernels, so they are bounded, not pinned: every record
    with ``quant`` set must carry ``token_match_rate`` ≥ the contract
    floor, ``max_logit_drift`` ≤ the contract ceiling (both from
    ``kernels.quant_collective.QUANT_TOLERANCE``), and the deterministic
    ``predicted_decode_wire_ratio`` below its per-quant ceiling — the
    quantized two-step must actually beat the bf16 all-reduce it replaces
    on wire bytes, and the packed int4 wire must beat int8."""
    if not os.path.exists(path):
        return [f"{path} missing — run the --dry-run bench first"]
    with open(path) as f:
        recs = [r for r in json.load(f) if r.get("quant")]
    name = os.path.basename(path)
    if not recs:
        return [f"{name}: quant series missing — regenerate the bench JSON"]
    tol_table = _quant_tolerance()
    failures = []
    for r in recs:
        tag = f"{name} {r['arch']}/{r['variant']}"
        tol = tol_table.get(r["quant"])
        if tol is None:
            failures.append(f"{tag}: unknown quant dtype {r['quant']!r}")
            continue
        missing = [k for k in ("token_match_rate", "max_logit_drift",
                               "predicted_decode_wire_ratio")
                   if k not in r]
        if missing:
            failures.append(f"{tag}: quant record missing {missing}")
            continue
        if r["token_match_rate"] < tol["token_match_floor"]:
            failures.append(
                f"{tag}: token_match_rate {r['token_match_rate']:.4f} < "
                f"contract floor {tol['token_match_floor']} — quantized "
                "decode is changing greedy choices beyond the contract")
        if r["max_logit_drift"] > tol["logit_drift_ceiling"]:
            failures.append(
                f"{tag}: max_logit_drift {r['max_logit_drift']:.4f} > "
                f"contract ceiling {tol['logit_drift_ceiling']} — tighten "
                "the kernels or loosen QUANT_TOLERANCE deliberately")
        ceiling = QUANT_WIRE_RATIO_CEILING[r["quant"]]
        if r["predicted_decode_wire_ratio"] >= ceiling:
            failures.append(
                f"{tag}: predicted_decode_wire_ratio "
                f"{r['predicted_decode_wire_ratio']:.4f} ≥ "
                f"{ceiling} — the two-step no longer "
                "saves wire bytes over the bf16 all-reduce")
    return failures


def check_disagg(path, full):
    """Gate the disagg-mixed series (DESIGN.md §14) in ``path``.

    Deterministic gates (both files): the chat token streams must be
    bitwise identical across all three modes and the full mixed streams
    identical between colocated and disagg (disaggregation changes the
    schedule, never a token); total mixed tokens must match; the measured
    handoff volume must equal the ``kv_handoff_ops`` closed form exactly
    and be nonzero; clearing the index must drain the shared pool to
    zero; and the §14 planner must prefer disagg for the mixed workload
    but colocated for the chat-only one.

    Wall-clock gates (checked-in full series only — the dry-run trace is
    too small for stable percentiles): the disagg decode pool's chat
    p99 TPOT must sit within 1.10× of the chat-only baseline while the
    colocated serve of the same mixed trace degrades it ≥ 1.5× — the
    head-of-line blocking the tentpole kills."""
    if not os.path.exists(path):
        return [f"{path} missing — run the --dry-run bench first"]
    with open(path) as f:
        recs = [r for r in json.load(f)
                if r.get("series") == "disagg-mixed"]
    name = os.path.basename(path)
    by_mode = {r.get("backend"): r for r in recs}
    if set(by_mode) != {"chat-only", "colocated", "disagg"}:
        return [f"{name}: disagg-mixed series incomplete: "
                f"got {sorted(by_mode)} — regenerate the bench JSON"]
    base, colo, dis = (by_mode["chat-only"], by_mode["colocated"],
                       by_mode["disagg"])
    failures = []
    if len({base["chat_token_checksum"], colo["chat_token_checksum"],
            dis["chat_token_checksum"]}) != 1:
        failures.append(
            f"{name}: chat token streams differ across modes — "
            "disaggregation must never change a token")
    if colo["token_checksum"] != dis["token_checksum"]:
        failures.append(
            f"{name}: mixed-trace token streams differ between colocated "
            "and disagg")
    if colo["total_tokens"] != dis["total_tokens"]:
        failures.append(
            f"{name}: total tokens differ ({colo['total_tokens']} vs "
            f"{dis['total_tokens']}) on the same mixed trace")
    if dis["handoffs"] == 0 or dis["handoff_bytes"] == 0:
        failures.append(f"{name}: disagg run shipped no KV pages — the "
                        "route threshold is not splitting the trace")
    if dis["handoff_bytes"] != dis["predicted_handoff_bytes"]:
        failures.append(
            f"{name}: measured handoff bytes {dis['handoff_bytes']} != "
            f"predicted {dis['predicted_handoff_bytes']} — the modeled "
            "transfer drifted off the kv_handoff_ops closed form")
    if not dis["pool_drained"]:
        failures.append(f"{name}: shared pool did not drain to zero after "
                        "the index clear — handed-off pages leaked")
    if dis["planner_mixed_mode"] != "disagg":
        failures.append(
            f"{name}: plan_disagg prefers {dis['planner_mixed_mode']!r} "
            "for the mixed workload — the §14 decision rule regressed")
    if dis["planner_chat_mode"] != "colocated":
        failures.append(
            f"{name}: plan_disagg prefers {dis['planner_chat_mode']!r} "
            "for chat-only traffic — disagg must not win without a long "
            "class to strip out")
    if full:
        ratio_dis = dis["chat_tpot_p99_s"] / base["chat_tpot_p99_s"]
        ratio_colo = colo["chat_tpot_p99_s"] / base["chat_tpot_p99_s"]
        if ratio_dis > 1.10:
            failures.append(
                f"{name}: disagg decode-pool chat p99 TPOT is "
                f"{ratio_dis:.2f}× the chat-only baseline (> 1.10×) — "
                "the decode pool is not isolated from long prefills")
        if ratio_colo < 1.5:
            failures.append(
                f"{name}: colocated chat p99 TPOT is only "
                f"{ratio_colo:.2f}× the chat-only baseline (< 1.5×) — "
                "the mixed trace no longer exhibits the head-of-line "
                "blocking the series exists to measure; retune the trace")
    return failures


def _index(records, key_fields):
    out = {}
    for r in records:
        out[tuple(r.get(k) for k in key_fields)] = r
    return out


def check(baseline_path, dry_path, key_fields, count_fields):
    failures = []
    if not os.path.exists(dry_path):
        return [f"{dry_path} missing — run the --dry-run bench first"]
    with open(baseline_path) as f:
        base = _index(json.load(f), key_fields)
    with open(dry_path) as f:
        dry = json.load(f)
    for rec in dry:
        key = tuple(rec.get(k) for k in key_fields)
        ref = base.get(key)
        if ref is None:
            failures.append(
                f"{os.path.basename(baseline_path)}: no baseline row for "
                f"{dict(zip(key_fields, key))} — regenerate the bench JSON")
            continue
        for field in count_fields:
            if rec.get(field) != ref.get(field):
                failures.append(
                    f"{os.path.basename(baseline_path)} "
                    f"{dict(zip(key_fields, key))}: {field} drifted\n"
                    f"    baseline: {ref.get(field)}\n"
                    f"    measured: {rec.get(field)}")
    return failures


def write_baselines():
    """``--write``: regenerate the checked-in count fields in one command.

    Runs both --dry-run benches in-process (they refresh
    ``results/BENCH_*.dryrun.json``), then copies every compared count
    field from the fresh dry-run records into the matching checked-in
    baseline rows.  Timing fields are machine-dependent and keep their
    baseline values — only the deterministic counts move.  Dry-run keys
    with no baseline row are reported (they need a full bench rerun to
    create the row in the first place)."""
    from benchmarks import decode_bench, serving_bench

    decode_bench.main(dry_run=True)
    serving_bench.main(dry_run=True)
    unmatched = []
    for baseline_path, dry_path, key_fields, count_fields in CHECKS:
        with open(baseline_path) as f:
            base_recs = json.load(f)
        base = _index(base_recs, key_fields)
        with open(dry_path) as f:
            dry = json.load(f)
        touched = 0
        for rec in dry:
            key = tuple(rec.get(k) for k in key_fields)
            ref = base.get(key)
            if ref is None:
                unmatched.append(f"{os.path.basename(baseline_path)}: "
                                 f"{dict(zip(key_fields, key))}")
                continue
            for field in count_fields:
                if field in rec and rec.get(field) != ref.get(field):
                    ref[field] = rec[field]
                    touched += 1
        with open(baseline_path, "w") as f:
            json.dump(base_recs, f, indent=2, sort_keys=True)
        print(f"--write: {os.path.basename(baseline_path)}: "
              f"{touched} count field(s) updated")
    if unmatched:
        print("--write: dry-run rows with NO baseline row (a full bench "
              "run must create them):")
        for u in unmatched:
            print(f"  {u}")


def main():
    failures = []
    for baseline, dry, keys, counts in CHECKS:
        failures += check(baseline, dry, keys, counts)
    failures += check_overload_ordering()
    failures += check_pp_occupancy(SERVE_DRY, full=False)
    if os.path.exists(SERVE_FULL):
        failures += check_pp_occupancy(SERVE_FULL, full=True)
    failures += check_prefix_cache(SERVE_DRY)
    if os.path.exists(SERVE_FULL):
        failures += check_prefix_cache(SERVE_FULL)
    failures += check_quant(DECODE_DRY)
    if os.path.exists(DECODE_FULL):
        failures += check_quant(DECODE_FULL)
    failures += check_disagg(SERVE_DRY, full=False)
    if os.path.exists(SERVE_FULL):
        failures += check_disagg(SERVE_FULL, full=True)
    if failures:
        print("BASELINE DRIFT — predicted collective counts changed:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print("baseline check OK: predicted collective counts match "
          "BENCH_decode.json / BENCH_serve.json, overload ordering holds, "
          "pp-occupancy sits on the pp_schedule_stats closed form, "
          "quant records satisfy the QUANT_TOLERANCE numerics contract, "
          "prefix-cache runs are bitwise identical with suffix-only "
          "prefill counts and a zero-leak drain, and the disagg-mixed "
          "series keeps its streams bitwise with an exactly-modeled "
          "KV handoff")


if __name__ == "__main__":
    if "--write" in sys.argv:
        write_baselines()
    else:
        main()
