"""Bench-regression gate: predicted collective counts must not drift.

Timing numbers in ``BENCH_decode.json`` / ``BENCH_serve.json`` are
machine-dependent, but the *predicted collective counts* each record carries
(``decode_collective_counts``, ``prefill_chunk_counts``) come straight from
``core.commodel`` and are exact program properties — if a refactor changes
them, either the engines' schedule changed (a real regression against the
paper's Tables III–VI) or the analytical model did.  Either way CI should
stop the merge until the baselines are regenerated deliberately.

CI runs ``decode_bench --dry-run`` / ``serving_bench --dry-run`` first (they
write ``results/BENCH_*.dryrun.json``), then this script diffs every dry-run
record's count fields against the checked-in baseline record with the same
key.  Run locally the same way:

    PYTHONPATH=src python -m benchmarks.decode_bench --dry-run
    PYTHONPATH=src python -m benchmarks.serving_bench --dry-run
    PYTHONPATH=src python -m benchmarks.check_baselines
"""
import json
import os
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

CHECKS = [
    # (baseline, dry-run output, key fields, compared count fields)
    (os.path.join(REPO, "BENCH_decode.json"),
     os.path.join(REPO, "results", "BENCH_decode.dryrun.json"),
     ("arch", "variant"),
     ("decode_collective_counts",)),
    (os.path.join(REPO, "BENCH_serve.json"),
     os.path.join(REPO, "results", "BENCH_serve.dryrun.json"),
     ("series", "arch", "backend", "tp", "cp", "pp", "paged"),
     ("decode_collective_counts", "prefill_chunk_counts",
      "prefill_collective_counts")),
]


def _index(records, key_fields):
    out = {}
    for r in records:
        out[tuple(r.get(k) for k in key_fields)] = r
    return out


def check(baseline_path, dry_path, key_fields, count_fields):
    failures = []
    if not os.path.exists(dry_path):
        return [f"{dry_path} missing — run the --dry-run bench first"]
    with open(baseline_path) as f:
        base = _index(json.load(f), key_fields)
    with open(dry_path) as f:
        dry = json.load(f)
    for rec in dry:
        key = tuple(rec.get(k) for k in key_fields)
        ref = base.get(key)
        if ref is None:
            failures.append(
                f"{os.path.basename(baseline_path)}: no baseline row for "
                f"{dict(zip(key_fields, key))} — regenerate the bench JSON")
            continue
        for field in count_fields:
            if rec.get(field) != ref.get(field):
                failures.append(
                    f"{os.path.basename(baseline_path)} "
                    f"{dict(zip(key_fields, key))}: {field} drifted\n"
                    f"    baseline: {ref.get(field)}\n"
                    f"    measured: {rec.get(field)}")
    return failures


def main():
    failures = []
    for baseline, dry, keys, counts in CHECKS:
        failures += check(baseline, dry, keys, counts)
    if failures:
        print("BASELINE DRIFT — predicted collective counts changed:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print("baseline check OK: predicted collective counts match "
          "BENCH_decode.json / BENCH_serve.json")


if __name__ == "__main__":
    main()
