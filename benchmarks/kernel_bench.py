"""Kernel microbenchmarks: jnp reference path wall time on CPU (the Pallas
kernels target TPU; interpret mode is a correctness tool, not a perf number,
so the CSV reports the reference path and marks the kernel's target)."""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ref import rms_norm_ref
from repro.kernels.rwkv6_scan.ref import wkv6_ref


def rows():
    rng = np.random.default_rng(0)
    out = []

    q = jnp.asarray(rng.standard_normal((1, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    f = jax.jit(flash_attention_ref)
    _, us = timed(lambda: jax.block_until_ready(f(q, k, v)))
    out.append(("kernel/flash_attention_ref/512x8x64", us,
                "pallas_target=tpu_vmem_blocked"))

    qd = jnp.asarray(rng.standard_normal((8, 8, 64)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((8, 4096, 2, 64)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((8, 4096, 2, 64)), jnp.float32)
    valid = jnp.arange(4096) < 3000
    fd = jax.jit(decode_attention_ref)
    _, us = timed(lambda: jax.block_until_ready(fd(qd, kd, vd, valid)))
    out.append(("kernel/decode_attention_ref/b8_w4096", us,
                "pallas_target=flash_decode_seq_blocks"))

    x = jnp.asarray(rng.standard_normal((4096, 2048)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((2048,)), jnp.float32)
    fr = jax.jit(rms_norm_ref)
    _, us = timed(lambda: jax.block_until_ready(fr(x, w)))
    out.append(("kernel/rmsnorm_ref/4096x2048", us,
                "pallas_target=row_blocked_fused"))

    r = jnp.asarray(rng.standard_normal((2, 256, 8, 64)) * 0.5, jnp.float32)
    kk = jnp.asarray(rng.standard_normal((2, 256, 8, 64)) * 0.5, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((2, 256, 8, 64)) * 0.5, jnp.float32)
    ww = jnp.asarray(rng.uniform(0.9, 0.999, (2, 256, 8, 64)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((8, 64)) * 0.3, jnp.float32)
    st = jnp.zeros((2, 8, 64, 64), jnp.float32)
    fw = jax.jit(wkv6_ref)
    _, us = timed(lambda: jax.block_until_ready(fw(r, kk, vv, ww, u, st)[0]))
    out.append(("kernel/wkv6_ref/b2_s256_h8", us,
                "pallas_target=vmem_state_chunked_scan"))
    return out


def main():
    print("Kernel microbenchmarks (CPU reference path)")
    for r in rows():
        print(f"  {r[0]:44s} {r[1]:10.1f} us  {r[2]}")


if __name__ == "__main__":
    main()
