"""Paper Fig 4: measured-vs-predicted TP collectives at FULL model size.

The explicit Megatron-TP engine (core/parallel_exec.py) is lowered for the
paper's actual subjects (Llama-3.2-3B / 3.1-8B / 2-13B, full layer counts) on
a 4-device TP mesh — ShapeDtypeStruct params, no allocation — and the
compiled HLO collective counts/bytes are compared against Eq. 1.  This is the
paper's validation plot as an equality check.

Runs in a subprocess so the 4-device host-platform flag stays contained.
"""
import json
import os
import subprocess
import sys

MODELS = ["llama32-3b", "llama31-8b", "llama2-13b"]
REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _measure():
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")
    import jax

    from repro.configs import get_config
    from repro.core import commodel as cm
    from repro.core import parallel_exec as px
    from repro.core.hlo_comm import parse_hlo_collectives, summarize

    out = []
    S, B, t = 128, 1, 4
    for arch in MODELS:
        cfg = get_config(arch)
        mesh = px.make_tp_mesh(t)
        fn = px.tp_prefill(cfg, mesh)
        model_params = jax.eval_shape(
            lambda: __import__("repro.models.transformer",
                               fromlist=["get_model"]).get_model(cfg).init(
                                   jax.random.PRNGKey(0)))
        toks = jax.ShapeDtypeStruct((B, S), jax.numpy.int32)
        hlo = fn.lower(model_params, toks).compile().as_text()
        meas = summarize(parse_hlo_collectives(hlo))
        pred = cm.tp_comm_ops(cfg, S, 1, t, gather_mode="allgather", batch=B)
        pred_ar = sum(o.count for o in pred if o.collective == "allreduce")
        # the CPU host backend upcasts bf16 collectives to f32 (b=4); on TPU
        # the wire dtype is bf16 (b=2, the paper's Table IV accounting)
        pred_ar_bytes = sum(o.count * o.elements * 4 for o in pred
                            if o.collective == "allreduce")
        out.append({
            "arch": arch,
            "measured_ar": meas["allreduce"]["count"],
            "predicted_ar": pred_ar,
            "measured_ar_bytes": meas["allreduce"]["msg_bytes"],
            "predicted_ar_bytes": pred_ar_bytes,
            "measured_ag": meas.get("allgather", {}).get("count", 0),
        })
    print("FIG4JSON:" + json.dumps(out))


def rows():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig4_validation", "--measure"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    out = []
    for line in r.stdout.splitlines():
        if line.startswith("FIG4JSON:"):
            for rec in json.loads(line[len("FIG4JSON:"):]):
                match = (rec["measured_ar"] == rec["predicted_ar"]
                         and rec["measured_ar_bytes"] == rec["predicted_ar_bytes"])
                out.append((f"fig4/{rec['arch']}/tp4_fullsize", 0.0,
                            f"measured_ar={rec['measured_ar']};"
                            f"predicted_ar={rec['predicted_ar']};"
                            f"ar_bytes={rec['measured_ar_bytes']};"
                            f"match={'EXACT' if match else 'MISMATCH'}"))
    if not out:
        out.append(("fig4/validation", 0.0,
                    f"subprocess_failed;stderr={r.stderr[-200:]}"))
    return out


def main():
    print("Fig 4 — full-size measured (HLO) vs predicted (Eq.1) TP collectives")
    for r in rows():
        print(f"  {r[0]:34s} {r[2]}")


if __name__ == "__main__":
    if "--measure" in sys.argv:
        _measure()
    else:
        main()
