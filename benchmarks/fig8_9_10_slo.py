"""Paper Figs 8–10: SLO predictions per parallelism layout (α–β model)."""
from benchmarks.common import timed
from repro.configs import get_config
from repro.core.slo import predict_slo


def rows():
    out = []
    l3 = get_config("llama32-3b")
    for t in (2, 4, 8):                                   # Fig 8
        r, us = timed(lambda t=t: predict_slo(l3, 128, 128, t=t))
        out.append((f"fig8/llama32-3b/tp{t}", us,
                    f"ttft_ms={r.ttft*1e3:.1f};tpot_ms={r.tpot*1e3:.2f};"
                    f"e2e_s={r.e2e:.2f}"))
    for p in (2, 4, 8):                                   # Fig 9
        r, us = timed(lambda p=p: predict_slo(l3, 128, 128, t=1, p=p))
        out.append((f"fig9/llama32-3b/pp{p}", us,
                    f"ttft_ms={r.ttft*1e3:.1f};tpot_ms={r.tpot*1e3:.2f};"
                    f"e2e_s={r.e2e:.2f}"))
    l13 = get_config("llama2-13b")
    for t, p in ((8, 1), (1, 8), (2, 4), (4, 2)):         # Fig 10
        r, us = timed(lambda t=t, p=p: predict_slo(l13, 128, 128, t=t, p=p))
        out.append((f"fig10/llama2-13b/tp{t}pp{p}", us,
                    f"ttft_ms={r.ttft*1e3:.1f};tpot_ms={r.tpot*1e3:.2f};"
                    f"e2e_s={r.e2e:.2f}"))
    return out


def main():
    print("Figs 8-10 — SLO predictions (H100-node profile, fitted constants)")
    print("  paper anchors: Fig8 TTFT 150/90/30ms TPOT 1.17/0.86/11.56ms;")
    print("                 Fig9 TTFT 430/1110/2520ms; Fig10 TP8 best (70ms)")
    for r in rows():
        print(f"  {r[0]:32s} {r[2]}")


if __name__ == "__main__":
    main()
