"""Shared helpers for the benchmark suite.

Every benchmark module exposes ``rows() -> list[(name, us_per_call, derived)]``
where ``us_per_call`` is the measured wall time of producing the quantity and
``derived`` is the benchmark's headline number (a count, byte volume, ms, …).
``benchmarks.run`` aggregates all modules into one CSV.
"""
from __future__ import annotations

import time
from typing import Callable, Tuple

Row = Tuple[str, float, str]


def timed(fn: Callable, repeat: int = 5):
    """Return (result, mean_us)."""
    fn()                                    # warmup / trace
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn()
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def fmt_bytes(b: float) -> str:
    return f"{b / 2**20:.2f}MiB"
