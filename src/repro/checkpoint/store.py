"""Sharded-pytree checkpointing without external deps.

Leaves are stored in a single ``.npz`` (path-joined keys) plus a JSON manifest
carrying the tree structure, dtypes and a step counter.  Arrays are pulled to
host via jax.device_get (works for sharded global arrays on a live mesh).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import numpy as np

import jax

_SEP = "/"


def _flatten(tree, prefix=()) -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
    else:
        out[_SEP.join(prefix)] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def save_checkpoint(path: str, tree, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(path, "arrays.npz"), **host)
    manifest = {"step": step,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host.items()}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str) -> Tuple[Any, int]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    for k, meta in manifest["leaves"].items():
        assert list(flat[k].shape) == meta["shape"], f"shape mismatch at {k}"
    return _unflatten(flat), manifest["step"]
