"""Explicit TP / PP / hybrid inference engines (paper-faithful schedule).

The production path (runtime/, launch/) relies on GSPMD to place collectives.
This module instead reproduces the *exact* collective schedule the paper
profiles in vLLM/Megatron, using shard_map with hand-placed collectives:

  TP   (Section III-A): vocab-parallel embedding psum (+1), per layer one
       psum after the attention output projection and one after the MLP
       down-projection (2L), and a logits gather over the vocab shards.
  PP   (Section III-B): per stage boundary TWO tensors (vLLM ships
       hidden_states and residual separately — we split the activation into
       two summands to reproduce the wire pattern) moved by ppermute.
  TP×PP (Section III-C): per-stage allreduces (2L/p + 1), boundary p2p of
       the [tokens, h/t] shard, and 2 allgathers to redistribute the
       received shard among the stage's TP workers.

XLA adaptation (DESIGN.md §2): the paper's NCCL `Gather` of logit shards has
no XLA equivalent; we all-gather (commodel gather_mode="allgather").

These engines cover the dense llama-family (the paper's subjects).

Two execution modes (DESIGN.md §5):

  unroll=True   paper-parity mode.  Layer loops are unrolled so every
                collective appears as a distinct HLO op — the per-op count
                parity with Tables III–VI is asserted against the compiled
                module.
  unroll=False  fast path (default for benchmarks/ and runtime/).  Block
                params keep their stacked [L, ...] leading axis and the layer
                loop runs under ``jax.lax.scan`` inside one shard_map, so the
                module stays O(1) in depth; decode jits donate the KV cache
                so XLA updates the [L, B, W, kv, D] buffers in place; and
                ``tp_generate`` fuses N greedy decode steps into a single
                dispatch with ``lax.fori_loop``.  Collective *counts* are
                unchanged — core/hlo_comm.py expands scan trip counts, so
                both modes report identical schedules.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig
from repro.models.layers import apply_rope, decode_cache_mask, gqa_attention, \
    make_mask, mlp_apply, rms_norm
from repro.models.transformer import greedy_decode_loop


# ---------------------------------------------------------------------------
# parameter partition specs (shared with Model.init pytrees)
# ---------------------------------------------------------------------------


def tp_param_specs(cfg: ModelConfig, tp_axis: str = "tp",
                   stage_axis: str = None) -> dict:
    """PartitionSpecs for a Model.init(...) pytree under explicit TP (+PP).

    Column-parallel: wq/wk/wv, w1/w3 (output dim sharded).  Row-parallel:
    wo, w2 (input dim sharded).  Vocab-parallel: embed, lm_head.
    With ``stage_axis``, block params gain a leading stage dimension.
    """
    st = (stage_axis,) if stage_axis else ()
    blk = {
        "wq": P(*st, None, None, tp_axis), "wk": P(*st, None, None, tp_axis),
        "wv": P(*st, None, None, tp_axis), "wo": P(*st, None, tp_axis, None),
        "w1": P(*st, None, None, tp_axis), "w3": P(*st, None, None, tp_axis),
        "w2": P(*st, None, tp_axis, None),
        "ln1": P(*st, None, None), "ln2": P(*st, None, None),
    }
    return {
        "blocks": blk,
        "embed": P(tp_axis, None),
        "lm_head": P(None, tp_axis),
        "final_norm": P(None),
    }


# ---------------------------------------------------------------------------
# local (per-shard) building blocks
# ---------------------------------------------------------------------------


def _vocab_parallel_embed(embed_local, tokens, axis: str):
    """Vocab-sharded embedding lookup + psum (the paper's '+1' allreduce)."""
    idx = jax.lax.axis_index(axis)
    vshard = embed_local.shape[0]
    local = tokens - idx * vshard
    valid = (local >= 0) & (local < vshard)
    x = embed_local[jnp.clip(local, 0, vshard - 1)]
    x = jnp.where(valid[..., None], x, 0)
    return jax.lax.psum(x, axis)


def _tp_layer_full(cfg, pl, x, positions, mask, axis: str, heads_t: int,
                   kv_t: int, cache_w=None):
    """One transformer layer under TP over full sequence.  2 psums."""
    B, S, _ = x.shape
    D = cfg.head_dim
    xn = rms_norm(x, pl["ln1"], cfg.norm_eps)
    q = apply_rope((xn @ pl["wq"]).reshape(B, S, heads_t, D), positions,
                   cfg.rope_theta)
    k = apply_rope((xn @ pl["wk"]).reshape(B, S, kv_t, D), positions,
                   cfg.rope_theta)
    v = (xn @ pl["wv"]).reshape(B, S, kv_t, D)
    attn = gqa_attention(q, k, v, mask).reshape(B, S, heads_t * D)
    x = x + jax.lax.psum(attn @ pl["wo"], axis)                # AR (attn out)
    xn2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
    mlp = mlp_apply(pl, xn2, cfg.activation)
    x = x + jax.lax.psum(mlp, axis)                            # AR (mlp down)
    cache = None
    if cache_w is not None:
        from repro.models.blocks import build_ring_cache
        cache = build_ring_cache(k, v, cache_w)
    return x, cache


def _tp_layer_step(cfg, pl, x, pos, cache, axis: str, heads_t: int, kv_t: int):
    """One decode step under TP.  2 psums."""
    B = x.shape[0]
    D = cfg.head_dim
    w = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    xn = rms_norm(x, pl["ln1"], cfg.norm_eps)
    q = apply_rope((xn @ pl["wq"]).reshape(B, 1, heads_t, D), positions,
                   cfg.rope_theta)
    k = apply_rope((xn @ pl["wk"]).reshape(B, 1, kv_t, D), positions,
                   cfg.rope_theta)
    v = (xn @ pl["wv"]).reshape(B, 1, kv_t, D)
    slot = pos % w
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    mask = decode_cache_mask(w, pos + 1, cfg.sliding_window)[None, :]
    attn = gqa_attention(q, ck, cv, mask).reshape(B, 1, heads_t * D)
    x = x + jax.lax.psum(attn @ pl["wo"], axis)
    xn2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
    x = x + jax.lax.psum(mlp_apply(pl, xn2, cfg.activation), axis)
    return x, {"k": ck, "v": cv}


def _layer_slice(blocks, l):
    return {k: v[l] for k, v in blocks.items()}


def _logits_allgather(params, x_last, axis: str, vocab: int = None,
                      eps: float = 1e-5):
    """Vocab-sharded logits + all-gather (paper's Gather, XLA-adapted)."""
    xn = rms_norm(x_last, params["final_norm"], eps)
    local = xn @ params["lm_head"]
    logits = jax.lax.all_gather(local, axis, axis=-1, tiled=True)
    if vocab is not None and vocab < logits.shape[-1]:
        col = jnp.arange(logits.shape[-1])
        logits = jnp.where(col < vocab, logits, jnp.finfo(jnp.float32).min)
    return logits


# ---------------------------------------------------------------------------
# TP engine
# ---------------------------------------------------------------------------


def make_tp_mesh(t: int) -> Mesh:
    return jax.make_mesh((t,), ("tp",))


_TP_CACHE_SPEC = {"k": P(None, None, None, "tp", None),
                  "v": P(None, None, None, "tp", None)}


def _tp_layers_full(cfg, params, x, positions, mask, heads_t, kv_t,
                    cache_w, unroll: bool):
    """All layers over a full sequence: unrolled (paper parity) or scanned."""
    if unroll:
        caches = []
        for l in range(cfg.num_layers):
            x, c = _tp_layer_full(cfg, _layer_slice(params["blocks"], l), x,
                                  positions, mask, "tp", heads_t, kv_t,
                                  cache_w)
            caches.append(c)
        cache = None
        if cache_w is not None:
            cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
        return x, cache

    def body(h, pl):
        h, c = _tp_layer_full(cfg, pl, h, positions, mask, "tp",
                              heads_t, kv_t, cache_w)
        return h, c

    return jax.lax.scan(body, x, params["blocks"])


def _tp_layers_step(cfg, params, x, pos, cache, heads_t, kv_t, unroll: bool):
    """All layers for one decode token against the stacked [L,...] cache."""
    if unroll:
        new_cache = []
        for l in range(cfg.num_layers):
            x, c = _tp_layer_step(cfg, _layer_slice(params["blocks"], l), x,
                                  pos, _layer_slice(cache, l), "tp",
                                  heads_t, kv_t)
            new_cache.append(c)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *new_cache)

    def body(h, inp):
        pl, cl = inp
        h, c = _tp_layer_step(cfg, pl, h, pos, cl, "tp", heads_t, kv_t)
        return h, c

    return jax.lax.scan(body, x, (params["blocks"], cache))


def _tp_single_step(cfg, params, cache, token, pos, heads_t, kv_t,
                    unroll: bool):
    """One full decode step: embed psum + all layers + logits all-gather."""
    x = _vocab_parallel_embed(params["embed"], token[:, None], "tp")
    x, cache = _tp_layers_step(cfg, params, x, pos, cache, heads_t, kv_t,
                               unroll)
    logits = _logits_allgather(params, x[:, 0, :], "tp", cfg.vocab_size,
                               cfg.norm_eps)
    return logits, cache


def tp_prefill(cfg: ModelConfig, mesh: Mesh, cache_w: int = None,
               unroll: bool = True):
    """jit'd fn(params, tokens) -> (logits [B,v], cache|None).

    Collectives per call: (2L+1) allreduce + 1 allgather — Eq. 1 / Table III.
    ``unroll=False`` scans the layer stack (same schedule, O(1)-depth HLO).
    """
    t = mesh.shape["tp"]
    heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
    specs = tp_param_specs(cfg)

    def fn(params, tokens):
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        mask = make_mask(S, S, window=cfg.sliding_window)
        x = _vocab_parallel_embed(params["embed"], tokens, "tp")
        x, cache = _tp_layers_full(cfg, params, x, positions, mask,
                                   heads_t, kv_t, cache_w, unroll)
        logits = _logits_allgather(params, x[:, -1, :], "tp", cfg.vocab_size,
                                   cfg.norm_eps)
        return logits, cache

    out_cache_spec = None if cache_w is None else _TP_CACHE_SPEC
    return jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(specs, P(None, None)),
        out_specs=(P(None, None), out_cache_spec),
        check_rep=False))


def tp_decode_step(cfg: ModelConfig, mesh: Mesh, unroll: bool = True,
                   donate: bool = None):
    """jit'd fn(params, cache, token [B], pos) -> (logits, cache).

    Collectives per call: (2L+1) allreduce + 1 allgather — Table III decode.
    The fast path (``unroll=False``) scans the stacked [L, B, W, kv, D] cache
    and donates it, so XLA aliases the update in place instead of the
    per-layer slice/re-stack copy; ``donate`` overrides that default (the
    paper-parity mode keeps the cache alive for step-by-step comparisons).
    """
    t = mesh.shape["tp"]
    heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
    specs = tp_param_specs(cfg)
    donate = (not unroll) if donate is None else donate

    def fn(params, cache, token, pos):
        return _tp_single_step(cfg, params, cache, token, pos,
                               heads_t, kv_t, unroll)

    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(specs, _TP_CACHE_SPEC, P(None), P()),
        out_specs=(P(None, None), _TP_CACHE_SPEC),
        check_rep=False),
        donate_argnums=(1,) if donate else ())


def tp_generate(cfg: ModelConfig, mesh: Mesh, num_tokens: int,
                unroll: bool = False):
    """jit'd fn(params, cache, token [B], pos) -> (tokens [B, N], cache).

    Fused greedy multi-token decode: N scanned decode steps run inside ONE
    dispatch via ``lax.fori_loop`` with argmax feedback.  ``tokens[:, i]`` is
    exactly the token a step-by-step ``tp_decode_step`` chain would produce
    after feeding ``token`` at ``pos`` and its successors at ``pos+1 ...``.
    The cache is donated: the [L, B, W, kv, D] buffers are updated in place
    across all N steps without ever being re-materialized on the host.
    """
    t = mesh.shape["tp"]
    heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
    specs = tp_param_specs(cfg)

    def fn(params, cache, token, pos):
        return greedy_decode_loop(
            lambda c, tok, p: _tp_single_step(cfg, params, c, tok, p,
                                              heads_t, kv_t, unroll),
            token, cache, pos, num_tokens)

    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(specs, _TP_CACHE_SPEC, P(None), P()),
        out_specs=(P(None, None), _TP_CACHE_SPEC),
        check_rep=False),
        donate_argnums=(1,))


# ---------------------------------------------------------------------------
# PP engine — one jitted computation per stage, explicit transfers (vLLM-style)
# ---------------------------------------------------------------------------
#
# Real PP serving (the paper's vLLM setup) runs one process group per stage
# and moves activations with NCCL send/recv.  The SPMD-lockstep alternative
# (shard_map over a "pp" axis) would execute every stage's collectives on
# every rank — inflating per-rank counts p×, which is NOT what the paper's
# per-rank profile shows.  So the engine mirrors vLLM: each stage is its own
# jit (optionally TP-sharded over its own device group) and the engine logs
# every inter-stage transfer — that log is our measured Table V / Eq. 2 side.


def _dense_local_layer(cfg, pl, x, positions, mask):
    """Full-width dense layer (no TP) — used by pure-PP stages."""
    B, S, _ = x.shape
    D = cfg.head_dim
    xn = rms_norm(x, pl["ln1"], cfg.norm_eps)
    q = apply_rope((xn @ pl["wq"]).reshape(B, S, cfg.num_heads, D), positions,
                   cfg.rope_theta)
    k = apply_rope((xn @ pl["wk"]).reshape(B, S, cfg.num_kv_heads, D),
                   positions, cfg.rope_theta)
    v = (xn @ pl["wv"]).reshape(B, S, cfg.num_kv_heads, D)
    attn = gqa_attention(q, k, v, mask).reshape(B, S, cfg.num_heads * D)
    x = x + attn @ pl["wo"]
    x = x + mlp_apply(pl, rms_norm(x, pl["ln2"], cfg.norm_eps), cfg.activation)
    return x


@dataclasses.dataclass
class TransferRecord:
    phase: str
    count: int          # individual tensors moved (the paper's Send count)
    shape: Tuple[int, ...]
    dtype_bytes: int

    @property
    def bytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return self.count * n * self.dtype_bytes


def stage_layer_range(cfg: ModelConfig, p: int, s: int) -> Tuple[int, int]:
    L = cfg.num_layers
    per = L // p
    return s * per, (s + 1) * per


class PipelineEngine:
    """Single-request PP (t=1) or hybrid TP×PP (t>1) inference engine.

    Stage s owns layers [s·L/p, (s+1)·L/p) on its own ``t``-device mesh.
    Boundary hand-off ships TWO tensors per hop (hidden_states + residual,
    the vLLM pattern) of shape [S, h/t] per TP worker, logged in
    ``self.transfers``.  Within a stage the TP collectives (allreduce per
    row-parallel linear, embedding psum on stage 0, logits all-gather on the
    last stage) are hand-placed and visible in each stage's HLO.

    ``unroll=False`` scans each stage's layer slice instead of unrolling it
    (same collective schedule, trip-counted in the stage HLO — DESIGN.md §5).
    """

    def __init__(self, cfg: ModelConfig, t: int = 1, p: int = 2,
                 devices=None, unroll: bool = True):
        self.cfg, self.t, self.p = cfg, t, p
        self.unroll = unroll
        devices = devices if devices is not None else jax.devices()
        assert len(devices) >= t * p, f"need {t * p} devices"
        self.meshes = [Mesh(np.asarray(devices[s * t:(s + 1) * t]), ("tp",))
                       for s in range(p)]
        self.transfers: list = []
        self._stage_fns = [self._build_stage(s) for s in range(p)]

    # -- per-stage jitted computations -------------------------------------
    def _build_stage(self, s: int):
        cfg, t, p = self.cfg, self.t, self.p
        lo, hi = stage_layer_range(cfg, p, s)
        heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
        mesh = self.meshes[s]
        first, last = s == 0, s == p - 1

        def fn(params, x_or_tokens):
            if first:
                if t > 1:
                    x = _vocab_parallel_embed(params["embed"], x_or_tokens,
                                              "tp")
                else:
                    x = params["embed"][x_or_tokens]
            else:
                if t > 1:   # redistribute the received h/t shards (2 tensors)
                    h1, h2 = x_or_tokens
                    g1 = jax.lax.all_gather(h1, "tp", axis=-1, tiled=True)
                    g2 = jax.lax.all_gather(h2, "tp", axis=-1, tiled=True)
                    x = g1 + g2
                else:
                    h1, h2 = x_or_tokens
                    x = h1 + h2
            B, S = x.shape[:2]
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            mask = make_mask(S, S, window=cfg.sliding_window)
            if self.unroll:
                for l in range(lo, hi):
                    pl = _layer_slice(params["blocks"], l)
                    if t > 1:
                        x, _ = _tp_layer_full(cfg, pl, x, positions, mask,
                                              "tp", heads_t, kv_t)
                    else:
                        x = _dense_local_layer(cfg, pl, x, positions, mask)
            else:
                stage_blocks = jax.tree.map(
                    lambda a: jax.lax.slice_in_dim(a, lo, hi, axis=0),
                    params["blocks"])

                def body(h, pl):
                    if t > 1:
                        h, _ = _tp_layer_full(cfg, pl, h, positions, mask,
                                              "tp", heads_t, kv_t)
                    else:
                        h = _dense_local_layer(cfg, pl, h, positions, mask)
                    return h, None

                x, _ = jax.lax.scan(body, x, stage_blocks)
            if last:
                if t > 1:
                    return _logits_allgather(params, x[:, -1, :], "tp",
                                             cfg.vocab_size, cfg.norm_eps)
                xn = rms_norm(x[:, -1, :], params["final_norm"], cfg.norm_eps)
                logits = xn @ params["lm_head"]
                if cfg.padded_vocab != cfg.vocab_size:
                    col = jnp.arange(logits.shape[-1])
                    logits = jnp.where(col < cfg.vocab_size, logits,
                                       jnp.finfo(jnp.float32).min)
                return logits
            # split into (hidden, residual)-like summand pair for the wire
            tp_idx = jax.lax.axis_index("tp") if t > 1 else 0
            h = cfg.d_model
            shard = (jax.lax.dynamic_slice_in_dim(
                x, tp_idx * (h // t), h // t, axis=-1) if t > 1 else x)
            return shard * 0.25, shard * 0.75

        specs = tp_param_specs(cfg)
        in_x_spec = (P(None, None) if first
                     else (P(None, None, "tp" if t > 1 else None),) * 2)
        out_spec = (P(None, None) if last
                    else (P(None, None, "tp" if t > 1 else None),) * 2)
        if t > 1:
            mapped = shard_map(fn, mesh=mesh, in_specs=(specs, in_x_spec),
                               out_specs=out_spec, check_rep=False)
        else:
            def mapped(params, x):          # single-device stage
                return fn(params, x)
        return jax.jit(mapped), mesh

    # -- driver --------------------------------------------------------------
    def _shard_params(self, params, mesh):
        specs = tp_param_specs(self.cfg)
        if self.t == 1:
            specs = jax.tree.map(lambda _: P(), specs,
                                 is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(
            params, jax.tree.map(
                lambda sp: NamedSharding(mesh, sp), specs,
                is_leaf=lambda x: isinstance(x, P)))

    def prepare(self, params):
        """Place one param copy per stage (each stage reads its own layers)."""
        return [self._shard_params(params, m) for m in self.meshes]

    def forward(self, staged_params, tokens, phase: str = "prefill"):
        """Run one pass; logs (p-1)×2 transfers of [S, h/t] — Eq. 2 / Eq. 7."""
        x = tokens
        for s in range(self.p):
            fn, mesh = self._stage_fns[s]
            out = fn(staged_params[s], x)
            if s < self.p - 1:
                nxt = self.meshes[s + 1]
                spec = P(None, None, "tp" if self.t > 1 else None)
                moved = tuple(
                    jax.device_put(h, NamedSharding(nxt, spec)) for h in out)
                for h in moved:
                    self.transfers.append(TransferRecord(
                        phase, 1, tuple(h.shape[:-1]) + (h.shape[-1] // self.t,),
                        jnp.dtype(h.dtype).itemsize))
                x = moved
            else:
                return out

    def stage_hlo(self, staged_params, tokens, s: int) -> str:
        """Compiled HLO of stage s (for collective-count validation)."""
        x = tokens
        for i in range(s):
            fn, _ = self._stage_fns[i]
            out = fn(staged_params[i], x)
            nxt = self.meshes[i + 1]
            spec = P(None, None, "tp" if self.t > 1 else None)
            x = tuple(jax.device_put(h, NamedSharding(nxt, spec))
                      for h in out)
        fn, _ = self._stage_fns[s]
        return fn.lower(staged_params[s], x).compile().as_text()

    def transfer_summary(self):
        total = sum(r.bytes for r in self.transfers)
        return {"count": sum(r.count for r in self.transfers),
                "bytes": total}
