"""Explicit TP / PP / hybrid inference engines (paper-faithful schedule).

The production path (runtime/, launch/) relies on GSPMD to place collectives.
This module instead reproduces the *exact* collective schedule the paper
profiles in vLLM/Megatron, using shard_map with hand-placed collectives:

  TP   (Section III-A): vocab-parallel embedding psum (+1), per layer one
       psum after the attention output projection and one after the MLP
       down-projection (2L), and a logits gather over the vocab shards.
  PP   (Section III-B): per stage boundary TWO tensors (vLLM ships
       hidden_states and residual separately — we split the activation into
       two summands to reproduce the wire pattern) moved by ``jax.device_put``
       between the per-stage jits and logged as TransferRecords, our measured
       Eq. 2 / Table V side (DESIGN.md §3 — not ppermute: an SPMD-lockstep
       collective would run every stage's schedule on every rank).
  TP×PP (Section III-C): per-stage allreduces (2L/p + 1), boundary p2p of
       the [tokens, h/t] shard, and 2 allgathers to redistribute the
       received shard among the stage's TP workers.

XLA adaptation (DESIGN.md §2): the paper's NCCL `Gather` of logit shards has
no XLA equivalent; we all-gather (commodel gather_mode="allgather").

These engines cover the dense llama-family (the paper's subjects).

Two execution modes (DESIGN.md §5):

  unroll=True   paper-parity mode.  Layer loops are unrolled so every
                collective appears as a distinct HLO op — the per-op count
                parity with Tables III–VI is asserted against the compiled
                module.
  unroll=False  fast path (default for benchmarks/ and runtime/).  Block
                params keep their stacked [L, ...] leading axis and the layer
                loop runs under ``jax.lax.scan`` inside one shard_map, so the
                module stays O(1) in depth; decode jits donate the KV cache
                so XLA updates the [L, B, W, kv, D] buffers in place; and
                ``tp_generate`` fuses N greedy decode steps into a single
                dispatch with ``lax.fori_loop``.  Collective *counts* are
                unchanged — core/hlo_comm.py expands scan trip counts, so
                both modes report identical schedules.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig
from repro.core.commodel import DEFAULT_QUANT_CHUNK, stage_layer_partition
from repro.kernels.quant_collective import (QUANT_DTYPES, chunk_amax,
                                            chunk_dequantize, chunk_quantize,
                                            collective_qmax, nibble_pack,
                                            nibble_unpack, scales_from_amax)
from repro.models.layers import apply_rope, decode_attn_mask, \
    decode_positions, gqa_attention, make_mask, mlp_apply, paged_attn_mask, \
    paged_cache_update, paged_gather, ring_cache_update, ring_kv_assemble, \
    rms_norm
from repro.models.transformer import greedy_decode_host_loop, \
    greedy_decode_loop


# ---------------------------------------------------------------------------
# parameter partition specs (shared with Model.init pytrees)
# ---------------------------------------------------------------------------


def tp_param_specs(cfg: ModelConfig, tp_axis: str = "tp",
                   stage_axis: str = None) -> dict:
    """PartitionSpecs for a Model.init(...) pytree under explicit TP (+PP).

    Column-parallel: wq/wk/wv, w1/w3 (output dim sharded).  Row-parallel:
    wo, w2 (input dim sharded).  Vocab-parallel: embed, lm_head.
    With ``stage_axis``, block params gain a leading stage dimension.
    ``tp_axis=None`` yields fully replicated specs (a t=1 engine on a
    cp-only mesh).
    """
    st = (stage_axis,) if stage_axis else ()
    blk = {
        "wq": P(*st, None, None, tp_axis), "wk": P(*st, None, None, tp_axis),
        "wv": P(*st, None, None, tp_axis), "wo": P(*st, None, tp_axis, None),
        "w1": P(*st, None, None, tp_axis), "w3": P(*st, None, None, tp_axis),
        "w2": P(*st, None, tp_axis, None),
        "ln1": P(*st, None, None), "ln2": P(*st, None, None),
    }
    return {
        "blocks": blk,
        "embed": P(tp_axis, None),
        "lm_head": P(None, tp_axis),
        "final_norm": P(None),
    }


# ---------------------------------------------------------------------------
# local (per-shard) building blocks
# ---------------------------------------------------------------------------


def _vocab_parallel_embed(embed_local, tokens, axis: str):
    """Vocab-sharded embedding lookup + psum (the paper's '+1' allreduce)."""
    idx = jax.lax.axis_index(axis)
    vshard = embed_local.shape[0]
    local = tokens - idx * vshard
    valid = (local >= 0) & (local < vshard)
    x = embed_local[jnp.clip(local, 0, vshard - 1)]
    x = jnp.where(valid[..., None], x, 0)
    return jax.lax.psum(x, axis)


def _maybe_psum(x, axis):
    """psum over the TP axis — identity when the layer runs full-width
    (``axis=None``, the pure-PP per-stage path)."""
    return jax.lax.psum(x, axis) if axis is not None else x


def _check_quant(quant):
    if quant is not None and quant not in QUANT_DTYPES:
        raise ValueError(f"unknown quant_collectives mode {quant!r}; "
                         f"expected None or one of {sorted(QUANT_DTYPES)}")
    return quant


def quantized_psum(x, axis, t: int, quant: str = "int8",
                   chunk: int = DEFAULT_QUANT_CHUNK):
    """Quantized two-step all-reduce over the TP axis (DESIGN.md §12).

    Lowers one full-width ``psum`` of x [..., h] into the Flash
    Communication decomposition:

      1. per-chunk abs-max + f32 ``pmax`` over the axis (the scale
         exchange — one small f32 all-reduce of [rows, ceil(h/chunk)]),
      2. symmetric quantize onto the shared scales, with ``floor(127/t)``
         (int8) / ``448/t`` (fp8-e4m3) headroom so the t-way sum cannot
         overflow the wire dtype — the int8 reduction is therefore EXACT,
      3. ``psum_scatter`` of the 1-byte payload (compiles to a genuine
         reduce-scatter HLO op over the quant dtype),
      4. ``all_gather`` of the reduced 1-byte shards,
      5. dequantize with the same shared scales (known on every rank from
         the pmax) back to x.dtype.

    ``quant="int4"`` swaps steps 2–4 for the packed-nibble variant: the
    reduce-scatter cannot carry 4-bit fields (integer partial sums would
    bleed across nibble boundaries), so the payload rides a tiled
    ``all_to_all`` instead — each rank receives every rank's packed copy
    of its own hidden block, unpacks, sums EXACTLY in int32 (|sum| <= 7t,
    which is why int4 keeps the full +-7 grid, see ``collective_qmax``),
    requantizes the reduced block by t back onto the 4-bit grid, and
    all-gathers the re-packed halves.  The wire moves 0.5 bytes/element on
    both hops; dequant runs at ``scales * t`` to undo the requantize.

    Identity fallbacks: ``axis=None`` / ``quant=None`` / ``t<=1`` run the
    plain ``_maybe_psum`` — bitwise-identical to the unquantized path with
    zero quant ops in the compiled module.
    """
    if axis is None or quant is None or t <= 1:
        return _maybe_psum(x, axis)
    h = x.shape[-1]
    if h % t:
        raise ValueError(f"quantized_psum scatters the hidden axis over "
                         f"t={t}: h={h} must divide")
    qmax = collective_qmax(quant, t)
    amax = jax.lax.pmax(chunk_amax(x, chunk), axis)
    scales = scales_from_amax(amax, qmax)
    q = chunk_quantize(x, scales, chunk, quant)
    if quant == "int4":
        if h % (2 * t):
            raise ValueError(f"int4 packs two values per byte and ships "
                             f"h/t-element blocks: h={h} must divide 2t="
                             f"{2 * t}")
        pa = jax.lax.all_to_all(nibble_pack(q), axis,
                                split_axis=x.ndim - 1,
                                concat_axis=x.ndim - 1, tiled=True)
        qa = nibble_unpack(pa)          # t source copies of the local block
        r = qa.astype(jnp.int32).reshape(*x.shape[:-1], t, h // t) \
              .sum(axis=-2)             # exact: |r| <= 7t
        rq = jnp.clip(jnp.round(r.astype(jnp.float32) / t),
                      -7, 7).astype(jnp.int8)
        pg = jax.lax.all_gather(nibble_pack(rq), axis, axis=x.ndim - 1,
                                tiled=True)
        return chunk_dequantize(nibble_unpack(pg), scales * t, chunk,
                                x.dtype)
    qs = jax.lax.psum_scatter(q, axis, scatter_dimension=x.ndim - 1,
                              tiled=True)
    qg = jax.lax.all_gather(qs, axis, axis=x.ndim - 1, tiled=True)
    return chunk_dequantize(qg, scales, chunk, x.dtype)


def _tp_layer_qkv(cfg, pl, xn, positions, heads_t: int, kv_t: int):
    """Normed input [B, S, h] -> (RoPE'd q, RoPE'd k, v), each
    [B, S, H_t, D] — the projection head shared by every layer variant."""
    B, S = xn.shape[:2]
    D = cfg.head_dim
    q = apply_rope((xn @ pl["wq"]).reshape(B, S, heads_t, D), positions,
                   cfg.rope_theta)
    k = apply_rope((xn @ pl["wk"]).reshape(B, S, kv_t, D), positions,
                   cfg.rope_theta)
    v = (xn @ pl["wv"]).reshape(B, S, kv_t, D)
    return q, k, v


def _tp_layer_out(cfg, pl, x, attn, axis, t: int = 1, quant: str = None,
                  quant_chunk: int = DEFAULT_QUANT_CHUNK):
    """Attention-output + MLP residual tail shared by every layer variant:
    the layer's TWO psums when TP-sharded (``axis`` set).  With ``quant``
    each psum lowers to the quantized two-step (``quantized_psum``,
    DESIGN.md §12) — the decode hot path's per-layer allreduces are the
    only collectives this knob ever touches."""
    x = x + quantized_psum(attn @ pl["wo"], axis, t, quant,
                           quant_chunk)                        # AR (attn out)
    xn2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
    return x + quantized_psum(mlp_apply(pl, xn2, cfg.activation), axis, t,
                              quant, quant_chunk)              # AR (mlp down)


def _tp_layer_full(cfg, pl, x, positions, mask, axis, heads_t: int,
                   kv_t: int, cache_w=None):
    """One transformer layer over a full sequence.  2 psums when TP-sharded
    (``axis`` set); ``axis=None`` runs the same math full-width."""
    B, S, _ = x.shape
    xn = rms_norm(x, pl["ln1"], cfg.norm_eps)
    q, k, v = _tp_layer_qkv(cfg, pl, xn, positions, heads_t, kv_t)
    attn = gqa_attention(q, k, v, mask).reshape(B, S, heads_t * cfg.head_dim)
    x = _tp_layer_out(cfg, pl, x, attn, axis)
    cache = None
    if cache_w is not None:
        from repro.models.blocks import build_ring_cache
        cache = build_ring_cache(k, v, cache_w)
    return x, cache


def _cp_layer_full(cfg, pl, x, positions, mask, c: int, axis, heads_t: int,
                   kv_t: int, cache_w=None):
    """One transformer layer of a context-parallel prefill (DESIGN.md §9):
    x is this worker's [B, S/c, h] sequence shard, ``positions`` its
    absolute positions and ``mask`` the shard-offset causal [S/c, S] mask.
    The K/V blocks ring-rotate around the "cp" axis (2·(c-1)
    collective-permutes) so attention covers the full sequence in absolute
    order — the monolithic layer's math, token for token.  TP psums
    (``axis``) compose unchanged; the optional ring cache is built from
    the assembled full-sequence K/V, identical on every cp worker."""
    B, s_loc, _ = x.shape
    xn = rms_norm(x, pl["ln1"], cfg.norm_eps)
    q, k, v = _tp_layer_qkv(cfg, pl, xn, positions, heads_t, kv_t)
    kf = ring_kv_assemble(k, "cp", c)
    vf = ring_kv_assemble(v, "cp", c)
    attn = gqa_attention(q, kf, vf, mask).reshape(B, s_loc,
                                                  heads_t * cfg.head_dim)
    x = _tp_layer_out(cfg, pl, x, attn, axis)
    cache = None
    if cache_w is not None:
        from repro.models.blocks import build_ring_cache
        cache = build_ring_cache(kf, vf, cache_w)
    return x, cache


def _tp_layer_step(cfg, pl, x, pos, cache, axis, heads_t: int, kv_t: int,
                   t: int = 1, quant: str = None,
                   quant_chunk: int = DEFAULT_QUANT_CHUNK):
    """One decode step against a ring cache.  2 psums when TP-sharded —
    quantized two-steps instead when ``quant`` is set (DESIGN.md §12).
    ``pos`` is a scalar (shared depth) or [B] per-sequence positions."""
    B = x.shape[0]
    w = cache["k"].shape[1]
    positions = decode_positions(pos, B)
    xn = rms_norm(x, pl["ln1"], cfg.norm_eps)
    q, k, v = _tp_layer_qkv(cfg, pl, xn, positions, heads_t, kv_t)
    ck, cv = ring_cache_update(cache["k"], cache["v"], k, v, pos)
    mask = decode_attn_mask(w, pos, cfg.sliding_window)
    attn = gqa_attention(q, ck, cv, mask).reshape(B, 1,
                                                  heads_t * cfg.head_dim)
    out = _tp_layer_out(cfg, pl, x, attn, axis, t, quant, quant_chunk)
    return out, {"k": ck, "v": cv}


def _tp_layer_paged(cfg, pl, x, pos, cache, bt, axis, heads_t: int,
                    kv_t: int):
    """One transformer layer of a *paged* pass: x [B, S, h] is a prefill
    chunk (S > 1) or a decode token (S == 1) starting at per-sequence
    positions ``pos`` [B]; K/V rows are scattered into the layer's
    [P, ps, kv_t, D] page pool at the pages ``bt`` names and the logical
    view is gathered back for attention (DESIGN.md §8).  The collective
    schedule is exactly the contiguous layer's: 2 psums when TP-sharded —
    paging is data movement, not communication."""
    B, S = x.shape[:2]
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    xn = rms_norm(x, pl["ln1"], cfg.norm_eps)
    q, k, v = _tp_layer_qkv(cfg, pl, xn, positions, heads_t, kv_t)
    ck, cv = paged_cache_update(cache["k"], cache["v"], k, v, pos, bt)
    kg, vg = paged_gather(ck, bt), paged_gather(cv, bt)
    mask = paged_attn_mask(kg.shape[1], pos, S)
    attn = gqa_attention(q, kg, vg, mask).reshape(B, S,
                                                  heads_t * cfg.head_dim)
    return _tp_layer_out(cfg, pl, x, attn, axis), {"k": ck, "v": cv}


def _layer_slice(blocks, l):
    return {k: v[l] for k, v in blocks.items()}


def _mask_pad_vocab(logits, vocab):
    """Mask pad-vocab columns to the *logit dtype's* min.  A hardcoded
    ``jnp.finfo(jnp.float32).min`` (a strongly-typed numpy scalar) would
    promote bf16 logits to f32 — and overflow to -inf if cast back."""
    if vocab is None or vocab >= logits.shape[-1]:
        return logits
    col = jnp.arange(logits.shape[-1])
    return jnp.where(col < vocab, logits, jnp.finfo(logits.dtype).min)


def _logits_allgather(params, x_last, axis: str, vocab: int = None,
                      eps: float = 1e-5):
    """Vocab-sharded logits + all-gather (paper's Gather, XLA-adapted)."""
    xn = rms_norm(x_last, params["final_norm"], eps)
    local = xn @ params["lm_head"]
    logits = jax.lax.all_gather(local, axis, axis=-1, tiled=True)
    return _mask_pad_vocab(logits, vocab)


def _embed_tokens(cfg, params, tokens, axis):
    """Embedding lookup: vocab-parallel psum when TP-sharded (``axis``
    set), plain table lookup full-width otherwise."""
    if axis is not None:
        return _vocab_parallel_embed(params["embed"], tokens, axis)
    return params["embed"][tokens]


def _head(cfg, params, x_last, axis):
    """Logits head on the last hidden state: vocab-sharded + all-gather
    when TP-sharded, dense otherwise."""
    if axis is not None:
        return _logits_allgather(params, x_last, axis, cfg.vocab_size,
                                 cfg.norm_eps)
    xn = rms_norm(x_last, params["final_norm"], cfg.norm_eps)
    return _mask_pad_vocab(xn @ params["lm_head"], cfg.vocab_size)


def _cp_last_hidden(x, last, axis_cp: str):
    """Hand the hidden state of absolute position ``last`` — owned by one
    cp shard of the sequence-sharded x [B, S/c, h] — to every worker: the
    owner contributes its row, everyone else zeros, one psum over the cp
    axis (the '+1 allreduce' of ``commodel.cp_comm_ops``)."""
    s_loc = x.shape[1]
    off = jax.lax.axis_index(axis_cp) * s_loc
    li = jnp.clip(last - off, 0, s_loc - 1)
    row = jax.lax.dynamic_slice_in_dim(x, li, 1, axis=1)[:, 0, :]
    owns = (last >= off) & (last < off + s_loc)
    return jax.lax.psum(jnp.where(owns, row, 0), axis_cp)


# ---------------------------------------------------------------------------
# TP engine
# ---------------------------------------------------------------------------


def make_tp_mesh(t: int) -> Mesh:
    return jax.make_mesh((t,), ("tp",))


def make_tp_cp_mesh(t: int, c: int = 1) -> Mesh:
    """Mesh for the single-stage engines on the (tp, cp) plane.  Degenerate
    axes are dropped so t=1 or c=1 never leaves a size-1 axis that XLA
    would emit degenerate collectives over; a fully degenerate (1, 1)
    request still needs one named axis for the shard_map plumbing."""
    shape = [s for s in ((t, "tp"), (c, "cp")) if s[0] > 1]
    if not shape:
        shape = [(1, "tp")]
    return jax.make_mesh(tuple(s for s, _ in shape),
                         tuple(n for _, n in shape))


def _tp_axis_of(mesh: Mesh):
    """(t, axis) of a mesh that may or may not carry a 'tp' axis; the axis
    name is None when t == 1 so callers skip degenerate collectives."""
    t = dict(mesh.shape).get("tp", 1)
    return t, ("tp" if t > 1 else None)


def _cache_spec(axis):
    """[L, B, W, kv, D] cache specs with kv heads on ``axis`` (or fully
    replicated for a t=1 engine); the per-stage [L_s, ...] caches use the
    same spec — always cp-replicated, since CP prefill assembles the full
    cache on every worker."""
    return {"k": P(None, None, None, axis, None),
            "v": P(None, None, None, axis, None)}


def _tp_layers_full(cfg, params, x, positions, mask, heads_t, kv_t,
                    cache_w, unroll: bool, axis="tp"):
    """All layers over a full sequence: unrolled (paper parity) or scanned."""
    if unroll:
        caches = []
        for l in range(cfg.num_layers):
            x, c = _tp_layer_full(cfg, _layer_slice(params["blocks"], l), x,
                                  positions, mask, axis, heads_t, kv_t,
                                  cache_w)
            caches.append(c)
        cache = None
        if cache_w is not None:
            cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
        return x, cache

    def body(h, pl):
        h, c = _tp_layer_full(cfg, pl, h, positions, mask, axis,
                              heads_t, kv_t, cache_w)
        return h, c

    return jax.lax.scan(body, x, params["blocks"])


def _tp_layers_step(cfg, params, x, pos, cache, heads_t, kv_t, unroll: bool,
                    axis="tp", t: int = 1, quant: str = None,
                    quant_chunk: int = DEFAULT_QUANT_CHUNK):
    """All layers for one decode token against the stacked [L,...] cache."""
    if unroll:
        new_cache = []
        for l in range(cfg.num_layers):
            x, c = _tp_layer_step(cfg, _layer_slice(params["blocks"], l), x,
                                  pos, _layer_slice(cache, l), axis,
                                  heads_t, kv_t, t, quant, quant_chunk)
            new_cache.append(c)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *new_cache)

    def body(h, inp):
        pl, cl = inp
        h, c = _tp_layer_step(cfg, pl, h, pos, cl, axis, heads_t, kv_t,
                              t, quant, quant_chunk)
        return h, c

    return jax.lax.scan(body, x, (params["blocks"], cache))


def _tp_single_step(cfg, params, cache, token, pos, heads_t, kv_t,
                    unroll: bool, axis="tp", t: int = 1, quant: str = None,
                    quant_chunk: int = DEFAULT_QUANT_CHUNK):
    """One full decode step: embed psum + all layers + logits all-gather.
    ``quant`` quantizes ONLY the per-layer psums; the embedding psum and
    the logits all-gather stay full-width (DESIGN.md §12)."""
    x = _embed_tokens(cfg, params, token[:, None], axis)
    x, cache = _tp_layers_step(cfg, params, x, pos, cache, heads_t, kv_t,
                               unroll, axis, t, quant, quant_chunk)
    logits = _head(cfg, params, x[:, 0, :], axis)
    return logits, cache


def tp_prefill(cfg: ModelConfig, mesh: Mesh, cache_w: int = None,
               unroll: bool = True):
    """jit'd fn(params, tokens) -> (logits [B,v], cache|None).

    Collectives per call: (2L+1) allreduce + 1 allgather — Eq. 1 / Table III.
    ``unroll=False`` scans the layer stack (same schedule, O(1)-depth HLO).
    """
    t, axis = _tp_axis_of(mesh)
    heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
    specs = tp_param_specs(cfg, tp_axis=axis)

    def fn(params, tokens):
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        mask = make_mask(S, S, window=cfg.sliding_window)
        x = _embed_tokens(cfg, params, tokens, axis)
        x, cache = _tp_layers_full(cfg, params, x, positions, mask,
                                   heads_t, kv_t, cache_w, unroll, axis)
        logits = _head(cfg, params, x[:, -1, :], axis)
        return logits, cache

    out_cache_spec = None if cache_w is None else _cache_spec(axis)
    return jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(specs, P(None, None)),
        out_specs=(P(None, None), out_cache_spec),
        check_rep=False))


def cp_prefill(cfg: ModelConfig, mesh: Mesh, cache_w: int = None,
               unroll: bool = True):
    """jit'd fn(params, tokens [B, S], last) -> (logits [B, v], cache|None)
    — the context-parallel prefill (DESIGN.md §9).

    The sequence axis is sharded over the mesh's "cp" axis (S must divide
    by c; the backends pad prompts): every worker embeds and runs each
    layer on its own [B, S/c, h] shard, with the layer's K/V blocks
    ring-exchanged in (c-1) collective-permute rounds
    (``layers.ring_kv_assemble``) so causal attention sees the full
    assembled sequence in absolute order — which keeps the pass
    token-identical to the single-group prefill (softmax reduces in the
    monolithic order; only matmul tiling noise remains).  ``last``
    (traced scalar) names
    the true last prompt position; its hidden state reaches the head via
    one psum over the cp axis.  Per-pass collectives therefore are the
    (2L+1)-allreduce + 1-allgather TP schedule (when t > 1, message rows
    shrunk to the shard) plus ``commodel.cp_comm_ops``: 2L(c-1)
    collective-permutes + 1 cp allreduce.

    The seeded ring cache is assembled FULL on every cp worker (the ring
    already moved every block), so the cache comes out of the shard_map
    replicated over cp and kv-sharded over tp — decode consumes it
    unchanged, which is the whole gather-into-slots handoff.
    """
    t, axis = _tp_axis_of(mesh)
    shape = dict(mesh.shape)
    if "cp" not in shape:
        raise ValueError("cp_prefill needs a mesh with a 'cp' axis "
                         "(make_tp_cp_mesh with c > 1); use tp_prefill "
                         "for c == 1")
    c = shape["cp"]
    heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
    specs = tp_param_specs(cfg, tp_axis=axis)

    def fn(params, tokens, last):
        B, s_loc = tokens.shape
        off = jax.lax.axis_index("cp") * s_loc
        positions = jnp.broadcast_to(off + jnp.arange(s_loc), (B, s_loc))
        mask = make_mask(s_loc, c * s_loc, q_offset=off,
                         window=cfg.sliding_window)
        x = _embed_tokens(cfg, params, tokens, axis)
        if unroll:
            caches = []
            for l in range(cfg.num_layers):
                x, cl = _cp_layer_full(cfg, _layer_slice(params["blocks"], l),
                                       x, positions, mask, c, axis, heads_t,
                                       kv_t, cache_w)
                caches.append(cl)
            cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
                     if cache_w is not None else None)
        else:
            def body(h, pl):
                return _cp_layer_full(cfg, pl, h, positions, mask, c, axis,
                                      heads_t, kv_t, cache_w)

            x, cache = jax.lax.scan(body, x, params["blocks"])
        x_last = _cp_last_hidden(x, last, "cp")
        logits = _head(cfg, params, x_last, axis)
        return logits, cache

    out_cache_spec = None if cache_w is None else _cache_spec(axis)
    return jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(specs, P(None, "cp"), P()),
        out_specs=(P(None, None), out_cache_spec),
        check_rep=False))


def tp_decode_step(cfg: ModelConfig, mesh: Mesh, unroll: bool = True,
                   donate: bool = None, vector_pos: bool = False,
                   quant_collectives: str = None,
                   quant_chunk: int = DEFAULT_QUANT_CHUNK):
    """jit'd fn(params, cache, token [B], pos) -> (logits, cache).

    Collectives per call: (2L+1) allreduce + 1 allgather — Table III decode.
    With ``quant_collectives`` ("int8" | "fp8") each of the 2L per-layer
    allreduces lowers to the quantized two-step (DESIGN.md §12): an f32
    amax allreduce of [B, ceil(h/chunk)] + a 1-byte reduce-scatter + a
    1-byte all-gather of [B, h] — so the compiled module shows (2L+1)
    allreduce (2L of them tiny f32 scale exchanges) + 2L reducescatter +
    (2L+1) allgather, exactly ``commodel.comm_ops_for(quant=...)``.  The
    embedding psum and logits gather stay full-width.
    The fast path (``unroll=False``) scans the stacked [L, B, W, kv, D] cache
    and donates it, so XLA aliases the update in place instead of the
    per-layer slice/re-stack copy; ``donate`` overrides that default (the
    paper-parity mode keeps the cache alive for step-by-step comparisons).
    ``vector_pos`` traces ``pos`` as a replicated [B] vector of per-sequence
    positions (the continuous-batching DecodeBackend step) instead of the
    scalar shared position.  On a mesh with a "cp" axis the step runs
    replicated over it — context parallelism is prefill-only (DESIGN.md §9).
    """
    t, axis = _tp_axis_of(mesh)
    quant = _check_quant(quant_collectives)
    heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
    specs = tp_param_specs(cfg, tp_axis=axis)
    cache_spec = _cache_spec(axis)
    donate = (not unroll) if donate is None else donate

    def fn(params, cache, token, pos):
        return _tp_single_step(cfg, params, cache, token, pos,
                               heads_t, kv_t, unroll, axis, t, quant,
                               quant_chunk)

    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(specs, cache_spec, P(None),
                  P(None) if vector_pos else P()),
        out_specs=(P(None, None), cache_spec),
        check_rep=False),
        donate_argnums=(1,) if donate else ())


def tp_generate(cfg: ModelConfig, mesh: Mesh, num_tokens: int,
                unroll: bool = False, vector_pos: bool = False,
                quant_collectives: str = None,
                quant_chunk: int = DEFAULT_QUANT_CHUNK):
    """jit'd fn(params, cache, token [B], pos) -> (tokens [B, N], cache).

    Fused greedy multi-token decode: N scanned decode steps run inside ONE
    dispatch via ``lax.fori_loop`` with argmax feedback.  ``tokens[:, i]`` is
    exactly the token a step-by-step ``tp_decode_step`` chain would produce
    after feeding ``token`` at ``pos`` and its successors at ``pos+1 ...``.
    The cache is donated: the [L, B, W, kv, D] buffers are updated in place
    across all N steps without ever being re-materialized on the host.
    ``vector_pos`` takes per-sequence [B] start positions (each sequence
    advances from its own depth — ragged fused decode).
    ``quant_collectives`` lowers the per-layer allreduces to the quantized
    two-step exactly as in ``tp_decode_step`` (DESIGN.md §12).
    """
    t, axis = _tp_axis_of(mesh)
    quant = _check_quant(quant_collectives)
    heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
    specs = tp_param_specs(cfg, tp_axis=axis)
    cache_spec = _cache_spec(axis)

    def fn(params, cache, token, pos):
        return greedy_decode_loop(
            lambda c, tok, p: _tp_single_step(cfg, params, c, tok, p,
                                              heads_t, kv_t, unroll, axis,
                                              t, quant, quant_chunk),
            token, cache, pos, num_tokens)

    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(specs, cache_spec, P(None),
                  P(None) if vector_pos else P()),
        out_specs=(P(None, None), cache_spec),
        check_rep=False),
        donate_argnums=(1,))


def tp_paged_step(cfg: ModelConfig, mesh: Mesh, unroll: bool = False,
                  donate: bool = True):
    """jit'd fn(params, cache, tokens [B,S], pos [B], bt [B,n]) ->
    (last-position logits [B, v], cache) — the paged TP pass (DESIGN.md §8).

    ONE builder serves chunked prefill (S = chunk) and paged decode (S = 1);
    each distinct (B, S, n) traces once.  Collectives per call are exactly
    the contiguous step's — (2L+1) allreduce + 1 logits all-gather — for ANY
    chunk length or batch: the page scatter/gather is per-shard local (the
    kv-head axis is the sharded one; the page axis is replicated), so paging
    adds data movement, never communication.  The [L, P, ps, kv/t, D] page
    pools are donated by default (in-place update across chunks and steps).
    """
    t, axis = _tp_axis_of(mesh)
    heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
    specs = tp_param_specs(cfg, tp_axis=axis)
    cache_spec = _cache_spec(axis)

    def fn(params, cache, tokens, pos, bt):
        x = _embed_tokens(cfg, params, tokens, axis)
        if unroll:
            new_cache = []
            for l in range(cfg.num_layers):
                x, c = _tp_layer_paged(cfg, _layer_slice(params["blocks"], l),
                                       x, pos, _layer_slice(cache, l), bt,
                                       axis, heads_t, kv_t)
                new_cache.append(c)
            cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_cache)
        else:
            def body(h, inp):
                pl, cl = inp
                h, c = _tp_layer_paged(cfg, pl, h, pos, cl, bt, axis,
                                       heads_t, kv_t)
                return h, c

            x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
        logits = _head(cfg, params, x[:, -1, :], axis)
        return logits, cache

    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(specs, cache_spec, P(None, None), P(None),
                  P(None, None)),
        out_specs=(P(None, None), cache_spec),
        check_rep=False),
        donate_argnums=(1,) if donate else ())


# ---------------------------------------------------------------------------
# PP engine — one jitted computation per stage, explicit transfers (vLLM-style)
# ---------------------------------------------------------------------------
#
# Real PP serving (the paper's vLLM setup) runs one process group per stage
# and moves activations with NCCL send/recv.  The SPMD-lockstep alternative
# (shard_map over a "pp" axis) would execute every stage's collectives on
# every rank — inflating per-rank counts p×, which is NOT what the paper's
# per-rank profile shows.  So the engine mirrors vLLM: each stage is its own
# jit (optionally TP-sharded over its own device group) and the engine logs
# every inter-stage transfer — that log is our measured Table V / Eq. 2 side.


@dataclasses.dataclass
class TransferRecord:
    phase: str
    count: int          # individual tensors moved (the paper's Send count)
    shape: Tuple[int, ...]
    dtype_bytes: int

    @property
    def bytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return self.count * n * self.dtype_bytes


def stage_layer_range(cfg: ModelConfig, p: int, s: int) -> Tuple[int, int]:
    """Layer interval [lo, hi) owned by stage s.

    An indivisible ``num_layers`` spreads its remainder over the *early*
    stages (``commodel.stage_layer_partition``, which the analytical side
    shares), so every layer is always executed — 28 layers at p=8 runs
    4+4+4+4+3+3+3+3, not 8×3 with four layers silently dropped.
    """
    sizes = stage_layer_partition(cfg.num_layers, p)
    lo = sum(sizes[:s])
    return lo, lo + sizes[s]


class PipelineEngine:
    """Single-request PP (t=1) or hybrid TP×CP×PP (t·c>1) serving engine.

    Stage s owns layers ``stage_layer_range(cfg, p, s)`` on its own
    ``t·c``-device mesh.  Boundary hand-off ships TWO tensors per hop
    (hidden_states + residual, the vLLM pattern) of shape [S, h/t] per TP
    worker, logged in ``self.transfers``.  Within a stage the TP collectives
    (allreduce per row-parallel linear, embedding psum on stage 0, logits
    all-gather on the last stage) are hand-placed and visible in each
    stage's HLO.

    Decode subsystem (DESIGN.md §6): ``prefill_with_cache`` seeds a
    per-stage [L_s, B, W, kv, D] ring KV cache, ``decode_once`` runs one
    token through every stage's jitted decode_step (cache donated on the
    fast path), and ``generate`` drives N greedy tokens through the
    pipeline — every decode boundary hop is a logged [1, h/t]×2
    TransferRecord, the measured side of the paper's Table V decode rows
    and the ``(p−1)·2·(s_d−1)`` term of Eq. 2.

    Context parallelism (``c > 1``, DESIGN.md §9) shards the *prefill*
    sequence axis over each stage's "cp" mesh axis: stage layers run
    ``_cp_layer_full`` (per-layer ring KV exchange), boundary pairs stay
    sequence-sharded on the wire ([S/c, h/t] per worker), and the last
    stage hands the final position's hidden state to the head with one cp
    allreduce — per-stage prefill counts are
    ``commodel.hybrid_stage_collectives(..., c, phase="prefill")``.
    Decode and paged passes run REPLICATED over the cp axis (CP is
    prefill-only): their per-rank collective counts are unchanged at any c.

    ``unroll=False`` scans each stage's layer slice instead of unrolling it
    (same collective schedule, trip-counted in the stage HLO — DESIGN.md §5).
    """

    def __init__(self, cfg: ModelConfig, t: int = 1, p: int = 2,
                 devices=None, unroll: bool = True, c: int = 1,
                 quant_collectives: str = None,
                 quant_chunk: int = DEFAULT_QUANT_CHUNK):
        self.cfg, self.t, self.p, self.c = cfg, t, p, c
        self.unroll = unroll
        # quantized two-step per-layer allreduces on the DECODE path only
        # (DESIGN.md §12) — prefill and paged passes stay full-width
        self.quant = _check_quant(quant_collectives)
        self.quant_chunk = quant_chunk
        devices = devices if devices is not None else jax.devices()
        assert len(devices) >= t * c * p, f"need {t * c * p} devices"
        self.meshes = [self._stage_mesh(devices[s * t * c:(s + 1) * t * c])
                       for s in range(p)]
        # shard_map whenever the stage mesh is non-trivial; a t=1 cp-only
        # stage still needs it for the ring permutes (and decode runs the
        # same fn replicated over cp — all-local, zero collectives)
        self._mapped = t > 1 or c > 1
        self._tp_axis = "tp" if t > 1 else None
        self._param_specs = tp_param_specs(cfg, tp_axis=self._tp_axis)
        self._stage_cache_spec = _cache_spec(self._tp_axis)
        self.transfers: list = []
        self._stage_fns = [self._build_stage(s) for s in range(p)]
        self._cache_stage_fns = {}      # cache_w -> per-stage prefill fns
        self._decode_stage_fns = {}     # vector_pos -> per-stage decode fns
        self._paged_stage_fns = None    # per-stage paged chunk/decode fns

    def _stage_mesh(self, devs) -> Mesh:
        t, c = self.t, self.c
        axes = [a for a in ((t, "tp"), (c, "cp")) if a[0] > 1]
        if not axes:
            axes = [(1, "tp")]
        return Mesh(np.asarray(devs).reshape([s for s, _ in axes]),
                    tuple(n for _, n in axes))

    # -- shared stage fragments (traced inside each stage's jit) -----------
    def _boundary_in(self, x_or_tokens):
        """Merge a received (hidden, residual) pair; t>1 first redistributes
        the h/t shards among the stage's TP workers (2 all-gathers).  A
        cp-sharded prefill pair stays sequence-sharded — no cp collective."""
        h1, h2 = x_or_tokens
        if self.t > 1:
            h1 = jax.lax.all_gather(h1, "tp", axis=-1, tiled=True)
            h2 = jax.lax.all_gather(h2, "tp", axis=-1, tiled=True)
        return h1 + h2

    def _boundary_out(self, x):
        """Split into the (hidden, residual)-like summand pair for the wire;
        t>1 ships only this worker's h/t shard."""
        t, h = self.t, self.cfg.d_model
        if t > 1:
            idx = jax.lax.axis_index("tp")
            x = jax.lax.dynamic_slice_in_dim(x, idx * (h // t), h // t,
                                             axis=-1)
        return x * 0.25, x * 0.75

    def _head_out(self, params, x_last):
        return _head(self.cfg, params, x_last, self._tp_axis)

    def _stage_blocks(self, params, lo, hi):
        return jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, lo, hi, axis=0),
            params["blocks"])

    def _boundary_pair_spec(self, seq_shard: bool = False):
        """Sharding of the two-tensor [B, S|1, h/t] boundary pair;
        ``seq_shard`` marks a cp-sharded prefill pair (sequence axis on
        "cp") — decode/paged pairs are cp-replicated."""
        seq = "cp" if (seq_shard and self.c > 1) else None
        return (P(None, seq, self._tp_axis),) * 2

    def _boundary_specs(self, s: int, seq_shard: bool = False):
        first, last = s == 0, s == self.p - 1
        pair = self._boundary_pair_spec(seq_shard)
        tok = P(None, "cp" if (seq_shard and self.c > 1) else None)
        in_x = tok if first else pair
        out = P(None, None) if last else pair
        return in_x, out

    # -- per-stage jitted computations -------------------------------------
    def _build_stage(self, s: int, cache_w: int = None):
        """Full-sequence stage fn; with ``cache_w`` it also emits the
        stage's seeded [L_s, B, W, kv, D] ring cache.  With c>1 the stage
        runs the CP prefill branch: x sequence-sharded over "cp", per-layer
        ring KV exchange, and an extra traced ``last`` argument naming the
        true last prompt position for the head (DESIGN.md §9)."""
        cfg, t, c, p = self.cfg, self.t, self.c, self.p
        lo, hi = stage_layer_range(cfg, p, s)
        heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
        axis = self._tp_axis
        mesh = self.meshes[s]
        first, last_stage = s == 0, s == p - 1

        def fn(params, x_or_tokens, last=None):
            x = (_embed_tokens(cfg, params, x_or_tokens, axis) if first
                 else self._boundary_in(x_or_tokens))
            B, s_loc = x.shape[:2]
            if c > 1:
                off = jax.lax.axis_index("cp") * s_loc
                positions = jnp.broadcast_to(off + jnp.arange(s_loc),
                                             (B, s_loc))
                mask = make_mask(s_loc, c * s_loc, q_offset=off,
                                 window=cfg.sliding_window)
                layer = lambda pl, h: _cp_layer_full(
                    cfg, pl, h, positions, mask, c, axis, heads_t, kv_t,
                    cache_w)
            else:
                positions = jnp.broadcast_to(jnp.arange(s_loc), (B, s_loc))
                mask = make_mask(s_loc, s_loc, window=cfg.sliding_window)
                layer = lambda pl, h: _tp_layer_full(
                    cfg, pl, h, positions, mask, axis, heads_t, kv_t,
                    cache_w)
            if self.unroll:
                caches = []
                for l in range(lo, hi):
                    x, cl = layer(_layer_slice(params["blocks"], l), x)
                    caches.append(cl)
                cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
                         if cache_w is not None else None)
            else:
                def body(h, pl):
                    return layer(pl, h)

                x, cache = jax.lax.scan(body, x,
                                        self._stage_blocks(params, lo, hi))
            if last_stage:
                x_last = (_cp_last_hidden(x, last, "cp") if c > 1
                          else x[:, -1, :])
                out = self._head_out(params, x_last)
            else:
                out = self._boundary_out(x)
            return out if cache_w is None else (out, cache)

        if c > 1:
            # uniform (params, x, last) signature across stages keeps the
            # driver simple; non-last stages ignore ``last``
            stage_fn = fn
        else:
            stage_fn = lambda params, x_or_tokens: fn(params, x_or_tokens)
        in_x_spec, out_spec = self._boundary_specs(s, seq_shard=True)
        full_out = (out_spec if cache_w is None
                    else (out_spec, self._stage_cache_spec))
        extra_in = (P(),) if c > 1 else ()
        if self._mapped:
            mapped = shard_map(stage_fn, mesh=mesh,
                               in_specs=(self._param_specs, in_x_spec)
                               + extra_in,
                               out_specs=full_out, check_rep=False)
        else:
            mapped = stage_fn               # single-device stage
        return jax.jit(mapped), mesh

    def _build_decode_stage(self, s: int, vector_pos: bool = False):
        """One-token stage fn against the stage's donated ring cache.
        ``vector_pos`` traces ``pos`` as a replicated [B] per-sequence
        vector (continuous batching) instead of the scalar shared depth.
        With c>1 the step runs replicated over the cp axis (CP is
        prefill-only): all specs are cp-unsharded and the per-rank
        collective counts are the c=1 stage's."""
        cfg, t, p = self.cfg, self.t, self.p
        lo, hi = stage_layer_range(cfg, p, s)
        heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
        axis = self._tp_axis
        mesh = self.meshes[s]
        first, last = s == 0, s == p - 1

        def fn(params, cache, x_or_tokens, pos):
            x = (_embed_tokens(cfg, params, x_or_tokens[:, None], axis)
                 if first else self._boundary_in(x_or_tokens))
            if self.unroll:
                new_cache = []
                for i, l in enumerate(range(lo, hi)):
                    x, c = _tp_layer_step(
                        cfg, _layer_slice(params["blocks"], l), x, pos,
                        _layer_slice(cache, i), axis, heads_t, kv_t,
                        t, self.quant, self.quant_chunk)
                    new_cache.append(c)
                cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_cache)
            else:
                def body(h, inp):
                    pl, cl = inp
                    h, c = _tp_layer_step(cfg, pl, h, pos, cl, axis,
                                          heads_t, kv_t, t, self.quant,
                                          self.quant_chunk)
                    return h, c

                x, cache = jax.lax.scan(
                    body, x, (self._stage_blocks(params, lo, hi), cache))
            out = (self._head_out(params, x[:, 0, :]) if last
                   else self._boundary_out(x))
            return out, cache

        _, out_spec = self._boundary_specs(s)
        in_x_spec = P(None) if first else self._boundary_pair_spec()
        pos_spec = P(None) if vector_pos else P()
        if self._mapped:
            mapped = shard_map(
                fn, mesh=mesh,
                in_specs=(self._param_specs, self._stage_cache_spec,
                          in_x_spec, pos_spec),
                out_specs=(out_spec, self._stage_cache_spec),
                check_rep=False)
        else:
            mapped = fn
        # fast path donates the cache (in-place update); paper-parity mode
        # keeps it alive for step-by-step comparisons — same convention as
        # tp_decode_step.
        donate = () if self.unroll else (1,)
        return jax.jit(mapped, donate_argnums=donate), mesh

    def _build_paged_stage(self, s: int):
        """Paged stage fn (DESIGN.md §8): fn(params, cache, x_or_tokens,
        pos [B], bt [B, n]) -> (out, cache) against the stage's donated
        [L_s, P, ps, kv/t, D] page pools.  One fn per stage serves every
        chunk length AND paged decode (each distinct shape traces once);
        the per-pass collective schedule is identical to the contiguous
        decode stage — ``commodel.hybrid_stage_collectives`` — because the
        page scatter/gather is shard-local."""
        cfg, t, p = self.cfg, self.t, self.p
        lo, hi = stage_layer_range(cfg, p, s)
        heads_t, kv_t = cfg.num_heads // t, cfg.num_kv_heads // t
        axis = self._tp_axis
        first, last = s == 0, s == p - 1

        def fn(params, cache, x_or_tokens, pos, bt):
            x = (_embed_tokens(cfg, params, x_or_tokens, axis) if first
                 else self._boundary_in(x_or_tokens))
            if self.unroll:
                new_cache = []
                for i, l in enumerate(range(lo, hi)):
                    x, c = _tp_layer_paged(
                        cfg, _layer_slice(params["blocks"], l), x, pos,
                        _layer_slice(cache, i), bt, axis, heads_t, kv_t)
                    new_cache.append(c)
                cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_cache)
            else:
                def body(h, inp):
                    pl, cl = inp
                    h, c = _tp_layer_paged(cfg, pl, h, pos, cl, bt, axis,
                                           heads_t, kv_t)
                    return h, c

                x, cache = jax.lax.scan(
                    body, x, (self._stage_blocks(params, lo, hi), cache))
            out = (self._head_out(params, x[:, -1, :]) if last
                   else self._boundary_out(x))
            return out, cache

        _, out_spec = self._boundary_specs(s)
        in_x_spec = (P(None, None) if first
                     else self._boundary_pair_spec())
        if self._mapped:
            mapped = shard_map(
                fn, mesh=self.meshes[s],
                in_specs=(self._param_specs, self._stage_cache_spec,
                          in_x_spec, P(None), P(None, None)),
                out_specs=(out_spec, self._stage_cache_spec),
                check_rep=False)
        else:
            mapped = fn
        donate = () if self.unroll else (1,)
        return jax.jit(mapped, donate_argnums=donate), self.meshes[s]

    def _paged_fns(self):
        if self._paged_stage_fns is None:
            self._paged_stage_fns = [self._build_paged_stage(s)
                                     for s in range(self.p)]
        return self._paged_stage_fns

    def _cache_fns(self, cache_w: int):
        if cache_w not in self._cache_stage_fns:
            self._cache_stage_fns[cache_w] = [
                self._build_stage(s, cache_w=cache_w) for s in range(self.p)]
        return self._cache_stage_fns[cache_w]

    def _decode_fns(self, vector_pos: bool = False):
        if vector_pos not in self._decode_stage_fns:
            self._decode_stage_fns[vector_pos] = [
                self._build_decode_stage(s, vector_pos=vector_pos)
                for s in range(self.p)]
        return self._decode_stage_fns[vector_pos]

    # -- driver --------------------------------------------------------------
    def _shard_params(self, params, mesh):
        return jax.device_put(
            params, jax.tree.map(
                lambda sp: NamedSharding(mesh, sp), self._param_specs,
                is_leaf=lambda x: isinstance(x, P)))

    def prepare(self, params):
        """Place one param copy per stage (each stage reads its own layers)."""
        return [self._shard_params(params, m) for m in self.meshes]

    def _move_boundary(self, out, s: int, phase: str, log: bool = True,
                       seq_shard: bool = False):
        """Ship the two-tensor boundary pair to stage s+1 (device_put,
        DESIGN.md §3) and log one TransferRecord per tensor.  ``seq_shard``
        marks a cp-sharded prefill pair: each worker then carries only its
        [S/c, h/t] block, which is what the record charges."""
        nxt = self.meshes[s + 1]
        spec = self._boundary_pair_spec(seq_shard)[0]
        moved = tuple(jax.device_put(h, NamedSharding(nxt, spec))
                      for h in out)
        c = self.c if (seq_shard and self.c > 1) else 1
        if log:
            for h in moved:
                self.transfers.append(TransferRecord(
                    phase, 1,
                    (h.shape[0], h.shape[1] // c, h.shape[-1] // self.t),
                    jnp.dtype(h.dtype).itemsize))
        return moved

    def _prefill_last(self, tokens, last):
        """Validate/default the ``last`` index of a CP prefill pass."""
        S = tokens.shape[1]
        if self.c > 1 and S % self.c:
            raise ValueError(
                f"CP prefill shards the sequence over c={self.c}: pad the "
                f"prompt to a multiple of c (got S={S})")
        return jnp.int32(S - 1 if last is None else last)

    def forward(self, staged_params, tokens, phase: str = "prefill",
                last: int = None):
        """Run one pass; logs (p-1)×2 transfers of [S, h/t] — Eq. 2 / Eq. 7.

        With c>1 the pass is CP-sharded (DESIGN.md §9): S must divide by c
        and ``last`` names the true last prompt position (default S-1) —
        logits come from it, boundary hops carry [S/c, h/t] per worker."""
        extra = (self._prefill_last(tokens, last),) if self.c > 1 else ()
        x = tokens
        for s in range(self.p):
            fn, _ = self._stage_fns[s]
            out = fn(staged_params[s], x, *extra)
            if s < self.p - 1:
                x = self._move_boundary(out, s, phase, seq_shard=True)
            else:
                return out

    def prefill_with_cache(self, staged_params, tokens, cache_w: int,
                           last: int = None):
        """Prefill that seeds every stage's [L_s, B, W, kv, D] ring cache.

        Returns (last-position logits [B, v], per-stage cache list); logs
        the same (p-1)×2 [S, h/t] prefill transfers as ``forward`` ([S/c,
        h/t] per worker under CP, where the seeded caches come out FULL on
        every cp worker thanks to the ring assembly — the gather-into-slots
        handoff, DESIGN.md §9).
        """
        extra = (self._prefill_last(tokens, last),) if self.c > 1 else ()
        fns = self._cache_fns(cache_w)
        x = tokens
        caches = []
        for s in range(self.p):
            fn, _ = fns[s]
            out, cache = fn(staged_params[s], x, *extra)
            caches.append(cache)
            if s < self.p - 1:
                x = self._move_boundary(out, s, "prefill", seq_shard=True)
            else:
                return out, caches

    def decode_once(self, staged_params, caches, token, pos):
        """One pipelined decode step: token [B] in, next-token logits out.

        Each stage runs its jitted decode_step against its own cache; every
        boundary ships the two-tensor [1, h/t] pair logged with
        phase="decode" — the measured Table V decode rows.  ``pos`` may be a
        scalar or a [B] vector of per-sequence positions (continuous
        batching).  Returns (logits [B, v], new per-stage caches); on the
        fast path the input caches are donated (consumed).
        """
        pos = jnp.asarray(pos, jnp.int32)
        fns = self._decode_fns(vector_pos=pos.ndim > 0)
        # next-token feedback hop to stage 0 (a few bytes; not charged by
        # Eq. 2, which counts only the boundary activation tensors)
        x = jax.device_put(token, NamedSharding(self.meshes[0], P(None)))
        new_caches = []
        out = None
        for s in range(self.p):
            fn, _ = fns[s]
            out, c = fn(staged_params[s], caches[s], x, pos)
            new_caches.append(c)
            if s < self.p - 1:
                x = self._move_boundary(out, s, "decode")
        return out, new_caches

    def paged_pass(self, staged_params, caches, tokens, pos, bt,
                   phase: str = "decode"):
        """One paged pass through all p stages: a prefill chunk
        (tokens [B, S], phase="prefill") or a paged decode step
        (tokens [B, 1], phase="decode") — DESIGN.md §8.

        Every boundary ships the same two-tensor [B, S, h/t] summand pair as
        the contiguous passes, logged with ``phase`` — so per-chunk prefill
        hops and per-step decode hops stay separately assertable against
        ``commodel.chunked_prefill_ops`` / the decode send rows.  Returns
        (last-position logits [B, v], new per-stage page pools); on the fast
        path the input pools are donated (consumed).
        """
        fns = self._paged_fns()
        pos = jnp.asarray(pos, jnp.int32)
        bt = jnp.asarray(bt, jnp.int32)
        x = jax.device_put(jnp.asarray(tokens, jnp.int32),
                           NamedSharding(self.meshes[0], P(None, None)))
        new_caches = []
        out = None
        for s in range(self.p):
            fn, _ = fns[s]
            out, c = fn(staged_params[s], caches[s], x, pos, bt)
            new_caches.append(c)
            if s < self.p - 1:
                x = self._move_boundary(out, s, phase)
        return out, new_caches

    # -- instruction-queue surface (runtime/schedule.py, DESIGN.md §11) ------
    def decode_stage_fns(self, vector_pos: bool = False):
        """The per-stage jitted decode fns, independently drivable: the
        dynamic instruction queue issues them one stage at a time instead
        of through the fused ``decode_once`` wave."""
        return [fn for fn, _ in self._decode_fns(vector_pos=vector_pos)]

    def paged_stage_fns(self):
        """Per-stage paged fns for queue-driven paged decode rounds."""
        return [fn for fn, _ in self._paged_fns()]

    def feed_tokens(self, tokens, paged: bool = False):
        """Place next-token ids on stage 0's mesh — the feedback hop that
        starts a decode round (a few bytes; not charged by Eq. 2)."""
        spec = P(None, None) if paged else P(None)
        return jax.device_put(jnp.asarray(tokens, jnp.int32),
                              NamedSharding(self.meshes[0], spec))

    def send_boundary(self, out, s: int, phase: str = "decode"):
        """Ship stage ``s``'s boundary pair to stage ``s+1`` and log its
        TransferRecords — the ``BoundarySend``/``BoundaryRecv`` pair of an
        instruction-queue round."""
        return self._move_boundary(out, s, phase)

    def generate(self, staged_params, caches, token, pos, num_tokens: int):
        """Greedy pipelined generation: N tokens through all p stages.

        The argmax feedback loop is the shared driver
        (``models.transformer.greedy_decode_host_loop``), so ``out[:, i]``
        equals what a chain of decode_once + argmax would emit — and, token
        for token, what ``tp_generate`` / ``InferenceEngine`` produce from
        the same params.  Logs (p-1)·2·N decode transfers: with the prefill
        token counted, exactly the paper's (p−1)·2·(s_d−1) for s_d = N+1.
        Returns (tokens [B, N] int32, final per-stage caches).
        """
        state = {"caches": caches}

        def step(tok, pos_i):
            logits, state["caches"] = self.decode_once(
                staged_params, state["caches"], tok, pos_i)
            return logits

        out = greedy_decode_host_loop(step, token, pos, num_tokens)
        return out, state["caches"]

    # -- introspection -------------------------------------------------------
    def stage_hlo(self, staged_params, tokens, s: int,
                  last: int = None) -> str:
        """Compiled HLO of stage s's prefill (collective-count validation);
        under CP the counts include the stage's ring permutes —
        ``commodel.hybrid_stage_collectives(..., c, phase="prefill")``."""
        extra = (self._prefill_last(tokens, last),) if self.c > 1 else ()
        x = tokens
        for i in range(s):
            fn, _ = self._stage_fns[i]
            out = fn(staged_params[i], x, *extra)
            x = self._move_boundary(out, i, "hlo", log=False,
                                    seq_shard=True)
        fn, _ = self._stage_fns[s]
        return fn.lower(staged_params[s], x, *extra).compile().as_text()

    def stage_decode_hlo(self, staged_params, caches, token, pos,
                         s: int) -> str:
        """Compiled HLO of stage s's decode_step — asserted against
        ``commodel.hybrid_stage_collectives``.  Earlier stages run on cache
        copies so the caller's caches survive donation."""
        fns = self._decode_fns()
        pos = jnp.int32(pos)
        x = jax.device_put(token, NamedSharding(self.meshes[0], P(None)))
        for i in range(s):
            fn, _ = fns[i]
            out, _ = fn(staged_params[i],
                        jax.tree.map(jnp.copy, caches[i]), x, pos)
            x = self._move_boundary(out, i, "hlo", log=False)
        fn, _ = fns[s]
        return fn.lower(staged_params[s], caches[s], x,
                        pos).compile().as_text()

    def stage_paged_hlo(self, staged_params, caches, tokens, pos, bt,
                        s: int) -> str:
        """Compiled HLO of stage s's paged pass (any chunk length) —
        asserted against ``commodel.hybrid_stage_collectives``, which covers
        paged passes too (counts are chunk-length-invariant).  Earlier
        stages run on cache copies so the caller's pools survive donation."""
        fns = self._paged_fns()
        pos = jnp.asarray(pos, jnp.int32)
        bt = jnp.asarray(bt, jnp.int32)
        x = jax.device_put(jnp.asarray(tokens, jnp.int32),
                           NamedSharding(self.meshes[0], P(None, None)))
        for i in range(s):
            fn, _ = fns[i]
            out, _ = fn(staged_params[i],
                        jax.tree.map(jnp.copy, caches[i]), x, pos, bt)
            x = self._move_boundary(out, i, "hlo", log=False)
        fn, _ = fns[s]
        return fn.lower(staged_params[s], caches[s], x, pos,
                        bt).compile().as_text()

    def transfer_summary(self, phase: str = None):
        """Aggregate logged transfers; ``phase`` filters to one phase so the
        decode rows can be asserted against pp/hybrid_comm_ops directly."""
        recs = [r for r in self.transfers if phase in (None, r.phase)]
        return {"count": sum(r.count for r in recs),
                "bytes": sum(r.bytes for r in recs)}
