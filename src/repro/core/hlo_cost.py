"""Trip-count-correct FLOP/byte analysis of post-SPMD HLO modules.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — for a
layer-scanned model that undercounts FLOPs by ~num_layers×.  This walker
re-derives both roofline numerators from the HLO text itself:

  * FLOPs: every ``dot`` contributes 2 · |result| · K (K = product of the
    lhs contracting-dim sizes); fusion-internal dots are included (XLA cost
    semantics).  Models here are matmul-dominated; elementwise FLOPs are
    ignored (standard roofline practice, < 2% here).
  * HBM bytes: every materializing op contributes |result| + Σ|operands|,
    with REGION-based accounting for slicing ops — a per-token
    dynamic-update-slice into a KV cache touches the update region, not the
    whole buffer (XLA aliases the buffer in place), and a dynamic-slice of
    the scanned layer stack reads one layer, not all L:
        dynamic-slice / gather        → 2 × |result|
        dynamic-update-slice / scatter → 2 × |update operand|
    Fusions inherit the semantics of their called computation: a fusion
    wrapping a DS/DUS/scatter is charged its region, everything else is
    charged result + operands (fusion internals stay in registers/VMEM).

Both numerators are multiplied through ``while`` known_trip_counts, so a
layer scan of L layers costs L× its body — what a runtime profile shows.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b(%s)\[([\d,]*)\]" % "|".join(_DTYPE_BYTES))
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"\bwhile\(.*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count.*?"n"\s*:\s*"(\d+)"')
_FUSION_RE = re.compile(r"\bfusion\(.*?calls=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"\bcall\(.*?to_apply=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPCODE_RE = re.compile(r"\b([a-z][\w\-]*)\(")
_COMMENT_RE = re.compile(r"/\*.*?\*/")

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "opt-barrier", "iota", "partition-id", "replica-id",
}
# ops whose traffic is the sliced/updated region, not the full operand
_SLICE_LIKE = {"dynamic-slice", "gather", "slice"}
_UPDATE_LIKE = {"dynamic-update-slice": 1, "scatter": 2}   # update operand idx


def _shape_dims(text: str) -> List[Tuple[int, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((_DTYPE_BYTES[dt], d))
    return out


def _nbytes(shapes: List[Tuple[int, List[int]]]) -> int:
    total = 0
    for b, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * b
    return total


def _bytes_of(text: str) -> int:
    return _nbytes(_shape_dims(text))


@dataclasses.dataclass
class _Comp:
    flops: float = 0.0
    bytes: float = 0.0                 # plain-op traffic
    region_bytes: float = 0.0          # DS/DUS/scatter region traffic inside
    is_region_comp: bool = False       # computation dominated by slicing ops
    whiles: list = dataclasses.field(default_factory=list)
    fusions: list = dataclasses.field(default_factory=list)  # (name, std_traffic)
    calls: list = dataclasses.field(default_factory=list)


def _parse(hlo_text: str):
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[str] = None
    symtab: Dict[str, List[Tuple[int, List[int]]]] = {}

    def operand_shapes(rhs: str) -> List[List[Tuple[int, List[int]]]]:
        args = rhs[rhs.index("("):] if "(" in rhs else ""
        head = args.split("), ")[0]
        names = _OPERAND_RE.findall(head)
        return [symtab.get(nm, []) for nm in names]

    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        m = _COMP_START_RE.match(line.strip())
        if m and not line.startswith(" "):
            cur = m.group(2)
            comps[cur] = _Comp()
            symtab = {}
            if m.group(1):
                entry = cur
            continue
        if cur is None or line.strip() == "}":
            if line.strip() == "}":
                cur = None
            continue
        s = line.strip()
        if "=" not in s:
            continue
        lhs, _, rhs = s.partition(" = ")
        rhs = _COMMENT_RE.sub("", rhs)
        name = lhs.strip().removeprefix("ROOT ").lstrip("%")
        om = _OPCODE_RE.search(rhs)
        opcode = om.group(1) if om else ""
        result_type = rhs.split(opcode + "(")[0] if opcode else rhs
        shapes = _shape_dims(result_type)
        symtab[name] = shapes
        comp = comps[cur]
        result_bytes = _nbytes(shapes)

        if opcode == "dot":
            ops = operand_shapes(rhs)
            inline = _shape_dims(rhs[rhs.index("("):].split(",")[0])
            lhs_dims = (inline[0][1] if inline
                        else (ops[0][0][1] if ops and ops[0] else []))
            cm = _LHS_CDIMS_RE.search(rhs)
            k = 1
            if cm and cm.group(1):
                for ci in cm.group(1).split(","):
                    ci = int(ci)
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
            n_result = 1
            for b, dims in shapes:
                for d in dims:
                    n_result *= d
            comp.flops += 2.0 * n_result * k

        wm = _WHILE_RE.search(rhs)
        if wm:
            tm = _TRIP_RE.search(rhs)
            comp.whiles.append((wm.group(1),
                                int(tm.group(1)) if tm else 1))
            continue
        fm = _FUSION_RE.search(rhs)
        if fm:
            std = result_bytes + sum(_nbytes(o) for o in operand_shapes(rhs))
            comp.fusions.append((fm.group(1), std, result_bytes))
            continue
        cm2 = _CALL_RE.search(rhs)
        if cm2:
            comp.calls.append(cm2.group(1))
            continue

        if opcode in _NO_TRAFFIC:
            continue
        if opcode in _SLICE_LIKE:
            comp.region_bytes += 2 * result_bytes
            comp.is_region_comp = True
            continue
        if opcode in _UPDATE_LIKE:
            ops = operand_shapes(rhs)
            idx = _UPDATE_LIKE[opcode]
            upd = _nbytes(ops[idx]) if len(ops) > idx else result_bytes
            comp.region_bytes += 2 * upd
            comp.is_region_comp = True
            continue
        traffic = result_bytes
        ops = operand_shapes(rhs)
        if ops:
            traffic += sum(_nbytes(o) for o in ops)
        else:
            inline = _bytes_of(rhs[rhs.index("("):].split("), ")[0][1:]) \
                if "(" in rhs else 0
            traffic += inline
        comp.bytes += traffic
    return comps, entry


def analyze_flops_bytes(hlo_text: str) -> Tuple[float, float]:
    """Return (flops, hbm_bytes) per module execution, trip-count expanded."""
    comps, entry = _parse(hlo_text)
    if entry is None:
        entry = next(iter(comps), None)

    flops_total = [0.0]
    bytes_total = [0.0]

    def fusion_traffic(callee: str, std: float, result_bytes: float) -> float:
        c = comps.get(callee)
        if c is None:
            return std
        if c.is_region_comp:
            # slicing fusion: charge the regions its body touches; full-buffer
            # copies riding in the same fusion alias in place under donation
            # (capped so mixed fusions can't re-inflate to buffer size)
            return c.region_bytes + min(c.bytes, c.region_bytes)
        return std

    def visit(name: str, mult: float, count_bytes: bool, depth=0):
        if name not in comps or depth > 16:
            return
        c = comps[name]
        flops_total[0] += c.flops * mult
        if count_bytes:
            bytes_total[0] += (c.bytes + c.region_bytes) * mult
        for body, trip in c.whiles:
            visit(body, mult * max(trip, 1), count_bytes, depth + 1)
        for callee, std, rb in c.fusions:
            if count_bytes:
                bytes_total[0] += fusion_traffic(callee, std, rb) * mult
            visit(callee, mult, False, depth + 1)          # flops only
        for cl in c.calls:
            visit(cl, mult, count_bytes, depth + 1)

    if entry:
        visit(entry, 1.0, True)
    return flops_total[0], bytes_total[0]
