"""Structural communication profiler: extract collectives from post-SPMD HLO.

This is the TPU/XLA analogue of the paper's PyTorch-profiler NCCL traces: we
walk ``compiled.as_text()`` (the partitioned, optimized HLO module) and record
every collective op with its message bytes and participant count.  Unlike a
sampled kernel trace this is exact — the compiled module *is* the schedule.

Collectives inside ``while`` bodies (e.g. the scanned-layer fast path of
core/parallel_exec.py, or the fused ``tp_generate`` token loop) are expanded
by the loop's ``known_trip_count``, so per-execution call counts match what a
runtime trace would show — an L-layer scan with 2 allreduces per iteration
reports 2L calls, identical to the unrolled paper-parity module.
``conditional`` ops are charged at their heaviest branch (wire-byte upper
bound for one execution, since the predicate is runtime data).

Conventions (matching core/commodel.py and the paper §V-B):
  wire bytes:  all-reduce 2(d-1)/d·size, all-gather (d-1)/d·gathered-size,
               reduce-scatter (d-1)·output-size, all-to-all (d-1)/d·size,
               collective-permute 1·size.
Async pairs (``*-start``/``*-done``) are counted once, on the start op.
Scatter-form lowerings — an all-reduce whose sole consumer is a
dynamic-slice of exactly the 1/d rank shard (one way XLA compiles
``psum_scatter``) — are reclassified to the reducescatter factor
(``_reclassify_scatter_forms``), so the quantized two-step path
(DESIGN.md §12) is charged identically whether it compiles to a native
``reduce-scatter`` op or the slice form.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Iterable, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_KINDS = {
    "all-reduce": "allreduce",
    "all-gather": "allgather",
    "reduce-scatter": "reducescatter",
    "all-to-all": "alltoall",
    "collective-permute": "collectivepermute",
}

_SHAPE_RE = re.compile(r"\b(%s)\[([\d,]*)\]" % "|".join(_DTYPE_BYTES))
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(.*?)\}\}?[,)\s]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{(.*?)\}\}")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"\bwhile\(.*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count.*?"n"\s*:\s*"(\d+)"')
_CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?(?:to_apply|calls)=%?([\w\.\-]+)")
_COND_BRANCH_RE = re.compile(
    r"(?:true_computation|false_computation)=%?([\w\.\-]+)")
_COND_LIST_RE = re.compile(r"branch_computations=\{(.*?)\}")


@dataclasses.dataclass
class HLOCollective:
    kind: str                    # canonical collective name
    out_bytes: int               # bytes moved by one call (result side)
    group_size: int              # participants d
    op_name: str = ""
    count: int = 1               # executions per module run (trip-expanded)

    @property
    def total_bytes(self) -> int:
        return self.out_bytes * self.count

    @property
    def wire_bytes(self) -> float:
        d = max(self.group_size, 1)
        if self.kind == "allreduce":
            f = 2.0 * (d - 1) / d
        elif self.kind in ("allgather", "alltoall"):
            f = (d - 1) / d
        elif self.kind == "reducescatter":
            f = float(d - 1)     # (d-1)/d × input == (d-1) × output
        else:
            f = 1.0              # collective-permute
        return self.total_bytes * f


def _shapes_in(text: str) -> List[int]:
    sizes = []
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        sizes.append(n * _DTYPE_BYTES[dt])
    return sizes


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        if first:
            return len(first.split(","))
    m = _SRC_TGT_RE.search(line)
    if m and m.group(1):
        return 2
    return 1


def _parse_collective_line(lhs: str, rhs: str, line: str) -> Optional[HLOCollective]:
    for opcode, kind in _KINDS.items():
        is_sync = f" {opcode}(" in " " + rhs
        is_start = f" {opcode}-start(" in " " + rhs
        if not (is_sync or is_start):
            continue
        result_type = rhs.split(opcode)[0]
        sizes = _shapes_in(result_type)
        if not sizes:
            return None
        if is_start:
            # async start result is a tuple (operands..., results..., ctx...)
            nbytes = min(sizes) if kind == "reducescatter" else max(sizes)
        else:
            nbytes = sum(sizes)
        op_name = lhs.strip()
        if op_name.startswith("ROOT "):
            op_name = op_name[5:]
        return HLOCollective(kind, nbytes, _group_size(line),
                             op_name.lstrip("%"))
    return None


def _parse_computations(hlo_text: str):
    """Split the module into computations with their collectives/whiles/calls."""
    comps: Dict[str, dict] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        m = _COMP_START_RE.match(line.strip())
        if m and not line.startswith(" "):
            name = m.group(2)
            comps[name] = {"colls": [], "whiles": [], "calls": [],
                           "conds": [], "ops": []}
            cur = name
            if m.group(1):
                entry = name
            continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}":
            cur = None
            continue
        if "=" not in s:
            continue
        lhs, _, rhs = s.partition(" = ")
        comps[cur]["ops"].append(rhs)
        if "-done(" in rhs:
            continue                       # counted at the matching start
        coll = _parse_collective_line(lhs, rhs, s)
        if coll is not None:
            comps[cur]["colls"].append(coll)
            continue
        wm = _WHILE_RE.search(rhs)
        if wm:
            tm = _TRIP_RE.search(rhs)
            trip = int(tm.group(1)) if tm else 1
            comps[cur]["whiles"].append((wm.group(1), trip))
            continue
        if " conditional(" in " " + rhs:
            lm = _COND_LIST_RE.search(rhs)
            branches = (lm.group(1).replace("%", "").replace(" ", "")
                        .split(",") if lm else _COND_BRANCH_RE.findall(rhs))
            if branches:
                comps[cur]["conds"].append(tuple(branches))
            continue
        cm = _CALL_RE.search(rhs)
        if cm:
            comps[cur]["calls"].append(cm.group(1))
    for comp in comps.values():
        _reclassify_scatter_forms(comp)
    return comps, entry


def _reclassify_scatter_forms(comp: dict) -> None:
    """Map scatter-form all-reduce lowerings to the reducescatter factor.

    ``psum_scatter`` does not always survive to a ``reduce-scatter`` HLO op:
    XLA may lower it as a full ``all-reduce`` whose *only* consumer is a
    ``dynamic-slice`` taking exactly the 1/d rank shard — semantically a
    reduce-scatter, and charged as one by NCCL-style accounting (each rank
    keeps 1/d of the reduction).  Counting it at the allreduce factor would
    overstate wire bytes 2d/(d-1)× vs the commodel's reducescatter row, so
    the op is reclassified: kind=reducescatter, out_bytes=the slice's bytes
    (wire = (d-1) × slice — identical to a native reduce-scatter op).  An
    all-reduce with any other consumer pattern is left untouched.
    """
    for coll in comp["colls"]:
        if coll.kind != "allreduce" or coll.group_size <= 1:
            continue
        pat = re.compile(r"[%\s(,]" + re.escape(coll.op_name) + r"[\s,)}]")
        consumers = [rhs for rhs in comp["ops"]
                     if coll.op_name in rhs and pat.search(rhs)]
        if len(consumers) != 1 or " dynamic-slice(" not in " " + consumers[0]:
            continue
        sizes = _shapes_in(consumers[0].split("dynamic-slice")[0])
        if sizes and sizes[0] * coll.group_size == coll.out_bytes:
            coll.kind = "reducescatter"
            coll.out_bytes = sizes[0]


def parse_hlo_collectives(hlo_text: str) -> List[HLOCollective]:
    """All collectives per module *execution* (while bodies trip-expanded)."""
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        entry = next(iter(comps), None)

    def visit(name: str, mult: int, depth: int = 0) -> List[HLOCollective]:
        if name not in comps or depth > 16:
            return []
        c = comps[name]
        out = [dataclasses.replace(coll, count=coll.count * mult)
               for coll in c["colls"]]
        for body, trip in c["whiles"]:
            out.extend(visit(body, mult * max(trip, 1), depth + 1))
        for callee in c["calls"]:
            out.extend(visit(callee, mult, depth + 1))
        for branches in c["conds"]:
            out.extend(max(
                (visit(b, mult, depth + 1) for b in branches),
                key=lambda lst: sum(x.wire_bytes for x in lst), default=[]))
        return out

    return visit(entry, 1) if entry else []


def summarize(colls: Iterable[HLOCollective]) -> Dict[str, dict]:
    """Aggregate by kind: calls, message bytes, wire bytes."""
    agg: Dict[str, dict] = defaultdict(lambda: {"count": 0, "msg_bytes": 0,
                                                "wire_bytes": 0.0})
    for c in colls:
        a = agg[c.kind]
        a["count"] += c.count
        a["msg_bytes"] += c.total_bytes
        a["wire_bytes"] += c.wire_bytes
    return dict(agg)


def collective_wire_bytes(hlo_text: str) -> float:
    """Total wire bytes of one module execution."""
    return sum(c.wire_bytes for c in parse_hlo_collectives(hlo_text))
