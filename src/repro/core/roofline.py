"""Three-term roofline analysis from compiled dry-run artifacts.

Terms (per the assignment, TPU v5e constants in config.base.TPU_V5E):

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  / (chips × link_bw)

``compiled.cost_analysis()`` reports the *per-partition* (per-chip) program,
so we use per-chip quantities directly (identical to the total/(chips×…)
form).  Collective bytes come from core.hlo_comm — the per-chip ring wire
volume with the paper's correction factors, trip-expanded through the layer
scan.  MODEL_FLOPS = 6·N·D (train) or 2·N·D (forward-only) with N the
*active* parameter count, D the global tokens processed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.config.base import HardwareProfile, ModelConfig, ShapeConfig, TPU_V5E
from repro.core import hlo_comm, hlo_cost


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float
    useful_ratio: float
    collectives: Dict[str, dict]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> str:
        return (f"{self.arch:18s} {self.shape:12s} {self.mesh:10s} "
                f"C {self.compute_s*1e3:9.3f} ms  M {self.memory_s*1e3:9.3f} ms  "
                f"K {self.collective_s*1e3:9.3f} ms  dom={self.dominant:10s} "
                f"useful={self.useful_ratio:6.3f}")


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (per decode step), active N."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.mode == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # one decode step


def _cost_get(cost: dict, key: str) -> float:
    if key in cost:
        return float(cost[key])
    # XLA sometimes splits "bytes accessed" per operand: sum the variants
    total = sum(float(v) for k, v in cost.items() if k.startswith(key))
    return total


def analyze(cfg: ModelConfig, shape: ShapeConfig, mesh_name: str,
            n_chips: int, cost: dict, hlo_text: str,
            hw: HardwareProfile = TPU_V5E,
            flops_override: Optional[float] = None) -> RooflineReport:
    # XLA's cost_analysis counts while bodies once; re-derive both numerators
    # with trip-count expansion (core.hlo_cost).  ``cost`` is kept for
    # cross-checking in the dry-run records.
    flops, hbm = hlo_cost.analyze_flops_bytes(hlo_text)
    if flops_override is not None:
        flops = flops_override
    if flops == 0.0:
        flops = _cost_get(cost, "flops")
    if hbm == 0.0:
        hbm = _cost_get(cost, "bytes accessed")
    colls = hlo_comm.parse_hlo_collectives(hlo_text)
    coll_bytes = sum(c.wire_bytes for c in colls)
    mf = model_flops(cfg, shape)
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, n_chips=n_chips,
        flops_per_chip=flops, hbm_bytes_per_chip=hbm,
        coll_bytes_per_chip=coll_bytes,
        compute_s=flops / hw.peak_flops,
        memory_s=hbm / hw.hbm_bw,
        collective_s=coll_bytes / hw.intra_bw,
        model_flops_total=mf,
        useful_ratio=(mf / (flops * n_chips)) if flops else 0.0,
        collectives=hlo_comm.summarize(colls),
    )
