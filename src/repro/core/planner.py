"""Automated parallelism selection (the paper's §VII 'future work', built).

Given a model, a serving scenario (S_p, S_d, SLO weights) and a hardware
profile, enumerate feasible (t, c, p) layouts, score each with the
analytical SLO model, and return a ranked plan.  The ranking reproduces the
paper's §V-C deployment guidance plus the sequence-parallel extension of
the companion work (arXiv:2408.10197):
  * short sequences + intra-node ⇒ pure TP (TTFT-optimal),
  * long-form generation / bandwidth-constrained ⇒ PP (volume-optimal),
  * moderate workloads ⇒ balanced hybrids; avoid unbalanced ones,
  * long prompts whose prefill is compute-bound on one TP group ⇒ context
    parallelism (CP shards the prefill sequence, DESIGN.md §9) — CP wins
    TTFT there and is pure overhead on short prompts.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.config.base import HardwareProfile, H100_NODE, ModelConfig
from repro.core.slo import DEFAULT_OVERHEADS, EngineOverheads, SLOReport, \
    predict_slo


@dataclasses.dataclass
class PlanCandidate:
    tensor_parallel: int
    context_parallel: int
    pipeline_parallel: int
    slo: SLOReport
    score: float
    occupancy: float = 1.0

    @property
    def name(self) -> str:
        return (f"TP={self.tensor_parallel} CP={self.context_parallel} "
                f"PP={self.pipeline_parallel}")


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def feasible_layouts(cfg: ModelConfig, world: int) -> List[tuple]:
    """All (t, c, p) with t·c·p == world the system can actually run.

    Constraints: the attention and kv heads must shard over t, and every
    pipeline stage must own at least one layer — p <= num_layers.  An
    INDIVISIBLE layer count is feasible: ``commodel.stage_layer_partition``
    spreads the remainder over the early stages and the engines follow the
    same split (PR 2), so the old ``num_layers % p == 0`` filter silently
    excluded layouts the system serves fine (e.g. Llama-3.2-3B's 28 layers
    at p=8).  CP adds no divisibility constraint of its own — prompts pad
    to a multiple of c (DESIGN.md §9).
    """
    outs = []
    for t in _divisors(world):
        if cfg.num_kv_heads % t or cfg.num_heads % t:
            continue
        for c in _divisors(world // t):
            p = world // (t * c)
            if p > cfg.num_layers:
                continue
            outs.append((t, c, p))
    return outs


def plan(cfg: ModelConfig, world: int, s_p: int, s_d: int, *,
         hw: HardwareProfile = H100_NODE,
         ov: EngineOverheads = DEFAULT_OVERHEADS,
         objective: str = "e2e",
         volume_budget: Optional[float] = None,
         inflight: int = 1, quant: Optional[str] = None,
         hit_rate: float = 0.0,
         hit_len: Optional[int] = None) -> List[PlanCandidate]:
    """Rank all feasible (t, c, p) layouts for ``world`` chips.

    objective: "ttft" | "tpot" | "e2e" | "volume".
    volume_budget: optional cap on comm wire bytes (models a bandwidth-
    constrained fabric — layouts above the cap are ranked last).
    inflight: dynamic-schedule microbatch depth (DESIGN.md §11).  PP
    layouts are scored with ``min(inflight, p)/p`` of the decode bubble
    filled — the "tpot" objective ranks by ``tpot_effective``, and "e2e"
    inherits the same term through predict_slo, so a deep pipeline that
    looks bad serialized can win once the scheduler keeps it occupied.
    At inflight=1 the ranking is bitwise the old one.
    quant: "int8" | "fp8" (DESIGN.md §12) scores every layout with the
    decode-phase TP allreduces priced at the quantized two-step — deep-TP
    layouts whose decode wire bytes priced them off the frontier re-enter
    it on short sequences (Flash Communication's shape).
    hit_rate / hit_len: expected prefix-cache hit statistics of the
    traffic (DESIGN.md §13).  Every layout is scored with ``hit_rate`` of
    requests prefilling only their ``s_p - hit_len`` suffix, so under
    template-heavy traffic prefill-bound advantages (CP's sharded
    prefill in particular) shrink toward zero and decode-bound layouts
    climb the ranking; at hit_rate=0 the ranking is bitwise the old one.
    """
    cands = []
    for t, c, p in feasible_layouts(cfg, world):
        slo = predict_slo(cfg, s_p, s_d, t, p, hw=hw, ov=ov, c=c,
                          inflight=inflight, quant=quant,
                          hit_rate=hit_rate, hit_len=hit_len)
        score = {
            "ttft": slo.ttft, "tpot": slo.breakdown["tpot_effective"],
            "e2e": slo.e2e, "volume": slo.comm_volume,
        }[objective]
        if volume_budget is not None and slo.comm_volume > volume_budget:
            score = float("inf")
        cands.append(PlanCandidate(t, c, p, slo, score,
                                   occupancy=slo.occupancy))
    cands.sort(key=lambda x: (x.score, x.slo.e2e))
    return cands


def recommend(cfg: ModelConfig, world: int, s_p: int, s_d: int,
              **kw) -> PlanCandidate:
    return plan(cfg, world, s_p, s_d, **kw)[0]
