"""Automated parallelism selection (the paper's §VII 'future work', built).

Given a model, a serving scenario (S_p, S_d, SLO weights) and a hardware
profile, enumerate feasible (t, p) layouts, score each with the analytical
SLO model, and return a ranked plan.  The ranking reproduces the paper's
§V-C deployment guidance:
  * short sequences + intra-node ⇒ pure TP (TTFT-optimal),
  * long-form generation / bandwidth-constrained ⇒ PP (volume-optimal),
  * moderate workloads ⇒ balanced hybrids; avoid unbalanced ones.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.config.base import HardwareProfile, H100_NODE, ModelConfig
from repro.core.slo import DEFAULT_OVERHEADS, EngineOverheads, SLOReport, \
    predict_slo


@dataclasses.dataclass
class PlanCandidate:
    tensor_parallel: int
    pipeline_parallel: int
    slo: SLOReport
    score: float

    @property
    def name(self) -> str:
        return f"TP={self.tensor_parallel} PP={self.pipeline_parallel}"


def feasible_layouts(cfg: ModelConfig, world: int) -> List[tuple]:
    outs = []
    for t in [d for d in range(1, world + 1) if world % d == 0]:
        p = world // t
        if cfg.num_kv_heads % t or cfg.num_heads % t:
            continue
        if cfg.num_layers % p:
            continue
        outs.append((t, p))
    return outs


def plan(cfg: ModelConfig, world: int, s_p: int, s_d: int, *,
         hw: HardwareProfile = H100_NODE,
         ov: EngineOverheads = DEFAULT_OVERHEADS,
         objective: str = "e2e",
         volume_budget: Optional[float] = None) -> List[PlanCandidate]:
    """Rank all feasible (t, p) layouts for ``world`` chips.

    objective: "ttft" | "tpot" | "e2e" | "volume".
    volume_budget: optional cap on comm wire bytes (models a bandwidth-
    constrained fabric — layouts above the cap are ranked last).
    """
    cands = []
    for t, p in feasible_layouts(cfg, world):
        slo = predict_slo(cfg, s_p, s_d, t, p, hw=hw, ov=ov)
        score = {
            "ttft": slo.ttft, "tpot": slo.tpot, "e2e": slo.e2e,
            "volume": slo.comm_volume,
        }[objective]
        if volume_budget is not None and slo.comm_volume > volume_budget:
            score = float("inf")
        cands.append(PlanCandidate(t, p, slo, score))
    cands.sort(key=lambda c: (c.score, c.slo.e2e))
    return cands


def recommend(cfg: ModelConfig, world: int, s_p: int, s_d: int,
              **kw) -> PlanCandidate:
    return plan(cfg, world, s_p, s_d, **kw)[0]
