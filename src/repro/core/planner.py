"""Automated parallelism selection (the paper's §VII 'future work', built).

Given a model, a serving scenario (S_p, S_d, SLO weights) and a hardware
profile, enumerate feasible (t, c, p) layouts, score each with the
analytical SLO model, and return a ranked plan.  The ranking reproduces the
paper's §V-C deployment guidance plus the sequence-parallel extension of
the companion work (arXiv:2408.10197):
  * short sequences + intra-node ⇒ pure TP (TTFT-optimal),
  * long-form generation / bandwidth-constrained ⇒ PP (volume-optimal),
  * moderate workloads ⇒ balanced hybrids; avoid unbalanced ones,
  * long prompts whose prefill is compute-bound on one TP group ⇒ context
    parallelism (CP shards the prefill sequence, DESIGN.md §9) — CP wins
    TTFT there and is pure overhead on short prompts.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.config.base import HardwareProfile, H100_NODE, ModelConfig
from repro.core.slo import DEFAULT_OVERHEADS, EngineOverheads, SLOReport, \
    predict_slo


@dataclasses.dataclass
class PlanCandidate:
    tensor_parallel: int
    context_parallel: int
    pipeline_parallel: int
    slo: SLOReport
    score: float
    occupancy: float = 1.0

    @property
    def name(self) -> str:
        return (f"TP={self.tensor_parallel} CP={self.context_parallel} "
                f"PP={self.pipeline_parallel}")


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def feasible_layouts(cfg: ModelConfig, world: int) -> List[tuple]:
    """All (t, c, p) with t·c·p == world the system can actually run.

    Constraints: the attention and kv heads must shard over t, and every
    pipeline stage must own at least one layer — p <= num_layers.  An
    INDIVISIBLE layer count is feasible: ``commodel.stage_layer_partition``
    spreads the remainder over the early stages and the engines follow the
    same split (PR 2), so the old ``num_layers % p == 0`` filter silently
    excluded layouts the system serves fine (e.g. Llama-3.2-3B's 28 layers
    at p=8).  CP adds no divisibility constraint of its own — prompts pad
    to a multiple of c (DESIGN.md §9).
    """
    outs = []
    for t in _divisors(world):
        if cfg.num_kv_heads % t or cfg.num_heads % t:
            continue
        for c in _divisors(world // t):
            p = world // (t * c)
            if p > cfg.num_layers:
                continue
            outs.append((t, c, p))
    return outs


def plan(cfg: ModelConfig, world: int, s_p: int, s_d: int, *,
         hw: HardwareProfile = H100_NODE,
         ov: EngineOverheads = DEFAULT_OVERHEADS,
         objective: str = "e2e",
         volume_budget: Optional[float] = None,
         inflight: int = 1, quant: Optional[str] = None,
         hit_rate: float = 0.0,
         hit_len: Optional[int] = None) -> List[PlanCandidate]:
    """Rank all feasible (t, c, p) layouts for ``world`` chips.

    objective: "ttft" | "tpot" | "e2e" | "volume".
    volume_budget: optional cap on comm wire bytes (models a bandwidth-
    constrained fabric — layouts above the cap are ranked last).
    inflight: dynamic-schedule microbatch depth (DESIGN.md §11).  PP
    layouts are scored with ``min(inflight, p)/p`` of the decode bubble
    filled — the "tpot" objective ranks by ``tpot_effective``, and "e2e"
    inherits the same term through predict_slo, so a deep pipeline that
    looks bad serialized can win once the scheduler keeps it occupied.
    At inflight=1 the ranking is bitwise the old one.
    quant: "int8" | "fp8" (DESIGN.md §12) scores every layout with the
    decode-phase TP allreduces priced at the quantized two-step — deep-TP
    layouts whose decode wire bytes priced them off the frontier re-enter
    it on short sequences (Flash Communication's shape).
    hit_rate / hit_len: expected prefix-cache hit statistics of the
    traffic (DESIGN.md §13).  Every layout is scored with ``hit_rate`` of
    requests prefilling only their ``s_p - hit_len`` suffix, so under
    template-heavy traffic prefill-bound advantages (CP's sharded
    prefill in particular) shrink toward zero and decode-bound layouts
    climb the ranking; at hit_rate=0 the ranking is bitwise the old one.
    """
    cands = []
    for t, c, p in feasible_layouts(cfg, world):
        slo = predict_slo(cfg, s_p, s_d, t, p, hw=hw, ov=ov, c=c,
                          inflight=inflight, quant=quant,
                          hit_rate=hit_rate, hit_len=hit_len)
        score = {
            "ttft": slo.ttft, "tpot": slo.breakdown["tpot_effective"],
            "e2e": slo.e2e, "volume": slo.comm_volume,
        }[objective]
        if volume_budget is not None and slo.comm_volume > volume_budget:
            score = float("inf")
        cands.append(PlanCandidate(t, c, p, slo, score,
                                   occupancy=slo.occupancy))
    cands.sort(key=lambda x: (x.score, x.slo.e2e))
    return cands


def recommend(cfg: ModelConfig, world: int, s_p: int, s_d: int,
              **kw) -> PlanCandidate:
    return plan(cfg, world, s_p, s_d, **kw)[0]


# ---------------------------------------------------------------------------
# disaggregated prefill/decode planning (DESIGN.md §14)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One request class of a mixed trace: arrival rate (req/s) at a fixed
    prompt/decode shape.  A workload is a list of these — e.g. chat
    (short s_p, long s_d) plus summarization (long s_p, short s_d)."""

    name: str
    s_p: int
    s_d: int
    rate: float

    def __post_init__(self):
        if self.s_p < 1 or self.s_d < 1:
            raise ValueError(
                f"class {self.name!r}: s_p and s_d must be >= 1")
        if self.rate <= 0:
            raise ValueError(
                f"class {self.name!r}: rate must be > 0, got {self.rate}")


@dataclasses.dataclass
class DisaggCandidate:
    """One serving-plane candidate for a mixed workload: either every
    class colocated on one engine pool, or a prefill pool + decode pool
    split (DESIGN.md §14).  ``utilization`` is the prefill busy fraction
    of the DECODE-serving engine — the head-of-line interference term
    that inflates its TPOT by 1/(1-u)."""

    mode: str                          # "colocated" | "disagg"
    decode_layout: tuple               # (t, c, p) of the decode-serving pool
    prefill_layout: Optional[tuple]    # (t, c, p); None when colocated
    prefill_world: int                 # chips on the prefill pool (0 = colo)
    score: float
    utilization: float
    per_class: dict                    # name -> {ttft, tpot, e2e, volume}

    @property
    def name(self) -> str:
        t, c, p = self.decode_layout
        dec = f"TP={t} CP={c} PP={p}"
        if self.mode == "colocated":
            return f"colocated[{dec}]"
        tt, cc, pp = self.prefill_layout
        return (f"disagg[pre({self.prefill_world}): TP={tt} CP={cc} "
                f"PP={pp} | dec: {dec}]")


def _busy(rep, ov) -> float:
    """Engine-busy seconds one request's prefill costs the pool that runs
    it (the front-end overhead is off-engine — same split as
    ``slo.recompute_time``)."""
    return max(0.0, rep.ttft - ov.request_overhead)


def _aggregate(per_class: dict, classes, objective: str) -> float:
    rate_tot = sum(k.rate for k in classes)
    return sum(k.rate / rate_tot * per_class[k.name][objective]
               for k in classes)


def plan_disagg(cfg: ModelConfig, world: int, classes, *,
                hw: HardwareProfile = H100_NODE,
                ov: EngineOverheads = DEFAULT_OVERHEADS,
                objective: str = "e2e", page_size: int = 16,
                route_prompt_len: Optional[int] = None,
                inflight: int = 1,
                quant: Optional[str] = None) -> List[DisaggCandidate]:
    """Rank colocated vs disaggregated serving planes for a mixed workload
    (DESIGN.md §14).

    The interference model is processor sharing: on an engine that serves
    both phases, prefill passes steal ``u = Σ rate·prefill_busy`` of the
    wall clock from decode rounds, so every class's effective TPOT is the
    clean TPOT × 1/(1-u) (u ≥ 1 is overload: score = inf).  That is the
    head-of-line cost the paper's mixed traces measure and disaggregation
    kills: the decode pool's u keeps only the SHORT classes' prefills plus
    the long classes' ≤ page_size suffix chunks — the long prefills move
    to the prefill pool, whose own utilization must also stay < 1 — at
    the price of (a) fewer chips serving decode and (b) a per-request
    handoff term (``predict_slo(handoff_pages=...)``) on long TTFT.  Hence
    the decision rule the ranking reproduces: prefill-heavy mixes prefer
    disagg, short-chat-only traffic keeps colocated (splitting the world
    just removes decode chips and idles a prefill pool).

    The decode pool is restricted to c == 1 layouts — handed-off requests
    admit through the prefix index, whose suffix prefill needs the
    chunk-offset path (DESIGN.md §13).  Long classes route to the prefill
    pool when ``s_p >= route_prompt_len`` (default 2 × page_size), the
    same routing rule ``runtime.scheduler.DisaggScheduler`` applies.
    """
    classes = list(classes)
    if not classes:
        raise ValueError("plan_disagg needs at least one TrafficClass")
    if objective not in ("ttft", "tpot", "e2e", "volume"):
        raise ValueError(f"unknown objective {objective!r}")
    thresh = 2 * page_size if route_prompt_len is None \
        else int(route_prompt_len)
    longs = [k for k in classes if k.s_p >= thresh]
    shorts = [k for k in classes if k.s_p < thresh]
    kw = dict(hw=hw, ov=ov, inflight=inflight, quant=quant)
    cands: List[DisaggCandidate] = []

    def row(rep, k, inflate: float) -> dict:
        tpot = rep.breakdown["tpot_effective"] * inflate
        return {"ttft": rep.ttft, "tpot": tpot,
                "e2e": rep.ttft + max(k.s_d - 1, 0) * tpot,
                "volume": rep.comm_volume}

    # -- colocated: one pool serves both phases of every class
    for t, c, p in feasible_layouts(cfg, world):
        reps = {k.name: predict_slo(cfg, k.s_p, k.s_d, t, p, c=c, **kw)
                for k in classes}
        u = sum(k.rate * _busy(reps[k.name], ov) for k in classes)
        inflate = 1.0 / (1.0 - u) if u < 1.0 else float("inf")
        per = {k.name: row(reps[k.name], k, inflate) for k in classes}
        cands.append(DisaggCandidate(
            "colocated", (t, c, p), None, 0,
            _aggregate(per, classes, objective), u, per))

    # -- disagg: every (prefill chips, decode chips) split of the world
    for w_pre in range(1, world) if longs else ():
        w_dec = world - w_pre
        dec_layouts = [(t, c, p) for t, c, p in feasible_layouts(cfg, w_dec)
                       if c == 1]
        pre_layouts = feasible_layouts(cfg, w_pre)
        for dt, dc, dp in dec_layouts:
            # decode-pool view of each class: shorts serve whole; longs
            # arrive with their full blocks handed off and prefill only
            # the suffix the §13 lookup leaves (1..page_size positions)
            reps = {}
            for k in shorts:
                reps[k.name] = predict_slo(cfg, k.s_p, k.s_d, dt, dp,
                                           c=dc, **kw)
            for k in longs:
                pages = k.s_p // page_size
                suffix = k.s_p - min(k.s_p - 1, pages * page_size)
                reps[k.name] = predict_slo(cfg, suffix, k.s_d, dt, dp,
                                           c=dc, handoff_pages=pages,
                                           page_size=page_size, **kw)
            u_dec = sum(k.rate * _busy(reps[k.name], ov) for k in classes)
            if u_dec >= 1.0:
                continue
            inflate = 1.0 / (1.0 - u_dec)
            for pt, pc, pp in pre_layouts:
                pre = {k.name: predict_slo(cfg, k.s_p, 2, pt, pp, c=pc,
                                           **kw) for k in longs}
                u_pre = sum(k.rate * _busy(pre[k.name], ov) for k in longs)
                if u_pre >= 1.0:
                    continue
                per = {}
                for k in classes:
                    r = row(reps[k.name], k, inflate)
                    if k.name in pre:
                        # a long request's TTFT chains the pools: its
                        # prefill runs on the prefill pool, then the
                        # handoff + suffix admission on the decode pool
                        # (already inside r via handoff_pages)
                        extra = _busy(pre[k.name], ov)
                        r["ttft"] += extra
                        r["e2e"] += extra
                        r["volume"] += pre[k.name].comm_volume
                    per[k.name] = r
                cands.append(DisaggCandidate(
                    "disagg", (dt, dc, dp), (pt, pc, pp), w_pre,
                    _aggregate(per, classes, objective),
                    max(u_dec, u_pre), per))

    cands.sort(key=lambda x: (x.score,
                              _aggregate(x.per_class, classes, "e2e")))
    return cands


def recommend_disagg(cfg: ModelConfig, world: int, classes,
                     **kw) -> DisaggCandidate:
    return plan_disagg(cfg, world, classes, **kw)[0]
