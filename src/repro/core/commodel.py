"""Analytical communication models — the paper's Section III, executable.

Every model returns a list of :class:`CommOp` (collective type, phase, call
count, per-call message shape, worker count).  Summing wire bytes with the
paper's NCCL ring correction factors reproduces Eq. 1 (TP), Eq. 2 (PP) and
Eq. 3–7 (hybrid) exactly; the per-op breakdown reproduces the count/shape
columns of Tables III–VI.

Accounting conventions (paper Section V):
  * allreduce wire volume:  2(d-1)/d × message bytes     [ring allreduce]
  * allgather wire volume:   (d-1)/d × gathered bytes
  * gather / p2p:            1 × message bytes
  * "send" and "recv" are both reported (the paper's profiler counts each
    direction, Table V) but only sends are charged in volume (Eq. 2).
  * per-link p2p carries TWO tensors per hop (hidden states + residual —
    the paper's "KV factor", Table V pattern (p-1)·2·…).

Beyond-paper extensions (flagged, OFF for paper-parity):
  * batch > 1 serving (the paper is single-request),
  * MoE expert-parallel all-to-all (paper §VII future work),
  * SSM/RWKV state hand-off between pipeline stages,
  * gather_mode="allgather" — XLA has no gather-to-root collective, so the
    TPU engine all-gathers the vocab shards instead (DESIGN.md §2),
  * context parallelism (``cp_comm_ops``, ``comm_ops_for(c=...)``) — the
    sequence axis sharded over a third mesh axis during *prefill only*
    (DESIGN.md §9): per layer the c workers of a CP group ring-exchange
    their K/V blocks in (c-1) collective-permute rounds of TWO tensors
    each (K and V — the companion paper arXiv:2408.10197's sequence-
    parallel exchange pattern), plus one [B, h] allreduce over the CP
    group to hand the last position's hidden state to the logits head.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.config.base import ModelConfig

_WIRE_FACTOR = {
    "allreduce": lambda d: 2.0 * (d - 1) / d,
    "allgather": lambda d: (d - 1) / d,
    "reducescatter": lambda d: (d - 1) / d,
    "gather": lambda d: 1.0,
    "alltoall": lambda d: (d - 1) / d,
    "send": lambda d: 1.0,
    "recv": lambda d: 0.0,   # same bytes as the matching send (not double-charged)
    "collectivepermute": lambda d: 1.0,   # ring hop: every rank ships its block
}

# Quantized-collective width entries (DESIGN.md §12): the two-step decode
# allreduce ships its payload at one of these widths while the per-chunk
# scales travel as f32.  fp8 is modeled at its nominal 1-byte width — real
# accelerator wire bytes; host-CPU XLA upcasts the f8 payload to f16 on the
# wire, which the HLO-parity tests gate per-platform.  int4 ships two
# values per uint8 byte (``kernels.quant_collective.nibble_pack``), hence
# the half-byte wire width.
QUANT_WIRE_BYTES = {"int8": 1, "fp8": 1, "int4": 0.5}
QUANT_SCALE_BYTES = 4
DEFAULT_QUANT_CHUNK = 128


@dataclasses.dataclass(frozen=True)
class CommOp:
    """One homogeneous class of collective calls."""

    collective: str              # allreduce|allgather|gather|send|recv|alltoall
    phase: str                   # "prefill" | "decode"
    count: int                   # number of calls
    shape: Tuple[int, ...]       # per-call message shape (elements)
    workers: int                 # participating workers d
    dtype_bytes: int = 2         # FP16/BF16 throughout the paper

    @property
    def elements(self) -> int:
        return math.prod(self.shape)

    @property
    def msg_bytes(self) -> int:
        """Raw bytes of one message (the paper's 'Message Size' column)."""
        return self.elements * self.dtype_bytes

    @property
    def total_msg_bytes(self) -> int:
        return self.count * self.msg_bytes

    @property
    def wire_bytes(self) -> float:
        """Network volume with the paper's correction factor applied."""
        return self.total_msg_bytes * _WIRE_FACTOR[self.collective](self.workers)


def total_volume(ops: List[CommOp], phase: Optional[str] = None) -> float:
    return sum(o.wire_bytes for o in ops if phase in (None, o.phase))


def by_collective(ops: List[CommOp]):
    out = {}
    for o in ops:
        out.setdefault(o.collective, []).append(o)
    return out


# ---------------------------------------------------------------------------
# Quantized two-step collectives (Flash Communication, DESIGN.md §12)
# ---------------------------------------------------------------------------


def quant_chunks(h: int, chunk: int) -> int:
    """Scale blocks covering a hidden width: ceil(h / chunk) — the last
    block may cover a remainder shorter than ``chunk``."""
    if chunk < 1:
        raise ValueError(f"quant chunk must be >= 1, got {chunk}")
    return -(-h // chunk)


def quant_decode_ar_ops(phase: str, count: int, rows: int, h: int, t: int,
                        quant: str, chunk: int) -> List[CommOp]:
    """Decompose ``count`` bf16 [rows, h] decode allreduces into the
    quantized two-step's wire ops (``parallel_exec.quantized_psum``):

      1. one f32 [rows, K] allreduce — the per-chunk abs-max exchange
         (``lax.pmax``) that gives every rank the shared scales,
      2. one 1-byte [rows, h] reducescatter — the quantized partial sums,
         exact integer addition under the floor(qmax/t) headroom,
      3. one 1-byte [rows, h] allgather — redistributing the reduced shards.

    ``quant="int4"`` swaps the reducescatter for a half-byte [rows, h]
    alltoall: packed nibbles cannot be partially summed on the wire, so
    each rank instead receives every rank's packed copy of its own hidden
    block, reduces exactly in int32, and the re-packed halves ride the
    half-byte allgather — same two payload hops, both at 0.5 bytes/element
    (``parallel_exec.quantized_psum``, DESIGN.md §12).

    Counts stay batch-invariant (``rows`` scales message bytes only) and the
    closed-form wire ratio vs one b-byte allreduce is
    ``(payload·2h + scale·2·4K) / (2·2h)`` — see ``quant_ar_wire_ratio``.
    """
    if quant not in QUANT_WIRE_BYTES:
        raise ValueError(f"unknown quant mode {quant!r}; "
                         f"expected one of {sorted(QUANT_WIRE_BYTES)}")
    K = quant_chunks(h, chunk)
    w = QUANT_WIRE_BYTES[quant]
    payload = "alltoall" if quant == "int4" else "reducescatter"
    return [
        CommOp("allreduce", phase, count, (rows, K), t, QUANT_SCALE_BYTES),
        CommOp(payload, phase, count, (rows, h), t, w),
        CommOp("allgather", phase, count, (rows, h), t, w),
    ]


def quant_ar_wire_ratio(h: int, t: int, quant: str = "int8",
                        chunk: int = DEFAULT_QUANT_CHUNK,
                        b: int = 2) -> float:
    """Wire bytes of one quantized two-step allreduce over one b-byte ring
    allreduce of the same [rows, h] message, in closed form:

        ratio = (2·w·h + 2·4·K) / (2·b·h)  =  w/b + 4K/(b·h)

    (every term carries the same (t-1)/t ring factor, so the ratio is
    t-invariant: the 1-byte two-step pins the payload at exactly half a
    bf16 ring allreduce plus the f32 scale overhead 4K/h — DESIGN.md §12
    derives why pushing toward ~0.28× needs a 4-bit payload.)"""
    K = quant_chunks(h, chunk)
    w = QUANT_WIRE_BYTES[quant]
    return (2 * w * h + 2 * QUANT_SCALE_BYTES * K) / (2 * b * h)


def _decode_ar_rows(n_layer_ar: int, steps: int, rows: int, h: int, t: int,
                    b: int, quant: Optional[str],
                    chunk: int) -> List[CommOp]:
    """Decode-phase allreduce rows for ``steps`` decode steps, each carrying
    ``n_layer_ar`` per-layer psums + 1 embedding psum of [rows, h].

    Unquantized this is the single aggregate ``(n_layer_ar+1)·steps`` row
    of the paper's Tables; with ``quant`` set the per-layer psums decompose
    into the two-step (``quant_decode_ar_ops``) while the embedding psum —
    which ``parallel_exec`` keeps full-width (its integer-lookup output is
    sparse and cheap; quantizing it buys < 1/(2L) of the bytes) — stays a
    b-byte allreduce."""
    if quant is None:
        return [CommOp("allreduce", "decode", (n_layer_ar + 1) * steps,
                       (rows, h), t, b)]
    return [CommOp("allreduce", "decode", steps, (rows, h), t, b)] + \
        quant_decode_ar_ops("decode", n_layer_ar * steps, rows, h, t,
                            quant, chunk)


# ---------------------------------------------------------------------------
# Eq. 1 — Tensor parallelism
# ---------------------------------------------------------------------------


def tp_comm_ops(cfg: ModelConfig, s_p: int, s_d: int, t: int, *,
                b: int = 2, batch: int = 1,
                gather_mode: str = "gather",
                quant: Optional[str] = None,
                quant_chunk: int = DEFAULT_QUANT_CHUNK) -> List[CommOp]:
    """Pure TP: (2L+1) allreduce per forward pass + per-token logit gather.

    The 2L comes from the two row-parallel linears per layer (attention output
    projection + MLP down-projection); the +1 from the vocab-parallel
    embedding.  Message rows scale with the tokens processed per pass.

    ``quant`` ("int8" | "fp8", DESIGN.md §12) decomposes the *decode-phase
    per-layer* allreduces into the quantized two-step
    (``quant_decode_ar_ops``); prefill rows, the embedding psum and the
    logit gather stay full-width — decode is where the TP wire bytes
    dominate, which is the regime the paper measures and Flash
    Communication attacks.
    """
    if t <= 1:
        return []
    L, h, v = cfg.num_layers, cfg.d_model, cfg.vocab_size
    n_ar = 2 * L + 1
    ops = [
        CommOp("allreduce", "prefill", n_ar, (batch * s_p, h), t, b),
        CommOp("gather", "prefill", 1, (batch * (v // t),), t, b),
    ]
    if s_d > 1:
        ops += _decode_ar_rows(2 * L, s_d - 1, batch * 1, h, t, b,
                               quant, quant_chunk)
        ops += [
            CommOp("gather", "decode", s_d - 1, (batch * (v // t),), t, b),
        ]
    if gather_mode == "allgather":
        ops = [dataclasses.replace(
                   o, collective="allgather",
                   shape=tuple(list(o.shape[:-1]) + [o.shape[-1] * t]))
               if o.collective == "gather" else o for o in ops]
    return ops


def v_tp(cfg: ModelConfig, s_p: int, s_d: int, t: int, b: int = 2) -> float:
    """Eq. 1 in closed form (bytes)."""
    L, h, v = cfg.num_layers, cfg.d_model, cfg.vocab_size
    return ((2 * L + 1) * (s_p + s_d - 1) * h * b * 2 * (t - 1) / t
            + s_d * (v / t) * b)


# ---------------------------------------------------------------------------
# Eq. 2 — Pipeline parallelism
# ---------------------------------------------------------------------------


def pp_comm_ops(cfg: ModelConfig, s_p: int, s_d: int, p: int, *,
                b: int = 2, batch: int = 1, h_shard: int = 1) -> List[CommOp]:
    """Pure PP: 2 tensors per link per pass (hidden states + residual)."""
    if p <= 1:
        return []
    h = cfg.d_model // h_shard
    links = p - 1
    ops = []
    for direction in ("send", "recv"):
        ops.append(CommOp(direction, "prefill", links * 2,
                          (batch * s_p, h), p, b))
        if s_d > 1:
            ops.append(CommOp(direction, "decode", links * 2 * (s_d - 1),
                              (batch * 1, h), p, b))
    return ops


def v_pp(cfg: ModelConfig, s_p: int, s_d: int, p: int, b: int = 2) -> float:
    """Eq. 2 in closed form (bytes)."""
    return (p - 1) * 2 * (s_p + s_d - 1) * cfg.d_model * b


# ---------------------------------------------------------------------------
# Eq. 3–7 — Hybrid TP × PP
# ---------------------------------------------------------------------------


def stage_layer_partition(L: int, p: int) -> List[int]:
    """Layers owned by each pipeline stage; the remainder of an indivisible
    L goes to the *early* stages (stage 0 first), so every layer is always
    assigned.  Shared with ``parallel_exec.stage_layer_range`` — the engine
    and the analytical model must agree on the split."""
    base, rem = divmod(L, p)
    return [base + (1 if s < rem else 0) for s in range(p)]


def hybrid_stage_collectives(cfg: ModelConfig, t: int, p: int,
                             stage: int, c: int = 1,
                             phase: str = "decode",
                             quant: Optional[str] = None) -> dict:
    """Collective *counts per pass* visible in one stage's compiled module
    under the explicit hybrid engine (gather_mode="allgather"): 2·L_s
    allreduces per stage (+1 embedding psum on stage 0), 2 boundary
    redistribute all-gathers on every receiving stage, and the logits
    all-gather on the last stage.  TP counts are identical for a prefill
    pass and a decode pass (only message shapes differ).

    With context parallelism (``c > 1``) a *prefill* pass additionally
    shows the stage's CP ring: 2·L_s·(c-1) collective-permutes (K and V
    rotate around the stage's cp axis each of the c-1 rounds) plus, on the
    last stage, the one allreduce that hands the final position's hidden
    state to the head.  CP is prefill-only — decode passes run replicated
    over the cp axis, so ``phase="decode"`` counts carry no CP term at any
    c (DESIGN.md §9).

    ``quant`` (DESIGN.md §12) applies to *decode* passes only: the stage's
    2·L_s per-layer psums each become one amax allreduce + one quantized
    reducescatter + one allgather, so the stage module shows 2·L_s
    allreduces still (now tiny f32 scale exchanges) plus 2·L_s of each
    two-step half next to the boundary/logit all-gathers; the stage-0
    embedding psum stays full-width.  int4 replaces the reducescatter
    half with the packed-nibble alltoall (``quant_decode_ar_ops``)."""
    L_s = stage_layer_partition(cfg.num_layers, p)[stage]
    counts: dict = {}
    if t > 1:
        counts["allreduce"] = 2 * L_s + (1 if stage == 0 else 0)
        ag = (2 if stage > 0 else 0) + (1 if stage == p - 1 else 0)
        if ag:
            counts["allgather"] = ag
        if quant is not None and phase == "decode":
            if quant == "int4":
                counts["alltoall"] = 2 * L_s
            else:
                counts["reducescatter"] = 2 * L_s
            counts["allgather"] = counts.get("allgather", 0) + 2 * L_s
    if c > 1 and phase == "prefill":
        counts["collectivepermute"] = 2 * L_s * (c - 1)
        if stage == p - 1:
            counts["allreduce"] = counts.get("allreduce", 0) + 1
    return counts


def hybrid_comm_ops(cfg: ModelConfig, s_p: int, s_d: int, t: int, p: int, *,
                    b: int = 2, batch: int = 1,
                    gather_mode: str = "gather",
                    quant: Optional[str] = None,
                    quant_chunk: int = DEFAULT_QUANT_CHUNK) -> List[CommOp]:
    """Hybrid: per-stage allreduce + inter-stage allgather + p2p + gather.

    ``quant`` decomposes the decode-phase per-layer allreduces exactly as
    in ``tp_comm_ops`` (stage-0 rank view: 2·L_0 per-layer psums quantize,
    the embedding psum stays full-width); boundary all-gathers and p2p
    hops are untouched — they already ship 1/t-width shards."""
    if p <= 1:
        return tp_comm_ops(cfg, s_p, s_d, t, b=b, batch=batch,
                           gather_mode=gather_mode, quant=quant,
                           quant_chunk=quant_chunk)
    if t <= 1:
        return pp_comm_ops(cfg, s_p, s_d, p, b=b, batch=batch)
    L, h, v = cfg.num_layers, cfg.d_model, cfg.vocab_size
    # stage-0 rank view: it owns the most layers under the uneven split and
    # carries the embedding allreduce (equals 2L/p + 1 when p divides L)
    n_layer_ar = 2 * stage_layer_partition(L, p)[0]
    n_ar = n_layer_ar + 1
    ops = [
        CommOp("allreduce", "prefill", n_ar, (batch * s_p, h), t, b),
        CommOp("allgather", "prefill", 2 * (p - 1), (batch * s_p, h), t, b),
        CommOp("gather", "prefill", 1, (batch * (v // t),), t, b),
        CommOp("send", "prefill", (p - 1) * 2, (batch * s_p, h // t), p, b),
        CommOp("recv", "prefill", (p - 1) * 2, (batch * s_p, h // t), p, b),
    ]
    if s_d > 1:
        d = s_d - 1
        ops += _decode_ar_rows(n_layer_ar, d, batch * 1, h, t, b,
                               quant, quant_chunk)
        ops += [
            CommOp("allgather", "decode", 2 * (p - 1) * d, (batch * 1, h), t, b),
            CommOp("gather", "decode", d, (batch * (v // t),), t, b),
            CommOp("send", "decode", (p - 1) * 2 * d, (batch * 1, h // t), p, b),
            CommOp("recv", "decode", (p - 1) * 2 * d, (batch * 1, h // t), p, b),
        ]
    if gather_mode == "allgather":
        ops = [dataclasses.replace(
                   o, collective="allgather",
                   shape=tuple(list(o.shape[:-1]) + [o.shape[-1] * t]))
               if o.collective == "gather" else o for o in ops]
    return ops


def v_hybrid_components(cfg: ModelConfig, s_p: int, s_d: int, t: int, p: int,
                        b: int = 2, include_embedding: bool = True) -> dict:
    """Eq. 4–7 in closed form (bytes per component).

    The allreduce term uses the stage-0 layer count of the uneven split
    (== the paper's 2L/p whenever p divides L), keeping the closed form
    equal to the ``hybrid_comm_ops`` per-op sum for every L."""
    L, h, v = cfg.num_layers, cfg.d_model, cfg.vocab_size
    steps = s_p + s_d - 1
    v_ar = (2 * stage_layer_partition(L, p)[0]) * steps * h * b * 2 * (t - 1) / t
    if include_embedding:
        v_ar += steps * h * b * 2 * (t - 1) / t   # first-rank embedding term
    return {
        "allreduce": v_ar,
        "allgather": 2 * (p - 1) * steps * h * b * (t - 1) / t,
        "gather": s_d * (v / t) * b,
        "p2p": (p - 1) * 2 * steps * (h / t) * b,
    }


def v_hybrid(cfg: ModelConfig, s_p: int, s_d: int, t: int, p: int,
             b: int = 2) -> float:
    """Eq. 3 in closed form (bytes)."""
    return sum(v_hybrid_components(cfg, s_p, s_d, t, p, b).values())


def chunked_prefill_ops(cfg: ModelConfig, s_p: int, chunk: int,
                        t: int = 1, p: int = 1, *, b: int = 2,
                        batch: int = 1,
                        gather_mode: str = "gather") -> List[CommOp]:
    """Prefill communication when the prompt is split into fixed-size chunks
    (DESIGN.md §8): ``ceil(s_p / chunk)`` passes, each carrying the SAME
    collective schedule as a full prefill pass — (2L+1) allreduce + 1 logit
    gather under TP, (p-1)·2 boundary sends under PP, the per-stage mix
    under hybrid — with message rows scaled to the chunk's tokens (the final
    chunk may be shorter).  Counts therefore grow linearly with the number
    of chunks while staying batch- and chunk-length-invariant *per chunk*,
    which is what lets the scheduler interleave chunks with decode steps
    without changing any per-step count column.

    The chunked engines compute the logits head every chunk (one uniform
    jitted pass — only the final chunk's argmax is consumed), so the gather
    count is per-chunk too; total allreduce bytes equal the monolithic
    prefill's exactly, the gather bytes exceed it by (n_chunks - 1) calls.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    sizes = [chunk] * (s_p // chunk)
    if s_p % chunk:
        sizes.append(s_p % chunk)
    ops: List[CommOp] = []
    for c in sorted(set(sizes), reverse=True):
        n = sizes.count(c)
        per_pass = hybrid_comm_ops(cfg, c, 1, t, p, b=b, batch=batch,
                                   gather_mode=gather_mode)
        ops += [dataclasses.replace(o, count=o.count * n)
                for o in per_pass if o.phase == "prefill"]
    return ops


# ---------------------------------------------------------------------------
# Context parallelism — sequence-sharded prefill (DESIGN.md §9)
# ---------------------------------------------------------------------------


def cp_shard_len(s_p: int, c: int) -> int:
    """Per-worker sequence shard of a CP prefill: the engines pad the prompt
    to a multiple of c, so every shard carries ``ceil(s_p / c)`` positions."""
    return -(-s_p // c)


def cp_comm_ops(cfg: ModelConfig, s_p: int, c: int, *, t: int = 1,
                b: int = 2, batch: int = 1) -> List[CommOp]:
    """Context-parallel prefill: per layer a ring exchange of the K/V blocks
    over the c sequence shards, aggregated over all pipeline stages (the
    same convention as the hybrid p2p rows).

    Each of the c-1 ring rounds moves TWO tensors (K and V) of one shard's
    [batch · s_p/c, kv_heads/t · head_dim] block per worker — kv heads stay
    TP-sharded, so CP composes with TP without touching its collectives —
    for 2·L·(c-1) collective-permutes per pass.  One extra [batch, h]
    allreduce over the CP group hands the last position's hidden state to
    the logits head (the position lives on one shard; the head runs
    replicated).  CP is prefill-only: decode runs replicated over the cp
    axis and contributes no decode-phase ops here (DESIGN.md §9).
    """
    if c <= 1:
        return []
    L, h = cfg.num_layers, cfg.d_model
    shard = cp_shard_len(s_p, c)
    kv_elems = (cfg.num_kv_heads // t) * cfg.head_dim
    return [
        CommOp("collectivepermute", "prefill", 2 * L * (c - 1),
               (batch * shard, kv_elems), c, b),
        CommOp("allreduce", "prefill", 1, (batch, h), c, b),
    ]


def v_cp(cfg: ModelConfig, s_p: int, c: int, t: int = 1, b: int = 2) -> float:
    """CP ring volume in closed form (bytes): 2L(c-1) blocks of
    ceil(s_p/c)·kv/t·D plus the last-hidden allreduce."""
    return total_volume(cp_comm_ops(cfg, s_p, c, t=t, b=b))


# ---------------------------------------------------------------------------
# Beyond-paper extensions
# ---------------------------------------------------------------------------


def moe_comm_ops(cfg: ModelConfig, s_p: int, s_d: int, e: int, *,
                 b: int = 2, batch: int = 1) -> List[CommOp]:
    """Expert-parallel all-to-all (paper §VII future work).

    Per MoE layer and forward pass: one dispatch and one combine all-to-all;
    each token is replicated to its top_k experts, so the message carries
    tokens × top_k rows of h.
    """
    if cfg.moe is None or e <= 1:
        return []
    L, h, k = cfg.num_layers, cfg.d_model, cfg.moe.top_k
    ops = [CommOp("alltoall", "prefill", 2 * L, (batch * s_p * k, h), e, b)]
    if s_d > 1:
        ops.append(CommOp("alltoall", "decode", 2 * L * (s_d - 1),
                          (batch * k, h), e, b))
    return ops


def ssm_pp_state_ops(cfg: ModelConfig, s_d: int, p: int, *, b: int = 2,
                     batch: int = 1) -> List[CommOp]:
    """RWKV/SSM pipeline hand-off: the recurrent state never moves (it is
    layer-local), so PP transfers are identical to dense PP — except that an
    engine migrating a request between stage replicas must ship the state:
    [H, hs, hs] per layer.  Exposed for capacity planning; zero by default in
    steady-state serving."""
    if cfg.ssm is None or p <= 1:
        return []
    H, hs = cfg.num_heads, cfg.ssm.head_size
    per_stage_layers = stage_layer_partition(cfg.num_layers, p)[0]
    return [CommOp("send", "decode", 1,
                   (batch * per_stage_layers * H, hs, hs), p, 4)]


def comm_ops_for(cfg: ModelConfig, s_p: int, s_d: int, t: int = 1, p: int = 1,
                 e: int = 1, *, c: int = 1, b: int = 2, batch: int = 1,
                 gather_mode: str = "gather", quant: Optional[str] = None,
                 quant_chunk: int = DEFAULT_QUANT_CHUNK) -> List[CommOp]:
    """Full per-architecture comm prediction: paper terms + extensions.

    Encoder-only architectures have no decode phase (s_d forced to 1); MoE
    architectures add expert-parallel all-to-all when e > 1.  Context
    parallelism (``c > 1``, DESIGN.md §9) shards the *prefill* sequence
    axis: the TP/PP prefill rows shrink to the ceil(s_p/c) shard each rank
    actually processes, the CP ring rows (``cp_comm_ops``) are added, and
    decode rows are untouched — decode runs replicated over the cp axis.
    ``quant`` ("int8" | "fp8", DESIGN.md §12) decomposes the decode-phase
    per-layer TP allreduces into the quantized two-step with ``quant_chunk``
    elements per f32 scale block.
    """
    if not cfg.is_decoder:
        s_d = 1
    s_eff = cp_shard_len(s_p, c) if c > 1 else s_p
    ops = hybrid_comm_ops(cfg, s_eff, s_d, t, p, b=b, batch=batch,
                          gather_mode=gather_mode, quant=quant,
                          quant_chunk=quant_chunk)
    ops += cp_comm_ops(cfg, s_p, c, t=t, b=b, batch=batch)
    ops += moe_comm_ops(cfg, s_eff, s_d, e, b=b, batch=batch)
    return ops


def preemption_recompute_ops(cfg: ModelConfig, prefix_len: int, t: int = 1,
                             p: int = 1, *, c: int = 1, b: int = 2,
                             batch: int = 1,
                             gather_mode: str = "gather") -> List[CommOp]:
    """Collectives of ONE preemption's recompute pass (DESIGN.md §10).

    Preemption-by-recompute re-admits an evicted request by re-prefilling
    its prompt + generated prefix (``prefix_len`` positions) in one
    monolithic pass — so the recovery cost is exactly a prefill's
    communication, with no decode rows: the prefill-phase rows of
    ``comm_ops_for`` at ``s_p = prefix_len``.  The scheduler logs these
    counts on each phase="recompute" StepRecord, extending the house
    invariant (predicted == compiled == measured) to the failure path.
    """
    ops = comm_ops_for(cfg, prefix_len, 1, t, p, c=c, b=b, batch=batch,
                       gather_mode=gather_mode)
    return [o for o in ops if o.phase == "prefill"]


# ---------------------------------------------------------------------------
# Cross-request prefix caching — skipped vs executed prefill (DESIGN.md §13)
# ---------------------------------------------------------------------------


def _agg_counts(ops: List[CommOp]) -> dict:
    counts: dict = {}
    for o in ops:
        counts[o.collective] = counts.get(o.collective, 0) + o.count
    return counts


@dataclasses.dataclass(frozen=True)
class PrefixCacheOps:
    """Executed-vs-skipped prefill communication of ONE cache-hit request
    (DESIGN.md §13): ``executed`` is what the suffix prefill actually
    issues — the rows the compiled HLO and the scheduler's phase="prefill"
    StepRecords must match — and ``cold`` is what the same request would
    have issued with no hit; the *savings* are their difference."""

    hit_len: int
    suffix_len: int
    executed: List[CommOp]
    cold: List[CommOp]

    @property
    def executed_counts(self) -> dict:
        return _agg_counts(self.executed)

    @property
    def cold_counts(self) -> dict:
        return _agg_counts(self.cold)

    @property
    def skipped_counts(self) -> dict:
        ex = self.executed_counts
        return {k: v - ex.get(k, 0) for k, v in self.cold_counts.items()}

    @property
    def executed_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.executed)

    @property
    def cold_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.cold)

    @property
    def skipped_bytes(self) -> float:
        return self.cold_bytes - self.executed_bytes


def prefix_cache_ops(cfg: ModelConfig, hit_len: int, suffix_len: int,
                     chunk: Optional[int] = None, t: int = 1, p: int = 1,
                     *, b: int = 2, batch: int = 1,
                     gather_mode: str = "gather") -> PrefixCacheOps:
    """Closed-form skipped-vs-executed prefill collectives for a request
    whose first ``hit_len`` positions came out of the prefix index and
    whose remaining ``suffix_len`` positions were prefilled (DESIGN.md
    §13).  ``chunk`` mirrors the scheduler's chunked prefill: the suffix
    splits at ``hit_len + k·chunk`` into ``ceil(suffix_len / chunk)``
    passes — exactly ``chunked_prefill_ops`` over the suffix, because
    per-chunk counts are chunk-length-invariant; ``chunk=None`` is the
    monolithic path (``prefill_whole(start=hit)``, one maximal chunk).
    Counts stay batch-invariant for the same reason every other count
    column in this module does: no count term carries a token or batch
    factor, only message bytes scale.

    ``hit_len = 0`` degenerates to ``executed == cold`` (a miss skips
    nothing), so callers can price a whole trace by mixing per-request
    hit lengths without special-casing misses.
    """
    if hit_len < 0 or suffix_len < 1:
        raise ValueError(
            f"need hit_len >= 0 and suffix_len >= 1 (the final position is "
            f"always prefilled), got {hit_len}/{suffix_len}")
    executed = chunked_prefill_ops(
        cfg, suffix_len, chunk if chunk else suffix_len, t, p, b=b,
        batch=batch, gather_mode=gather_mode)
    total = hit_len + suffix_len
    cold = chunked_prefill_ops(
        cfg, total, chunk if chunk else total, t, p, b=b, batch=batch,
        gather_mode=gather_mode)
    return PrefixCacheOps(hit_len=hit_len, suffix_len=suffix_len,
                          executed=executed, cold=cold)


# ---------------------------------------------------------------------------
# Disaggregated prefill/decode — the KV-page handoff transfer (DESIGN.md §14)
# ---------------------------------------------------------------------------


def kv_page_bytes(cfg: ModelConfig, page_size: int, b: int = 2) -> int:
    """Device bytes of ONE KV page across every layer: each layer's page
    holds ``page_size × kv_heads × head_dim`` K rows plus the same V rows,
    so the unit the disaggregated handoff ships is
    ``2 · L · page_size · kv · D · b`` — the exact footprint a
    ``KVPool`` page occupies in each backend's [L, P, ps, kv, D] pools."""
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    return 2 * cfg.num_layers * page_size * cfg.num_kv_heads \
        * cfg.head_dim * b


def kv_handoff_pages(prompt_len: int, page_size: int) -> int:
    """Closed-form page count of ONE request's prefill→decode handoff:
    exactly the prompt's FULL blocks — what ``PrefixIndex.insert`` indexes
    (a partial tail page keeps being rewritten by the suffix prefill and
    decode, so it never ships; the decode pool recomputes it).  This is the
    single source for both the predicted side (``kv_handoff_ops``,
    ``slo.predict_slo``'s interconnect term) and the measured side (the
    scheduler ships the pages a lookup of the freshly inserted prompt
    returns)."""
    if prompt_len < 0:
        raise ValueError(f"prompt_len must be >= 0, got {prompt_len}")
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    return prompt_len // page_size


def kv_handoff_ops(cfg: ModelConfig, pages: int, page_size: int, *,
                   b: int = 2, count: int = 1) -> List[CommOp]:
    """The disaggregated prefill→decode KV handoff as a modeled transfer
    (DESIGN.md §14): when the prefill pool finishes a prompt, its ``pages``
    full KV pages cross the pool interconnect to the decode pool — one
    send/recv pair per handed-off request, ``bytes = pages × page_bytes``
    with no wire-factor discount (a p2p copy ships every byte once, like
    the PP boundary rows).  The scheduler logs exactly these rows on each
    phase="handoff" StepRecord, so measured handoff transfers can be
    asserted equal to this closed form the same way boundary transfers
    match ``pp_comm_ops``."""
    if pages < 0:
        raise ValueError(f"pages must be >= 0, got {pages}")
    shape = (pages, 2 * cfg.num_layers * page_size
             * cfg.num_kv_heads * cfg.head_dim)
    return [CommOp("send", "handoff", count, shape, 2, b),
            CommOp("recv", "handoff", count, shape, 2, b)]


# ---------------------------------------------------------------------------
# Dynamic pipeline schedules — instruction counts + ticks (DESIGN.md §11)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PPScheduleStats:
    """Closed-form shape of a drain-first dynamic PP decode schedule
    (runtime/schedule.py): ``depth`` microbatch groups, each decoding
    ``rounds`` tokens through ``p`` stages, at most one StageForward per
    stage per tick, deepest stage first.

    Tick count: group g's round r occupies stage s at tick
    ``r·max(p, depth) + g + s`` — consecutive rounds of one group are
    ``max(p, depth)`` ticks apart (at depth ≥ p the pipeline is saturated
    and a group must wait for its own previous round; at depth < p the
    round trip through p stages dominates).  The last group's last round
    leaves stage p-1 at tick ``(rounds-1)·max(p, depth) + (depth-1) +
    (p-1)``, so the makespan is::

        M = rounds · max(p, depth) + min(p, depth) − 1

    Per-stage busy ticks are ``depth · rounds`` (every round visits every
    stage once), giving busy fraction ``depth·rounds / M`` → ``depth/p``
    of the stages' time at depth < p, → 1 as depth ≥ p — the bubble-
    occupancy term of ``slo.predict_slo(inflight=...)``.
    """
    p: int
    depth: int
    rounds: int

    @property
    def stage_forwards(self) -> Tuple[int, ...]:
        """StageForward instructions issued per stage."""
        return (self.depth * self.rounds,) * self.p

    @property
    def boundary_sends(self) -> int:
        """BoundarySend instructions (== BoundaryRecv): (p-1) links per
        round, each shipping the 2-tensor summand pair."""
        return (self.p - 1) * 2 * self.depth * self.rounds

    @property
    def samples(self) -> int:
        """SampleToken instructions — one per completed round."""
        return self.depth * self.rounds

    @property
    def ticks(self) -> int:
        """Schedule makespan M (0 when nothing decodes)."""
        if self.rounds == 0 or self.depth == 0:
            return 0
        return self.rounds * max(self.p, self.depth) \
            + min(self.p, self.depth) - 1

    @property
    def busy_fraction(self) -> float:
        """Per-stage (uniform) fraction of ticks spent busy."""
        t = self.ticks
        return self.depth * self.rounds / t if t else 0.0


def pp_schedule_stats(p: int, depth: int, rounds: int) -> PPScheduleStats:
    """Predicted instruction counts / ticks / occupancy of a dynamic PP
    decode schedule at in-flight ``depth`` over ``rounds`` decode rounds
    per group.  Pinned == the executed queue's instruction log and tick
    counters (tests/test_schedule.py) == the pp-occupancy bench series."""
    if p < 1 or depth < 0 or rounds < 0:
        raise ValueError(f"invalid schedule: p={p} depth={depth} "
                         f"rounds={rounds}")
    return PPScheduleStats(p=p, depth=depth, rounds=rounds)


def pp_schedule_ops(cfg: ModelConfig, depth: int, rounds: int, p: int, *,
                    t: int = 1, b: int = 2, group: int = 1) -> List[CommOp]:
    """Boundary transfers of a dynamic PP decode schedule (DESIGN.md §11).

    Every round still ships the PP closed form — (p-1) links × 2 tensors
    of [group, h/t] — so wire bytes *per token* are depth-invariant while
    tick throughput scales toward ×p: filling the bubble is free on the
    wire, which is the paper's PP-bytes-vs-latency tradeoff closing.
    """
    if p <= 1 or depth * rounds == 0:
        return []
    n = depth * rounds
    h = cfg.d_model // t
    return [CommOp(direction, "decode", (p - 1) * 2 * n,
                   (group * 1, h), p, b)
            for direction in ("send", "recv")]
