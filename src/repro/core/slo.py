"""α–β SLO predictor: TTFT / TPOT / E2E per parallelism layout (paper §V-C).

The paper *measures* SLOs on 4×H100 nodes running vLLM V0 (eager mode, custom
allreduce off).  We cannot measure wall time in this container, so this module
is the analytical counterpart: per-phase compute/memory terms + per-collective
α–β latencies + engine overheads.

Calibration: the engine-overhead constants below are FITTED to the paper's
published curves (Figs 8–10) and documented as such — the paper itself pairs
analytical models with measured validation; we invert the direction.  The
calibrated model reproduces (asserted in tests/test_slo.py):
  * Fig 8 — TTFT monotonically improves TP2→TP4→TP8; TPOT/E2E degrade badly
    once the TP group crosses nodes (TP8),
  * Fig 9 — PP TTFT grows with pipeline depth; TPOT jumps when a pipeline
    link crosses nodes (PP8),
  * Fig 10 — TP8 beats PP8 and hybrids on TTFT for Llama-2-13B.
Known residual: the paper's catastrophic TP4×PP2 outlier (15.15 s E2E) is a
configuration pathology the paper reports without a mechanism; the analytical
model predicts it close to TP2×PP4, not catastrophic (EXPERIMENTS.md §SLO).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.config.base import HardwareProfile, H100_NODE, ModelConfig
from repro.core.commodel import DEFAULT_QUANT_CHUNK, CommOp, comm_ops_for, \
    cp_comm_ops, cp_shard_len, kv_handoff_ops


@dataclasses.dataclass(frozen=True)
class EngineOverheads:
    """vLLM-V0-like engine constants (fitted to paper Figs 8–10)."""

    request_overhead: float = 10e-3       # scheduling + tokenize per request
    prefill_eff_base: float = 0.005       # eager-mode effective MFU @3.6B
    prefill_eff_ref_params: float = 3.6e9
    decode_hbm_eff: float = 1.0           # decode streams weights ~at HBM bw
    per_layer_launch: float = 20e-6       # per layer per decode step (eager)
    stage_overhead_prefill: float = 150e-3  # per pipeline stage per prefill
    stage_overhead_decode: float = 0.2e-3   # per stage per decode step
    cross_link_decode_overhead: float = 6e-3  # per cross-node pipeline link
    cp_round_overhead: float = 50e-6  # per CP ring round per layer: the
    #   eager-mode launch/sync of the blocking permute chain (DESIGN.md §9)
    #   — what makes CP pure overhead on short prompts, amortized on long


DEFAULT_OVERHEADS = EngineOverheads()


@dataclasses.dataclass
class SLOReport:
    ttft: float
    tpot: float
    e2e: float
    comm_volume: float
    breakdown: Dict[str, float]
    occupancy: float = 1.0

    def row(self) -> str:
        return (f"TTFT {self.ttft*1e3:8.1f} ms  TPOT {self.tpot*1e3:7.2f} ms  "
                f"E2E {self.e2e:6.2f} s  comm {self.comm_volume/2**20:8.1f} MiB")


def _prefill_eff(n_params: float, ov: EngineOverheads) -> float:
    return min(0.2, ov.prefill_eff_base
               * math.sqrt(n_params / ov.prefill_eff_ref_params))


def _collective_time(op: CommOp, hw: HardwareProfile, cross: bool) -> float:
    bw = hw.inter_bw if cross else hw.intra_bw
    alpha = hw.inter_alpha if cross else hw.intra_alpha
    return op.count * alpha + op.wire_bytes / bw


def split_p2p_count(count: int, p: int, cross_links: int):
    """Split a p2p call count between intra- and cross-node pipeline links.

    ``cross_links`` of the ``p - 1`` links cross nodes; rounding is guarded
    so the two parts are each in [0, count] and ALWAYS sum to ``count`` —
    the naive ``int(count * (1 - frac))`` truncation silently shifts calls
    from the intra to the (α-heavier) cross bucket.
    """
    if p <= 1 or cross_links <= 0:
        return count, 0
    frac_cross = min(cross_links / (p - 1), 1.0)
    cross = min(count, max(0, round(count * frac_cross)))
    return count - cross, cross


def predict_slo(cfg: ModelConfig, s_p: int, s_d: int, t: int = 1, p: int = 1,
                hw: HardwareProfile = H100_NODE,
                ov: EngineOverheads = DEFAULT_OVERHEADS,
                batch: int = 1, dtype_bytes: int = 2,
                c: int = 1, inflight: int = 1, quant: str = None,
                quant_chunk: int = DEFAULT_QUANT_CHUNK,
                hit_rate: float = 0.0,
                hit_len: int = None,
                handoff_pages: int = 0,
                page_size: int = 16) -> SLOReport:
    """Predict TTFT/TPOT/E2E for a (t, c, p) layout of one inference
    request.  Context parallelism (``c > 1``, DESIGN.md §9) divides the
    prefill compute over t·c workers and adds the per-layer ring latency
    (``commodel.cp_comm_ops``: 2L(c-1) permutes + 1 cp allreduce) to the
    prefill communication; decode terms are untouched — the cp workers
    replicate decode, so CP buys TTFT on long prompts and is pure overhead
    on short ones (and on TPOT always).

    ``inflight`` is the dynamic-schedule microbatch depth (DESIGN.md §11):
    with d groups in flight a p-stage pipeline keeps each stage busy
    ``occ = min(d, p)/p`` of the time, so the *per-request* decode cadence
    improves from one token per p stage-steps to one per ``p·occ`` —
    ``tpot_effective = tpot / (occ · p)`` with tpot the single-request
    serialized value.  At ``inflight=1`` every term is bitwise the old
    report (occ·p = 1 only when p = 1; for p > 1 occ = 1/p and
    tpot_effective = tpot exactly, since tpot already serializes stages).

    ``quant`` ("int8" | "fp8", DESIGN.md §12) prices the decode-phase
    per-layer TP allreduces at the quantized two-step decomposition
    (``comm_ops_for(quant=...)``).  Latency model: a ring allreduce IS a
    reduce-scatter + all-gather internally, and Flash Communication fuses
    the amax exchange into the quantize kernel's launch — so each
    quantized AR is charged ONE α (carried by its amax-allreduce row; the
    1-byte payload rows are bytes-only), the same launch cost as the
    full-width AR it replaces.  The win is therefore pure wire bytes
    (~w/b + scale overhead of the original), which lands exactly where
    the paper says TP hurts: bandwidth-bound decode at large t.

    ``hit_rate`` (DESIGN.md §13) prices cross-request prefix caching: a
    fraction ``hit_rate`` of requests find their first ``hit_len`` prompt
    positions in the index (default: the whole prompt minus the final
    position — a fully shared template) and prefill only the suffix, so
    their TTFT is the TTFT of a ``s_p - hit_len``-token request on the
    same layout.  The report mixes the cold and hit terms linearly;
    ``hit_rate=0`` is bitwise the uncached report.  Decode terms never
    move — the cache skips prefill only — which is exactly why the
    planner's ranking shifts under template-heavy traffic: layouts that
    buy prefill time (CP's ring, prefill-lean PP splits) lose their edge
    when prefill is mostly skipped, while decode-bound layouts keep
    theirs.

    ``handoff_pages`` (DESIGN.md §14) prices disaggregated admission: the
    request's prompt KV was prefilled on a SEPARATE pool and its full
    blocks cross the interconnect before this layout's decode starts —
    TTFT gains one cross-node α plus ``handoff_pages`` × page bytes at
    ``hw.inter_bw`` (``commodel.kv_handoff_ops``), and the bytes join
    ``comm_volume``.  ``handoff_pages=0`` is bitwise the colocated
    report."""
    if not 0.0 <= hit_rate <= 1.0:
        raise ValueError(f"hit_rate must be in [0, 1], got {hit_rate}")
    if handoff_pages < 0:
        raise ValueError(
            f"handoff_pages must be >= 0, got {handoff_pages}")
    if hit_rate > 0.0:
        hit = s_p - 1 if hit_len is None else int(hit_len)
        if not 1 <= hit < s_p:
            raise ValueError(
                f"hit_len must be in [1, s_p) — the final position is "
                f"always prefilled — got {hit} at s_p={s_p}")
        # the handoff term rides through both legs: mixing is linear, so
        # the constant addend survives exactly once
        cold = predict_slo(cfg, s_p, s_d, t, p, hw=hw, ov=ov, batch=batch,
                           dtype_bytes=dtype_bytes, c=c, inflight=inflight,
                           quant=quant, quant_chunk=quant_chunk,
                           handoff_pages=handoff_pages, page_size=page_size)
        hot = predict_slo(cfg, s_p - hit, s_d, t, p, hw=hw, ov=ov,
                          batch=batch, dtype_bytes=dtype_bytes, c=c,
                          inflight=inflight, quant=quant,
                          quant_chunk=quant_chunk,
                          handoff_pages=handoff_pages, page_size=page_size)
        mix = lambda a, b: (1.0 - hit_rate) * a + hit_rate * b
        breakdown = dict(cold.breakdown)
        breakdown.update({"hit_rate": hit_rate, "hit_len": hit,
                          "ttft_cold": cold.ttft, "ttft_hit": hot.ttft})
        return SLOReport(mix(cold.ttft, hot.ttft), cold.tpot,
                         mix(cold.e2e, hot.e2e),
                         mix(cold.comm_volume, hot.comm_volume),
                         breakdown, occupancy=cold.occupancy)
    n_active = cfg.active_param_count()
    world = t * c * p
    nodes = max(1, math.ceil(world / hw.intra_degree))
    # placement puts each TP group on contiguous chips, so TP collectives
    # cross nodes only when t itself outgrows the fast domain; the CP ring
    # wraps the t-groups and crosses once the t·c stage group does
    tp_cross = t > hw.intra_degree
    cp_cross = t * c > hw.intra_degree
    cross_links = max(0, min(p - 1, nodes - 1)) if p > 1 else 0

    # CP ring ops timed separately (they cross at t·c, the rest at t)
    cp_ops = cp_comm_ops(cfg, s_p, c, t=t, b=dtype_bytes, batch=batch)
    qkw = dict(quant=quant, quant_chunk=quant_chunk)
    ops = comm_ops_for(cfg, s_p, s_d, t, p, batch=batch, b=dtype_bytes,
                       **qkw) \
        if c == 1 else comm_ops_for(cfg, cp_shard_len(s_p, c), s_d, t, p,
                                    batch=batch, b=dtype_bytes, **qkw)
    comm_volume = sum(o.wire_bytes for o in ops + cp_ops)

    def phase_comm(phase: str) -> float:
        total = 0.0
        for o in cp_ops:
            if o.phase == phase:
                total += _collective_time(o, hw, cp_cross)
        for o in ops:
            if o.phase != phase:
                continue
            if o.collective in ("send", "recv"):
                if o.collective == "recv":
                    continue
                # split p2p count between intra and cross links (guarded
                # rounding: parts always sum to o.count)
                n_intra, n_cross = split_p2p_count(o.count, p, cross_links)
                intra = dataclasses.replace(o, count=n_intra)
                cross = dataclasses.replace(o, count=n_cross)
                total += _collective_time(intra, hw, False)
                total += _collective_time(cross, hw, True)
            elif (quant is not None and o.dtype_bytes <= 1
                  and o.collective in ("reducescatter", "allgather",
                                       "alltoall")):
                # quantized two-step payload rows (1-byte int8/fp8, or the
                # half-byte int4 alltoall/allgather pair): bytes-only — the
                # α is carried once per quantized AR by the amax-allreduce
                # row (see the quant paragraph in the docstring)
                bw = hw.inter_bw if tp_cross else hw.intra_bw
                total += o.wire_bytes / bw
            else:
                total += _collective_time(o, hw, tp_cross)
        return total

    # disaggregated admission (DESIGN.md §14): KV pages cross from the
    # prefill pool before decode starts — one cross-node α (the transfer
    # is a single batched send) plus the pages' wire bytes
    handoff_s = 0.0
    if handoff_pages:
        handoff_bytes = float(sum(
            o.wire_bytes for o in kv_handoff_ops(cfg, handoff_pages,
                                                 page_size,
                                                 b=dtype_bytes)))
        handoff_s = hw.inter_alpha + handoff_bytes / hw.inter_bw
        comm_volume += handoff_bytes

    eff = _prefill_eff(n_active, ov)
    prefill_flops = 2 * n_active * s_p * batch
    # PP serializes stages: compute parallelism over the t·c stage group
    # (CP shards the prefill sequence — each worker runs s_p/c positions)
    prefill_compute = prefill_flops / (max(t * c, 1) * hw.peak_flops * eff)
    ttft = (ov.request_overhead + prefill_compute + phase_comm("prefill")
            + handoff_s
            + (p * ov.stage_overhead_prefill if p > 1 else 0.0)
            + (2 * cfg.num_layers * (c - 1) * ov.cp_round_overhead
               if c > 1 else 0.0))

    # decode: weight streaming at HBM bandwidth; stages serialized
    param_bytes = n_active * dtype_bytes
    decode_compute = param_bytes / (max(t, 1) * hw.hbm_bw * ov.decode_hbm_eff)
    decode_comm = phase_comm("decode") / max(s_d - 1, 1)
    tpot = (decode_compute + cfg.num_layers * ov.per_layer_launch
            + (p * ov.stage_overhead_decode if p > 1 else 0.0)
            + cross_links * ov.cross_link_decode_overhead + decode_comm)

    # dynamic-schedule occupancy (DESIGN.md §11): depth-d in-flight
    # microbatching fills d of the p bubble slots, so the effective
    # per-request token cadence divides by the filled fraction × depth.
    depth_eff = min(max(1, int(inflight)), p)
    occ = depth_eff / p if p > 1 else 1.0
    tpot_effective = tpot / depth_eff if p > 1 else tpot

    e2e = ttft + max(s_d - 1, 0) * tpot_effective
    breakdown = {
        "prefill_compute": prefill_compute,
        "prefill_comm": phase_comm("prefill"),
        "decode_compute": decode_compute,
        "decode_comm_per_tok": decode_comm,
        "pp_occupancy": occ, "tpot_effective": tpot_effective,
        "nodes": nodes, "tp_cross": tp_cross, "cross_links": cross_links,
    }
    if handoff_pages:
        breakdown["handoff_s"] = handoff_s
        breakdown["handoff_bytes"] = handoff_bytes
    return SLOReport(ttft, tpot, e2e, comm_volume, breakdown, occupancy=occ)


# ---------------------------------------------------------------------------
# goodput under overload (DESIGN.md §10)
# ---------------------------------------------------------------------------


def recompute_time(cfg: ModelConfig, prefix_len: int, t: int = 1, p: int = 1,
                   hw: HardwareProfile = H100_NODE,
                   ov: EngineOverheads = DEFAULT_OVERHEADS,
                   batch: int = 1, dtype_bytes: int = 2,
                   c: int = 1) -> float:
    """Wall time of ONE preemption's recompute pass: the TTFT of a
    ``prefix_len``-token prefill minus the per-request frontend overhead
    (the request is already tokenized and scheduled — recovery re-runs the
    model, not the frontend).  The communication inside is
    ``commodel.preemption_recompute_ops``."""
    rep = predict_slo(cfg, prefix_len, 1, t, p, hw=hw, ov=ov, batch=batch,
                      dtype_bytes=dtype_bytes, c=c)
    return max(0.0, rep.ttft - ov.request_overhead)


@dataclasses.dataclass
class GoodputReport:
    """Predicted serving capacity of one admission policy under a given
    request mix and KV-cache budget."""

    concurrency: int          # requests decoding at once (slot- or page-bound)
    preempt_rate: float       # expected preemptions per request
    recompute_s: float        # wall cost of one recompute pass
    service_s: float          # per-request service time incl. recovery
    goodput_tok_s: float      # useful tokens completed per second
    breakdown: Dict[str, float]

    def row(self) -> str:
        return (f"conc {self.concurrency:3d}  preempt/req "
                f"{self.preempt_rate:5.2f}  service {self.service_s:6.3f} s  "
                f"goodput {self.goodput_tok_s:8.1f} tok/s")


def predict_goodput(cfg: ModelConfig, s_p: int, s_d: int, *,
                    num_slots: int, capacity_tokens: int,
                    eos_mean: float = None, admission: str = "conservative",
                    t: int = 1, p: int = 1,
                    hw: HardwareProfile = H100_NODE,
                    ov: EngineOverheads = DEFAULT_OVERHEADS,
                    dtype_bytes: int = 2, c: int = 1,
                    inflight: int = 1, quant: str = None,
                    quant_chunk: int = DEFAULT_QUANT_CHUNK) -> GoodputReport:
    """Goodput of a slot/page-bound serving engine under overload.

    The request mix decodes ``eos_mean`` tokens on average (early stop;
    defaults to the full budget ``s_d``) but commits ``s_d`` at admission.
    Conservative admission reserves each request's worst case
    (``s_p + s_d - 1`` cache positions), so concurrency is bound by
    ``capacity_tokens // worst`` even though most requests never grow that
    far — the stranded-capacity effect.  Optimistic admission packs by the
    *actual* footprint (``s_p + eos_mean - 1``) and pays for the
    overcommit with preemptions: when the expected live footprint
    ``concurrency × actual`` exceeds capacity, the overflow fraction is
    recovered by recompute passes of the mean preempted prefix
    (``recompute_time``).  Goodput divides useful tokens by the per-request
    service time including that recovery tax — the quantity the overload
    series of benchmarks/serving_bench.py measures."""
    if admission not in ("conservative", "optimistic"):
        raise ValueError(f"unknown admission policy {admission!r}")
    n_eff = float(s_d if eos_mean is None else min(eos_mean, s_d))
    if n_eff < 1:
        raise ValueError(f"eos_mean must be >= 1, got {eos_mean}")
    worst = s_p + s_d - 1
    actual = s_p + n_eff - 1.0
    if admission == "conservative":
        concurrency = min(num_slots, capacity_tokens // worst)
        preempt_rate = 0.0
    else:
        # optimistic admits on CURRENT need (the prompt's pages) — so the
        # admitted set is prompt-bound, its live footprint can overflow
        # capacity, and the overflow is recovered by preemption
        admitted = min(num_slots, int(capacity_tokens // s_p))
        preempt_rate = max(0.0, admitted * actual / capacity_tokens - 1.0)
        # steady-state decoding set is what the actual footprint sustains
        concurrency = min(admitted, int(capacity_tokens // actual))
    concurrency = max(concurrency, 1) if capacity_tokens >= worst else 0
    if concurrency == 0:
        return GoodputReport(0, 0.0, 0.0, float("inf"), 0.0,
                             {"worst_tokens": worst, "actual_tokens": actual})
    base = predict_slo(cfg, s_p, int(round(n_eff)), t, p, hw=hw, ov=ov,
                       batch=concurrency, dtype_bytes=dtype_bytes, c=c,
                       inflight=inflight, quant=quant,
                       quant_chunk=quant_chunk)
    # a preemption strikes mid-decode: mean recomputed prefix is the prompt
    # plus half the decoded tokens
    rec = recompute_time(cfg, int(s_p + n_eff / 2), t, p, hw=hw, ov=ov,
                         dtype_bytes=dtype_bytes, c=c)
    service = (base.ttft
               + max(int(round(n_eff)) - 1, 0)
               * base.breakdown["tpot_effective"]
               + preempt_rate * rec)
    goodput = concurrency * n_eff / service
    return GoodputReport(
        concurrency=int(concurrency), preempt_rate=preempt_rate,
        recompute_s=rec, service_s=service, goodput_tok_s=goodput,
        breakdown={"worst_tokens": float(worst), "actual_tokens": actual,
                   "e2e_s": base.e2e, "recovery_s": preempt_rate * rec,
                   "pp_occupancy": base.occupancy})
