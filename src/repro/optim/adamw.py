"""Minimal AdamW (fp32 state) + cosine LR schedule, pure pytree functional."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable = cosine_schedule(3e-4, 100, 10_000)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        m = jax.tree.map(lambda m_, g: self.b1 * m_ + (1 - self.b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: self.b2 * v_ + (1 - self.b2) * g * g,
                         state["v"], grads)
        sf = jnp.asarray(step, jnp.float32)
        bc1 = 1 - self.b1 ** sf
        bc2 = 1 - self.b2 ** sf
        lr = self.lr(step)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}, {
            "grad_norm": gnorm, "lr": lr}
