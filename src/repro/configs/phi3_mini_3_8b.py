"""Phi-3-mini 3.8B — dense, RoPE + SwiGLU, MHA-as-GQA (kv=32) [arXiv:2404.14219]."""
from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    activation="swiglu",
    rope_theta=10_000.0,
    citation="arXiv:2404.14219 (Phi-3 Technical Report)",
)
