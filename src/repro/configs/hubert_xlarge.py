"""HuBERT X-Large — encoder-only audio transformer (w2v2 backbone) [arXiv:2106.07447].

Assignment carve-out: the conv/mel frontend is a stub — ``input_specs`` feeds
precomputed frame embeddings of shape (batch, frames, d_model).  Encoder-only:
no decode phases (decode_32k / long_500k skipped, see DESIGN.md).
"""
from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,          # k-means target codebook
    activation="gelu",
    frontend="audio_stub",
    is_decoder=False,
    citation="arXiv:2106.07447 (HuBERT)",
)
