"""Hymba 1.5B — hybrid-head: parallel attention + mamba heads per layer [arXiv:2411.13676]."""
from repro.config.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    activation="swiglu",
    sliding_window=1024,     # hymba uses SWA on most layers
    attn_head_fraction=0.5,  # heads split between attention and SSM paths
    ssm=SSMConfig(state_size=16, kind="mamba", head_size=64),
    citation="arXiv:2411.13676 (Hymba)",
)
