"""Llama-3.1-8B — the paper's primary profiling subject (Tables III, V, VI)."""
from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="llama31-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    activation="swiglu",
    rope_theta=500_000.0,
    citation="arXiv:2407.21783 (Llama 3 herd); paper Table III/V/VI subject",
)
