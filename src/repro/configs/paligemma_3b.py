"""PaliGemma 3B — SigLIP vision encoder (stubbed) + Gemma decoder [arXiv:2407.07726].

Assignment carve-out: the SigLIP ViT is a stub — ``input_specs`` provides 256
precomputed patch embeddings per image that are prepended to the token sequence.
"""
from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,          # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    activation="geglu",
    tie_embeddings=True,
    scale_embedding=True,
    frontend="siglip_stub",
    num_prefix_tokens=256,   # 224px / patch14 -> 256 patches
    citation="arXiv:2407.07726 (PaliGemma)",
)
