"""Mixtral 8x22B — sparse MoE, 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from repro.config.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    activation="swiglu",
    rope_theta=1_000_000.0,
    sliding_window=4096,     # SWA per assignment bracket (Mixtral lineage)
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=16384),
    citation="arXiv:2401.04088 (Mixtral of Experts)",
)
