"""Architecture registry.

Each ``repro/configs/<id>.py`` exports ``CONFIG: ModelConfig``.  Architecture ids
use dashes on the CLI (``--arch granite-8b``) and underscores as module names.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config.base import ModelConfig

# Assigned pool (10) + the paper's own Llama models (3).
ARCH_IDS: List[str] = [
    "granite-8b",
    "rwkv6-7b",
    "mixtral-8x22b",
    "internlm2-1.8b",
    "phi3-mini-3.8b",
    "hubert-xlarge",
    "paligemma-3b",
    "gemma-7b",
    "deepseek-moe-16b",
    "hymba-1.5b",
    # paper reference models (Section IV-B)
    "llama31-8b",
    "llama32-3b",
    "llama2-13b",
]


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_module_name(arch_id)).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
