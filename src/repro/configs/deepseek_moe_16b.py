"""DeepSeekMoE 16B — fine-grained MoE: 2 shared + 64 routed, top-6 [arXiv:2401.06066]."""
from repro.config.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,               # per-expert width (fine-grained)
    vocab_size=102400,
    activation="swiglu",
    moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408,
                  num_shared_experts=2, shared_d_ff=1408),
    citation="arXiv:2401.06066 (DeepSeekMoE)",
)
