"""Llama-3.2-3B — paper's SLO-scaling subject (Figs 8, 9) and Table IV column."""
from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="llama32-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    activation="swiglu",
    rope_theta=500_000.0,
    citation="Llama 3.2 model card; paper Fig 8/9 + Table IV subject",
)
