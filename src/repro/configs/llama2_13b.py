"""Llama-2-13B — paper's hybrid-parallelism SLO subject (Fig 10) and Table IV column."""
from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-13b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=13824,
    vocab_size=32000,
    activation="swiglu",
    citation="arXiv:2307.09288 (Llama 2); paper Fig 10 + Table IV subject",
)
