"""RWKV-6 "Finch" 7B — attention-free RNN with data-dependent decay [arXiv:2404.05892]."""
from repro.config.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,            # 4096 / head_size 64 wkv heads
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,              # channel-mix hidden
    vocab_size=65536,
    activation="swiglu",     # channel-mix uses squared-relu in paper; swiglu-width kept
    ssm=SSMConfig(head_size=64, kind="rwkv6"),
    citation="arXiv:2404.05892 (Eagle and Finch: RWKV-5/6)",
)
