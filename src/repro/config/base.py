"""Configuration dataclasses for the repro framework.

Everything downstream (model zoo, comm model, sharding rules, dry-run) is driven
by these frozen dataclasses.  Architectures live in ``repro.configs.<id>`` and
are looked up through :func:`repro.configs.get_config`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25   # == num_experts ⇒ dropless

    @property
    def active_experts(self) -> int:
        return self.top_k + self.num_shared_experts


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-attention (RWKV6, Mamba-style) configuration."""

    head_size: int = 64          # per-head recurrent channel width
    state_size: int = 16         # mamba-style SSM state (hymba); rwkv uses head_size
    kind: str = "rwkv6"          # "rwkv6" | "mamba"
    expand: int = 1              # channel expansion for mamba-style blocks
    conv_width: int = 4          # local conv width (mamba-style)
    scan_impl: str = "step"      # "step" (per-token scan) | "chunked" (§Perf)
    scan_chunk: int = 16         # time-chunk length for the chunked path


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single architecture.  ``family`` selects the model-zoo implementation."""

    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    activation: str = "swiglu"   # swiglu | geglu | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # sliding-window attention width
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (hymba): how many of num_heads are attention vs ssm heads
    attn_head_fraction: float = 1.0
    # modality frontends (assignment carve-out: stubbed, embeddings provided)
    frontend: Optional[str] = None         # None | "siglip_stub" | "audio_stub"
    num_prefix_tokens: int = 0             # image patches / audio frames
    is_decoder: bool = True                # False => encoder-only (no decode phases)
    scale_embedding: bool = False          # multiply embeddings by sqrt(d_model) (gemma)
    remat: str = "none"                    # "none" | "full" | "dots" (train-time)
    attention_impl: str = "ref"            # "ref" | "chunked" (flash-style, §Perf)
    attention_chunk: int = 1024            # KV block size for the chunked path
    moe_dispatch: str = "gspmd"            # "gspmd" | "local" (shard_map, §Perf)
    moe_fsdp: bool = False                 # shard expert weights over "data" too
    dtype: str = "bfloat16"
    citation: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: heads {self.num_heads} not divisible by kv {self.num_kv_heads}")

    # ---- derived quantities used by the comm model and roofline ----
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 so the embedding/LM-head shard
        cleanly on a 16-wide model axis (pad logits are masked to -inf)."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def gqa_groups(self) -> int:
        return self.num_heads // self.num_kv_heads

    def param_count(self) -> int:
        """Total parameter count N (embedding included once if tied)."""
        h, L = self.d_model, self.num_layers
        attn = h * self.q_dim + 2 * h * self.kv_dim + self.q_dim * h
        n_glu = 3 if self.activation in ("swiglu", "geglu") else 2
        if self.moe is not None:
            mlp = self.moe.num_experts * n_glu * h * self.moe.expert_d_ff
            mlp += self.moe.num_shared_experts * n_glu * h * self.moe.shared_d_ff
            mlp += h * self.moe.num_experts  # router
        else:
            mlp = n_glu * h * self.d_ff
        if self.family == "ssm":
            # rwkv6: time-mix (r,k,v,g,o ~ 5 h^2 at head granularity) + channel-mix
            attn = 5 * h * h + h * self.ssm.head_size  # decay/projection extras folded in
            mlp = 2 * h * self.d_ff
        if self.family == "hybrid":
            # parallel attn + ssm head groups share qkv/out projections; add ssm extras
            attn += 2 * h * self.ssm.state_size * 2
        norms = 2 * h
        per_layer = attn + mlp + norms
        emb = self.vocab_size * h
        head = 0 if self.tie_embeddings else self.vocab_size * h
        return L * per_layer + emb + head + h

    def active_param_count(self) -> int:
        """Active (per-token) parameters — differs from total only for MoE."""
        if self.moe is None:
            return self.param_count()
        h, L = self.d_model, self.num_layers
        n_glu = 3 if self.activation in ("swiglu", "geglu") else 2
        dense_total = self.param_count()
        all_experts = self.moe.num_experts * n_glu * h * self.moe.expert_d_ff
        active = self.moe.top_k * n_glu * h * self.moe.expert_d_ff
        return dense_total - L * (all_experts - active)

    def reduced(self, max_d_model: int = 256, num_layers: int = 2,
                max_experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests."""
        scale = max(1, self.d_model // max_d_model)
        d_model = max(64, self.d_model // scale)
        num_heads = max(1, min(self.num_heads, 4))
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        while num_heads % num_kv:
            num_kv -= 1
        head_dim = max(8, d_model // num_heads)
        moe = None
        if self.moe is not None:
            n_exp = min(self.moe.num_experts, max_experts)
            moe = dataclasses.replace(
                self.moe,
                num_experts=n_exp,
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=d_model * 2,
                shared_d_ff=d_model * 2 if self.moe.num_shared_experts else 0,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                capacity_factor=float(n_exp),   # dropless at test scale
            )
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=d_model * 4,
            vocab_size=vocab,
            moe=moe,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            num_prefix_tokens=min(self.num_prefix_tokens, 16),
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned (seq_len, global_batch) workload shape."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                    # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelismConfig:
    """Paper-study parallelism layout (explicit TP / PP engine) or mesh layout."""

    tensor_parallel: int = 1
    pipeline_parallel: int = 1
    data_parallel: int = 1
    pods: int = 1

    @property
    def world_size(self) -> int:
        return (self.tensor_parallel * self.pipeline_parallel
                * self.data_parallel * self.pods)


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """α–β hardware model used by core/slo.py and core/roofline.py."""

    name: str
    peak_flops: float            # bf16 FLOP/s per chip
    hbm_bw: float                # bytes/s per chip
    intra_bw: float              # bytes/s per chip, fast domain (NVLink / ICI)
    inter_bw: float              # bytes/s per chip, slow domain (IB / DCN)
    intra_alpha: float           # seconds per collective, fast domain
    inter_alpha: float           # seconds per collective, slow domain
    intra_degree: int = 4        # chips per fast domain (node / pod slice)


# Target hardware for this repo (assignment constants).
TPU_V5E = HardwareProfile(
    name="tpu_v5e",
    peak_flops=197e12, hbm_bw=819e9,
    intra_bw=50e9, inter_bw=25e9,
    intra_alpha=1e-6, inter_alpha=10e-6,
    intra_degree=256,
)

# The paper's platform (Table II): 4xH100-94GB NVLink node, IB NDR400.
# inter_alpha is the *effective* cross-node small-message collective latency
# observed through vLLM V0 + NCCL (fitted to Fig 8's TP=8 TPOT blow-up); raw
# NCCL IB latency is ~20 µs, the engine stack inflates it ~6×.
H100_NODE = HardwareProfile(
    name="h100_node",
    peak_flops=660e12, hbm_bw=2.4e12,
    intra_bw=450e9, inter_bw=50e9,
    intra_alpha=8e-6, inter_alpha=120e-6,
    intra_degree=4,
)

HARDWARE = {"tpu_v5e": TPU_V5E, "h100_node": H100_NODE}
