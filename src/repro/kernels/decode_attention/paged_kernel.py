"""Pallas TPU *paged* decode-attention kernel (DESIGN.md §8).

Same flash-decode structure as ``decode_kernel.py`` — one query token per
sequence, online softmax over KV blocks — but the KV cache is the KVPool's
[P, ps, Hkv, D] page pools instead of a contiguous [B, W, Hkv, D] slab, and
the kernel indexes pages *directly*: the per-sequence block table rides in as
a scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``), so the BlockSpec
index map can pick physical page ``block_tables[b, j]`` for logical page j
while the grid streams logical pages sequentially.  No gather materializes
the logical view — the DMA engine fetches exactly one page per grid step,
which is the point of paging: attention reads scale with the sequence's
actual length (pages named by its table), not with a padded max_len slab.

Grid: (B, Hkv, n_pages) — n_pages minor/sequential.  ``lengths[b]`` masks
the tail of the last page and any scratch-aliased entries.

TPU alignment: page_size ideally a multiple of the 8-row sublane and D a
multiple of 128 for full MXU tiles; interpret mode (tests, CPU) takes any
shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(np.finfo(np.float32).min)


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i,
                  *, scale, page_size):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q = q_ref[0, 0].astype(jnp.float32) * scale               # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)                       # [ps, D]
    s = q @ k.T                                               # [G, ps]
    # logical position of each row in this page vs the sequence's length
    idx = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size),
                                                   1)
    mask = idx < len_ref[b]                                   # [1, ps]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_i[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_i[...] = l_i[...] * alpha + p.sum(axis=1, keepdims=True)
    m_i[...] = m_new
    acc[...] = acc[...] * alpha + p @ v_ref[0, 0].astype(jnp.float32)

    @pl.when(j == nj - 1)
    def _flush():
        o_ref[0, 0] = (acc[...] /
                       jnp.maximum(l_i[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_pages, v_pages, block_tables, lengths,
                                  *, interpret: bool = False):
    """q: [B,Hq,D]; k_pages/v_pages: [P,ps,Hkv,D]; block_tables: [B,n] int32
    (physical page of logical page j); lengths: [B] int32 -> [B,Hq,D]."""
    B, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    G = Hq // Hkv
    n = block_tables.shape[1]

    qt = q.reshape(B, Hkv, G, D)                              # [B,Hkv,G,D]
    kt = k_pages.transpose(2, 0, 1, 3)                        # [Hkv,P,ps,D]
    vt = v_pages.transpose(2, 0, 1, 3)

    def kv_index(b, h, j, bt_ref, len_ref):
        return (h, bt_ref[b, j], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, j, bt_ref, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D), kv_index),
            pl.BlockSpec((1, 1, ps, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, bt_ref, len_ref: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, D), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=1.0 / np.sqrt(D),
                          page_size=ps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      qt, kt, vt)
    return out.reshape(B, Hq, D)
