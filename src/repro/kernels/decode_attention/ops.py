"""Dispatching wrapper for decode attention."""
from __future__ import annotations

import os

import jax

from repro.kernels.decode_attention.decode_kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k, v, valid):
    if jax.default_backend() == "tpu":
        return decode_attention_pallas(q, k, v, valid)
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return decode_attention_pallas(q, k, v, valid, interpret=True)
    return decode_attention_ref(q, k, v, valid)
