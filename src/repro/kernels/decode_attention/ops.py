"""Dispatching wrapper for decode attention."""
from __future__ import annotations

import os

import jax

from repro.kernels.decode_attention.decode_kernel import decode_attention_pallas
from repro.kernels.decode_attention.paged_kernel import \
    paged_decode_attention_pallas
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)


def decode_attention(q, k, v, valid):
    if jax.default_backend() == "tpu":
        return decode_attention_pallas(q, k, v, valid)
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return decode_attention_pallas(q, k, v, valid, interpret=True)
    return decode_attention_ref(q, k, v, valid)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths):
    """Paged flash-decode (DESIGN.md §8): the Pallas kernel indexes KV pages
    directly via the scalar-prefetched block tables on TPU; elsewhere the
    jnp oracle gathers the logical view."""
    if jax.default_backend() == "tpu":
        return paged_decode_attention_pallas(q, k_pages, v_pages,
                                             block_tables, lengths)
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return paged_decode_attention_pallas(q, k_pages, v_pages,
                                             block_tables, lengths,
                                             interpret=True)
    return paged_decode_attention_ref(q, k_pages, v_pages, block_tables,
                                      lengths)
