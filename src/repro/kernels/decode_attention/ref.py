"""Pure-jnp oracle for single-token decode attention against a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """Oracle for the paged kernel: gather the logical view named by the
    block tables, then masked attention.  q: [B,Hq,D]; k_pages/v_pages:
    [P,ps,Hkv,D]; block_tables: [B,n] int32; lengths: [B] int32."""
    B, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    n = block_tables.shape[1]
    G = Hq // Hkv
    k = k_pages[block_tables].reshape(B, n * ps, Hkv, D)
    v = v_pages[block_tables].reshape(B, n * ps, Hkv, D)
    valid = jnp.arange(n * ps)[None, :] < lengths[:, None]      # [B, T]
    qg = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    logits *= 1.0 / np.sqrt(D)
    logits = jnp.where(valid[:, None, None, :], logits,
                       jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v)
    return out.reshape(B, Hq, D)


def decode_attention_ref(q, k, v, valid):
    """q: [B,Hq,D]; k,v: [B,W,Hkv,D]; valid: [W] bool -> [B,Hq,D]."""
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    logits *= 1.0 / np.sqrt(D)
    logits = jnp.where(valid[None, None, None, :], logits,
                       jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v)
    return out.reshape(B, Hq, D)
