"""Pallas TPU decode-attention (flash-decode) kernel.

One query token per sequence attends to a [W, Hkv, D] KV cache.  The KV length
is the long dimension, so the grid streams KV blocks sequentially while the
G = Hq/Hkv query heads for one KV head ride along as the MXU M-dimension:
scores for a block are a [G, bkv] matmul — small-M but D-deep, which keeps the
MXU busy for head_dim >= 128 archs.  Online softmax state ([G,1] max/denom and
[G,D] accumulator) persists in VMEM scratch across KV blocks.

Grid: (B, Hkv, nKV) — nKV minor/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(np.finfo(np.float32).min)


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, acc, m_i, l_i,
                   *, scale):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q = q_ref[0, 0].astype(jnp.float32) * scale               # [G, D]
    k = k_ref[0].astype(jnp.float32)                          # [bkv, D]
    s = q @ k.T                                               # [G, bkv]
    mask = valid_ref[...] != 0                                # [1, bkv]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_i[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_i[...] = l_i[...] * alpha + p.sum(axis=1, keepdims=True)
    m_i[...] = m_new
    acc[...] = acc[...] * alpha + p @ v_ref[0].astype(jnp.float32)

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_i[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention_pallas(q, k, v, valid, *, block_kv: int = 512,
                            interpret: bool = False):
    """q: [B,Hq,D]; k,v: [B,W,Hkv,D]; valid: [W] bool -> [B,Hq,D]."""
    B, Hq, D = q.shape
    W, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_kv = min(block_kv, W)
    assert W % block_kv == 0

    qt = q.reshape(B, Hkv, G, D)                              # [B,Hkv,G,D]
    kt = k.transpose(0, 2, 1, 3)                              # [B,Hkv,W,D]
    vt = v.transpose(0, 2, 1, 3)
    valid2 = valid.astype(jnp.int32)[None, :]                 # [1, W]

    grid = (B, Hkv, W // block_kv)
    q_spec = pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0))
    kv_spec = pl.BlockSpec((None, 1, block_kv, D), lambda b, h, ik: (b, h, ik, 0))
    valid_spec = pl.BlockSpec((1, block_kv), lambda b, h, ik: (0, ik))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=1.0 / np.sqrt(D)),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, valid_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((G, D), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, valid2)
    return out.reshape(B, Hq, D)
