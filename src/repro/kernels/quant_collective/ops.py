"""Dispatching wrappers + numerics contract for quantized collectives.

``chunk_amax`` / ``chunk_quantize`` / ``chunk_dequantize`` pick the Pallas
kernel on TPU (or under ``REPRO_PALLAS_INTERPRET=1``) and the jnp oracle
elsewhere, like every other kernel package here.

This module is also the single home of the quantized-collective *numerics
contract* (DESIGN.md §12):

* ``QUANT_DTYPES``     — supported wire modes and their payload dtypes,
* ``collective_qmax``  — the per-rank quant ceiling with summation headroom
  (``floor(127/t)`` for int8, ``448/t`` for fp8-e4m3) so the integer
  reduce-scatter over ``t`` ranks can never overflow the wire dtype,
* ``scales_from_amax`` — shared scale from the globally pmax'ed abs-max,
  with a zero-chunk guard (scale 1.0 where amax == 0),
* ``QUANT_TOLERANCE``  — the tested accuracy floors/ceilings: greedy
  token-match rate vs the bf16 path must be >= ``token_match_floor`` and
  max logit drift <= ``logit_drift_ceiling``.  tests, quant_demo, and
  check_baselines all import these same constants — tighten or loosen the
  contract by editing them here and nowhere else.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.quant_collective.ref import (chunk_amax_ref,
                                                chunk_dequantize_ref,
                                                chunk_quantize_ref,
                                                nibble_pack_ref,
                                                nibble_unpack_ref)
from repro.kernels.quant_collective.quant_kernel import (
    chunk_amax_pallas, chunk_dequantize_pallas, chunk_quantize_pallas,
    nibble_pack_pallas, nibble_unpack_pallas)

# int4 has no native jnp dtype: values live in int8 storage (|q| <= 7) and
# ``nibble_pack``/``nibble_unpack`` convert to/from the 2-per-byte uint8
# wire form the packed all-to-all actually ships (DESIGN.md §12).
QUANT_DTYPES = {
    "int8": jnp.int8,
    "fp8": jnp.float8_e4m3fn,
    "int4": jnp.int8,
}

DEFAULT_CHUNK = 128

# The tested accuracy contract per wire mode, measured teacher-forced
# against the bf16 path.  Calibrated on the decode bench's reduced configs
# (random weights — near-worst-case logit margins, drift compounds through
# 32 steps of quantized KV-cache history): worst observed int8 row is
# token_match 0.9375 / drift 0.172 at t=4, fp8 0.906 / 0.145 at t=2, so
# the ceilings carry ~1.5-2x headroom while staying tight enough that a
# scale-handling bug (which lands drift in the 1.0+ range) trips the gate.
# int8 with summation headroom keeps per-element relative error ~2^-7;
# fp8-e4m3 carries ~2^-3 mantissa steps, hence the looser row.
QUANT_TOLERANCE = {
    "int8": {"token_match_floor": 0.90, "logit_drift_ceiling": 0.25},
    "fp8": {"token_match_floor": 0.75, "logit_drift_ceiling": 0.30},
    # int4 keeps the full +-7 grid (no /t headroom — the packed all-to-all
    # sums exactly in int32, see ``collective_qmax``) but requantizes the
    # reduced row back onto the 4-bit grid before the gather, so per-psum
    # error is bounded by t * (amax/7) and grows with the TP degree.
    # Calibrated like the rows above, from the BENCH_decode series: worst
    # full-bench token_match 0.4688 / drift 1.632 (fused-q4 at t=4; the
    # t=2 hybrid sits near 0.59-0.61 match).  The dry-run bench samples
    # only 16 tokens, so its match rate quantizes to 1/16 steps and
    # bottoms out at 5/16 = 0.3125 — the floor sits one flipped token
    # below that.  4-bit wire is the aggressive end of the tradeoff — the
    # contract only pins that it does not silently get WORSE, not that it
    # is deployable for greedy decode.
    "int4": {"token_match_floor": 0.25, "logit_drift_ceiling": 2.0},
}


def collective_qmax(quant: str, t: int) -> float:
    """Per-rank quant ceiling with headroom for an exact t-way sum.

    Each rank quantizes with the *global* (pmax'ed) per-chunk abs-max, so
    every |q| <= qmax; capping qmax at ``range/t`` bounds the reduce-scatter
    partial sum by the wire dtype's max — the integer sum is exact and the
    fp8 sum cannot saturate.

    int4 is the exception: ``floor(7/t)`` would collapse the grid to +-1 at
    t >= 4, so the packed path keeps the full +-7 range and gets exactness
    elsewhere — the all-to-all ships per-rank nibbles unsummed, every rank
    accumulates its hidden block in int32 (|sum| <= 7t, exact), and only the
    requantize-by-t before the gather rounds (DESIGN.md §12).
    """
    if quant not in QUANT_DTYPES:
        raise ValueError(f"unknown quant mode {quant!r}; "
                         f"expected one of {sorted(QUANT_DTYPES)}")
    if t < 1:
        raise ValueError(f"t must be >= 1, got {t}")
    if quant == "int8":
        return float(127 // t)
    if quant == "int4":
        return 7.0
    return 448.0 / t


def scales_from_amax(amax, qmax: float):
    """Per-chunk scale from the global abs-max, guarding all-zero chunks."""
    amax = amax.astype(jnp.float32)
    return jnp.where(amax > 0.0, amax / qmax, 1.0)


def _use_pallas():
    if jax.default_backend() == "tpu":
        return True, False
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return True, True
    return False, False


def chunk_amax(x, chunk: int = DEFAULT_CHUNK):
    pallas, interpret = _use_pallas()
    if pallas:
        return chunk_amax_pallas(x, chunk=chunk, interpret=interpret)
    return chunk_amax_ref(x, chunk)


def chunk_quantize(x, scales, chunk: int = DEFAULT_CHUNK, quant: str = "int8"):
    qdtype = QUANT_DTYPES[quant]
    pallas, interpret = _use_pallas()
    if pallas:
        return chunk_quantize_pallas(x, scales, chunk=chunk, qdtype=qdtype,
                                     interpret=interpret)
    return chunk_quantize_ref(x, scales, chunk, qdtype)


def chunk_dequantize(q, scales, chunk: int = DEFAULT_CHUNK,
                     out_dtype=jnp.float32):
    pallas, interpret = _use_pallas()
    if pallas:
        return chunk_dequantize_pallas(q, scales, chunk=chunk,
                                       out_dtype=out_dtype,
                                       interpret=interpret)
    return chunk_dequantize_ref(q, scales, chunk, out_dtype)


def nibble_pack(q):
    """int4 values (int8 storage, |q| <= 7) -> 2-per-byte uint8 wire form."""
    pallas, interpret = _use_pallas()
    if pallas:
        return nibble_pack_pallas(q, interpret=interpret)
    return nibble_pack_ref(q)


def nibble_unpack(b):
    """2-per-byte uint8 wire form -> sign-extended int8 values."""
    pallas, interpret = _use_pallas()
    if pallas:
        return nibble_unpack_pallas(b, interpret=interpret)
    return nibble_unpack_ref(b)
