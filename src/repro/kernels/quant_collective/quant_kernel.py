"""Pallas TPU kernels for per-chunk symmetric collective quantization.

Row-blocked like the fused RMSNorm kernel: each grid cell handles a
[block_rows, K*chunk] tile entirely in VMEM.  All three ops are
bandwidth-bound elementwise passes, so the win is fusing the
reshape/scale/round/cast chain into one HBM read + one write.  The hidden
axis is pre-padded to a whole number of chunks on the host (zeros — inert
for abs-max and sliced off on the way out), so the in-kernel reshape to
(block_rows, K, chunk) is always exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flatten_rows(x):
    h = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    return x.reshape(rows, h), rows, h


def _pad_axes(x, block_rows: int, chunk: int):
    rows, hp = x.shape
    rpad = (-rows) % block_rows
    cpad = (-hp) % chunk
    if rpad or cpad:
        x = jnp.pad(x, ((0, rpad), (0, cpad)))
    return x


def _amax_kernel(x_ref, o_ref, *, chunk):
    x = x_ref[...].astype(jnp.float32)
    br, hp = x.shape
    o_ref[...] = jnp.abs(x).reshape(br, hp // chunk, chunk).max(axis=-1)


@functools.partial(jax.jit, static_argnames=("chunk", "block_rows", "interpret"))
def chunk_amax_pallas(x, chunk: int = 128, block_rows: int = 256,
                      interpret: bool = False):
    xf, rows, h = _flatten_rows(x)
    k = -(-h // chunk)
    block_rows = min(block_rows, rows)
    xf = _pad_axes(xf, block_rows, chunk)
    n = xf.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_amax_kernel, chunk=chunk),
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, k * chunk), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xf.shape[0], k), jnp.float32),
        interpret=interpret,
    )(xf)
    return out[:rows].reshape(*x.shape[:-1], k)


def _quantize_kernel(x_ref, s_ref, o_ref, *, chunk, clip_lo, clip_hi,
                     integer):
    x = x_ref[...].astype(jnp.float32)
    br, hp = x.shape
    xc = x.reshape(br, hp // chunk, chunk) / s_ref[...][..., None]
    if integer:
        xc = jnp.round(xc)
    xc = jnp.clip(xc, clip_lo, clip_hi)
    o_ref[...] = xc.reshape(br, hp).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "qdtype", "block_rows", "interpret"))
def chunk_quantize_pallas(x, scales, chunk: int = 128, qdtype=jnp.int8,
                          block_rows: int = 256, interpret: bool = False):
    xf, rows, h = _flatten_rows(x)
    k = -(-h // chunk)
    sf = scales.reshape(rows, k)
    block_rows = min(block_rows, rows)
    xf = _pad_axes(xf, block_rows, chunk)
    sf = _pad_axes(sf, block_rows, 1)
    sf = jnp.where(sf == 0.0, 1.0, sf)  # padded rows: avoid 0/0 in-kernel
    integer = jnp.issubdtype(qdtype, jnp.integer)
    if integer:
        info = jnp.iinfo(qdtype)
        clip_lo, clip_hi = float(info.min + 1), float(info.max)
    else:
        fmax = float(jnp.finfo(qdtype).max)  # saturate, don't overflow to nan
        clip_lo, clip_hi = -fmax, fmax
    n = xf.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, chunk=chunk,
                          clip_lo=clip_lo, clip_hi=clip_hi, integer=integer),
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, k * chunk), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, k * chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, qdtype),
        interpret=interpret,
    )(xf, sf)
    return out[:rows, :h].reshape(x.shape)


def _pack_kernel(q_ref, o_ref):
    q = q_ref[...].astype(jnp.uint8)
    br, hp = q.shape
    pairs = q.reshape(br, hp // 2, 2)
    o_ref[...] = (pairs[..., 0] & 0xF) | ((pairs[..., 1] & 0xF) << 4)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def nibble_pack_pallas(q, block_rows: int = 256, interpret: bool = False):
    qf, rows, h = _flatten_rows(q)
    if h % 2:
        raise ValueError(f"nibble packing needs an even last axis, got {h}")
    block_rows = min(block_rows, rows)
    qf = _pad_axes(qf, block_rows, 2)
    n = qf.shape[0] // block_rows
    out = pl.pallas_call(
        _pack_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, h), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, h // 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qf.shape[0], h // 2), jnp.uint8),
        interpret=interpret,
    )(qf)
    return out[:rows].reshape(*q.shape[:-1], h // 2)


def _unpack_kernel(b_ref, o_ref):
    b = b_ref[...]
    br, m = b.shape
    lo = (b & 0xF).astype(jnp.int8)
    hi = ((b >> 4) & 0xF).astype(jnp.int8)
    pairs = jnp.stack([(lo ^ 8) - 8, (hi ^ 8) - 8], axis=-1)
    o_ref[...] = pairs.reshape(br, 2 * m).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def nibble_unpack_pallas(b, block_rows: int = 256, interpret: bool = False):
    bf, rows, m = _flatten_rows(b)
    block_rows = min(block_rows, rows)
    bf = _pad_axes(bf, block_rows, 1)
    n = bf.shape[0] // block_rows
    out = pl.pallas_call(
        _unpack_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, 2 * m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bf.shape[0], 2 * m), jnp.int8),
        interpret=interpret,
    )(bf)
    return out[:rows].reshape(*b.shape[:-1], 2 * m)


def _dequantize_kernel(q_ref, s_ref, o_ref, *, chunk):
    q = q_ref[...].astype(jnp.float32)
    br, hp = q.shape
    xc = q.reshape(br, hp // chunk, chunk) * s_ref[...][..., None]
    o_ref[...] = xc.reshape(br, hp).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "out_dtype", "block_rows",
                                    "interpret"))
def chunk_dequantize_pallas(q, scales, chunk: int = 128,
                            out_dtype=jnp.float32, block_rows: int = 256,
                            interpret: bool = False):
    qf, rows, h = _flatten_rows(q)
    k = -(-h // chunk)
    sf = scales.reshape(rows, k)
    block_rows = min(block_rows, rows)
    qf = _pad_axes(qf, block_rows, chunk)
    sf = _pad_axes(sf, block_rows, 1)
    n = qf.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, chunk=chunk),
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, k * chunk), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, k * chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(qf.shape, out_dtype),
        interpret=interpret,
    )(qf, sf)
    return out[:rows, :h].reshape(q.shape)
