"""Pure-jnp oracle for per-chunk symmetric collective quantization.

The quantized two-step all-reduce (DESIGN.md §12) needs three dense ops on
the activation row entering a TP ``psum``:

* ``chunk_amax_ref``    — abs-max over each ``chunk``-wide block of the last
  axis (the per-chunk scale statistic, exchanged via ``pmax``),
* ``chunk_quantize_ref`` — symmetric round-to-nearest onto the quant grid,
* ``chunk_dequantize_ref`` — back to the accumulation dtype.

The hidden axis is padded up to a whole number of chunks and sliced back, so
``h % chunk != 0`` (odd remainders) is exact: the zero padding can neither
raise an abs-max nor leak into the sliced output.
"""
from __future__ import annotations

import jax.numpy as jnp


def _pad_to_chunks(x, chunk: int):
    h = x.shape[-1]
    k = -(-h // chunk)
    pad = k * chunk - h
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, k


def chunk_amax_ref(x, chunk: int):
    """Per-chunk abs-max of the last axis: [..., h] -> [..., K] float32."""
    xp, k = _pad_to_chunks(jnp.abs(x.astype(jnp.float32)), chunk)
    return xp.reshape(*x.shape[:-1], k, chunk).max(axis=-1)


def chunk_quantize_ref(x, scales, chunk: int, qdtype):
    """Symmetric quantize: q = round(x / scale) per chunk, cast to qdtype.

    ``scales`` is [..., K] float32 (broadcast over each chunk).  Integer
    targets are clipped to the signed range as a guard; callers are expected
    to have built ``scales`` with enough headroom (see
    ``ops.collective_qmax``) that the clip never actually binds.
    """
    h = x.shape[-1]
    xp, k = _pad_to_chunks(x.astype(jnp.float32), chunk)
    xc = xp.reshape(*x.shape[:-1], k, chunk) / scales[..., None]
    if jnp.issubdtype(qdtype, jnp.integer):
        info = jnp.iinfo(qdtype)
        xc = jnp.clip(jnp.round(xc), info.min + 1, info.max)
    else:
        fmax = float(jnp.finfo(qdtype).max)  # saturate, don't overflow to nan
        xc = jnp.clip(xc, -fmax, fmax)
    return xc.reshape(*x.shape[:-1], k * chunk)[..., :h].astype(qdtype)


def chunk_dequantize_ref(q, scales, chunk: int, out_dtype):
    """Dequantize: x = q * scale per chunk, cast to ``out_dtype``."""
    h = q.shape[-1]
    qp, k = _pad_to_chunks(q.astype(jnp.float32), chunk)
    xc = qp.reshape(*q.shape[:-1], k, chunk) * scales[..., None]
    return xc.reshape(*q.shape[:-1], k * chunk)[..., :h].astype(out_dtype)
