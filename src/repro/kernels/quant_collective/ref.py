"""Pure-jnp oracle for per-chunk symmetric collective quantization.

The quantized two-step all-reduce (DESIGN.md §12) needs three dense ops on
the activation row entering a TP ``psum``:

* ``chunk_amax_ref``    — abs-max over each ``chunk``-wide block of the last
  axis (the per-chunk scale statistic, exchanged via ``pmax``),
* ``chunk_quantize_ref`` — symmetric round-to-nearest onto the quant grid,
* ``chunk_dequantize_ref`` — back to the accumulation dtype.

The hidden axis is padded up to a whole number of chunks and sliced back, so
``h % chunk != 0`` (odd remainders) is exact: the zero padding can neither
raise an abs-max nor leak into the sliced output.
"""
from __future__ import annotations

import jax.numpy as jnp


def _pad_to_chunks(x, chunk: int):
    h = x.shape[-1]
    k = -(-h // chunk)
    pad = k * chunk - h
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, k


def chunk_amax_ref(x, chunk: int):
    """Per-chunk abs-max of the last axis: [..., h] -> [..., K] float32."""
    xp, k = _pad_to_chunks(jnp.abs(x.astype(jnp.float32)), chunk)
    return xp.reshape(*x.shape[:-1], k, chunk).max(axis=-1)


def chunk_quantize_ref(x, scales, chunk: int, qdtype):
    """Symmetric quantize: q = round(x / scale) per chunk, cast to qdtype.

    ``scales`` is [..., K] float32 (broadcast over each chunk).  Integer
    targets are clipped to the signed range as a guard; callers are expected
    to have built ``scales`` with enough headroom (see
    ``ops.collective_qmax``) that the clip never actually binds.
    """
    h = x.shape[-1]
    xp, k = _pad_to_chunks(x.astype(jnp.float32), chunk)
    xc = xp.reshape(*x.shape[:-1], k, chunk) / scales[..., None]
    if jnp.issubdtype(qdtype, jnp.integer):
        info = jnp.iinfo(qdtype)
        xc = jnp.clip(jnp.round(xc), info.min + 1, info.max)
    else:
        fmax = float(jnp.finfo(qdtype).max)  # saturate, don't overflow to nan
        xc = jnp.clip(xc, -fmax, fmax)
    return xc.reshape(*x.shape[:-1], k * chunk)[..., :h].astype(qdtype)


def chunk_dequantize_ref(q, scales, chunk: int, out_dtype):
    """Dequantize: x = q * scale per chunk, cast to ``out_dtype``."""
    h = q.shape[-1]
    qp, k = _pad_to_chunks(q.astype(jnp.float32), chunk)
    xc = qp.reshape(*q.shape[:-1], k, chunk) * scales[..., None]
    return xc.reshape(*q.shape[:-1], k * chunk)[..., :h].astype(out_dtype)


def nibble_pack_ref(q):
    """Pack int4 values (int8 storage, |q| <= 7) two-per-byte: [..., h] ->
    [..., h//2] uint8.  Even positions land in the low nibble, odd in the
    high — two's-complement truncation to 4 bits, inverted exactly by
    ``nibble_unpack_ref``."""
    h = q.shape[-1]
    if h % 2:
        raise ValueError(f"nibble packing needs an even last axis, got {h}")
    u = q.astype(jnp.uint8)
    pairs = u.reshape(*q.shape[:-1], h // 2, 2)
    return (pairs[..., 0] & 0xF) | ((pairs[..., 1] & 0xF) << 4)


def nibble_unpack_ref(b):
    """Unpack two-per-byte nibbles back to int8: [..., m] -> [..., 2m],
    sign-extending each 4-bit field ((n ^ 8) - 8)."""
    lo = (b & 0xF).astype(jnp.int8)
    hi = ((b >> 4) & 0xF).astype(jnp.int8)
    pairs = jnp.stack([(lo ^ 8) - 8, (hi ^ 8) - 8], axis=-1)
    return pairs.reshape(*b.shape[:-1], b.shape[-1] * 2).astype(jnp.int8)
