from repro.kernels.quant_collective.ops import (  # noqa: F401
    DEFAULT_CHUNK, QUANT_DTYPES, QUANT_TOLERANCE, chunk_amax,
    chunk_dequantize, chunk_quantize, collective_qmax, nibble_pack,
    nibble_unpack, scales_from_amax)
