"""Chunk-parallel WKV6 (§Perf): matmul-form linear attention in sub-chunks.

The per-token scan (ref.py) reads and writes the [hs, hs] recurrent state
every timestep — S·L state round-trips through HBM dominate the rwkv6
training roofline.  This reformulation processes time in chunks of C tokens:

  intra-chunk:  y_t += Σ_{τ<t} (r_t ⊙ e^{Λ_{t-1}-Λ_τ}) · k_τ · v_τ
                via an exact pairwise [C, C, hs] log-domain decay tensor
                (the factorized r̃·k̃ form is numerically unstable for
                fast-decay channels; C=16 keeps the tensor small)
  diagonal:     y_t += (r_t ⊙ u ⊙ k_t) · v_t
  inter-chunk:  y_t += (r_t ⊙ e^{Λ_{t-1}}) · S_chunk_start
  state update: S' = diag(e^{Λ_C}) S + Σ_τ (k_τ ⊙ e^{Λ_C-Λ_τ}) v_τᵀ

with Λ the running per-channel log-decay cumsum — every exponent is ≤ 0,
so everything is stable in fp32.  The scan now carries state once per chunk —
S/C state round-trips instead of S — and all inner ops are MXU-shaped
matmuls.  Exactly the blocking a TPU Pallas kernel would use; numerics are
validated against the per-token oracle in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6_chunked(r, k, v, w, u, state, *, chunk: int = 16):
    """r,k,v,w: [B,S,H,hs]; u: [H,hs]; state: [B,H,hs,hs] f32.

    Returns (y [B,S,H,hs] in r.dtype, final_state f32).  Requires S % chunk
    == 0 (the model pads or picks chunk | S).
    """
    B, S, H, hs = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    def to_chunks(t):   # [B,S,H,hs] -> [n,B,H,C,hs]
        return (t.reshape(B, n, chunk, H, hs)
                 .transpose(1, 0, 3, 2, 4).astype(jnp.float32))

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    uf = u.astype(jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # strict lower

    def body(s, inp):
        r_c, k_c, v_c, w_c = inp                  # [B,H,C,hs]
        lw = jnp.log(jnp.maximum(w_c, 1e-38))
        lam = jnp.cumsum(lw, axis=2)              # Λ_τ (inclusive)
        lam_ex = lam - lw                         # Λ_{t-1} (exclusive)
        lam_end = lam[:, :, -1:, :]               # Λ_C

        # pairwise decay Λ_{t-1} - Λ_τ (τ < t): always ≤ 0, exact in log
        # domain — the factorized r̃·k̃ form is unstable for fast-decay
        # channels (clamped factor ↔ non-negligible product), so the [C,C,hs]
        # pairwise tensor is materialized per chunk (C=16 keeps it small).
        expo = lam_ex[:, :, :, None, :] - lam[:, :, None, :, :]   # [B,H,C,C,hs]
        d = jnp.where(causal[None, None, :, :, None], expo, -jnp.inf)
        A = jnp.einsum("bhti,bhsi,bhtsi->bhts", r_c, k_c, jnp.exp(d))
        diag = jnp.einsum("bhti,hi->bht", r_c * k_c, uf)
        y = jnp.einsum("bhts,bhsj->bhtj", A, v_c)
        y += diag[..., None] * v_c
        y += jnp.einsum("bhti,bhij->bhtj", r_c * jnp.exp(lam_ex), s)  # inter

        k_hat = k_c * jnp.exp(lam_end - lam)
        s = jnp.exp(lam_end[:, :, 0, :])[..., None] * s \
            + jnp.einsum("bhsi,bhsj->bhij", k_hat, v_c)
        return s, y

    final, ys = jax.lax.scan(body, state.astype(jnp.float32),
                             (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hs)
    return y.astype(r.dtype), final
