"""Dispatching wrapper for the WKV6 recurrence.

On TPU the Pallas kernel runs compiled; elsewhere the pure-jnp oracle is used
(the models import this entry point, so CPU smoke tests and the dry-run see
clean jnp HLO while TPU deployments get the fused kernel).  Set
``REPRO_PALLAS_INTERPRET=1`` to force the kernel body through the Pallas
interpreter (used by the kernel tests).
"""
from __future__ import annotations

import os

import jax

from repro.kernels.rwkv6_scan.ref import wkv6_ref
from repro.kernels.rwkv6_scan.wkv6_kernel import wkv6_pallas


def _backend() -> str:
    return jax.default_backend()


def wkv6(r, k, v, w, u, state):
    if _backend() == "tpu":
        return wkv6_pallas(r, k, v, w, u, state)
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return wkv6_pallas(r, k, v, w, u, state, interpret=True)
    return wkv6_ref(r, k, v, w, u, state)
