"""Pure-jnp oracle for the RWKV-6 (Finch) WKV recurrence.

Per head with state S ∈ R^{hs×hs} (key-channel i, value-channel j):

    y_t[j]     = Σ_i r_t[i] · (S_t[i,j] + u[i]·k_t[i]·v_t[j])
    S_{t+1}[i,j] = w_t[i]·S_t[i,j] + k_t[i]·v_t[j]

with data-dependent decay w_t ∈ (0,1)^{hs} [arXiv:2404.05892, Eq. 18-19].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, state):
    """r,k,v,w: [B,S,H,hs]; u: [H,hs]; state: [B,H,hs,hs] (f32).

    Returns (y [B,S,H,hs] in r.dtype, final_state [B,H,hs,hs] f32).
    """
    B, S, H, hs = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                                   # [B,H,hs]
        kv = kt[..., :, None] * vt[..., None, :]               # [B,H,hs,hs]
        y = jnp.einsum("bhi,bhij->bhj", rt, s + uf[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    final, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), final
