"""Pallas TPU kernel for the RWKV-6 WKV recurrence.

TPU adaptation of the (GPU-oriented) CUDA wkv6 kernel: one grid cell per
(batch·head, time-chunk); the [hs, hs] recurrent state lives in a VMEM
scratch buffer that persists across the sequential time-chunk grid dimension
(the TPU grid is executed in order, minor-most last), so HBM traffic is the
r/k/v/w streams once plus one state read/write per (b,h) — the same data-flow
the paper's GPU kernel achieves with shared memory, re-thought for the
HBM→VMEM hierarchy.

Grid: (B*H, S // chunk).  Blocks: r/k/v/w [chunk, hs]; y [chunk, hs];
state in/out [hs, hs].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sout_ref, state):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[...].astype(jnp.float32)

    chunk = r_ref.shape[0]
    u = u_ref[...].astype(jnp.float32)          # [hs]

    def step(t, s):
        r_t = r_ref[t, :].astype(jnp.float32)   # [hs]
        k_t = k_ref[t, :].astype(jnp.float32)
        v_t = v_ref[t, :].astype(jnp.float32)
        w_t = w_ref[t, :].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]        # [hs, hs]
        y = (r_t[None, :] @ (s + u[:, None] * kv))[0]
        y_ref[t, :] = y.astype(y_ref.dtype)
        return w_t[:, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, step, state[...])
    state[...] = s

    @pl.when(c == nc - 1)
    def _flush():
        sout_ref[...] = s


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, state, *, chunk: int = 128,
                interpret: bool = False):
    """r,k,v,w: [B,S,H,hs]; u: [H,hs]; state: [B,H,hs,hs] f32."""
    B, S, H, hs = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, f"S={S} not divisible by chunk={chunk}"
    BH = B * H

    def flat(t):   # [B,S,H,hs] -> [B*H, S, hs]
        return t.transpose(0, 2, 1, 3).reshape(BH, S, hs)

    rf, kf, vf, wf = map(flat, (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (B, H, hs)).reshape(BH, hs)
    sf = state.reshape(BH, hs, hs).astype(jnp.float32)

    seq_spec = pl.BlockSpec((None, chunk, hs), lambda bh, c: (bh, c, 0))
    bh_spec = pl.BlockSpec((None, hs), lambda bh, c: (bh, 0))
    st_spec = pl.BlockSpec((None, hs, hs), lambda bh, c: (bh, 0, 0))

    y, s_out = pl.pallas_call(
        _wkv6_kernel,
        grid=(BH, S // chunk),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, bh_spec, st_spec],
        out_specs=[seq_spec, st_spec],
        out_shape=[jax.ShapeDtypeStruct((BH, S, hs), r.dtype),
                   jax.ShapeDtypeStruct((BH, hs, hs), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, sf)

    y = y.reshape(B, H, S, hs).transpose(0, 2, 1, 3)
    return y, s_out.reshape(B, H, hs, hs)
