"""Dispatching wrapper for fused RMSNorm."""
from __future__ import annotations

import os

import jax

from repro.kernels.rmsnorm.ref import rms_norm_ref
from repro.kernels.rmsnorm.rmsnorm_kernel import rms_norm_pallas


def rms_norm(x, weight, eps: float = 1e-5):
    if jax.default_backend() == "tpu":
        return rms_norm_pallas(x, weight, eps=eps)
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return rms_norm_pallas(x, weight, eps=eps, interpret=True)
    return rms_norm_ref(x, weight, eps)
