from repro.kernels.rmsnorm.ops import rms_norm  # noqa: F401
