"""Pure-jnp oracle for fused RMSNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm_ref(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)
