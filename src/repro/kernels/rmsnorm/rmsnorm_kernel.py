"""Pallas TPU fused RMSNorm kernel.

Row-blocked: each grid cell normalizes a [block_rows, h] tile entirely in
VMEM (one HBM read + one write per element — the op is bandwidth-bound, so
fusing the square/mean/rsqrt/scale chain removes three HBM round-trips that
an unfused jnp chain would cost at this size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (out * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rms_norm_pallas(x, weight, eps: float = 1e-5, block_rows: int = 256,
                    interpret: bool = False):
    orig_shape = x.shape
    h = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    xf = x.reshape(rows, h)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n = xf.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
                  pl.BlockSpec((h,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, weight)
    return out[:rows].reshape(orig_shape)
