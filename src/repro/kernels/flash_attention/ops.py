"""Dispatching wrapper for prefill flash attention."""
from __future__ import annotations

import os

import jax

from repro.kernels.flash_attention.flash_kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window=None):
    if jax.default_backend() == "tpu":
        return flash_attention_pallas(q, k, v, causal=causal, window=window)
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=True)
    return flash_attention_ref(q, k, v, causal=causal, window=window)
