"""Pure-jnp oracle for causal (optionally sliding-window) GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: [B,S,Hq,D]; k,v: [B,S,Hkv,D] -> [B,S,Hq,D]."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits *= 1.0 / np.sqrt(D)
    q_pos = jnp.arange(S)[:, None]
    kv_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, Hq, D)
