"""Pallas TPU flash-attention (prefill) kernel: causal + sliding-window GQA.

TPU adaptation of the FlashAttention blocking: the online-softmax running
max/denominator and the output accumulator live in VMEM scratch that persists
across the sequential KV-block grid dimension; Q/K/V tiles stream HBM→VMEM
once per (batch, head, q-block).  Block sizes are MXU-aligned (multiples of
128 on the contracted/lane dims).  Fully-masked KV blocks (beyond the causal
frontier or before the sliding window) are skipped with ``pl.when``.

Grid: (B, Hq, nQ, nKV) — nKV minor/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(np.finfo(np.float32).min)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i,
                  *, scale, block_q, block_kv, causal, window, seq_len):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q_start = iq * block_q
    k_start = ik * block_kv
    # Block-level relevance: any (q, k) pair with k <= q and k > q - window?
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if window is not None:
        relevant = jnp.logical_and(
            relevant, k_start + block_kv - 1 > q_start - window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bkv, D]
        s = q @ k.T                                          # [bq, bkv]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_len
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_i[...]                                    # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_i[...] = l_i[...] * alpha + p.sum(axis=1, keepdims=True)
        m_i[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                  # [bkv, D]
        acc[...] = acc[...] * alpha + p @ v

    @pl.when(ik == nk - 1)
    def _flush():
        denom = jnp.maximum(l_i[...], 1e-30)
        o_ref[0, 0] = (acc[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False):
    """q: [B,S,Hq,D]; k,v: [B,S,Hkv,D] -> [B,S,Hq,D]."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0

    qt = q.transpose(0, 2, 1, 3)                             # [B,Hq,S,D]
    kt = k.transpose(0, 2, 1, 3)                             # [B,Hkv,S,D]
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, Hq, S // block_q, S // block_kv)
    q_spec = pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0))
    kv_spec = pl.BlockSpec((1, 1, block_kv, D),
                           lambda b, h, iq, ik: (b, h // G, ik, 0))
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=1.0 / np.sqrt(D),
                          block_q=block_q, block_kv=block_kv,
                          causal=causal, window=window, seq_len=S),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32),
                        pltpu.VMEM((block_q, 1), jnp.float32),
                        pltpu.VMEM((block_q, 1), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
