"""Synthetic token pipeline: seeded, reproducible, mesh-shardable.

A deterministic counter-based generator (splitmix64 over (seed, step, index))
produces token streams without any host-side RNG state, so every data-parallel
host can materialize exactly its shard of the global batch — the pattern a
real distributed loader must follow.  Documents/packing: fixed-length packed
sequences with BOS resets every ``doc_len`` tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

try:
    import jax
    from jax.sharding import NamedSharding
except Exception:                                  # pragma: no cover
    jax = None


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bos_id: int = 1
    doc_len: int = 512

    def batch_at(self, step: int, lo: int = 0, hi: Optional[int] = None) -> np.ndarray:
        """Rows [lo, hi) of the global batch for ``step`` — any host can ask
        for exactly its shard."""
        hi = self.global_batch if hi is None else hi
        rows = np.arange(lo, hi, dtype=np.uint64)[:, None]
        cols = np.arange(self.seq_len, dtype=np.uint64)[None, :]
        key = (np.uint64(self.seed) * np.uint64(0x100000001B3)
               + np.uint64(step) * np.uint64(0x1000193))
        raw = _splitmix64(key + rows * np.uint64(self.seq_len * 131) + cols)
        toks = (raw % np.uint64(max(self.vocab_size - 2, 1))).astype(np.int32) + 2
        toks[:, ::self.doc_len] = self.bos_id       # packed document resets
        return toks

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    # ------------------------------------------------------------------
    def global_array(self, step: int, mesh, spec):
        """Materialize step's batch as a correctly-sharded global jax.Array,
        each addressable shard filled host-side (no full-batch broadcast)."""
        sharding = NamedSharding(mesh, spec)
        shape = (self.global_batch, self.seq_len)

        def cb(index):
            rows = index[0]
            lo = rows.start or 0
            hi = rows.stop if rows.stop is not None else self.global_batch
            sl = self.batch_at(step, lo, hi)
            cols = index[1]
            return sl[:, cols]

        return jax.make_array_from_callback(shape, sharding, cb)
