"""Cross-request prefix index: radix/hash lookup over prompt token blocks
(DESIGN.md §13).

Production traffic is thousands of users sharing a handful of system
prompts — the paper's TTFT analysis says prefill (and every collective
inside it) dominates short interactive requests, yet that prefill is
recomputed per request for tokens the KV pool already holds.  This module
is the vLLM-style fix: an index over *page-granular* blocks of prompt
tokens, so ``Scheduler`` can detect the longest cached prefix of a new
request, ``adopt`` its pages, and run chunked prefill only over the novel
suffix.

Keying.  Block ``i`` of a prompt is tokens ``[i·ps, (i+1)·ps)``; its key is
the raw bytes of the prompt's first ``(i+1)·ps`` tokens — a chain key, so a
block entry matches only when every block before it matches too (the radix
property, with exact-bytes keys instead of hashes: a hash collision here
would silently serve another prompt's KV, which is a token-corruption bug,
not a cache miss).  Only FULL blocks are indexed: a partial tail page's
rows keep being rewritten by decode, so its content is not a function of
the prompt alone.

Ref-counting.  Each entry owns its single page through a dedicated pool
owner (negative ids — slot owners are >= 0) via ``KVPool.adopt``, so the
ordinary refcount machinery keeps cached pages alive after the request
that wrote them frees its slot, and ``stats()`` stays physically honest.
A cache hit re-adopts the matched entries' pages into the new request's
slot; a hit that covers the whole prompt is capped at ``prompt_len - 1``
(the last position must be prefilled to produce the first token), which
shares the final page *partially* — the first write into it triggers the
pool's copy-on-write.

Eviction.  Entries are LRU (refreshed on lookup hit and on insert).  Under
pool pressure the backend calls ``evict_one``/``evict_for`` to pop LRU
entries until enough pages return to the free list; an entry whose page
other owners still hold frees nothing immediately (the page returns when
the last owner does) but stops pinning it.  ``reclaimable_pages`` — the
entries whose page would free *right now* — joins the admission gate's
free-page arithmetic, so a pool full of cold cache is never mistaken for a
full pool.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import List, Optional

import numpy as np

from repro.runtime.kvpool import KVPool


@dataclasses.dataclass
class PrefixHit:
    """Longest cached prefix of a prompt: the physical pages to adopt and
    the token positions they cover (capped below the full prompt so the
    final position is always prefilled)."""

    length: int                  # tokens covered (0 = miss)
    pages: List[int]             # physical pages, logical order

    @property
    def hit(self) -> bool:
        return self.length > 0


@dataclasses.dataclass
class _Entry:
    owner: int                   # index-held pool owner (negative)
    page: int                    # the single physical page this entry pins
    blocks: int                  # chain depth: this is block `blocks - 1`


class PrefixIndex:
    """Page-granular prefix cache over a :class:`KVPool`."""

    def __init__(self, pool: KVPool, max_entries: Optional[int] = None):
        self.pool = pool
        self.page_size = pool.page_size
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()
        self._next_owner = -1    # index owners are negative; slots are >= 0
        self.hits = 0            # lookups that matched >= 1 block
        self.misses = 0
        self.evictions = 0       # entries evicted (pressure or capacity)

    # ------------------------------------------------------------- keying
    def _key(self, tokens: np.ndarray, blocks: int) -> bytes:
        return np.ascontiguousarray(
            tokens[:blocks * self.page_size], np.int32).tobytes()

    # ------------------------------------------------------------ interface
    def lookup(self, tokens) -> PrefixHit:
        """Longest cached prefix of ``tokens``, capped at ``len(tokens)-1``
        so at least one position remains for the suffix prefill (the hit
        request still needs the final position's logits).  Matched entries
        are LRU-refreshed.  The returned pages are NOT yet pinned for the
        caller — adopt them (``KVPool.adopt``) before anything can evict."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        pages: List[int] = []
        blocks = 0
        while blocks < len(tokens) // self.page_size:
            e = self._entries.get(self._key(tokens, blocks + 1))
            if e is None:
                break
            pages.append(e.page)
            blocks += 1
        if blocks == 0:
            self.misses += 1
            return PrefixHit(0, [])
        for i in range(blocks):
            self._entries.move_to_end(self._key(tokens, i + 1))
        self.hits += 1
        # a fully-covered prompt keeps its final position for the suffix
        # prefill; the shortened length still spans the same pages, so the
        # last one is shared PARTIALLY and the first write COWs it
        length = min(blocks * self.page_size, len(tokens) - 1)
        return PrefixHit(length, pages)

    def insert(self, tokens, pages: List[int]) -> int:
        """Index every full block of ``tokens`` whose KV lives in
        ``pages`` (the owning slot's block table, logical order).  Each new
        entry pins its page through a fresh index owner; blocks already
        present are only LRU-refreshed — idempotent, so re-inserting after
        a recompute or a cache-hit admission is free.  Returns the number
        of NEW entries created."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        full = len(tokens) // self.page_size
        created = 0
        for i in range(min(full, len(pages))):
            key = self._key(tokens, i + 1)
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            owner = self._next_owner
            self._next_owner -= 1
            self.pool.adopt(owner, [pages[i]], self.page_size)
            self._entries[key] = _Entry(owner, pages[i], i + 1)
            created += 1
        while self.max_entries is not None \
                and len(self._entries) > self.max_entries:
            self.evict_one()
        return created

    # ------------------------------------------------------------- eviction
    def evict_one(self) -> bool:
        """Drop the LRU entry (False when the index is empty).  The page
        returns to the free list only if no slot (or deeper entry) still
        holds it — either way the index stops pinning it."""
        if not self._entries:
            return False
        _, e = self._entries.popitem(last=False)
        self.pool.free(e.owner)
        self.evictions += 1
        return True

    def evict_for(self, pages_needed: int) -> int:
        """Evict LRU entries until ``pages_needed`` pages are free in the
        pool (or the index is empty); returns entries evicted."""
        n = 0
        while self.pool.free_pages < pages_needed and self.evict_one():
            n += 1
        return n

    def clear(self) -> int:
        """Evict everything — the drain the zero-leak CI gate exercises."""
        n = 0
        while self.evict_one():
            n += 1
        return n

    # --------------------------------------------------------- introspection
    def reclaimable_pages(self) -> int:
        """Pages that would return to the free list if the index dropped
        every entry right now — entries whose page no one else holds."""
        return sum(1 for e in self._entries.values()
                   if self.pool.page_refcount(e.page) == 1)

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "reclaimable_pages": self.reclaimable_pages()}
