"""Production sharding rules for the (data, model[, pod]) mesh.

Megatron-consistent tensor layout (so the GSPMD collective schedule matches
the paper's TP analysis): column-parallel Q/K/V and GLU-up projections, row-
parallel output/down projections, vocab-parallel embedding and LM head.  MoE
expert stacks are sharded on the expert axis ("model") — expert parallelism.
RWKV/SSM channel projections follow the same column/row pattern.

KV caches: heads are sharded over "model" when divisible, otherwise the cache
*length* axis is sharded (sequence-parallel decode — a beyond-paper adaptation
needed for MQA archs like paligemma on a 16-wide model axis).

Optimizer state is additionally sharded like its parameter (ZeRO-style: the
fp32 m/v copies inherit the param spec, which already spreads them over
"model"; a further "data"-axis scatter is applied to replicated params).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig

# leaf-name -> which dim gets the "model" axis (negative = from the end)
_COL = {"wq", "wk", "wv", "w1", "w3", "sw1", "sw3", "in_proj", "wr", "wg",
        "cwk", "cwr", "w_dt", "lm_head"}          # shard last dim
_ROW = {"wo", "w2", "sw2", "cwv", "ssm_out"}      # shard second-to-last dim
_EXPERT = {"we1", "we2", "we3"}                   # shard expert dim (1)
_HEAD = {"u"}                                     # shard head dim (1)
_DI = {"w_B", "w_C", "A_log", "b_dt", "D"}        # shard d_inner dim (1)
_VOCAB0 = {"embed"}                               # shard dim 0 (vocab)
_REPLICATED = {"router", "w0", "wa", "wb"}        # small / fp32-sensitive


def _candidate_dims(name: str, ndim: int):
    """Preferred 'model'-axis dims per leaf class, in fallback order.

    MoE expert stacks prefer the expert dim (expert parallelism) but fall
    back to the FFN dim when num_experts < axis size (e.g. mixtral's 8
    experts on a 16-wide axis become tensor-parallel experts)."""
    if name in _VOCAB0:
        return [0, 1]
    if name in {"we1", "we3"}:
        return [1, 3]            # experts, then d_ff (column)
    if name == "we2":
        return [1, 2]            # experts, then d_ff (row)
    if name in _COL:
        return [ndim - 1]
    if name in _ROW:
        return [ndim - 2]
    if name in (_HEAD | _DI):
        return [1]
    return []


def _spec_for_leaf(name: str, shape, model_axis: str,
                   axis_size: Optional[int]) -> P:
    ndim = len(shape)
    none = [None] * ndim
    if name in _REPLICATED or ndim <= 1:
        return P(*none)
    for dim in _candidate_dims(name, ndim):
        if axis_size is None or shape[dim] % axis_size == 0:
            spec = list(none)
            spec[dim] = model_axis
            return P(*spec)
    return P(*none)


def param_specs(cfg: ModelConfig, params_shape, model_axis: str = "model",
                axis_size: Optional[int] = None):
    """PartitionSpec pytree matching a Model.init shape-tree.

    ``axis_size`` enables divisibility-aware fallbacks; pass the mesh's
    model-axis size (production: 16)."""

    def walk(tree, name=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        spec = _spec_for_leaf(name, tree.shape, model_axis, axis_size)
        if cfg.moe_fsdp and name in ("we1", "we2", "we3"):
            # §Perf: FSDP-style second-axis sharding of expert weights; the
            # local-dispatch path all-gathers them just-in-time per layer.
            data_dim = {"we1": 2, "we3": 2, "we2": 3}[name]
            if spec[data_dim] is None and tree.shape[data_dim] % 16 == 0:
                parts = list(spec)
                parts[data_dim] = "data"
                spec = P(*parts)
        return spec

    return walk(params_shape)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that carry data parallelism ("pod" first in multi-pod meshes)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_spec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> P:
    """Spec for [B, ...] host data; replicate when batch isn't divisible."""
    axes = batch_axes(mesh)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    lead = axes if (global_batch % dp == 0 and global_batch >= dp) else None
    return P(lead, *([None] * extra_dims))


def cache_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                model_axis: str = "model"):
    """Specs for Model.init_cache pytrees: [L, B, W, Hkv, D] k/v (+ states)."""
    m = mesh.shape[model_axis]
    axes = batch_axes(mesh)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    bdim = axes if global_batch % dp == 0 and global_batch >= dp else None

    def kv_spec(width: int):
        if cfg.num_kv_heads % m == 0:
            return P(None, bdim, None, model_axis, None)
        if width % m == 0:
            return P(None, bdim, model_axis, None, None)   # seq-parallel cache
        return P(None, bdim, None, None, None)

    def walk(name, leaf):
        if name in ("k", "v"):
            return kv_spec(leaf.shape[2])
        if name == "state":        # rwkv [L,B,H,hs,hs]
            ax = model_axis if leaf.shape[2] % m == 0 else None
            return P(None, bdim, ax, None, None)
        if name == "ssm_state":    # hymba [L,B,di,N]
            ax = model_axis if leaf.shape[2] % m == 0 else None
            return P(None, bdim, ax, None)
        if name in ("tm_prev", "cm_prev"):
            return P(None, bdim, None)
        return P(*([None] * len(leaf.shape)))

    def tree(t):
        return {k: walk(k, v) for k, v in t.items()}

    return tree


def shardings_from_specs(mesh: Mesh, specs):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def logits_spec(mesh: Mesh, global_batch: int, model_axis: str = "model",
                seq_dim: bool = False) -> P:
    b = data_spec(mesh, global_batch, 0)
    lead = b[0] if len(b) else None
    if seq_dim:
        return P(lead, None, model_axis)
    return P(lead, model_axis)
