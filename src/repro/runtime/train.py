"""Training substrate: loss functions + jit-able train_step per architecture.

Loss variants:
  * ``dense``   — next-token CE over the full logits (baseline).
  * ``fused``   — chunked CE that never materializes the [B,S,V] logits in one
    piece (vocab-chunked logsumexp).  This is a §Perf hillclimb option for the
    huge-vocab archs (paligemma 257k, gemma 256k); numerically identical.

Encoder (hubert) trains frame classification (no shift); VLM (paligemma)
computes CE on the text suffix only (prefix patches carry no targets).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.optim.adamw import AdamW


def _ce(logits, targets, vocab: int):
    """Mean cross-entropy in fp32.  logits [..., V]; targets [...] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def ce_from_hidden_chunked(x, head, targets, chunk: int = 16384,
                           vocab: int = None):
    """CE computed from final hidden states with a vocab-chunked logsumexp —
    peak activation ~[B,S,chunk] instead of [B,S,V].  x: [...,h];
    head: [h,V]; targets: [...] int.  ``vocab`` masks padded head columns.
    Numerically identical to _ce."""
    xf = x.astype(jnp.float32)
    V = head.shape[-1] if vocab is None else vocab
    chunk = min(chunk, V)
    n_chunks = (V + chunk - 1) // chunk
    pad = n_chunks * chunk - head.shape[-1]
    head_p = jnp.pad(head, [(0, 0), (0, pad)]) if pad > 0 else head

    def body(carry, i):
        run_max, run_sum = carry
        w = jax.lax.dynamic_slice_in_dim(head_p, i * chunk, chunk, axis=-1)
        lg = xf @ w.astype(jnp.float32)                       # [..., chunk]
        col = i * chunk + jnp.arange(chunk)
        lg = jnp.where(col < V, lg, -jnp.inf)
        m = jnp.maximum(run_max, lg.max(-1))
        run_sum = (run_sum * jnp.exp(run_max - m)
                   + jnp.exp(lg - m[..., None]).sum(-1))
        return (m, run_sum), None

    init = (jnp.full(xf.shape[:-1], -jnp.inf, jnp.float32),
            jnp.zeros(xf.shape[:-1], jnp.float32))
    (m, s), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    logz = m + jnp.log(s)
    gold_w = jnp.take(head, targets, axis=-1)                 # [h, ...]
    gold_w = jnp.moveaxis(gold_w, 0, -1).astype(jnp.float32)  # [..., h]
    gold = jnp.sum(xf * gold_w, axis=-1)
    return jnp.mean(logz - gold)


def make_loss_fn(model: Model, loss_impl: str = "dense") -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        if cfg.family == "encoder":
            logits, aux = model.forward(params, features=batch["features"])
            loss = _ce(logits, batch["targets"], cfg.vocab_size)
            return loss + aux, {"ce": loss, "aux": aux}
        prefix = batch.get("prefix_emb")
        tokens = batch["tokens"]
        if loss_impl == "fused":
            hidden, aux = model.forward(params, tokens, prefix_emb=prefix,
                                        return_hidden=True)
            if prefix is not None:
                hidden = hidden[:, prefix.shape[1]:]
            loss = ce_from_hidden_chunked(hidden[:, :-1],
                                          model.head_matrix(params),
                                          tokens[:, 1:],
                                          vocab=cfg.vocab_size)
        else:
            logits, aux = model.forward(params, tokens, prefix_emb=prefix)
            if prefix is not None:
                logits = logits[:, prefix.shape[1]:]
            loss = _ce(logits[:, :-1], tokens[:, 1:], cfg.vocab_size)
        return loss + aux, {"ce": loss, "aux": aux}

    return loss_fn


def make_train_step(model: Model, optimizer: AdamW,
                    loss_impl: str = "dense") -> Callable:
    loss_fn = make_loss_fn(model, loss_impl)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def make_eval_step(model: Model) -> Callable:
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return {"loss": loss, **metrics}

    return eval_step
