"""Continuous-batching scheduler over a DecodeBackend (paper §V-C, serving).

vLLM-style iteration-level scheduling, reduced to the pieces the paper's SLO
study actually exercises: a fixed pool of KV-cache *slots*, admission of
queued requests into freed slots between decode steps (each admission is one
batch-1 prefill scattered into the slot row), one fused decode step per
iteration over the whole slot batch with per-sequence positions, and
EOS/length-based eviction.

Robustness layer (DESIGN.md §10).  The scheduler survives the traffic mixes
that oversubscribe it instead of only modeling the sunny day:

  * **Admission policy.**  ``admission="conservative"`` (default) commits
    every paged request's worst-case decode budget up front — mid-decode
    page exhaustion is impossible, but EOS-heavy traffic strands pool
    capacity on budgets that never materialize.  ``admission="optimistic"``
    admits on *current* need (the prompt's pages) and recovers from the
    resulting pressure by preemption.
  * **Preemption-by-recompute.**  When ``KVPool.extend`` hits
    ``MemoryError`` mid-decode, the youngest active request is preempted:
    pages and slot freed, the request requeued *retaining its generated
    tokens*.  Re-admission re-prefills prompt + generated prefix in one
    pass; greedy decode is deterministic, so the recompute's final-position
    token must equal the last token generated before preemption (asserted
    at runtime — the token-identity invariant), and the stream continues
    bitwise identical to an uninterrupted run.  Each recompute pass is
    logged as a phase="recompute" StepRecord carrying the predicted prefill
    collectives of the prefix (``commodel.preemption_recompute_ops``) next
    to the measured PP transfers.
  * **Deadlines & cancellation.**  ``Request.deadline`` /
    ``ttft_deadline`` shed hopeless requests mid-flight
    (finish_reason="deadline"); ``Scheduler.cancel(rid)`` shed them on
    demand ("cancelled").
  * **Fault tolerance.**  With a ``runtime.faults.FaultInjector`` attached,
    injected faults at the decode/prefill/pool/pp_transfer sites are
    absorbed: transient failures retry with exponential backoff (visible on
    the virtual clock), permanent ones finish the affected requests with
    finish_reason="error", injected pool exhaustion takes the preemption
    path, and transfer delays stretch the clock.

The scheduler measures the quantities ``core.slo.predict_slo`` predicts —
per-request TTFT / TPOT / E2E — and records per-step communication: predicted
collective counts/bytes from ``commodel.comm_ops_for`` plus, for pipeline
backends, the engine's measured boundary TransferRecords.  The paper's claim
that per-step collective *counts* are batch-invariant (only message bytes
scale with batch) is load-bearing here — it is what makes a fixed-capacity
decode step correct for a varying active set — so it is asserted against
``comm_ops_for(batch=...)`` at construction time.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.commodel import kv_handoff_ops, kv_handoff_pages
from repro.runtime.backends import DecodeBackend
from repro.runtime.faults import PermanentFault, TransientFault
from repro.runtime.request import Request, RequestMetrics
from repro.runtime.schedule import RoundResult, make_queue


# ---------------------------------------------------------------------------
# clocks (injectable so tests run on virtual time)
# ---------------------------------------------------------------------------


class WallClock:
    """Real time, relative to construction; ``wait_until`` sleeps."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t: float) -> None:
        delta = t - self.now()
        if delta > 0:
            time.sleep(delta)


class VirtualClock:
    """Deterministic clock for tests: time only moves via ``wait_until`` /
    ``advance`` — decode steps take zero virtual time."""

    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def wait_until(self, t: float) -> None:
        self.t = max(self.t, t)

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------------------
# per-step traffic records
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepRecord:
    """Communication of one scheduler iteration: one decode *round* (one
    microbatch group through the instruction queue — the whole slot batch on
    fused backends), one prefill chunk (chunked-prefill mode, DESIGN.md §8),
    or one preemption's recompute pass (DESIGN.md §10).

    ``wall_s`` and the per-stage ``stage_busy``/``stage_idle`` tick deltas
    (DESIGN.md §11) make schedule occupancy a *measured* quantity: summing
    the deltas over a run reproduces the queue's busy/idle totals, and
    ``busy/(busy+idle)`` per stage is the measured bubble occupancy next to
    the ``commodel.pp_schedule_stats`` prediction."""

    step: int
    n_active: int
    collective_counts: Dict[str, int]     # predicted, per decode round/pass
    predicted_wire_bytes: float           # at the group batch (decode) / 1
    measured_transfers: Dict[str, int]    # PP boundary hops of this round
    phase: str = "decode"                 # "decode" | "prefill" | "recompute"
    rid: Optional[int] = None             # request, for prefill/recompute
    prefix_len: Optional[int] = None      # recomputed positions (recompute)
    cached_prefix_len: Optional[int] = None   # prefix-cache hit (prefill,
    #                                           DESIGN.md §13): positions
    #                                           adopted instead of computed
    wall_s: float = 0.0                   # host wall time of the round/pass
    stage_busy: Optional[List[int]] = None   # per-stage busy ticks (decode)
    stage_idle: Optional[List[int]] = None   # per-stage idle ticks (decode)


def step_collective_counts(backend: DecodeBackend,
                           batch: int = 1) -> Dict[str, int]:
    """Collective call counts of ONE decode step, summed by collective."""
    counts: Dict[str, int] = {}
    for o in backend.decode_comm_ops(batch=batch):
        counts[o.collective] = counts.get(o.collective, 0) + o.count
    return counts


def assert_counts_batch_invariant(backend: DecodeBackend) -> None:
    """The paper's batch-invariance property, asserted: a decode step issues
    the same number of collectives at any batch size — only wire bytes scale
    (linearly).  The scheduler's fixed-capacity step depends on this."""
    base = backend.decode_comm_ops(batch=1)
    for batch in (2, backend.num_slots):
        if batch < 2:
            continue
        scaled = backend.decode_comm_ops(batch=batch)
        if step_collective_counts(backend, 1) != \
                step_collective_counts(backend, batch):
            raise AssertionError(
                f"per-step collective counts vary with batch={batch}: "
                f"{step_collective_counts(backend, 1)} vs "
                f"{step_collective_counts(backend, batch)}")
        for o1, ob in zip(base, scaled):
            if not np.isclose(ob.wire_bytes, batch * o1.wire_bytes):
                raise AssertionError(
                    f"wire bytes not linear in batch for {o1.collective}: "
                    f"{ob.wire_bytes} != {batch} * {o1.wire_bytes}")


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


_NORMAL_FINISH = ("length", "eos")


@dataclasses.dataclass
class ServingReport:
    metrics: List[RequestMetrics]
    steps: List[StepRecord]
    wall_time: float

    @property
    def total_tokens(self) -> int:
        return sum(m.num_generated for m in self.metrics)

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.wall_time if self.wall_time else 0.0

    @property
    def preemptions(self) -> int:
        return sum(m.preemptions for m in self.metrics)

    @property
    def retries(self) -> int:
        return sum(m.retries for m in self.metrics)

    def tokens_by_rid(self) -> Dict[int, List[int]]:
        return {m.rid: list(m.tokens) for m in self.metrics}

    def occupancy(self) -> dict:
        """Measured decode-schedule occupancy (DESIGN.md §11), aggregated
        over the decode StepRecords' per-stage busy/idle tick deltas:
        schedule ticks, per-stage busy fractions, decode tokens per tick.
        Deterministic — the schedule clock, not wall time — so the
        pp-occupancy bench series can gate it exactly."""
        recs = [r for r in self.steps
                if r.phase == "decode" and r.stage_busy is not None]
        if not recs:
            return {"ticks": 0, "decode_tokens": 0, "tokens_per_tick": 0.0,
                    "stage_busy_fraction": [], "busy_fraction_mean": 0.0}
        n = len(recs[0].stage_busy)
        busy = [sum(r.stage_busy[s] for r in recs) for s in range(n)]
        idle = [sum(r.stage_idle[s] for r in recs) for s in range(n)]
        ticks = busy[0] + idle[0]   # every stage is busy or idle each tick
        frac = [b / max(b + i, 1) for b, i in zip(busy, idle)]
        # n_active at record time == tokens appended by that round
        dec_tokens = sum(r.n_active for r in recs)
        return {"ticks": ticks,
                "decode_tokens": dec_tokens,
                "tokens_per_tick": dec_tokens / ticks if ticks else 0.0,
                "stage_busy_fraction": frac,
                "busy_fraction_mean": float(np.mean(frac))}

    def summary(self) -> dict:
        def _pct(vals, q):
            return float(np.percentile(vals, q)) if vals else 0.0

        # shed requests may never have produced a first token — keep their
        # zero-initialized first_token out of the TTFT statistics
        ttfts = [m.ttft for m in self.metrics if m.num_generated > 0]
        tpots = [m.tpot for m in self.metrics if m.num_generated > 1]
        e2es = [m.e2e for m in self.metrics]
        return {
            "requests": len(self.metrics),
            "total_tokens": self.total_tokens,
            "wall_time_s": self.wall_time,
            "throughput_tok_s": self.throughput,
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_p95_s": _pct(ttfts, 95),
            "tpot_mean_s": float(np.mean(tpots)) if tpots else 0.0,
            "tpot_p95_s": _pct(tpots, 95),
            "e2e_mean_s": float(np.mean(e2es)) if e2es else 0.0,
            "e2e_p95_s": _pct(e2es, 95),
            "preemptions": self.preemptions,
            "retries": self.retries,
            "shed": len([m for m in self.metrics
                         if m.finish_reason not in _NORMAL_FINISH]),
        }


@dataclasses.dataclass
class _Active:
    req: Request
    metrics: RequestMetrics
    seq: int = 0                  # admission sequence (preemption order)


@dataclasses.dataclass
class _Prefilling:
    """A request whose prompt (or recompute prefix) is mid-way through
    chunked prefill."""

    req: Request
    metrics: RequestMetrics
    prefix: np.ndarray            # tokens being prefilled (prompt, or
    #                               prompt + generated prefix on recompute)
    done: int = 0                 # prefix positions already prefilled
    resume: Optional[List[int]] = None   # generated tokens (recompute only)
    cached: int = 0               # prefix-cache hit length: positions
    #                               adopted at admission, never computed —
    #                               chunking starts at done == cached


class Scheduler:
    """Continuous batching over ``backend.num_slots`` KV-cache slots.

    One ``step()`` = shed expired requests, admit every arrived request a
    free slot can take (batch-1 prefill each, TTFT stamped), then ONE fused
    decode step over the full slot batch with per-sequence positions, then
    eviction of finished sequences (EOS or length), freeing their slots for
    the next iteration's admissions.

    ``chunk_size`` (paged backends only, DESIGN.md §8) turns prefill into
    *chunked* prefill: admission only allocates the slot's pages, and each
    iteration advances ONE prefilling request by one ``chunk_size``-token
    pass before the decode step — so a long prompt no longer stalls running
    slots for its whole prefill, only for one chunk.  Iterations with no
    decoding slot skip the jitted decode step entirely (nothing useful would
    run in it) and just advance prefill / wait for the next arrival.

    ``admission`` ("conservative" | "optimistic"), ``faults``,
    ``retry_limit`` and ``retry_backoff`` are the robustness knobs —
    DESIGN.md §10 and the module docstring.
    """

    def __init__(self, backend: DecodeBackend, clock=None,
                 chunk_size: int = None, admission: str = "conservative",
                 faults=None, retry_limit: int = 3,
                 retry_backoff: float = 0.05):
        self.backend = backend
        self.clock = clock if clock is not None else WallClock()
        self.num_slots = backend.num_slots
        self.queue: List[Request] = []     # sorted by arrival, FIFO in ties
        self.free: List[int] = list(range(self.num_slots))
        self.active: Dict[int, _Active] = {}
        self.prefilling: Dict[int, _Prefilling] = {}   # slot -> state (FIFO)
        self.chunk_size = chunk_size
        if admission not in ("conservative", "optimistic"):
            raise ValueError(
                f"admission must be 'conservative' or 'optimistic', "
                f"got {admission!r}")
        if admission == "optimistic" and not getattr(backend, "paged", False):
            raise ValueError(
                "optimistic admission relaxes the KV-page commitment; "
                "contiguous slot backends have nothing to overcommit — "
                "construct the backend with paged=True")
        self.admission = admission
        self.faults = faults
        if retry_limit < 0:
            raise ValueError(f"retry_limit must be >= 0, got {retry_limit}")
        self.retry_limit = int(retry_limit)
        self.retry_backoff = float(retry_backoff)
        if chunk_size is not None:
            if chunk_size < 1:
                raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
            if not getattr(backend, "paged", False):
                raise ValueError(
                    "chunked prefill writes straight into KV pages; "
                    "construct the backend with paged=True")
            if getattr(backend, "c", 1) > 1:
                raise ValueError(
                    "chunked prefill and context parallelism are "
                    "alternative long-prompt strategies (DESIGN.md §9); "
                    "a c>1 backend prefills monolithically")
            # per-chunk counts are chunk-length-invariant (commodel.
            # chunked_prefill_ops) — compute once at the nominal size
            self._chunk_counts = self._count(
                backend.chunk_comm_ops(chunk_size))
        self.tokens = np.zeros(self.num_slots, np.int32)
        self.pos = np.zeros(self.num_slots, np.int64)
        self.finished: List[RequestMetrics] = []
        self.step_log: List[StepRecord] = []
        self._step_i = 0
        self._rids: set = set()            # every rid this run has seen
        self._preempted: Dict[int, RequestMetrics] = {}  # rid -> metrics
        self._adm_seq = 0
        self._total_tokens = 0
        self._last_sig = None
        self._idle_iters = 0
        # the batch-invariance the fixed-capacity step relies on (paper
        # Tables III–VI: no batch term in any count column)
        assert_counts_batch_invariant(backend)
        self._step_counts = step_collective_counts(backend, 1)
        # the engine's instruction queue (DESIGN.md §11): decode no longer
        # calls backend.decode_step directly — rounds are begun per
        # microbatch group and pumped through the queue
        self._queue = make_queue(backend)
        self._group_size = self._queue.group_size
        self._step_bytes = sum(
            o.wire_bytes
            for o in backend.decode_comm_ops(batch=self._group_size))

    @staticmethod
    def _count(ops) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for o in ops:
            counts[o.collective] = counts.get(o.collective, 0) + o.count
        return counts

    # ------------------------------------------------------------- intake
    def submit(self, requests) -> None:
        reqs = [requests] if isinstance(requests, Request) else list(requests)
        paged = getattr(self.backend, "paged", False)
        c = getattr(self.backend, "c", 1)
        seen: set = set()
        for r in reqs:
            if r.rid in self._rids or r.rid in seen:
                raise ValueError(
                    f"duplicate rid {r.rid}: already submitted this run "
                    f"(per-request metrics and token streams key on rid)")
            seen.add(r.rid)
            # the last generated token is never fed back, so the highest
            # cache position written is prompt_len + max_new_tokens - 2;
            # CP pads the prompt to a multiple of c (DESIGN.md §9)
            need = max(r.prompt_len + r.max_new_tokens - 1,
                       -(-r.prompt_len // c) * c)
            w = self.backend.cfg.sliding_window
            if need > self.backend.max_len and not w:
                raise ValueError(
                    f"request {r.rid} needs {need} cache positions "
                    f"> max_len {self.backend.max_len}")
            if paged:
                # a request the EMPTY pool couldn't hold would never pass
                # the admission gate — reject it up front, don't deadlock
                need_pages = -(-need // self.backend.page_size)
                usable = self.backend.pool.num_pages - 1    # minus scratch
                if need_pages > usable:
                    raise ValueError(
                        f"request {r.rid} needs {need_pages} pages "
                        f"> pool capacity {usable}")
        for r in reqs:
            self._rids.add(r.rid)
            self._enqueue(r)

    def _enqueue(self, req: Request) -> None:
        """Sorted insert by arrival time — O(log n) search + one list
        insert, replacing the old full re-sort per submit.  ``insort`` is
        right-biased, so equal arrivals keep FIFO submission order (and a
        preempted request requeues behind same-arrival peers)."""
        bisect.insort(self.queue, req, key=lambda r: r.arrival)

    # ------------------------------------------------------------- lifecycle
    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it currently lives — queued, mid-
        prefill, or actively decoding.  Generated tokens are kept and the
        request finishes with ``finish_reason="cancelled"``.  Returns False
        when the rid is unknown or already finished (cancellation raced
        completion — the tokens already exist either way)."""
        now = self.clock.now()
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                self._shed_queued(req, "cancelled", now)
                return True
        for slot, st in list(self.prefilling.items()):
            if st.req.rid == rid:
                self._abort_prefill(slot, "cancelled", now)
                return True
        for slot, st in list(self.active.items()):
            if st.req.rid == rid:
                # complete any in-flight round first: freeing the slot (and
                # its pages) under a round that still writes them would
                # corrupt a concurrent group's schedule (DESIGN.md §11)
                self._drain_queue()
                if slot in self.active and self.active[slot].req.rid == rid:
                    self._finish(slot, "cancelled", self.clock.now())
                return True         # tokens exist either way (drain may
                #                     have finished the request normally)
        return False

    @staticmethod
    def _expired(req: Request, now: float, pre_first_token: bool) -> bool:
        if req.deadline is not None and now > req.arrival + req.deadline:
            return True
        return pre_first_token and req.ttft_deadline is not None \
            and now > req.arrival + req.ttft_deadline

    def _shed_queued(self, req: Request, reason: str, now: float) -> None:
        """Finish a request straight out of the queue (deadline/cancel).
        A preempted request keeps the tokens it generated before eviction."""
        m = self._preempted.pop(req.rid, None)
        if m is None:
            m = RequestMetrics(rid=req.rid, prompt_len=req.prompt_len,
                               arrival=req.arrival)
        m.finished = now
        m.finish_reason = reason
        self.finished.append(m)

    def _abort_prefill(self, slot: int, reason: str, now: float) -> None:
        st = self.prefilling.pop(slot)
        self.backend.free_slots([slot])
        self.free.append(slot)
        st.metrics.finished = now
        st.metrics.finish_reason = reason
        self.finished.append(st.metrics)

    def _shed_expired(self, now: float) -> None:
        """Drop every queued / mid-prefill request that can no longer meet
        its deadline — head-of-line or not, capacity spent on it is wasted."""
        for req in [r for r in self.queue
                    if self._expired(r, now, r.rid not in self._preempted)]:
            self.queue.remove(req)
            self._shed_queued(req, "deadline", now)
        for slot, st in list(self.prefilling.items()):
            if self._expired(st.req, now, st.resume is None):
                self._abort_prefill(slot, "deadline", now)

    # ------------------------------------------------------------- faults
    def _apply_fault(self, site: str) -> None:
        if self.faults is None:
            return
        f = self.faults.draw(site)
        if f is None:
            return
        if f.kind == "delay":
            self.clock.wait_until(self.clock.now() + f.delay_s)
        elif f.kind == "oom":
            raise MemoryError(f"injected fault at {site}")
        elif f.kind == "transient":
            raise TransientFault(f"injected fault at {site}")
        else:
            raise PermanentFault(f"injected fault at {site}")

    def _backoff(self, attempt: int) -> None:
        """Exponential backoff before retry attempt N (1-based), on the
        scheduler clock so virtual-clock tests see the waits."""
        self.clock.wait_until(self.clock.now()
                              + self.retry_backoff * 2.0 ** (attempt - 1))

    # ------------------------------------------------------------- admission
    def _finish(self, slot: int, reason: str, now: float) -> None:
        st = self.active.pop(slot)
        st.metrics.finished = now
        st.metrics.finish_reason = reason
        self.finished.append(st.metrics)
        self.backend.free_slots([slot])
        self.free.append(slot)
        self.tokens[slot] = 0
        self.pos[slot] = 0

    def _preempt_youngest(self) -> None:
        """Evict the most recently admitted active request: free its pages
        and slot, requeue it retaining its generated tokens (re-admission
        recomputes the prefix — DESIGN.md §10).

        With rounds in flight (DESIGN.md §11) victims come from groups with
        NO issued work: freeing pages a busy round still writes would let a
        subsequent ``start_round`` re-allocate them mid-write.  The group
        whose ``start_round`` raised MemoryError is never busy and holds at
        least one active slot, so a safe candidate always exists; at depth
        1 every group is idle here and this reduces to the old global
        youngest-first rule."""
        busy = self._queue.busy_groups()
        cands = [s for s in self.active if s // self._group_size not in busy]
        slot = max(cands or self.active,
                   key=lambda s: self.active[s].seq)
        st = self.active.pop(slot)
        st.metrics.preemptions += 1
        self._preempted[st.req.rid] = st.metrics
        self.backend.free_slots([slot])
        self.free.append(slot)
        self.tokens[slot] = 0
        self.pos[slot] = 0
        self._enqueue(st.req)

    def _run_prefill(self, slot: int, prefix: np.ndarray,
                     metrics: RequestMetrics,
                     start: int = 0) -> Optional[int]:
        """One whole-prefix prefill pass with fault injection + bounded
        retry; returns the final position's greedy token, or None when the
        request errored out (caller frees the slot).  ``start`` skips a
        prefix-cache hit's adopted positions (DESIGN.md §13) — a retry
        rewrites the same suffix rows, so it stays idempotent."""
        paged = getattr(self.backend, "paged", False)
        attempt = 0
        while True:
            try:
                self._apply_fault("prefill")
                if paged:
                    tok = int(self.backend.prefill_whole(slot, prefix,
                                                         start=start))
                    self.backend.finish_prefill(slot)
                else:
                    tok = int(self.backend.prefill_into_slots(
                        [prefix], [slot])[0])
                return tok
            except TransientFault:
                attempt += 1
                if attempt > self.retry_limit:
                    return None
                metrics.retries += 1
                self._backoff(attempt)
            except PermanentFault:
                return None

    def _stop_reason(self, req: Request,
                     metrics: RequestMetrics) -> Optional[str]:
        """Normal finish check after a token append: model EOS, emulated
        EOS (``eos_pos``), or exhausted decode budget."""
        if req.eos_id is not None and metrics.tokens[-1] == req.eos_id:
            return "eos"
        if req.eos_pos is not None and \
                metrics.num_generated >= req.eos_pos:
            return "eos"
        if metrics.num_generated >= req.max_new_tokens:
            return "length"
        return None

    def _admit_ready(self) -> None:
        paged = getattr(self.backend, "paged", False)
        optimistic = self.admission == "optimistic"
        while self.free and self.queue and \
                self.queue[0].arrival <= self.clock.now():
            req = self.queue[0]
            state = self._preempted.get(req.rid)
            if state is None:
                prefix_len = req.prompt_len
                budget = req.max_new_tokens
            else:
                # recompute prefix: prompt + all generated tokens but the
                # last (which was emitted, never fed back) — total worst
                # case positions are unchanged from first admission
                prefix_len = req.prompt_len + len(state.tokens) - 1
                budget = req.max_new_tokens - len(state.tokens) + 1
            if paged and not self.backend.can_admit(prefix_len, budget,
                                                    optimistic=optimistic):
                # a free slot but not enough pages: keep it queued
                # (head-of-line — admission order stays arrival order)
                # until evictions free pages.  Optimistic admission only
                # needs the prefix's pages now; the decode budget is
                # covered by preemption instead of reservation.
                break
            # Sync: complete in-flight rounds before the admission prefill
            # donates into caches/pages a round may still read (no-op at
            # depth 1 — nothing is ever in flight between steps)
            self._drain_queue()
            self.queue.pop(0)
            slot = self.free.pop(0)
            self._adm_seq += 1
            if state is None:
                m = RequestMetrics(rid=req.rid, prompt_len=req.prompt_len,
                                   arrival=req.arrival,
                                   admitted=self.clock.now())
                prefix = req.prompt
                resume = None
            else:
                m = self._preempted.pop(req.rid)
                prefix = np.concatenate(
                    [req.prompt, np.asarray(m.tokens[:-1], np.int32)])
                resume = list(m.tokens)
            hit = 0
            if paged:
                # prefix-cache lookup (DESIGN.md §13) covers fresh prompts
                # AND recompute prefixes: a recompute prefix begins with
                # the very prompt whose blocks the index pinned at first
                # admission, so re-admission adopts those pages and
                # recomputes only from the first novel position on.  The
                # §10 token-identity assertion stays honest — generated
                # tokens never enter the index (``cache_prefix`` indexes
                # prompts only), so a hit can never cover the recomputed
                # tail whose final token the assertion checks.
                if getattr(self.backend, "prefix_index", None) is not None:
                    hit = self.backend.begin_prefill_cached(slot, prefix,
                                                            budget)
                    if resume is None:
                        m.cached_prefix_len = hit
                else:
                    self.backend.begin_prefill(slot, len(prefix), budget)
                if self.chunk_size is not None:
                    self.prefilling[slot] = _Prefilling(req, m, prefix=prefix,
                                                        resume=resume,
                                                        done=hit, cached=hit)
                    continue
            if resume is not None:
                # isolate the recompute pass's measured boundary hops
                self.backend.drain_transfers()
            tok = self._run_prefill(slot, prefix, m, start=hit)
            if tok is None:
                self.backend.free_slots([slot])
                self.free.append(slot)
                m.finished = self.clock.now()
                m.finish_reason = "error"
                self.finished.append(m)
                continue
            self._queue.note_prefill(slot)
            now = self.clock.now()
            if resume is not None:
                self._log_recompute(req.rid, len(prefix), cached=hit)
                self._resume_active(slot, req, m, resume, len(prefix), tok)
                continue
            if paged and hasattr(self.backend, "cache_prefix"):
                # index the freshly committed prompt blocks (DESIGN.md §13)
                self.backend.cache_prefix(slot, req.prompt)
            m.first_token = now
            m.tokens.append(tok)
            self._total_tokens += 1
            self.active[slot] = _Active(req, m, seq=self._adm_seq)
            self.tokens[slot] = tok
            self.pos[slot] = req.prompt_len
            reason = self._stop_reason(req, m)
            if reason:
                self._finish(slot, reason, now)

    def _log_recompute(self, rid: int, prefix_len: int,
                       cached: int = 0) -> None:
        """Log one recompute pass.  A warm recompute (prefix-cache hit on
        re-admission) only executes ``prefix_len - cached`` positions, so
        predicted wire bytes scale with the honest suffix — counts are
        prefill-length-invariant either way."""
        ops = self.backend.prefill_comm_ops(prefix_len - cached)
        self.step_log.append(StepRecord(
            step=self._step_i, n_active=len(self.active),
            collective_counts=self._count(ops),
            predicted_wire_bytes=sum(o.wire_bytes for o in ops),
            measured_transfers=self.backend.drain_transfers(),
            phase="recompute", rid=rid, prefix_len=prefix_len,
            cached_prefix_len=cached or None))
        self._step_i += 1

    def _resume_active(self, slot: int, req: Request, m: RequestMetrics,
                       resume: List[int], prefix_len: int,
                       tok: int) -> None:
        """Rejoin the decoding set after a recompute pass.  The pass's
        final-position greedy token must be bitwise the last token the
        request generated before preemption — greedy decode is
        deterministic, so anything else means the recomputed KV diverged."""
        if tok != resume[-1]:
            raise RuntimeError(
                f"preemption token-identity violated for rid {req.rid}: "
                f"recompute of {prefix_len} positions produced token {tok}, "
                f"stream had {resume[-1]}")
        self.active[slot] = _Active(req, m, seq=self._adm_seq)
        self.tokens[slot] = resume[-1]
        self.pos[slot] = prefix_len
        # metrics keep their original admitted/first_token stamps: TTFT
        # already happened; preemption shows up in TPOT/E2E, where the
        # recompute actually costs

    def _advance_prefill(self) -> None:
        """Run ONE prefill chunk for the oldest mid-prefill request; on the
        final chunk the request's first token is stamped (TTFT) and the slot
        joins the decoding set.  A recompute prefix (``resume``) re-chunks
        the same way, logging phase="recompute" records."""
        # Sync: a chunk writes the slot's pages — no round may be mid-read
        self._drain_queue()
        slot = next(iter(self.prefilling))
        st = self.prefilling[slot]
        start = st.done
        end = min(start + self.chunk_size, len(st.prefix))
        attempt = 0
        while True:
            try:
                self._apply_fault("prefill")
                t0 = time.perf_counter()
                tok = self.backend.prefill_chunk(
                    slot, st.prefix[start:end], start)
                wall = time.perf_counter() - t0
                break
            except TransientFault:
                attempt += 1
                if attempt > self.retry_limit:
                    self._abort_prefill(slot, "error", self.clock.now())
                    return
                st.metrics.retries += 1
                self._backoff(attempt)
            except PermanentFault:
                self._abort_prefill(slot, "error", self.clock.now())
                return
        st.done = end
        self._queue.note_prefill(slot)
        self.step_log.append(StepRecord(
            step=self._step_i, n_active=len(self.active),
            collective_counts=dict(self._chunk_counts),
            predicted_wire_bytes=sum(
                o.wire_bytes
                for o in self.backend.chunk_comm_ops(end - start)),
            measured_transfers=self.backend.drain_transfers(),
            phase="prefill" if st.resume is None else "recompute",
            rid=st.req.rid,
            prefix_len=None if st.resume is None else len(st.prefix),
            cached_prefix_len=st.cached or None,
            wall_s=wall))
        self._step_i += 1
        if end < len(st.prefix):
            return
        del self.prefilling[slot]
        self.backend.finish_prefill(slot)
        now = self.clock.now()
        self._adm_seq += 1
        if st.resume is not None:
            self._resume_active(slot, st.req, st.metrics, st.resume,
                                len(st.prefix), int(tok))
            return
        if hasattr(self.backend, "cache_prefix"):
            # index the freshly committed prompt blocks (DESIGN.md §13)
            self.backend.cache_prefix(slot, st.req.prompt)
        st.metrics.first_token = now
        st.metrics.tokens.append(int(tok))
        self._total_tokens += 1
        self.active[slot] = _Active(st.req, st.metrics, seq=self._adm_seq)
        self.tokens[slot] = int(tok)
        self.pos[slot] = st.req.prompt_len
        reason = self._stop_reason(st.req, st.metrics)
        if reason:
            self._finish(slot, reason, now)

    # ------------------------------------------------------------- stepping
    def _error_active(self, why: str) -> None:
        now = self.clock.now()
        for slot in list(self.active):
            self._finish(slot, "error", now)

    def _refill_rounds(self) -> None:
        """Begin one decode round for every microbatch group that has at
        least one active slot and no round in flight.  On fused backends
        there is exactly one group spanning every slot — one round per
        iteration, the pre-refactor cadence."""
        G = self._group_size
        pending = self._queue.pending_groups()
        for g in range(self.num_slots // G):
            if g in pending:
                continue
            lo = g * G
            if not any(s in self.active for s in range(lo, lo + G)):
                continue
            self._queue.begin_round(g, self.tokens, self.pos)

    def _pump_queue(self) -> Optional[List[RoundResult]]:
        """Refill + pump the instruction queue behind the recovery ladder
        (the fused era's ``_recovered_decode``): preemption on pool
        exhaustion, bounded backoff retries on transient faults, error-
        finish + queue abort on permanent ones.  Per-attempt fault draws
        keep the pre-refactor order (pp_transfer → pool → decode), so
        seeded fault schedules hit the same sites.  Returns the completed
        rounds, or None when this iteration's decode was abandoned."""
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    if self.backend.p > 1:
                        self._apply_fault("pp_transfer")
                    self._apply_fault("pool")
                    self._apply_fault("decode")
                self._refill_rounds()
                if not self._queue.in_flight:
                    return None
                return self._queue.pump()
            except MemoryError:
                if len(self.active) < 2:
                    # nothing else to preempt: the pages are held by
                    # mid-prefill slots (their owner frees them by
                    # finishing) or the fault was injected — stall this
                    # iteration instead of thrashing the lone request
                    return None
                self._preempt_youngest()
            except TransientFault:
                attempt += 1
                if attempt > self.retry_limit:
                    self._error_active("retries exhausted")
                    self._queue.abort_all()
                    return None
                for st in self.active.values():
                    st.metrics.retries += 1
                self._backoff(attempt)
            except PermanentFault:
                self._error_active("permanent fault")
                self._queue.abort_all()
                return None

    def _complete_round(self, res: RoundResult) -> None:
        """Land one completed round: record its traffic/occupancy and
        append its tokens to the slots still active (a slot preempted or
        cancelled mid-round is simply skipped — its instructions died with
        the round that carried them)."""
        now = self.clock.now()
        # n_active is the number of slots THIS round appends to — at depth 1
        # the single group spans every slot, so this equals len(self.active)
        # (the pre-queue semantic); at depth > 1 it is the group's live rows,
        # which is what ServingReport.occupancy() sums as decode tokens.
        appended = sum(1 for slot in res.slots if slot in self.active)
        self.step_log.append(StepRecord(
            step=self._step_i, n_active=appended,
            collective_counts=dict(self._step_counts),
            predicted_wire_bytes=self._step_bytes,
            measured_transfers=dict(res.transfers),
            wall_s=res.wall_s,
            stage_busy=list(res.stage_busy),
            stage_idle=list(res.stage_idle)))
        self._step_i += 1
        base = res.slots[0]
        for slot in res.slots:
            st = self.active.get(slot)
            if st is None:
                continue
            tok = int(res.tokens[slot - base])
            st.metrics.tokens.append(tok)
            self._total_tokens += 1
            self.tokens[slot] = tok
            self.pos[slot] += 1
            reason = self._stop_reason(st.req, st.metrics)
            if reason:
                self._finish(slot, reason, now)
            elif self._expired(st.req, now, pre_first_token=False):
                self._finish(slot, "deadline", now)

    def _drain_queue(self) -> None:
        """Complete every in-flight round (the ``Sync`` instruction) before
        any operation that mutates caches/pages a round may still touch —
        admission prefill, prefill chunk, cancellation.  No-op at depth 1:
        the fused queue never holds issued work between steps."""
        drained = bool(self._queue.in_flight)
        for res in self._queue.sync():
            self._complete_round(res)
        if drained:
            # round hops were attributed per round at send time; reset the
            # backend cursor so they don't leak into the next prefill/chunk
            # record's measured_transfers
            self.backend.drain_transfers()

    def step(self) -> bool:
        """One scheduler iteration; returns False when fully drained."""
        if not self.queue and not self.active and not self.prefilling \
                and not self._queue.in_flight:
            return False
        self._shed_expired(self.clock.now())
        self._admit_ready()
        self.backend.drain_transfers()      # prefill hops: not decode traffic
        if self.prefilling:
            self._advance_prefill()
        if not self.active and not self._queue.in_flight:
            # nothing is decoding: skip the jitted decode step entirely — a
            # fixed-capacity step over all-garbage lanes would burn a full
            # model pass for nothing.  Only advance the clock (to the next
            # arrival) when no prefill is in flight either.
            if not self.prefilling and self.queue:
                self.clock.wait_until(self.queue[0].arrival)
            return self._next(True)
        results = self._pump_queue()
        if results is None:
            return self._next(True)
        for res in results:
            self._complete_round(res)
        return self._next(bool(self.queue or self.active or self.prefilling
                               or self._queue.in_flight))

    def _next(self, more: bool) -> bool:
        """Stall guard: a live scheduler must change *something* every
        iteration — admit, prefill, decode, finish, preempt, or move the
        clock.  A signature frozen for thousands of iterations means a
        logic bug (or a pathological 100%-fault injector), and an explicit
        error beats an infinite loop."""
        if not more:
            return False
        sig = (len(self.queue), len(self.active), len(self.prefilling),
               len(self.finished), self._total_tokens, self._step_i,
               self.clock.now())
        if sig == self._last_sig:
            self._idle_iters += 1
            if self._idle_iters > 10_000:
                raise RuntimeError(
                    "scheduler stalled: no progress in 10000 iterations "
                    f"(queue={len(self.queue)} active={len(self.active)} "
                    f"prefilling={len(self.prefilling)})")
        else:
            self._idle_iters = 0
            self._last_sig = sig
        return True

    def run(self, requests=None) -> ServingReport:
        """Drive until every submitted request has finished."""
        t0 = self.clock.now()
        if requests is not None:
            self.submit(requests)
        while self.step():
            pass
        report = ServingReport(
            metrics=sorted(self.finished, key=lambda m: m.rid),
            steps=self.step_log, wall_time=self.clock.now() - t0)
        self.finished, self.step_log = [], []
        self._step_i = 0
        self._rids = set()
        self._last_sig, self._idle_iters = None, 0
        return report


def serve(backend: DecodeBackend, requests: Sequence[Request],
          clock=None) -> ServingReport:
    """One-shot convenience wrapper: schedule ``requests`` to completion."""
    return Scheduler(backend, clock=clock).run(requests)


# ---------------------------------------------------------------------------
# disaggregated prefill/decode pools (DESIGN.md §14)
# ---------------------------------------------------------------------------


class _ShiftedClock:
    """The decode pool's view of time when both pools run in ONE process:
    ``now()`` is the base clock minus every second the prefill pool has spent
    computing and shipping pages so far.  On real disaggregated hardware
    those seconds overlap the decode pool's work; subtracting them is what
    makes the in-process measurement honest — decode-side TTFT/TPOT read as
    if the prefill pool were a separate machine.  With a ``VirtualClock``
    base the offset stays 0 (prefill passes take no virtual time) and the
    two timelines coincide exactly."""

    def __init__(self, base):
        self.base = base
        self.offset = 0.0

    def now(self) -> float:
        return self.base.now() - self.offset

    def wait_until(self, t: float) -> None:
        self.base.wait_until(t + self.offset)


@dataclasses.dataclass
class HandoffRecord:
    """One request's prefill→decode KV handoff, predicted next to measured.

    ``queue_s`` and ``prefill_s`` live on the BASE clock (the prefill pool's
    own timeline); ``submitted`` is the decode-clock instant the request
    joined the decode pool's queue — its rewritten arrival, from which every
    decode-side metric of this request is measured."""

    rid: int
    pages: int                # full prompt blocks shipped (kv_handoff_pages)
    bytes: int                # measured: device bytes landed by import_page
    predicted_bytes: float    # commodel.kv_handoff_ops closed form
    queue_s: float            # arrival → prefill start on the prefill pool
    prefill_s: float          # prefill-pool busy span (compute + page ship)
    submitted: float          # decode-clock arrival at the decode pool
    first_token: int          # prefill pool's final-position greedy token —
    #                           asserted equal to the decode pool's first
    #                           streamed token (cross-pool identity, §14)


@dataclasses.dataclass
class DisaggReport:
    """A disaggregated run: the decode pool's ServingReport (every
    per-request metric, on the decode clock) plus the handoff ledger.
    phase="handoff" StepRecords are interleaved into ``decode.steps``."""

    decode: ServingReport
    handoffs: List[HandoffRecord]
    wall_time: float          # base-clock span: prefill + ship + decode

    @property
    def metrics(self) -> List[RequestMetrics]:
        return self.decode.metrics

    @property
    def total_tokens(self) -> int:
        return self.decode.total_tokens

    def tokens_by_rid(self) -> Dict[int, List[int]]:
        return self.decode.tokens_by_rid()

    @property
    def handoff_pages(self) -> int:
        return sum(h.pages for h in self.handoffs)

    @property
    def handoff_bytes(self) -> int:
        return sum(h.bytes for h in self.handoffs)

    def summary(self) -> dict:
        out = self.decode.summary()
        queue = [h.queue_s for h in self.handoffs]
        out["disagg"] = {
            "handoffs": len(self.handoffs),
            "handoff_pages": self.handoff_pages,
            "handoff_bytes": self.handoff_bytes,
            "predicted_handoff_bytes": float(
                sum(h.predicted_bytes for h in self.handoffs)),
            "prefill_pool_busy_s": float(
                sum(h.prefill_s for h in self.handoffs)),
            "prefill_queue_p95_s": float(np.percentile(queue, 95))
            if queue else 0.0,
            "base_wall_time_s": self.wall_time,
        }
        return out


class DisaggScheduler:
    """Two engine pools, one serving plane (DESIGN.md §14).

    Fresh long-prompt admissions route to a *prefill pool* (monolithic
    batch-1 prefill, CP or TP layout); short prompts go straight to the
    *decode pool* (a full ``Scheduler`` over a paged, prefix-cached
    backend).  When the prefill pool finishes a prompt it hands the KV off:
    the prompt's full blocks ship page-by-page (``export_page`` →
    ``import_page``, measured device bytes), the pages are then pinned into
    the SHARED prefix index (``cache_prefix``), the prefill slot is freed,
    and the request is resubmitted to the decode pool — whose cache-aware
    admission (§13) hits on the freshly indexed blocks and prefills only
    the final partial page.  Head-of-line blocking dies here: a 2k-token
    prompt no longer stalls the decode pool's running batch for its whole
    prefill, only for one ≤ page_size suffix chunk.

    The handoff is a first-class *modeled* transfer: each one logs a
    phase="handoff" StepRecord whose predicted wire bytes
    (``commodel.kv_handoff_ops`` — pages × kv_page_bytes) are asserted
    EQUAL to the measured device bytes the import landed, per request.

    Invariants (asserted at runtime):

      * **Cross-pool token identity.**  The decode pool's first streamed
        token must equal the greedy token the prefill pool computed at the
        prompt's final position.  Greedy decode is deterministic, so this
        holds whenever both pools' prefill numerics agree bitwise — which
        they do when the pools share a layout kind (e.g. TP/TP).  A CP
        prefill pool's projection matmuls tile differently (~1e-7 KV noise,
        see tests/test_cp.py), giving token-level but not guaranteed-bitwise
        equality; the assertion is what surfaces a pairing that drifts.
      * **Handoff accounting.**  measured bytes == predicted bytes ==
        ``kv_handoff_pages(prompt_len, page_size)`` × page bytes, exactly.

    Timeline semantics: the decode pool runs on a ``_ShiftedClock`` that
    subtracts prefill-pool busy time from the base clock, so decode-side
    TTFT/TPOT measure the decode pool as dedicated hardware.  Handed-off
    requests' arrivals are rewritten to the handoff-completion instant on
    the decode clock (prefill-side latency lives in ``HandoffRecord``);
    deadlines therefore apply per pool — the prefill queue sheds against
    the original arrival, the decode pool against the rewritten one.
    """

    def __init__(self, prefill_backend: DecodeBackend,
                 decode_backend: DecodeBackend, clock=None,
                 chunk_size: int = None, admission: str = "conservative",
                 faults=None, route_prompt_len: Optional[int] = None,
                 retry_limit: int = 3, retry_backoff: float = 0.05):
        if not getattr(prefill_backend, "paged", False) \
                or not getattr(decode_backend, "paged", False):
            raise ValueError(
                "disaggregated pools hand KV off as pages — construct BOTH "
                "backends with paged=True")
        if decode_backend.prefix_index is None:
            raise ValueError(
                "the decode pool admits handed-off requests through its "
                "prefix index — construct the decode backend with "
                "prefix_cache=True (DESIGN.md §13/§14)")
        if prefill_backend.pool is not decode_backend.pool:
            raise ValueError(
                "disaggregated pools must share ONE KVPool (construct the "
                "prefill backend with pool=decode_backend.pool) — the "
                "handoff names pages of a common address space")
        pr = (prefill_backend._owner_base,
              prefill_backend._owner_base + prefill_backend.num_slots)
        dr = (decode_backend._owner_base,
              decode_backend._owner_base + decode_backend.num_slots)
        if max(pr[0], dr[0]) < min(pr[1], dr[1]):
            raise ValueError(
                f"pool-sharing backends need disjoint owner ranges, got "
                f"{pr} and {dr} — construct one with "
                f"owner_base=<the other's num_slots>")
        if prefill_backend.cfg is not decode_backend.cfg \
                and prefill_backend.cfg != decode_backend.cfg:
            raise ValueError(
                "both pools must serve the same model config — cross-pool "
                "token identity is asserted per request")
        self.prefill_backend = prefill_backend
        self.decode_backend = decode_backend
        # one index, both pools: the prefill pool INSERTS finished prompts
        # (and may evict cold entries under page pressure, §13), the decode
        # pool HITS on them at admission
        prefill_backend.prefix_index = decode_backend.prefix_index
        self.index = decode_backend.prefix_index
        self.base_clock = clock if clock is not None else WallClock()
        self._dclock = _ShiftedClock(self.base_clock)
        self.decode = Scheduler(decode_backend, clock=self._dclock,
                                chunk_size=chunk_size, admission=admission,
                                faults=faults, retry_limit=retry_limit,
                                retry_backoff=retry_backoff)
        ps = decode_backend.page_size
        self.route_prompt_len = (2 * ps if route_prompt_len is None
                                 else int(route_prompt_len))
        if self.route_prompt_len < ps:
            raise ValueError(
                f"route_prompt_len {self.route_prompt_len} < page_size "
                f"{ps}: a prompt with no full block has nothing to hand "
                f"off — the decode pool would cold-prefill it anyway")
        self.faults = faults
        self.retry_limit = int(retry_limit)
        self.retry_backoff = float(retry_backoff)
        self.pending: List[Request] = []      # prefill-pool queue, by arrival
        self.handoffs: List[HandoffRecord] = []
        self.finished_prefill: List[RequestMetrics] = []  # shed/errored here
        self._expected_first: Dict[int, int] = {}
        self._pre_retries: Dict[int, int] = {}
        self._rids: set = set()
        self._b = jnp.dtype(decode_backend.cfg.dtype).itemsize

    # ------------------------------------------------------------- intake
    def submit(self, requests) -> None:
        """Route: prompts of ``route_prompt_len``+ tokens queue for the
        prefill pool; everything else goes straight to the decode pool
        (its own ``submit`` validates capacity).  Prefill-routed requests
        are checked against BOTH pools now, so a request that could never
        fit fails at submit, not mid-run at handoff."""
        reqs = [requests] if isinstance(requests, Request) else list(requests)
        routed: List[Request] = []
        for r in reqs:
            if r.rid in self._rids:
                raise ValueError(
                    f"duplicate rid {r.rid}: already submitted this run")
            self._rids.add(r.rid)
            if r.prompt_len >= self.route_prompt_len:
                routed.append(r)
            else:
                self.decode.submit(r)
        pb, db = self.prefill_backend, self.decode_backend
        usable = db.pool.num_pages - 1          # minus the scratch page
        for r in routed:
            pre_len = pb._alloc_len(r.prompt_len)
            if pre_len > pb.max_len:
                raise ValueError(
                    f"request {r.rid} prompt ({pre_len} CP-padded "
                    f"positions) > prefill pool max_len {pb.max_len}")
            need = r.prompt_len + r.max_new_tokens - 1
            if need > db.max_len:
                raise ValueError(
                    f"request {r.rid} needs {need} cache positions > "
                    f"decode pool max_len {db.max_len}")
            need_pages = max(-(-pre_len // pb.page_size),
                             -(-need // db.page_size))
            if need_pages > usable:
                raise ValueError(
                    f"request {r.rid} needs {need_pages} pages > shared "
                    f"pool capacity {usable}")
            bisect.insort(self.pending, r, key=lambda x: x.arrival)

    def cancel(self, rid: int) -> bool:
        """Cancel wherever the request lives — the prefill-pool queue or
        anywhere inside the decode pool."""
        for req in self.pending:
            if req.rid == rid:
                self.pending.remove(req)
                self._fail(req, "cancelled")
                return True
        return self.decode.cancel(rid)

    # ------------------------------------------------------------- faults
    def _apply_fault(self, site: str) -> None:
        """Prefill-pool fault draws run on the BASE clock: a delay here
        stretches the prefill pool's timeline (and so the clock offset),
        never the decode pool's."""
        if self.faults is None:
            return
        f = self.faults.draw(site)
        if f is None:
            return
        if f.kind == "delay":
            self.base_clock.wait_until(self.base_clock.now() + f.delay_s)
        elif f.kind == "oom":
            raise MemoryError(f"injected fault at {site}")
        elif f.kind == "transient":
            raise TransientFault(f"injected fault at {site}")
        else:
            raise PermanentFault(f"injected fault at {site}")

    def _backoff(self, attempt: int) -> None:
        self.base_clock.wait_until(
            self.base_clock.now()
            + self.retry_backoff * 2.0 ** (attempt - 1))

    def _fail(self, req: Request, reason: str) -> None:
        """Finish a request on the prefill side (shed / cancelled /
        errored before handoff) — its metrics row joins the final report
        with no tokens, timeline on the base clock."""
        m = RequestMetrics(rid=req.rid, prompt_len=req.prompt_len,
                           arrival=req.arrival)
        m.retries = self._pre_retries.pop(req.rid, 0)
        m.finished = self.base_clock.now()
        m.finish_reason = reason
        self.finished_prefill.append(m)

    def _shed_pending(self, now: float) -> None:
        for req in [r for r in self.pending
                    if Scheduler._expired(r, now, True)]:
            self.pending.remove(req)
            self._fail(req, "deadline")

    # ------------------------------------------------------------- handoff
    def _prefill_arrived(self, now: float) -> bool:
        """Prefill + hand off every pending request whose arrival has
        passed, in arrival order.  Returns True if anything happened."""
        did = False
        while self.pending and self.pending[0].arrival <= now:
            if not self._prefill_one(self.pending[0]):
                break                     # deferred on pool pressure
            did = True
            now = self.base_clock.now()
            self._shed_pending(now)       # the pass moved the clock
        return did

    def _prefill_one(self, req: Request) -> bool:
        """One monolithic prefill + page handoff.  Returns False when the
        request was deferred (shared pool exhausted while the decode pool
        still holds pages — retried next iteration), True otherwise."""
        be = self.prefill_backend
        t_start = self.base_clock.now()
        try:
            # slot 0: the prefill pool runs one batch-1 pass at a time
            be.begin_prefill(0, req.prompt_len, 1)
        except MemoryError:
            # pool exhausted and the index drained of cold entries: wait
            # for the decode pool to free pages — unless it is idle too,
            # in which case the pages simply don't exist
            if (self.decode.active or self.decode.prefilling
                    or self.decode._queue.in_flight):
                return False
            self.pending.remove(req)
            self._fail(req, "error")
            return True
        self.pending.remove(req)
        queue_s = max(0.0, t_start - req.arrival)
        attempt = 0
        while True:
            try:
                self._apply_fault("prefill")
                tok = be.prefill_whole(0, req.prompt)
                break
            except (TransientFault, MemoryError):
                # injected prefill oom retries too: the slot's pages are
                # already claimed, and re-prefilling them is idempotent
                attempt += 1
                if attempt > self.retry_limit:
                    be.free_slots([0])
                    self._fail(req, "error")
                    return True
                self._pre_retries[req.rid] = \
                    self._pre_retries.get(req.rid, 0) + 1
                self._backoff(attempt)
            except PermanentFault:
                be.free_slots([0])
                self._fail(req, "error")
                return True
        # ship the prompt's full blocks BEFORE indexing them: an index
        # entry must never name a page whose decode-side content has not
        # landed — a hit on it would silently decode over garbage KV
        n_pages = kv_handoff_pages(req.prompt_len, be.page_size)
        table = [int(p) for p in
                 be.pool.block_table(be._owner(0))[:n_pages]]
        measured, shipped, attempt = 0, 0, 0
        while shipped < len(table):
            try:
                self._apply_fault("handoff")
                pg = table[shipped]
                measured += self.decode_backend.import_page(
                    pg, be.export_page(pg))
                shipped += 1
            except TransientFault:
                attempt += 1
                if attempt > self.retry_limit:
                    be.free_slots([0])
                    self._fail(req, "error")
                    return True
                self._pre_retries[req.rid] = \
                    self._pre_retries.get(req.rid, 0) + 1
                self._backoff(attempt)
            except PermanentFault:
                be.free_slots([0])
                self._fail(req, "error")
                return True
        be.cache_prefix(0, req.prompt)        # pins the shipped pages
        hit = self.index.lookup(np.asarray(req.prompt, np.int32))
        # §13 caps a lookup one position short, so a block-aligned prompt
        # hits one block fewer than it shipped — the last shipped page
        # stays pinned for FUTURE prompts sharing the prefix
        if list(hit.pages) != table[:len(hit.pages)] \
                or len(hit.pages) < n_pages - 1:
            raise RuntimeError(
                f"handoff pages diverged for rid {req.rid}: shipped "
                f"{table}, index holds {list(hit.pages)}")
        be.free_slots([0])
        elapsed = self.base_clock.now() - t_start
        # dedicated-hardware semantics: the decode clock does not see the
        # prefill pool's busy span
        self._dclock.offset += elapsed
        submitted = self._dclock.now()
        ops = kv_handoff_ops(be.cfg, n_pages, be.page_size, b=self._b)
        predicted = sum(o.wire_bytes for o in ops)
        if measured != int(predicted):
            raise RuntimeError(
                f"handoff bytes diverged for rid {req.rid}: measured "
                f"{measured} != predicted {int(predicted)} "
                f"({n_pages} pages × kv_page_bytes)")
        self.decode.step_log.append(StepRecord(
            step=self.decode._step_i, n_active=len(self.decode.active),
            collective_counts=Scheduler._count(ops),
            predicted_wire_bytes=predicted,
            measured_transfers={"count": n_pages, "bytes": measured},
            phase="handoff", rid=req.rid,
            prefix_len=n_pages * be.page_size, wall_s=elapsed))
        self.handoffs.append(HandoffRecord(
            rid=req.rid, pages=n_pages, bytes=measured,
            predicted_bytes=predicted, queue_s=queue_s, prefill_s=elapsed,
            submitted=submitted, first_token=int(tok)))
        self._expected_first[req.rid] = int(tok)
        self.decode.submit(dataclasses.replace(req, arrival=submitted))
        return True

    # ------------------------------------------------------------- driving
    def run(self, requests=None) -> DisaggReport:
        """Drive both pools until every submitted request has finished."""
        t0 = self.base_clock.now()
        d0 = self._dclock.now()
        if requests is not None:
            self.submit(requests)
        while True:
            now = self.base_clock.now()
            self._shed_pending(now)
            progressed = self._prefill_arrived(now)
            decode_idle = (not self.decode.active
                           and not self.decode.prefilling
                           and not self.decode._queue.in_flight)
            if decode_idle and self.pending and not progressed:
                # the decode pool would nap until ITS next arrival; if the
                # prefill pool's next request is due sooner on the base
                # clock, advance to it instead of letting a due handoff
                # wait behind the nap
                next_dec = (self.decode.queue[0].arrival
                            + self._dclock.offset
                            if self.decode.queue else float("inf"))
                if self.pending[0].arrival <= next_dec:
                    self.base_clock.wait_until(self.pending[0].arrival)
                    continue
            alive = self.decode.step()
            if not alive and not self.pending:
                break
        # fold prefill-side retries into the decode-side metrics rows
        metrics = sorted(self.decode.finished + self.finished_prefill,
                         key=lambda m: m.rid)
        for m in metrics:
            m.retries += self._pre_retries.pop(m.rid, 0)
        # the §14 cross-pool identity: greedy decode is deterministic, so
        # any divergence means the handed-off KV pages are not the pages
        # the decode pool would have written itself
        for m in metrics:
            exp = self._expected_first.get(m.rid)
            if exp is not None and m.tokens and m.tokens[0] != exp:
                raise RuntimeError(
                    f"cross-pool token divergence for rid {m.rid}: decode "
                    f"pool streamed {m.tokens[0]}, prefill pool computed "
                    f"{exp} — handed-off KV differs from native prefill")
        dec = ServingReport(metrics=metrics, steps=self.decode.step_log,
                            wall_time=self._dclock.now() - d0)
        report = DisaggReport(decode=dec, handoffs=self.handoffs,
                              wall_time=self.base_clock.now() - t0)
        self.decode.finished, self.decode.step_log = [], []
        self.decode._step_i = 0
        self.decode._rids = set()
        self.decode._last_sig, self.decode._idle_iters = None, 0
        self.handoffs, self.finished_prefill = [], []
        self._expected_first, self._pre_retries = {}, {}
        self._rids = set()
        return report
