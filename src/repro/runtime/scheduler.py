"""Continuous-batching scheduler over a DecodeBackend (paper §V-C, serving).

vLLM-style iteration-level scheduling, reduced to the pieces the paper's SLO
study actually exercises: a fixed pool of KV-cache *slots*, admission of
queued requests into freed slots between decode steps (each admission is one
batch-1 prefill scattered into the slot row), one fused decode step per
iteration over the whole slot batch with per-sequence positions, and
EOS/length-based eviction.  What it deliberately does NOT reproduce from
vLLM: paged KV blocks (slots are contiguous rows; paging is a later PR),
chunked/piggybacked prefill (prefill runs alone between decode steps), and
preemption/swapping (admission only when a slot is free) — see DESIGN.md §7.

The scheduler measures the quantities ``core.slo.predict_slo`` predicts —
per-request TTFT / TPOT / E2E — and records per-step communication: predicted
collective counts/bytes from ``commodel.comm_ops_for`` plus, for pipeline
backends, the engine's measured boundary TransferRecords.  The paper's claim
that per-step collective *counts* are batch-invariant (only message bytes
scale with batch) is load-bearing here — it is what makes a fixed-capacity
decode step correct for a varying active set — so it is asserted against
``comm_ops_for(batch=...)`` at construction time.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Sequence

import numpy as np

from repro.runtime.backends import DecodeBackend
from repro.runtime.request import Request, RequestMetrics


# ---------------------------------------------------------------------------
# clocks (injectable so tests run on virtual time)
# ---------------------------------------------------------------------------


class WallClock:
    """Real time, relative to construction; ``wait_until`` sleeps."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t: float) -> None:
        delta = t - self.now()
        if delta > 0:
            time.sleep(delta)


class VirtualClock:
    """Deterministic clock for tests: time only moves via ``wait_until`` /
    ``advance`` — decode steps take zero virtual time."""

    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def wait_until(self, t: float) -> None:
        self.t = max(self.t, t)

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------------------
# per-step traffic records
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepRecord:
    """Communication of one scheduler iteration: one fused decode step, or
    (chunked-prefill mode, DESIGN.md §8) one prefill chunk."""

    step: int
    n_active: int
    collective_counts: Dict[str, int]     # predicted, per decode step/chunk
    predicted_wire_bytes: float           # at batch=num_slots (decode) / 1
    measured_transfers: Dict[str, int]    # PP boundary hops since last step
    phase: str = "decode"                 # "decode" | "prefill"


def step_collective_counts(backend: DecodeBackend,
                           batch: int = 1) -> Dict[str, int]:
    """Collective call counts of ONE decode step, summed by collective."""
    counts: Dict[str, int] = {}
    for o in backend.decode_comm_ops(batch=batch):
        counts[o.collective] = counts.get(o.collective, 0) + o.count
    return counts


def assert_counts_batch_invariant(backend: DecodeBackend) -> None:
    """The paper's batch-invariance property, asserted: a decode step issues
    the same number of collectives at any batch size — only wire bytes scale
    (linearly).  The scheduler's fixed-capacity step depends on this."""
    base = backend.decode_comm_ops(batch=1)
    for batch in (2, backend.num_slots):
        if batch < 2:
            continue
        scaled = backend.decode_comm_ops(batch=batch)
        if step_collective_counts(backend, 1) != \
                step_collective_counts(backend, batch):
            raise AssertionError(
                f"per-step collective counts vary with batch={batch}: "
                f"{step_collective_counts(backend, 1)} vs "
                f"{step_collective_counts(backend, batch)}")
        for o1, ob in zip(base, scaled):
            if not np.isclose(ob.wire_bytes, batch * o1.wire_bytes):
                raise AssertionError(
                    f"wire bytes not linear in batch for {o1.collective}: "
                    f"{ob.wire_bytes} != {batch} * {o1.wire_bytes}")


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServingReport:
    metrics: List[RequestMetrics]
    steps: List[StepRecord]
    wall_time: float

    @property
    def total_tokens(self) -> int:
        return sum(m.num_generated for m in self.metrics)

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.wall_time if self.wall_time else 0.0

    def tokens_by_rid(self) -> Dict[int, List[int]]:
        return {m.rid: list(m.tokens) for m in self.metrics}

    def summary(self) -> dict:
        def _pct(vals, q):
            return float(np.percentile(vals, q)) if vals else 0.0

        ttfts = [m.ttft for m in self.metrics]
        tpots = [m.tpot for m in self.metrics if m.num_generated > 1]
        e2es = [m.e2e for m in self.metrics]
        return {
            "requests": len(self.metrics),
            "total_tokens": self.total_tokens,
            "wall_time_s": self.wall_time,
            "throughput_tok_s": self.throughput,
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_p95_s": _pct(ttfts, 95),
            "tpot_mean_s": float(np.mean(tpots)) if tpots else 0.0,
            "tpot_p95_s": _pct(tpots, 95),
            "e2e_mean_s": float(np.mean(e2es)) if e2es else 0.0,
            "e2e_p95_s": _pct(e2es, 95),
        }


@dataclasses.dataclass
class _Active:
    req: Request
    metrics: RequestMetrics


@dataclasses.dataclass
class _Prefilling:
    """A request whose prompt is mid-way through chunked prefill."""

    req: Request
    metrics: RequestMetrics
    done: int = 0                 # prompt positions already prefilled


class Scheduler:
    """Continuous batching over ``backend.num_slots`` KV-cache slots.

    One ``step()`` = admit every arrived request a free slot can take
    (batch-1 prefill each, TTFT stamped), then ONE fused decode step over
    the full slot batch with per-sequence positions, then eviction of
    finished sequences (EOS or length), freeing their slots for the next
    iteration's admissions.

    ``chunk_size`` (paged backends only, DESIGN.md §8) turns prefill into
    *chunked* prefill: admission only allocates the slot's pages, and each
    iteration advances ONE prefilling request by one ``chunk_size``-token
    pass before the decode step — so a long prompt no longer stalls running
    slots for its whole prefill, only for one chunk.  Iterations with no
    decoding slot skip the jitted decode step entirely (nothing useful would
    run in it) and just advance prefill / wait for the next arrival.
    """

    def __init__(self, backend: DecodeBackend, clock=None,
                 chunk_size: int = None):
        self.backend = backend
        self.clock = clock if clock is not None else WallClock()
        self.num_slots = backend.num_slots
        self.queue: deque = deque()
        self.free: List[int] = list(range(self.num_slots))
        self.active: Dict[int, _Active] = {}
        self.prefilling: Dict[int, _Prefilling] = {}   # slot -> state (FIFO)
        self.chunk_size = chunk_size
        if chunk_size is not None:
            if chunk_size < 1:
                raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
            if not getattr(backend, "paged", False):
                raise ValueError(
                    "chunked prefill writes straight into KV pages; "
                    "construct the backend with paged=True")
            if getattr(backend, "c", 1) > 1:
                raise ValueError(
                    "chunked prefill and context parallelism are "
                    "alternative long-prompt strategies (DESIGN.md §9); "
                    "a c>1 backend prefills monolithically")
            # per-chunk counts are chunk-length-invariant (commodel.
            # chunked_prefill_ops) — compute once at the nominal size
            self._chunk_counts = self._count(
                backend.chunk_comm_ops(chunk_size))
        self.tokens = np.zeros(self.num_slots, np.int32)
        self.pos = np.zeros(self.num_slots, np.int64)
        self.finished: List[RequestMetrics] = []
        self.step_log: List[StepRecord] = []
        self._step_i = 0
        # the batch-invariance the fixed-capacity step relies on (paper
        # Tables III–VI: no batch term in any count column)
        assert_counts_batch_invariant(backend)
        self._step_counts = step_collective_counts(backend, 1)
        self._step_bytes = sum(
            o.wire_bytes
            for o in backend.decode_comm_ops(batch=self.num_slots))

    @staticmethod
    def _count(ops) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for o in ops:
            counts[o.collective] = counts.get(o.collective, 0) + o.count
        return counts

    # ------------------------------------------------------------- intake
    def submit(self, requests) -> None:
        reqs = [requests] if isinstance(requests, Request) else list(requests)
        paged = getattr(self.backend, "paged", False)
        c = getattr(self.backend, "c", 1)
        for r in reqs:
            # the last generated token is never fed back, so the highest
            # cache position written is prompt_len + max_new_tokens - 2;
            # CP pads the prompt to a multiple of c (DESIGN.md §9)
            need = max(r.prompt_len + r.max_new_tokens - 1,
                       -(-r.prompt_len // c) * c)
            w = self.backend.cfg.sliding_window
            if need > self.backend.max_len and not w:
                raise ValueError(
                    f"request {r.rid} needs {need} cache positions "
                    f"> max_len {self.backend.max_len}")
            if paged:
                # a request the EMPTY pool couldn't hold would never pass
                # the admission gate — reject it up front, don't deadlock
                need_pages = -(-need // self.backend.page_size)
                usable = self.backend.pool.num_pages - 1    # minus scratch
                if need_pages > usable:
                    raise ValueError(
                        f"request {r.rid} needs {need_pages} pages "
                        f"> pool capacity {usable}")
        self.queue.extend(reqs)
        # arrival order == admission order
        self.queue = deque(sorted(self.queue, key=lambda r: r.arrival))

    # ------------------------------------------------------------- admission
    def _finish(self, slot: int, reason: str, now: float) -> None:
        st = self.active.pop(slot)
        st.metrics.finished = now
        st.metrics.finish_reason = reason
        self.finished.append(st.metrics)
        self.backend.free_slots([slot])
        self.free.append(slot)
        self.tokens[slot] = 0
        self.pos[slot] = 0

    def _admit_ready(self) -> None:
        paged = getattr(self.backend, "paged", False)
        while self.free and self.queue and \
                self.queue[0].arrival <= self.clock.now():
            req = self.queue[0]
            if paged and not self.backend.can_admit(req.prompt_len,
                                                    req.max_new_tokens):
                # a free slot but not enough pages for this request's worst
                # case on top of live requests' committed growth: keep it
                # queued (head-of-line — admission order stays arrival
                # order) until evictions free pages
                break
            self.queue.popleft()
            slot = self.free.pop(0)
            m = RequestMetrics(rid=req.rid, prompt_len=req.prompt_len,
                               arrival=req.arrival,
                               admitted=self.clock.now())
            if paged:
                # admission claims the slot's pages and commits the decode
                # budget; chunked mode then advances one chunk per
                # iteration, non-chunked prefills as one maximal chunk
                # (one sequence-sharded CP pass on a c>1 backend)
                self.backend.begin_prefill(slot, req.prompt_len,
                                           req.max_new_tokens)
                if self.chunk_size is not None:
                    self.prefilling[slot] = _Prefilling(req, m)
                    continue
                first = int(self.backend.prefill_whole(slot, req.prompt))
                self.backend.finish_prefill(slot)
            else:
                first = int(self.backend.prefill_into_slots([req.prompt],
                                                            [slot])[0])
            m.first_token = self.clock.now()
            m.tokens.append(first)
            self.active[slot] = _Active(req, m)
            self.tokens[slot] = first
            self.pos[slot] = req.prompt_len
            if req.eos_id is not None and first == req.eos_id:
                self._finish(slot, "eos", self.clock.now())
            elif req.max_new_tokens == 1:
                self._finish(slot, "length", self.clock.now())

    def _advance_prefill(self) -> None:
        """Run ONE prefill chunk for the oldest mid-prefill request; on the
        final chunk the request's first token is stamped (TTFT) and the slot
        joins the decoding set."""
        slot = next(iter(self.prefilling))
        st = self.prefilling[slot]
        start = st.done
        end = min(start + self.chunk_size, st.req.prompt_len)
        tok = self.backend.prefill_chunk(slot, st.req.prompt[start:end],
                                         start)
        st.done = end
        self.step_log.append(StepRecord(
            step=self._step_i, n_active=len(self.active),
            collective_counts=dict(self._chunk_counts),
            predicted_wire_bytes=sum(
                o.wire_bytes
                for o in self.backend.chunk_comm_ops(end - start)),
            measured_transfers=self.backend.drain_transfers(),
            phase="prefill"))
        self._step_i += 1
        if end < st.req.prompt_len:
            return
        del self.prefilling[slot]
        self.backend.finish_prefill(slot)
        now = self.clock.now()
        st.metrics.first_token = now
        st.metrics.tokens.append(tok)
        self.active[slot] = _Active(st.req, st.metrics)
        self.tokens[slot] = tok
        self.pos[slot] = st.req.prompt_len
        if st.req.eos_id is not None and tok == st.req.eos_id:
            self._finish(slot, "eos", now)
        elif st.req.max_new_tokens == 1:
            self._finish(slot, "length", now)

    # ------------------------------------------------------------- stepping
    def step(self) -> bool:
        """One scheduler iteration; returns False when fully drained."""
        if not self.queue and not self.active and not self.prefilling:
            return False
        self._admit_ready()
        self.backend.drain_transfers()      # prefill hops: not decode traffic
        if self.prefilling:
            self._advance_prefill()
        if not self.active:
            # nothing is decoding: skip the jitted decode step entirely — a
            # fixed-capacity step over all-garbage lanes would burn a full
            # model pass for nothing.  Only advance the clock (to the next
            # arrival) when no prefill is in flight either.
            if not self.prefilling and self.queue:
                self.clock.wait_until(self.queue[0].arrival)
            return bool(self.queue or self.active or self.prefilling)
        nxt = self.backend.decode_step(self.tokens, self.pos)
        now = self.clock.now()
        self.step_log.append(StepRecord(
            step=self._step_i, n_active=len(self.active),
            collective_counts=dict(self._step_counts),
            predicted_wire_bytes=self._step_bytes,
            measured_transfers=self.backend.drain_transfers()))
        self._step_i += 1
        for slot in list(self.active):
            st = self.active[slot]
            tok = int(nxt[slot])
            st.metrics.tokens.append(tok)
            self.tokens[slot] = tok
            self.pos[slot] += 1
            if st.req.eos_id is not None and tok == st.req.eos_id:
                self._finish(slot, "eos", now)
            elif st.metrics.num_generated >= st.req.max_new_tokens:
                self._finish(slot, "length", now)
        return bool(self.queue or self.active or self.prefilling)

    def run(self, requests=None) -> ServingReport:
        """Drive until every submitted request has finished."""
        t0 = self.clock.now()
        if requests is not None:
            self.submit(requests)
        while self.step():
            pass
        report = ServingReport(
            metrics=sorted(self.finished, key=lambda m: m.rid),
            steps=self.step_log, wall_time=self.clock.now() - t0)
        self.finished, self.step_log = [], []
        self._step_i = 0
        return report


def serve(backend: DecodeBackend, requests: Sequence[Request],
          clock=None) -> ServingReport:
    """One-shot convenience wrapper: schedule ``requests`` to completion."""
    return Scheduler(backend, clock=clock).run(requests)
