"""Inference engine: batched prefill + autoregressive decode (serve path).

Wraps the model's prefill/decode_step into jitted, optionally mesh-sharded
functions.  ``serve_step`` is the unit the decode-shape dry-runs lower: ONE
new token for every sequence in the batch against a seq_len-deep KV cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.transformer import Model, get_model


def make_serve_step(model: Model):
    """(params, cache, token [B], pos) -> (next_token [B], cache)."""

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


class InferenceEngine:
    """Single-host serving loop with greedy sampling and batched requests.

    Decode runs on the fused multi-token path: ``decode_chunk`` steps per
    dispatch via ``Model.decode_steps`` (lax.fori_loop with argmax feedback),
    falling back to single jitted steps for the tail.  Both decode jits
    donate the KV cache, so the [L, B, W, kv, D] buffers are updated in
    place for the whole generation.  ``decode_chunk=1`` recovers the seed
    one-dispatch-per-token loop exactly (the output is identical either way).
    """

    def __init__(self, cfg: ModelConfig, params, max_len: int = 2048,
                 decode_chunk: int = 8):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_len = max_len
        self.decode_chunk = max(int(decode_chunk), 1)
        self._prefill = jax.jit(
            functools.partial(self.model.prefill, max_len=max_len))
        self._step = jax.jit(make_serve_step(self.model),
                             donate_argnums=(1,))
        self._steps = jax.jit(
            functools.partial(self.model.decode_steps,
                              num_tokens=self.decode_chunk),
            donate_argnums=(1,))
        self._encode = jax.jit(self.model.forward)

    def generate(self, tokens, max_new_tokens: int = 32,
                 prefix_emb=None) -> jnp.ndarray:
        """tokens [B, S_p] -> generated [B, max_new_tokens] (greedy)."""
        if not self.cfg.is_decoder:
            raise ValueError(f"{self.cfg.name} is encoder-only: no decode")
        kwargs = {}
        if prefix_emb is not None:
            kwargs["prefix_emb"] = prefix_emb
        logits, cache, _ = self._prefill(self.params, tokens, **kwargs)
        npre = 0 if prefix_emb is None else prefix_emb.shape[1]
        pos = tokens.shape[1] + npre
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pieces = [tok[:, None]]
        remaining = max_new_tokens - 1
        while remaining >= self.decode_chunk > 1:
            chunk, cache = self._steps(self.params, cache, tok,
                                       jnp.int32(pos))
            pieces.append(chunk)
            tok = chunk[:, -1]
            pos += self.decode_chunk
            remaining -= self.decode_chunk
        for _ in range(remaining):
            tok, cache = self._step(self.params, cache, tok, jnp.int32(pos))
            pieces.append(tok[:, None])
            pos += 1
        return jnp.concatenate(pieces, axis=1)

    def encode(self, features):
        # the jit lives on the engine: a fresh jax.jit(...) per call would
        # wrap a new callable every time and re-trace on every encode
        logits, _ = self._encode(self.params, features=features)
        return logits
