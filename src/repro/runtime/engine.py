"""Inference engine: batched prefill + autoregressive decode (serve path).

Wraps the model's prefill/decode_step into jitted, optionally mesh-sharded
functions.  ``serve_step`` is the unit the decode-shape dry-runs lower: ONE
new token for every sequence in the batch against a seq_len-deep KV cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.transformer import Model, get_model


def make_serve_step(model: Model, greedy: bool = True):
    """(params, cache, token [B], pos) -> (next_token [B], cache)."""

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


class InferenceEngine:
    """Single-host serving loop with greedy sampling and batched requests."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 2048):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            functools.partial(self.model.prefill, max_len=max_len))
        self._step = jax.jit(make_serve_step(self.model),
                             donate_argnums=(1,))

    def generate(self, tokens, max_new_tokens: int = 32,
                 prefix_emb=None) -> jnp.ndarray:
        """tokens [B, S_p] -> generated [B, max_new_tokens] (greedy)."""
        if not self.cfg.is_decoder:
            raise ValueError(f"{self.cfg.name} is encoder-only: no decode")
        kwargs = {}
        if prefix_emb is not None:
            kwargs["prefix_emb"] = prefix_emb
        logits, cache, _ = self._prefill(self.params, tokens, **kwargs)
        npre = 0 if prefix_emb is None else prefix_emb.shape[1]
        pos = tokens.shape[1] + npre
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs = [tok]
        for _ in range(max_new_tokens - 1):
            tok, cache = self._step(self.params, cache, tok, jnp.int32(pos))
            outs.append(tok)
            pos += 1
        return jnp.stack(outs, axis=1)

    def encode(self, features):
        logits, _ = jax.jit(self.model.forward)(self.params,
                                                features=features)
        return logits
