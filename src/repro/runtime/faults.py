"""Deterministic seeded fault injection for the serving layer (DESIGN.md §10).

The paper's SLO study is about service quality under real-world conditions;
a real-world serving stack also has to *survive* them.  This module is the
harness the scheduler's recovery paths are tested against: a seeded source
of faults injected at named instrumentation sites, so a chaos run is exactly
reproducible from ``(seed, rates)`` and a unit test can script the precise
step a fault lands on.

Sites (drawn by the scheduler, ``runtime/scheduler.py``):

  ``decode``       before the fused decode step — a transient fault models a
                   recoverable step failure (retried with backoff), a
                   permanent one a dead engine (active requests finish with
                   ``finish_reason="error"``).
  ``prefill``      before a prefill pass / chunk — same taxonomy, scoped to
                   the one admitting/prefilling request.
  ``pool``         an injected ``MemoryError`` standing in for KV-pool
                   exhaustion mid-decode — exercises preemption-by-recompute
                   exactly like a real ``KVPool.extend`` failure.
  ``pp_transfer``  a pipeline boundary hop delayed (latency spike, applied
                   to the scheduler clock) or failed (transient, retried);
                   only drawn when the backend has p > 1.

Faults are *drawn*, never ambient: each ``draw(site)`` advances a
deterministic per-site counter and an rng stream derived from ``(seed,
site)``, so the fault schedule is a pure function of the call sequence —
independent of wall time, and independent across sites (adding draws at one
site never shifts another site's schedule).  ``FaultInjector.scripted``
pins faults to exact (site, nth-call) coordinates for regression tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


class TransientFault(RuntimeError):
    """A recoverable failure: the operation may succeed if retried."""


class PermanentFault(RuntimeError):
    """An unrecoverable failure: retrying cannot help."""


SITES = ("decode", "prefill", "pool", "pp_transfer", "handoff")

# how an injected fault at each site manifests, and with what weight the
# random mode picks each kind (delays only exist at the transfer sites —
# a slow boundary hop or a slow KV-page ship is a latency spike, not an
# exception; "handoff" is the disaggregated prefill→decode page transfer,
# DESIGN.md §14)
_KINDS = {
    "decode": ("transient", "permanent"),
    "prefill": ("transient", "permanent"),
    "pool": ("oom",),
    "pp_transfer": ("delay", "transient"),
    "handoff": ("delay", "transient", "permanent"),
}


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault: what happens when the scheduler draws it."""

    site: str
    kind: str                  # "transient" | "permanent" | "oom" | "delay"
    delay_s: float = 0.0       # latency spike (kind == "delay" only)

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}")
        if self.kind not in _KINDS[self.site]:
            raise ValueError(
                f"site {self.site!r} cannot inject kind {self.kind!r} "
                f"(allowed: {_KINDS[self.site]})")


class FaultInjector:
    """Seeded random fault schedule over the instrumentation sites.

    ``rates`` maps site -> per-draw fault probability (unlisted sites never
    fault).  ``transient_frac`` splits decode/prefill faults between
    transient and permanent; ``delay_frac`` splits pp_transfer faults
    between latency spikes of ``delay_s`` seconds and transient failures.
    ``max_faults`` bounds the total injections (a finite chaos schedule is
    what makes "the scheduler always terminates" a theorem rather than a
    probability-1 statement).

    Every draw is logged in ``injected`` as (site, call_index, Fault) so a
    test can assert exactly which faults a run absorbed.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 transient_frac: float = 0.9, delay_frac: float = 0.5,
                 delay_s: float = 10e-3,
                 max_faults: Optional[int] = 64):
        rates = dict(rates or {})
        for site in rates:
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r}; "
                                 f"sites are {SITES}")
        self.rates = rates
        self.transient_frac = float(transient_frac)
        self.delay_frac = float(delay_frac)
        self.delay_s = float(delay_s)
        self.max_faults = max_faults
        self.seed = int(seed)
        # independent stream per site: draws at one site never perturb
        # another site's schedule
        self._rngs = {site: np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(i,)))
            for i, site in enumerate(SITES)}
        self._calls = {site: 0 for site in SITES}
        self.injected: List[Tuple[str, int, Fault]] = []

    # ------------------------------------------------------------------
    def _pick_kind(self, site: str, u: float) -> Fault:
        if site == "pool":
            return Fault(site, "oom")
        if site == "pp_transfer":
            if u < self.delay_frac:
                return Fault(site, "delay", delay_s=self.delay_s)
            return Fault(site, "transient")
        kind = "transient" if u < self.transient_frac else "permanent"
        return Fault(site, kind)

    def draw(self, site: str) -> Optional[Fault]:
        """Advance ``site``'s schedule one step; returns the fault to
        inject at this call, or None."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}")
        idx = self._calls[site]
        self._calls[site] += 1
        rng = self._rngs[site]
        # always burn exactly two uniforms per draw so the site's schedule
        # depends only on its own call count
        u_fault, u_kind = rng.random(), rng.random()
        if self.max_faults is not None and \
                len(self.injected) >= self.max_faults:
            return None
        if u_fault >= self.rates.get(site, 0.0):
            return None
        fault = self._pick_kind(site, u_kind)
        self.injected.append((site, idx, fault))
        return fault

    # ------------------------------------------------------------------
    @classmethod
    def scripted(cls, plan: Dict[Tuple[str, int], Fault]) -> "FaultInjector":
        """Deterministic injector: fault exactly at the given
        (site, nth-call-at-that-site) coordinates, nowhere else."""
        inj = cls(seed=0, rates={})
        inj._plan = {}
        for (site, idx), fault in plan.items():
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r}")
            if fault.site != site:
                raise ValueError(
                    f"fault site {fault.site!r} does not match key {site!r}")
            inj._plan[(site, idx)] = fault

        def draw(site: str) -> Optional[Fault]:
            idx = inj._calls[site]
            inj._calls[site] += 1
            fault = inj._plan.get((site, idx))
            if fault is not None:
                inj.injected.append((site, idx, fault))
            return fault

        inj.draw = draw                      # type: ignore[method-assign]
        return inj
