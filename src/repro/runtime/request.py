"""Serving requests and per-request SLO metrics (paper §V-C, request level).

The paper's SLO study is about *serving*: requests with distinct arrival
times, prompt lengths and decode budgets.  This module is the request-level
vocabulary the continuous-batching scheduler (runtime/scheduler.py) consumes:
a :class:`Request` (prompt + decode budget + arrival time + optional
deadlines), the :class:`RequestMetrics` record (TTFT / TPOT / E2E — the
paper's Fig. 8–10 quantities, measured instead of predicted), and a Poisson
trace generator for benchmarks/serving_bench.py.

Finish-reason taxonomy (DESIGN.md §10):

  ``"length"``     decode budget exhausted (``max_new_tokens``) — normal.
  ``"eos"``        the model emitted ``eos_id`` (or the emulated early stop
                   ``eos_pos`` was reached) — normal, early.
  ``"deadline"``   shed: the request could no longer meet its
                   ``deadline`` / ``ttft_deadline``; tokens generated so
                   far are kept.
  ``"cancelled"``  shed by an explicit ``Scheduler.cancel(rid)``.
  ``"error"``      a permanent fault (or exhausted retries) killed the
                   request mid-flight.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One inference request.

    ``arrival`` is in seconds relative to the start of the scheduler run
    (0.0 = queued before the run starts).  ``eos_id`` stops decode early when
    the model emits it; ``max_new_tokens`` always bounds the decode length
    (first token from prefill included).

    ``eos_pos`` is the *emulated* early stop: finish with reason "eos" after
    that many generated tokens.  Synthetic traces need it because greedy
    streams from randomly-initialized weights have no designated EOS token —
    it exercises the exact same early-eviction path (the one that strands
    conservative-admission capacity, DESIGN.md §10) with a deterministic,
    trace-controlled stop.

    ``deadline`` / ``ttft_deadline`` are SLO budgets in seconds *relative to
    arrival*: miss either and the scheduler sheds the request mid-flight
    with ``finish_reason="deadline"`` instead of spending capacity on an
    answer nobody is waiting for.
    """

    rid: int
    prompt: np.ndarray               # [S] int32 token ids
    max_new_tokens: int
    arrival: float = 0.0
    eos_id: Optional[int] = None
    eos_pos: Optional[int] = None    # emulated EOS after N generated tokens
    deadline: Optional[float] = None       # E2E budget, seconds from arrival
    ttft_deadline: Optional[float] = None  # first-token budget, from arrival

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")
        if self.eos_pos is not None and self.eos_pos < 1:
            raise ValueError(f"request {self.rid}: eos_pos < 1")
        for name in ("deadline", "ttft_deadline"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"request {self.rid}: {name} must be > 0")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass
class RequestMetrics:
    """Measured per-request SLOs — the serving counterpart of
    ``core.slo.predict_slo`` (which predicts the same three quantities
    analytically for a single request on an idle engine)."""

    rid: int
    prompt_len: int
    arrival: float
    admitted: float = 0.0            # FIRST prefill start (queue delay ends)
    first_token: float = 0.0         # TTFT reference point
    finished: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish_reason: str = ""          # taxonomy in the module docstring
    preemptions: int = 0             # times evicted + recomputed (§10)
    retries: int = 0                 # transient-fault retries while active
    cached_prefix_len: int = 0       # prefix-cache hit at admission: prompt
    #                                  positions adopted, not computed (§13)

    @property
    def num_generated(self) -> int:
        return len(self.tokens)

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival

    @property
    def queue_delay(self) -> float:
        return self.admitted - self.arrival

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first (0 for 1-token runs)."""
        if self.num_generated <= 1:
            return 0.0
        return (self.finished - self.first_token) / (self.num_generated - 1)

    @property
    def e2e(self) -> float:
        return self.finished - self.arrival

    def row(self) -> str:
        return (f"req {self.rid:3d}  s_p {self.prompt_len:4d} "
                f"n_out {self.num_generated:4d}  "
                f"TTFT {self.ttft*1e3:8.1f} ms  TPOT {self.tpot*1e3:7.2f} ms  "
                f"E2E {self.e2e:6.3f} s  [{self.finish_reason}]")


def make_poisson_trace(n_requests: int, rate: float, vocab_size: int,
                       prompt_lens=(8, 64), decode_lens=(4, 32),
                       seed: int = 0, quantum: int = 1,
                       eos_prob: float = 0.0) -> List[Request]:
    """Mixed-length request trace with Poisson arrivals at ``rate`` req/s.

    Prompt and decode lengths are drawn uniformly from the given inclusive
    ranges — the "application-specific request mix" knob the related work
    (Topcu et al.) shows flips parallelization tradeoffs.  ``rate=inf``
    (or <= 0) makes every request arrive at t=0 (closed-batch mode).
    ``quantum`` rounds prompt lengths down to a multiple (vLLM-style shape
    bucketing: each distinct prompt length compiles one batch-1 prefill).

    ``eos_prob`` makes the trace EOS-heavy: each request's emulated early
    stop (``Request.eos_pos``) is drawn geometrically with per-token stop
    probability ``eos_prob``, truncated by the decode budget — so requests
    commit their full ``max_new_tokens`` worst case at admission but mostly
    finish far earlier, exactly the mix that strands conservative-admission
    capacity (DESIGN.md §10).
    """
    rng = np.random.default_rng(seed)
    if rate and np.isfinite(rate) and rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    else:
        arrivals = np.zeros(n_requests)
    if not 0.0 <= eos_prob < 1.0:
        raise ValueError(f"eos_prob must be in [0, 1), got {eos_prob}")
    reqs = []
    for i in range(n_requests):
        s_p = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        if quantum > 1:
            s_p = max(prompt_lens[0], (s_p // quantum) * quantum)
        n_d = int(rng.integers(decode_lens[0], decode_lens[1] + 1))
        eos_pos = None
        if eos_prob > 0.0:
            stop = int(rng.geometric(eos_prob))
            eos_pos = stop if stop < n_d else None
        prompt = rng.integers(2, vocab_size, s_p).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=n_d,
                            arrival=float(arrivals[i]), eos_pos=eos_pos))
    return reqs


def make_template_trace(n_requests: int, rate: float, vocab_size: int,
                        n_templates: int = 4, template_len: int = 48,
                        suffix_lens=(4, 16), decode_lens=(4, 16),
                        zipf_a: float = 1.5, seed: int = 0) -> List[Request]:
    """Template-heavy trace for the prefix cache (DESIGN.md §13): each
    prompt is a shared *template* (system prompt) of ``template_len`` tokens
    followed by a per-request unique suffix.  Templates are drawn
    zipf-distributed (exponent ``zipf_a``) over ``n_templates`` — the
    production shape where thousands of users share a handful of system
    prompts, so most requests after the first per template hit the index
    for the whole template.  Suffixes embed the rid, so no two prompts are
    identical and every hit still prefills a genuine novel suffix.
    """
    rng = np.random.default_rng(seed)
    if n_templates < 1:
        raise ValueError(f"n_templates must be >= 1, got {n_templates}")
    if not zipf_a > 1.0:
        raise ValueError(f"zipf_a must be > 1 (zipf support), got {zipf_a}")
    templates = [rng.integers(2, vocab_size, template_len).astype(np.int32)
                 for _ in range(n_templates)]
    if rate and np.isfinite(rate) and rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    else:
        arrivals = np.zeros(n_requests)
    reqs = []
    for i in range(n_requests):
        t = (int(rng.zipf(zipf_a)) - 1) % n_templates
        s_s = int(rng.integers(suffix_lens[0], suffix_lens[1] + 1))
        suffix = rng.integers(2, vocab_size, s_s).astype(np.int32)
        suffix[0] = 2 + i % (vocab_size - 2)     # rid-unique: never a dup
        n_d = int(rng.integers(decode_lens[0], decode_lens[1] + 1))
        reqs.append(Request(
            rid=i, prompt=np.concatenate([templates[t], suffix]),
            max_new_tokens=n_d, arrival=float(arrivals[i])))
    return reqs
