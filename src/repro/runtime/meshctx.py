"""Process-wide mesh context.

Model code is mesh-agnostic except for explicitly-scheduled collectives
(e.g. the shard_map MoE local-dispatch path).  Drivers that lower for a mesh
register it here; model code asks for it lazily.
"""
from __future__ import annotations


_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH
