"""Paged KV-cache block allocator (DESIGN.md §8).

The slot backends used to pin every request to a contiguous ``max_len`` KV
row — long-context traces either OOM the slot pool or waste most of it.  The
pool instead carves the cache into fixed-size *pages* of ``page_size`` token
positions each and hands requests pages on demand: a request's KV lives at
the physical pages named by its *block table*, in logical order, and logical
position ``q`` maps to physical row ``table[q // page_size] * page_size +
q % page_size``.

This module is the host-side bookkeeping only — pure Python over integers,
no jax.  The device side (``models/layers.paged_gather`` and the engines'
paged steps) consumes the block tables as [B, pages_per_seq] int32 arrays.

Invariants (property-tested in tests/test_kvpool.py):

  * a free page is never in any live block table, and a live page is owned
    by exactly one owner unless it was explicitly shared (``fork`` /
    ``adopt``) — pages are ref-counted, so shared prefixes free correctly;
  * freed pages return to the free list and are reused (LIFO — the hottest
    page comes back first);
  * ``stats()`` always accounts for every page:
    ``free_pages + allocated_pages == num_pages`` (page 0 is a reserved
    scratch page, counted as allocated forever), and a page shared by k
    owners counts ONCE — physically — in every token column.

Copy-on-write (DESIGN.md §13).  ``extend`` growing into a *shared* partial
tail page no longer refuses: it claims a private page, swaps it into the
owner's table, decrefs the original, and records a :class:`CowEvent` naming
(src, dst, committed rows).  The pool is host bookkeeping — it cannot touch
device memory — so the backend that owns the device page pools drains
``take_cow_events()`` after every ``extend`` and replays each event as a
device row copy *before* the pass that writes the new positions.  The claim
happens atomically with the ordinary growth claim: a pool-oom mid-COW
raises ``MemoryError`` with the owner's table, lengths, refcounts and the
event log all untouched (no half-copied page can leak).

Page 0 is **reserved**: it is never handed out, and backends point the block
tables of inactive slots at it so a fused decode step's garbage writes for
free slots land in scratch instead of corrupting a live page (the paged
counterpart of "free slots compute garbage the scheduler ignores",
DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

SCRATCH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class CowEvent:
    """One copy-on-write the pool performed in bookkeeping and the backend
    must replay on the device pools: copy the ``rows`` committed positions
    of physical page ``src`` into the freshly claimed page ``dst``."""

    src: int
    dst: int
    rows: int


@dataclasses.dataclass(frozen=True)
class PoolStats:
    """Occupancy + fragmentation snapshot; fields sum to the pool size.

    Every token column is *physical*: a page shared by k owners (``fork`` /
    ``adopt``) contributes its committed rows ONCE — the per-owner sum the
    pre-COW pool reported double-counted every ref-shared page, pushing
    utilization past 1.0 under prefix sharing.  ``shared_pages`` counts the
    pages currently held by more than one owner; ``cow_copies`` is the
    pool-lifetime count of copy-on-write page splits."""

    num_pages: int
    page_size: int
    free_pages: int
    allocated_pages: int          # includes the reserved scratch page
    used_tokens: int              # PHYSICAL token positions occupied
    internal_frag_tokens: int     # allocated-but-unused positions (physical)
    shared_pages: int = 0         # pages with refcount > 1 right now
    cow_copies: int = 0           # lifetime copy-on-write splits

    @property
    def capacity_tokens(self) -> int:
        return self.num_pages * self.page_size

    @property
    def utilization(self) -> float:
        """Occupied fraction of the *allocated* (non-scratch) capacity."""
        alloc = (self.allocated_pages - 1) * self.page_size
        return self.used_tokens / alloc if alloc else 0.0


class KVPool:
    """Fixed-size-page KV allocator with per-owner block tables.

    ``allocate(owner, num_tokens)`` claims pages for a new sequence,
    ``extend(owner, new_len)`` grows it (decode crossing a page boundary;
    copy-on-write when the partial tail is shared), ``free(owner)`` releases
    it, ``fork(owner, new_owner, length=...)`` shares a prefix of the
    current pages (both owners read the same prefix; the pages free only
    when the last owner releases them), ``adopt(owner, pages, num_tokens)``
    builds an owner from an explicit list of live pages — the prefix
    index's cache-hit handoff (runtime/prefix_index.py).
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # LIFO free list, excluding the reserved scratch page 0
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refcount: Dict[int, int] = {}      # physical page -> owners
        self._tables: Dict[int, List[int]] = {}  # owner -> logical->physical
        self._lengths: Dict[int, int] = {}       # owner -> tokens occupied
        self._cow_events: List[CowEvent] = []    # pending device-row copies
        self.cow_copies = 0                      # lifetime COW splits

    # ------------------------------------------------------------- helpers
    def _pages_for(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)      # ceil div

    def _claim(self, n: int) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"pool exhausted: need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            assert pg not in self._refcount, f"page {pg} double-assigned"
            self._refcount[pg] = 1
        return pages

    # ------------------------------------------------------------ interface
    def allocate(self, owner: int, num_tokens: int) -> List[int]:
        """Claim pages covering ``num_tokens`` positions for a new owner;
        returns the block table (logical order)."""
        if owner in self._tables:
            raise KeyError(f"owner {owner} already holds an allocation")
        if num_tokens < 1:
            raise ValueError(f"num_tokens must be >= 1, got {num_tokens}")
        self._tables[owner] = self._claim(self._pages_for(num_tokens))
        self._lengths[owner] = num_tokens
        return list(self._tables[owner])

    def extend(self, owner: int, new_len: int) -> List[int]:
        """Grow an allocation to cover ``new_len`` positions (no-op when the
        current last page still has room); returns the updated table.

        Growing into a *shared* partial tail page copy-on-writes it
        (DESIGN.md §13): a private page is claimed, swapped into this
        owner's table, and the original decref'd — the sibling owners keep
        reading the untouched original.  The split is recorded as a
        :class:`CowEvent` for the backend to replay as a device row copy
        (``take_cow_events``).  All pages — the COW copy and any growth —
        are claimed in ONE atomic step, so a pool-oom raises ``MemoryError``
        before any state mutates.  A page-aligned shared prefix grows
        without copying: new positions land only on freshly-claimed
        exclusive pages.
        """
        table = self._tables[owner]
        cur = self._lengths[owner]
        if new_len < cur:
            raise ValueError(
                f"extend shrinks owner {owner}: {new_len} < {cur}")
        cow = new_len > cur and cur % self.page_size != 0 and \
            self._refcount[table[-1]] > 1
        need = self._pages_for(new_len) - len(table)
        pages = self._claim(need + (1 if cow else 0))
        if cow:
            src, dst = table[-1], pages[0]
            committed = cur - (len(table) - 1) * self.page_size
            table[-1] = dst
            self._refcount[src] -= 1     # shared: never hits 0 here
            self.cow_copies += 1
            self._cow_events.append(CowEvent(src, dst, committed))
            pages = pages[1:]
        table.extend(pages)
        self._lengths[owner] = new_len
        return list(table)

    def take_cow_events(self) -> List[CowEvent]:
        """Drain the pending copy-on-write events.  The device-side owner
        of the page pools MUST replay each as a row copy src→dst before the
        next pass that writes (or reads) the new private page."""
        events, self._cow_events = self._cow_events, []
        return events

    def fork(self, owner: int, new_owner: int,
             length: int = None) -> List[int]:
        """Share a prefix of ``owner``'s pages with ``new_owner``: both
        tables name the same physical pages, refcounts bumped.  ``length``
        (tokens; default: the owner's full length) shares only the pages
        covering that prefix — the cache-hit fork, where the new request
        adopts the cached pages and prefills just its novel suffix."""
        if new_owner in self._tables:
            raise KeyError(f"owner {new_owner} already holds an allocation")
        length = self._lengths[owner] if length is None else int(length)
        if not 1 <= length <= self._lengths[owner]:
            raise ValueError(
                f"fork length {length} outside (0, {self._lengths[owner]}]")
        table = self._tables[owner][:self._pages_for(length)]
        for pg in table:
            self._refcount[pg] += 1
        self._tables[new_owner] = list(table)
        self._lengths[new_owner] = length
        return list(table)

    def adopt(self, owner: int, pages: List[int],
              num_tokens: int) -> List[int]:
        """Build ``owner``'s allocation from an explicit list of LIVE pages
        (each refcount-bumped) covering ``num_tokens`` positions — how a
        cache hit assembled from per-block prefix-index entries lands in a
        slot, and the KV-handoff unit disaggregated prefill will ship."""
        if owner in self._tables:
            raise KeyError(f"owner {owner} already holds an allocation")
        pages = [int(pg) for pg in pages]
        if not pages:
            raise ValueError("adopt needs at least one page")
        if not (len(pages) - 1) * self.page_size < num_tokens \
                <= len(pages) * self.page_size:
            raise ValueError(
                f"{num_tokens} tokens do not fit exactly {len(pages)} pages "
                f"of {self.page_size}")
        for pg in pages:
            if pg not in self._refcount:
                raise ValueError(f"page {pg} is not live — cannot adopt")
        for pg in pages:
            self._refcount[pg] += 1
        self._tables[owner] = list(pages)
        self._lengths[owner] = int(num_tokens)
        return list(pages)

    def free(self, owner: int) -> None:
        """Release an owner; pages whose refcount hits zero rejoin the free
        list (LIFO).  Freeing an unknown owner is a no-op — the scheduler
        frees slots it may never have admitted into."""
        table = self._tables.pop(owner, None)
        if table is None:
            return
        del self._lengths[owner]
        for pg in reversed(table):
            self._refcount[pg] -= 1
            if self._refcount[pg] == 0:
                del self._refcount[pg]
                self._free.append(pg)

    # --------------------------------------------------------- introspection
    def block_table(self, owner: int) -> List[int]:
        return list(self._tables[owner])

    def owners(self) -> List[int]:
        return list(self._tables)

    def length(self, owner: int) -> int:
        return self._lengths[owner]

    def page_refcount(self, page: int) -> int:
        """Owners currently holding physical ``page`` (0 when free)."""
        return self._refcount.get(page, 0)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def stats(self) -> PoolStats:
        # physical occupancy: each page's committed rows counted ONCE —
        # the deepest committed row any owner has in it (owners sharing a
        # page agree on its content; they can only differ in how far their
        # own length reaches into it)
        rows: Dict[int, int] = {}
        for o, t in self._tables.items():
            ln = self._lengths[o]
            for i, pg in enumerate(t):
                r = min(self.page_size, ln - i * self.page_size)
                if r > rows.get(pg, 0):
                    rows[pg] = r
        used = sum(rows.values())
        allocated = self.num_pages - len(self._free)
        return PoolStats(
            num_pages=self.num_pages, page_size=self.page_size,
            free_pages=len(self._free),
            allocated_pages=allocated,
            used_tokens=used,
            internal_frag_tokens=(allocated - 1) * self.page_size - used,
            shared_pages=sum(1 for n in self._refcount.values() if n > 1),
            cow_copies=self.cow_copies)
