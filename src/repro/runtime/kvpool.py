"""Paged KV-cache block allocator (DESIGN.md §8).

The slot backends used to pin every request to a contiguous ``max_len`` KV
row — long-context traces either OOM the slot pool or waste most of it.  The
pool instead carves the cache into fixed-size *pages* of ``page_size`` token
positions each and hands requests pages on demand: a request's KV lives at
the physical pages named by its *block table*, in logical order, and logical
position ``q`` maps to physical row ``table[q // page_size] * page_size +
q % page_size``.

This module is the host-side bookkeeping only — pure Python over integers,
no jax.  The device side (``models/layers.paged_gather`` and the engines'
paged steps) consumes the block tables as [B, pages_per_seq] int32 arrays.

Invariants (property-tested in tests/test_kvpool.py):

  * a free page is never in any live block table, and a live page is owned
    by exactly one owner unless it was explicitly shared (``fork``) — pages
    are ref-counted, so shared prefixes free correctly;
  * freed pages return to the free list and are reused (LIFO — the hottest
    page comes back first);
  * ``stats()`` always accounts for every page:
    ``free_pages + allocated_pages == num_pages`` (page 0 is a reserved
    scratch page, counted as allocated forever).

Page 0 is **reserved**: it is never handed out, and backends point the block
tables of inactive slots at it so a fused decode step's garbage writes for
free slots land in scratch instead of corrupting a live page (the paged
counterpart of "free slots compute garbage the scheduler ignores",
DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

SCRATCH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PoolStats:
    """Occupancy + fragmentation snapshot; fields sum to the pool size."""

    num_pages: int
    page_size: int
    free_pages: int
    allocated_pages: int          # includes the reserved scratch page
    used_tokens: int              # token positions actually occupied
    internal_frag_tokens: int     # allocated-but-unused tail positions

    @property
    def capacity_tokens(self) -> int:
        return self.num_pages * self.page_size

    @property
    def utilization(self) -> float:
        """Occupied fraction of the *allocated* (non-scratch) capacity."""
        alloc = (self.allocated_pages - 1) * self.page_size
        return self.used_tokens / alloc if alloc else 0.0


class KVPool:
    """Fixed-size-page KV allocator with per-owner block tables.

    ``allocate(owner, num_tokens)`` claims pages for a new sequence,
    ``extend(owner, new_len)`` grows it (decode crossing a page boundary),
    ``free(owner)`` releases it, ``fork(owner, new_owner)`` shares the
    current pages copy-on-nothing (both owners read the same prefix; the
    pages free only when the last owner releases them).
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # LIFO free list, excluding the reserved scratch page 0
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refcount: Dict[int, int] = {}      # physical page -> owners
        self._tables: Dict[int, List[int]] = {}  # owner -> logical->physical
        self._lengths: Dict[int, int] = {}       # owner -> tokens occupied

    # ------------------------------------------------------------- helpers
    def _pages_for(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)      # ceil div

    def _claim(self, n: int) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"pool exhausted: need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            assert pg not in self._refcount, f"page {pg} double-assigned"
            self._refcount[pg] = 1
        return pages

    # ------------------------------------------------------------ interface
    def allocate(self, owner: int, num_tokens: int) -> List[int]:
        """Claim pages covering ``num_tokens`` positions for a new owner;
        returns the block table (logical order)."""
        if owner in self._tables:
            raise KeyError(f"owner {owner} already holds an allocation")
        if num_tokens < 1:
            raise ValueError(f"num_tokens must be >= 1, got {num_tokens}")
        self._tables[owner] = self._claim(self._pages_for(num_tokens))
        self._lengths[owner] = num_tokens
        return list(self._tables[owner])

    def extend(self, owner: int, new_len: int) -> List[int]:
        """Grow an allocation to cover ``new_len`` positions (no-op when the
        current last page still has room); returns the updated table.

        Growing past a *shared* partial tail page is refused: the new
        positions would be written into rows the other owner also reads
        (there is no copy-on-write here — the pool is host bookkeeping and
        cannot copy device pages).  A page-aligned shared prefix grows
        fine: new positions land only on freshly-claimed exclusive pages.
        """
        table = self._tables[owner]
        cur = self._lengths[owner]
        if new_len < cur:
            raise ValueError(
                f"extend shrinks owner {owner}: {new_len} < {cur}")
        if new_len > cur and cur % self.page_size != 0 and \
                self._refcount[table[-1]] > 1:
            raise ValueError(
                f"owner {owner} grows into shared tail page {table[-1]} "
                "(forked, not page-aligned) — copy it before extending")
        need = self._pages_for(new_len) - len(table)
        if need > 0:
            table.extend(self._claim(need))
        self._lengths[owner] = new_len
        return list(table)

    def fork(self, owner: int, new_owner: int) -> List[int]:
        """Share ``owner``'s pages with ``new_owner`` (prefix sharing): both
        tables name the same physical pages, refcounts bumped."""
        if new_owner in self._tables:
            raise KeyError(f"owner {new_owner} already holds an allocation")
        table = self._tables[owner]
        for pg in table:
            self._refcount[pg] += 1
        self._tables[new_owner] = list(table)
        self._lengths[new_owner] = self._lengths[owner]
        return list(table)

    def free(self, owner: int) -> None:
        """Release an owner; pages whose refcount hits zero rejoin the free
        list (LIFO).  Freeing an unknown owner is a no-op — the scheduler
        frees slots it may never have admitted into."""
        table = self._tables.pop(owner, None)
        if table is None:
            return
        del self._lengths[owner]
        for pg in reversed(table):
            self._refcount[pg] -= 1
            if self._refcount[pg] == 0:
                del self._refcount[pg]
                self._free.append(pg)

    # --------------------------------------------------------- introspection
    def block_table(self, owner: int) -> List[int]:
        return list(self._tables[owner])

    def owners(self) -> List[int]:
        return list(self._tables)

    def length(self, owner: int) -> int:
        return self._lengths[owner]

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def stats(self) -> PoolStats:
        used = sum(self._lengths.values())
        # a page shared by k owners is still ONE allocated physical page,
        # but each owner's tail slack counts toward internal fragmentation
        slack = sum(len(t) * self.page_size - self._lengths[o]
                    for o, t in self._tables.items())
        return PoolStats(
            num_pages=self.num_pages, page_size=self.page_size,
            free_pages=len(self._free),
            allocated_pages=self.num_pages - len(self._free),
            used_tokens=used, internal_frag_tokens=slack)
