"""DecodeBackend: one slot-based decode protocol over all three engines.

The repo grew three decode paths — the GSPMD ``Model`` path
(runtime/engine.py), the explicit-collective TP engine
(core/parallel_exec.tp_decode_step) and the per-stage-jit ``PipelineEngine``
— each serving one fixed, same-length batch with a scalar decode position.
The continuous-batching scheduler (runtime/scheduler.py) instead needs a
*slot* abstraction: a KV cache with ``num_slots`` independent batch rows,
where any row can be (re)filled by prefilling a new request while the other
rows keep decoding from their own depths.

The protocol (DESIGN.md §7):

  prefill_into_slots(prompts, slots) -> first greedy token per request.
      Each request is prefilled alone at its true length (batch-1 pass —
      row-wise math is identical to serving it solo, which is what makes the
      scheduler token-identical to isolated serving) and its seeded KV cache
      is scattered into the slot's batch row.
  decode_step(tokens [B], pos [B]) -> next greedy token for every slot.
      ONE jitted step over the full slot batch with per-sequence positions
      (models/transformer.py + core/parallel_exec.py vector-pos paths);
      free slots decode garbage that the scheduler ignores — the collective
      *count* of the step is batch-invariant either way (the paper's
      Tables III–VI carry no batch term in the count columns), which is why
      a fixed-capacity step can serve a varying active set.
  free_slots(slots)
      Bookkeeping only: a freed row is overwritten by the next admission.

Per-step predicted communication comes from ``commodel.comm_ops_for`` via
:meth:`DecodeBackend.decode_comm_ops`; the PP/hybrid backend additionally
exposes the engine's measured TransferRecords through ``drain_transfers``.
"""
from __future__ import annotations

import functools
from typing import List, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig
from repro.core import parallel_exec as px
from repro.core.commodel import CommOp, comm_ops_for
from repro.models.transformer import get_model


@runtime_checkable
class DecodeBackend(Protocol):
    """Slot-based decode interface the scheduler drives (DESIGN.md §7)."""

    cfg: ModelConfig
    num_slots: int
    max_len: int
    t: int
    p: int

    def prefill_into_slots(self, prompts: Sequence[np.ndarray],
                           slots: Sequence[int]) -> np.ndarray: ...

    def decode_step(self, tokens: np.ndarray,
                    pos: np.ndarray) -> np.ndarray: ...

    def free_slots(self, slots: Sequence[int]) -> None: ...

    def decode_comm_ops(self, batch: int = 1) -> List[CommOp]: ...

    def drain_transfers(self) -> dict: ...


def _write_slot(big, small, slot):
    """Scatter a batch-1 cache pytree into batch row ``slot`` of the slot
    cache (every cache family keeps batch on axis 1 of each leaf)."""
    return jax.tree.map(
        lambda b, s: jax.lax.dynamic_update_slice_in_dim(b, s, slot, axis=1),
        big, small)


class _BackendBase:
    """Shared slot bookkeeping + predicted per-step communication."""

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 t: int, p: int):
        if not cfg.is_decoder:
            raise ValueError(f"{cfg.name} is encoder-only: no decode")
        self.cfg = cfg
        self.num_slots = int(num_slots)
        self.max_len = int(max_len)
        self.t, self.p = int(t), int(p)

    def decode_comm_ops(self, batch: int = 1) -> List[CommOp]:
        """Predicted collectives for ONE decode step over ``batch`` rows:
        the decode-phase rows of ``comm_ops_for`` at s_d=2 (one step past
        the prefill token), gather_mode="allgather" (the XLA engines), at
        the backend's actual activation width — so predicted bytes sit on
        the same scale as the measured TransferRecords."""
        ops = comm_ops_for(self.cfg, 1, 2, self.t, self.p, batch=batch,
                           b=jnp.dtype(self.cfg.dtype).itemsize,
                           gather_mode="allgather")
        return [o for o in ops if o.phase == "decode"]

    def drain_transfers(self) -> dict:
        """Inter-stage bytes moved since the last drain (PP only)."""
        return {"count": 0, "bytes": 0}

    def free_slots(self, slots: Sequence[int]) -> None:
        for s in slots:
            if not 0 <= s < self.num_slots:
                raise IndexError(f"slot {s} out of range")

    # -- shared admission loop (template method) ---------------------------
    def prefill_into_slots(self, prompts, slots) -> np.ndarray:
        """Admit requests: one batch-1 prefill per prompt at its true
        length (row-wise identical to serving it solo), scattered into the
        slot's batch row.  Returns the first greedy token per request."""
        first = np.zeros(len(slots), np.int32)
        for i, (prompt, slot) in enumerate(zip(prompts, slots)):
            logits, small = self._prefill_one(self._as_prompt(prompt))
            self._scatter(small, slot)
            first[i] = self._first_token(logits)[0]
        return first

    def _prefill_one(self, prompt):
        """(logits [1, v], seeded batch-1 cache) for one prompt."""
        raise NotImplementedError

    def _scatter(self, small, slot: int) -> None:
        """Write a batch-1 cache into the slot row (default: single slot
        cache pytree on ``self.cache`` via the donating ``self._write``)."""
        self.cache = self._write(self.cache, small, jnp.int32(slot))

    def _first_token(self, logits) -> np.ndarray:
        return np.asarray(jnp.argmax(logits, -1), np.int32)

    def _as_prompt(self, prompt) -> jnp.ndarray:
        return jnp.asarray(np.asarray(prompt, np.int32))[None, :]


class ModelBackend(_BackendBase):
    """GSPMD ``Model`` path (the runtime/engine.py lineage) behind the
    DecodeBackend protocol.  Single jit per decode step, donated slot cache,
    per-sequence positions through ``Model.decode_step``."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 max_len: int = 256):
        super().__init__(cfg, num_slots, max_len, t=1, p=1)
        self.model = get_model(cfg)
        self.params = params
        self.cache = self.model.init_cache(num_slots, max_len)
        self._prefill = jax.jit(
            functools.partial(self.model.prefill, max_len=max_len))
        self._step = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._write = jax.jit(_write_slot, donate_argnums=(0,))

    def _prefill_one(self, prompt):
        logits, small, _ = self._prefill(self.params, prompt)
        return logits, small

    def decode_step(self, tokens, pos) -> np.ndarray:
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32))
        return self._first_token(logits)


class TPBackend(_BackendBase):
    """Explicit tensor-parallel engine (core/parallel_exec.py) behind the
    protocol: shard_map with hand-placed collectives — (2L+1) allreduce +
    1 logits all-gather per decode step, regardless of slot count."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 max_len: int = 256, t: int = 2, unroll: bool = False):
        super().__init__(cfg, num_slots, max_len, t=t, p=1)
        if cfg.family != "dense":
            raise ValueError("explicit TP engine covers the dense family")
        self.params = params
        self.mesh = px.make_tp_mesh(t)
        self.cache_w = get_model(cfg).cache_width(max_len)
        self._prefill = px.tp_prefill(cfg, self.mesh, cache_w=self.cache_w,
                                      unroll=unroll)
        self._step = px.tp_decode_step(cfg, self.mesh, unroll=unroll,
                                       vector_pos=True)
        shard = lambda sp: NamedSharding(self.mesh, sp)
        self.cache = {
            key: jax.device_put(
                jnp.zeros((cfg.num_layers, num_slots, self.cache_w,
                           cfg.num_kv_heads, cfg.head_dim),
                          jnp.dtype(cfg.dtype)),
                shard(P(None, None, None, "tp", None)))
            for key in ("k", "v")}
        self._write = jax.jit(_write_slot, donate_argnums=(0,))

    def _prefill_one(self, prompt):
        return self._prefill(self.params, prompt)

    def decode_step(self, tokens, pos) -> np.ndarray:
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32))
        return self._first_token(logits)

    def decode_step_hlo(self) -> str:
        """Compiled HLO of the slot decode step (collective-count checks)."""
        tok = jax.ShapeDtypeStruct((self.num_slots,), jnp.int32)
        pos = jax.ShapeDtypeStruct((self.num_slots,), jnp.int32)
        return self._step.lower(self.params, self.cache, tok,
                                pos).compile().as_text()


class PPBackend(_BackendBase):
    """PipelineEngine (pure PP when t=1, hybrid TP×PP when t>1) behind the
    protocol: per-stage slot caches, one decode step = one token through all
    p stages with (p-1)·2 logged boundary transfers."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 max_len: int = 256, t: int = 1, p: int = 2,
                 unroll: bool = False, devices=None):
        super().__init__(cfg, num_slots, max_len, t=t, p=p)
        if cfg.family != "dense":
            raise ValueError("PipelineEngine covers the dense family")
        self.engine = px.PipelineEngine(cfg, t=t, p=p, unroll=unroll,
                                        devices=devices)
        self.staged = self.engine.prepare(params)
        self.cache_w = get_model(cfg).cache_width(max_len)
        self.caches = []
        for s in range(p):
            lo, hi = px.stage_layer_range(cfg, p, s)
            leaves = {
                key: jnp.zeros((hi - lo, num_slots, self.cache_w,
                                cfg.num_kv_heads, cfg.head_dim),
                               jnp.dtype(cfg.dtype))
                for key in ("k", "v")}
            if t > 1:
                leaves = {
                    key: jax.device_put(
                        a, NamedSharding(self.engine.meshes[s],
                                         P(None, None, None, "tp", None)))
                    for key, a in leaves.items()}
            self.caches.append(leaves)
        self._writes = [jax.jit(_write_slot, donate_argnums=(0,))
                        for _ in range(p)]
        self._drained = 0              # transfer-log cursor

    def _prefill_one(self, prompt):
        return self.engine.prefill_with_cache(self.staged, prompt,
                                              cache_w=self.cache_w)

    def _scatter(self, small, slot: int) -> None:
        self.caches = [
            self._writes[s](self.caches[s], small[s], jnp.int32(slot))
            for s in range(self.p)]

    def decode_step(self, tokens, pos) -> np.ndarray:
        logits, self.caches = self.engine.decode_once(
            self.staged, self.caches, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(np.asarray(pos), jnp.int32))
        return self._first_token(logits)

    def drain_transfers(self) -> dict:
        recs = self.engine.transfers[self._drained:]
        self._drained = len(self.engine.transfers)
        return {"count": sum(r.count for r in recs),
                "bytes": sum(r.bytes for r in recs)}

    def stage_decode_hlo(self, stage: int) -> str:
        """Compiled HLO of one stage's slot decode step (vector pos)."""
        fns = self.engine._decode_fns(vector_pos=True)
        pos = jnp.zeros((self.num_slots,), jnp.int32)
        tok = jnp.zeros((self.num_slots,), jnp.int32)
        x = jax.device_put(tok, NamedSharding(self.engine.meshes[0], P(None)))
        for i in range(stage):
            fn, _ = fns[i]
            out, _ = fn(self.staged[i],
                        jax.tree.map(jnp.copy, self.caches[i]), x, pos)
            x = self.engine._move_boundary(out, i, "hlo", log=False)
        fn, _ = fns[stage]
        return fn.lower(self.staged[stage], self.caches[stage], x,
                        pos).compile().as_text()


def make_backend(kind: str, cfg: ModelConfig, params, num_slots: int,
                 max_len: int = 256, t: int = 1, p: int = 1,
                 unroll: bool = False) -> DecodeBackend:
    """Backend factory keyed by engine kind: "gspmd" | "tp" | "pp".

    Degenerate layouts are rejected, not coerced — a silently bumped t/p
    would attribute measured SLOs to a layout the caller never asked for.
    """
    if kind == "gspmd":
        return ModelBackend(cfg, params, num_slots, max_len)
    if kind == "tp":
        if t < 2:
            raise ValueError(f"tp backend needs t >= 2, got t={t}")
        return TPBackend(cfg, params, num_slots, max_len, t=t, unroll=unroll)
    if kind == "pp":
        if p < 2:
            raise ValueError(f"pp backend needs p >= 2, got p={p}")
        return PPBackend(cfg, params, num_slots, max_len, t=t, p=p,
                         unroll=unroll)
    raise ValueError(f"unknown backend kind: {kind!r}")
