"""DecodeBackend: one slot-based decode protocol over all three engines.

The repo grew three decode paths — the GSPMD ``Model`` path
(runtime/engine.py), the explicit-collective TP engine
(core/parallel_exec.tp_decode_step) and the per-stage-jit ``PipelineEngine``
— each serving one fixed, same-length batch with a scalar decode position.
The continuous-batching scheduler (runtime/scheduler.py) instead needs a
*slot* abstraction: a KV cache with ``num_slots`` independent batch rows,
where any row can be (re)filled by prefilling a new request while the other
rows keep decoding from their own depths.

The protocol (DESIGN.md §7):

  prefill_into_slots(prompts, slots) -> first greedy token per request.
      Each request is prefilled alone at its true length (batch-1 pass —
      row-wise math is identical to serving it solo, which is what makes the
      scheduler token-identical to isolated serving) and its seeded KV cache
      is scattered into the slot's batch row.
  decode_step(tokens [B], pos [B]) -> next greedy token for every slot.
      ONE jitted step over the full slot batch with per-sequence positions
      (models/transformer.py + core/parallel_exec.py vector-pos paths);
      free slots decode garbage that the scheduler ignores — the collective
      *count* of the step is batch-invariant either way (the paper's
      Tables III–VI carry no batch term in the count columns), which is why
      a fixed-capacity step can serve a varying active set.
  free_slots(slots)
      Bookkeeping only: a freed row is overwritten by the next admission.

Per-step predicted communication comes from ``commodel.comm_ops_for`` via
:meth:`DecodeBackend.decode_comm_ops`; the PP/hybrid backend additionally
exposes the engine's measured TransferRecords through ``drain_transfers``.

Paged mode (DESIGN.md §8).  With ``paged=True`` every backend swaps the
contiguous [.., num_slots, max_len, ..] slot cache for fixed-size KV *pages*
([.., num_pages, page_size, ..]) managed by a host-side ``runtime.kvpool.
KVPool``: slots own pages on demand instead of a pinned ``max_len`` row, so
long-context and short requests share one pool without reserving worst-case
memory.  Prefill becomes *chunked* — three extra methods drive it:

  begin_prefill(slot, prompt_len)   allocate the slot's pages
  prefill_chunk(slot, tokens, start) -> greedy token of the chunk's last
      position (only the final chunk's is meaningful); ONE jitted paged
      pass per chunk, same collective schedule as a full prefill pass
      (``commodel.chunked_prefill_ops``)
  finish_prefill(slot)              mark the slot decode-eligible

``decode_step`` keeps its protocol signature; in paged mode it extends each
decode-eligible slot's pages to cover the incoming position and points every
ineligible slot's block-table row at the reserved scratch page 0, so the
fixed-capacity step's garbage lanes can never corrupt a live page.

Context parallelism (``c > 1`` on the explicit backends, DESIGN.md §9)
changes ONLY how a request's prefill runs: the prompt is padded to a
multiple of c, sequence-sharded over the mesh's cp axis, and each layer's
K/V ring-exchanged (``parallel_exec.cp_prefill`` / the CP stage fns) — the
ring assembles the FULL cache on every cp worker, so the seeded KV drops
into the contiguous slot row via the ordinary ``_scatter``, or into the KV
pages via ``_seed_pages``, and ``decode_step`` is untouched (it runs
replicated over the cp axis).  CP and chunked prefill are alternative
long-prompt strategies: ``Scheduler(chunk_size=...)`` rejects c>1 backends.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig
from repro.core import parallel_exec as px
from repro.core.commodel import DEFAULT_QUANT_CHUNK, CommOp, \
    chunked_prefill_ops, comm_ops_for
from repro.models.layers import paged_cache_update
from repro.models.transformer import get_model
from repro.runtime.kvpool import KVPool
from repro.runtime.prefix_index import PrefixIndex
from repro.runtime.schedule import DynamicPPQueue, FusedQueue


@runtime_checkable
class DecodeBackend(Protocol):
    """Slot-based decode interface the scheduler drives (DESIGN.md §7)."""

    cfg: ModelConfig
    num_slots: int
    max_len: int
    t: int
    c: int
    p: int
    inflight: int        # in-flight microbatch groups (1 on fused backends)
    group_size: int      # slots per group (num_slots // inflight)

    def prefill_into_slots(self, prompts: Sequence[np.ndarray],
                           slots: Sequence[int]) -> np.ndarray: ...

    def decode_step(self, tokens: np.ndarray,
                    pos: np.ndarray) -> np.ndarray: ...

    def free_slots(self, slots: Sequence[int]) -> None: ...

    def decode_comm_ops(self, batch: int = 1) -> List[CommOp]: ...

    def drain_transfers(self) -> dict: ...


def _write_slot(big, small, slot):
    """Scatter a batch-1 cache pytree into batch row ``slot`` of the slot
    cache (every cache family keeps batch on axis 1 of each leaf)."""
    return jax.tree.map(
        lambda b, s: jax.lax.dynamic_update_slice_in_dim(b, s, slot, axis=1),
        big, small)


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_page(pools, rows, page):
    """Land one exported KV page's rows {k, v: [L, ps, kv, D]} at physical
    page ``page`` of the device page pools ([L, P, ps, kv, D] leaves, page
    axis 1) — the import half of the disaggregated KV handoff
    (DESIGN.md §14).  ``page`` is a traced scalar, so repeated imports
    compile once per pool shape, like ``_copy_page_rows``."""
    def one(a, d):
        return jax.lax.dynamic_update_slice_in_dim(a, d[:, None], page,
                                                   axis=1)
    return jax.tree.map(one, pools, rows)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page_rows(pools, src, dst):
    """Replay one ``KVPool`` copy-on-write on the device page pools: copy
    physical page ``src``'s rows into page ``dst`` on every leaf (page axis
    is axis 1 of each [L, P, ps, kv, D] pool).  The whole page is copied —
    rows past the owner's committed length are garbage either way (the
    paged attention mask never exposes them, DESIGN.md §8) and the static
    shape keeps this ONE compiled module per pool shape.  src/dst are
    traced scalars, so repeated COWs never recompile."""
    def one(a):
        page = jax.lax.dynamic_slice_in_dim(a, src, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(a, page, dst, axis=1)
    return jax.tree.map(one, pools)


def _seed_pages(pools, small, bt):
    """Scatter a batch-1 contiguous cache {k,v: [L, 1, S, kv, D]} into the
    KV page pools {k,v: [L, P, ps, kv, D]} at the pages ``bt`` [1, n]
    names — the CP gather-into-pages handoff (DESIGN.md §9).  Pure data
    movement on unsharded axes (kv heads keep their TP sharding), jitted
    with the pools donated so the write happens in place."""
    pos = jnp.zeros((1,), jnp.int32)

    def per_layer(pk, pv, k, v):
        return paged_cache_update(pk, pv, k, v, pos, bt)

    ck, cv = jax.vmap(per_layer)(pools["k"], pools["v"],
                                 small["k"], small["v"])
    return {"k": ck, "v": cv}


class _BackendBase:
    """Shared slot bookkeeping + predicted per-step communication."""

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 t: int, p: int, paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None, c: int = 1,
                 quant_collectives: Optional[str] = None,
                 quant_chunk: int = DEFAULT_QUANT_CHUNK,
                 prefix_cache: bool = False,
                 pool: Optional[KVPool] = None, owner_base: int = 0):
        if not cfg.is_decoder:
            raise ValueError(f"{cfg.name} is encoder-only: no decode")
        if quant_collectives is not None and paged:
            raise ValueError(
                "quantized collectives cover the contiguous decode step; "
                "the paged engines run full-width (DESIGN.md §12)")
        if prefix_cache and not paged:
            raise ValueError(
                "prefix caching shares KV pages across requests — "
                "construct the backend with paged=True (DESIGN.md §13)")
        if prefix_cache and c > 1:
            raise ValueError(
                "a cache hit prefills only the novel suffix, which needs "
                "the chunked (offset) prefill path; CP prefills the whole "
                "sequence monolithically (DESIGN.md §9/§13) — use c=1")
        self.cfg = cfg
        self.quant = quant_collectives
        self.quant_chunk = int(quant_chunk)
        self.num_slots = int(num_slots)
        self.max_len = int(max_len)
        self.t, self.c, self.p = int(t), int(c), int(p)
        # fused backends run one microbatch group spanning every slot;
        # PPBackend overrides both when inflight > 1 (DESIGN.md §11)
        self.inflight = 1
        self.group_size = self.num_slots
        self.paged = bool(paged)
        if owner_base < 0:
            raise ValueError(
                f"owner_base must be >= 0 (negative ids belong to the "
                f"prefix index), got {owner_base}")
        self._owner_base = int(owner_base)
        if pool is not None and not paged:
            raise ValueError("a shared KVPool needs paged=True")
        if self.paged:
            if cfg.family != "dense":
                raise ValueError(
                    f"paged mode covers dense attention; {cfg.name} is "
                    f"{cfg.family}")
            if cfg.sliding_window:
                raise ValueError(
                    "paged mode keeps every position (no ring wrap); "
                    f"{cfg.name} uses a sliding window — serve it contiguous")
            self.page_size = int(page_size)
            self.pages_per_slot = -(-self.max_len // self.page_size)
            if pool is not None:
                # disaggregated pools (DESIGN.md §14) share ONE page space:
                # both backends' block tables name pages of the same host
                # allocator, so pages the prefill pool wrote are adoptable
                # by the decode pool; owner_base keeps their slot owner ids
                # disjoint.  NOTE: device page pools stay per-backend —
                # sharing the allocator shares *addressing*, the page
                # CONTENT still crosses via export_page/import_page.
                if pool.page_size != self.page_size:
                    raise ValueError(
                        f"shared pool page_size {pool.page_size} != "
                        f"backend page_size {self.page_size}")
                self.pool = pool
            else:
                if num_pages is None:
                    # capacity parity with the contiguous slot cache, +1 for
                    # the reserved scratch page; a smaller pool
                    # oversubscribes (long-context mixes that would OOM
                    # contiguous slots)
                    num_pages = 1 + self.num_slots * self.pages_per_slot
                self.pool = KVPool(num_pages, self.page_size)
            self.block_tables = np.zeros(
                (self.num_slots, self.pages_per_slot), np.int32)
            self._decodable: set = set()
            self._worst: dict = {}      # slot -> worst-case pages committed
        self.prefix_index = PrefixIndex(self.pool) if prefix_cache else None

    # -- paged bookkeeping (DESIGN.md §8) ----------------------------------
    def _require_paged(self):
        if not self.paged:
            raise RuntimeError("chunked-prefill API needs paged=True")

    def _owner(self, slot: int) -> int:
        """Pool owner id of a local slot.  Backends sharing one KVPool
        (disaggregated pools, DESIGN.md §14) claim disjoint owner ranges
        via ``owner_base``; single-pool backends keep owner == slot."""
        return self._owner_base + slot

    def _set_table(self, slot: int) -> None:
        table = self.pool.block_table(self._owner(slot))
        row = np.zeros(self.pages_per_slot, np.int32)
        row[:len(table)] = table
        self.block_tables[slot] = row

    def _pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def _alloc_len(self, prompt_len: int) -> int:
        """Cache positions a prompt claims up front: its true length, or the
        CP-padded length (prompts pad to a multiple of c so the sequence
        shards equally — the pad rows' garbage KV sits inside the slot's
        own pages and decode overwrites each position before the causal
        mask ever exposes it, DESIGN.md §9)."""
        return prompt_len if self.c == 1 else \
            -(-prompt_len // self.c) * self.c

    def can_admit(self, prompt_len: int, max_new_tokens: int = 1,
                  optimistic: bool = False) -> bool:
        """True when the pool can cover this request's WORST case (prompt +
        max_new_tokens - 1 positions, or the CP-padded prompt if longer) on
        top of every live request's committed future growth.  Without
        preemption (DESIGN.md §7/8) this admission gate is what keeps an
        oversubscribed pool from running out of pages mid-decode: a request
        the gate rejects stays queued until evictions free pages.

        ``optimistic=True`` (DESIGN.md §10) gates only on the request's
        CURRENT need — the pages its prompt/prefix claims at ``begin_prefill``
        — ignoring everyone's future decode growth.  Mid-decode page
        exhaustion then becomes possible and is the scheduler's problem
        (preemption-by-recompute); the payoff is that EOS-heavy traffic no
        longer strands pool capacity on decode budgets that never
        materialize.

        With a prefix index attached, pages pinned only by unreferenced
        cached prefixes count as free — they are reclaimable on demand
        (``_claim_guard``), so a pool full of cold cache never deadlocks
        admission (DESIGN.md §13)."""
        self._require_paged()
        free = self.pool.free_pages + (self.prefix_index.reclaimable_pages()
                                       if self.prefix_index else 0)
        if optimistic:
            return free >= self._pages_for(self._alloc_len(prompt_len))
        # committed growth of THIS backend's own slots (index owners never
        # grow — negative ids — and a pool-sharing sibling backend tracks
        # its own commitments: its live pages are already out of ``free``,
        # and its future growth is recovered by preemption, not reserved
        # across the pool boundary)
        committed = sum(
            max(0, self._worst.get(o - self._owner_base, 0)
                - len(self.pool.block_table(o)))
            for o in self.pool.owners()
            if 0 <= o - self._owner_base < self.num_slots)
        need = self._pages_for(max(self._alloc_len(prompt_len),
                                   prompt_len + max_new_tokens - 1))
        return free - committed >= need

    def _claim_guard(self, fn):
        """Run a pool claim; under pressure, evict LRU cached prefixes
        until it succeeds (or the index is drained — then the MemoryError
        propagates to the scheduler's preemption ladder)."""
        while True:
            try:
                return fn()
            except MemoryError:
                if self.prefix_index is None \
                        or not self.prefix_index.evict_one():
                    raise

    def _apply_cow(self) -> None:
        """Replay the pool's pending copy-on-write events as device page
        copies — MUST run after every ``pool.extend`` before the next pass
        touches the privatized page (DESIGN.md §13)."""
        for ev in self.pool.take_cow_events():
            self._copy_page(ev.src, ev.dst)

    def _copy_page(self, src: int, dst: int) -> None:
        """Copy physical page src -> dst on this backend's device pools."""
        raise NotImplementedError

    # -- KV-page handoff (disaggregated pools, DESIGN.md §14) --------------
    def export_page(self, page: int) -> dict:
        """Read physical ``page``'s KV rows off this backend's device page
        pools as host arrays {k, v: [L, ps, kv, D]} — the unit the
        disaggregated prefill→decode handoff ships
        (``commodel.kv_handoff_ops``)."""
        self._require_paged()
        return {key: np.asarray(self.cache[key][:, page])
                for key in ("k", "v")}

    def import_page(self, page: int, data: dict) -> int:
        """Land exported KV rows {k, v: [L, ps, kv, D]} at physical
        ``page`` of this backend's device page pools; returns the device
        bytes written — the measured half of the handoff invariant
        (asserted equal to ``kv_handoff_ops``'s closed form per request)."""
        self._require_paged()
        rows = {key: jnp.asarray(np.asarray(data[key]),
                                 jnp.dtype(self.cfg.dtype))
                for key in ("k", "v")}
        self.cache = _write_page(self.cache, rows, jnp.int32(page))
        return sum(int(a.nbytes) for a in rows.values())

    def begin_prefill(self, slot: int, prompt_len: int,
                      max_new_tokens: int = 1) -> None:
        """Allocate the slot's pages for a new request's prompt (CP-padded
        when c > 1) and commit its worst-case decode growth
        (see ``can_admit``)."""
        self._require_paged()
        self.pool.free(self._owner(slot))   # defensive: slot may be reused
        self._decodable.discard(slot)
        self._claim_guard(
            lambda: self.pool.allocate(self._owner(slot),
                                       self._alloc_len(prompt_len)))
        self._worst[slot] = self._pages_for(
            max(self._alloc_len(prompt_len),
                prompt_len + max_new_tokens - 1))
        self._set_table(slot)

    def begin_prefill_cached(self, slot: int, prompt,
                             max_new_tokens: int = 1) -> int:
        """Cache-aware admission (DESIGN.md §13): look the prompt up in the
        prefix index, adopt the longest cached prefix's pages into the
        slot, and extend to the full prompt — claiming fresh pages for the
        suffix and copy-on-writing a partially shared tail (a fully cached
        prompt is capped one position short, so its last page IS shared
        partially and privatizes here, before the suffix chunk writes it).
        Returns the hit length in tokens (0 = cold: plain begin_prefill).
        The caller prefills only positions hit..prompt_len-1."""
        self._require_paged()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if self.prefix_index is None:
            self.begin_prefill(slot, len(prompt), max_new_tokens)
            return 0
        self.pool.free(self._owner(slot))   # defensive: slot may be reused
        self._decodable.discard(slot)
        hit = self.prefix_index.lookup(prompt)
        if not hit.hit:
            self.begin_prefill(slot, len(prompt), max_new_tokens)
            return 0
        self.pool.adopt(self._owner(slot), hit.pages, hit.length)
        try:
            self._claim_guard(
                lambda: self.pool.extend(self._owner(slot),
                                         self._alloc_len(len(prompt))))
        except MemoryError:
            # nothing half-claimed: extend is atomic
            self.pool.free(self._owner(slot))
            raise
        self._apply_cow()
        self._worst[slot] = self._pages_for(
            max(self._alloc_len(len(prompt)),
                len(prompt) + max_new_tokens - 1))
        self._set_table(slot)
        return hit.length

    def cache_prefix(self, slot: int, tokens) -> int:
        """Insert a fully prefilled slot's prompt blocks into the prefix
        index (no-op without one); returns new entries created.  Only full
        blocks are indexed, and they are exactly the slot's first pages —
        committed by the prefill that just finished, never rewritten (decode
        writes land at positions past the prompt)."""
        if not self.paged or self.prefix_index is None:
            return 0
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        return self.prefix_index.insert(
            tokens, self.pool.block_table(self._owner(slot)))

    def prefill_chunk(self, slot: int, tokens, start: int) -> int:
        """One chunked-prefill pass for ``tokens`` at positions
        start..start+S-1; returns the greedy token of the chunk's last
        position (the request's first token when this is the final chunk)."""
        self._require_paged()
        if self.c > 1:
            raise RuntimeError(
                "chunked prefill and context parallelism are alternative "
                "long-prompt strategies; a c>1 backend prefills "
                "monolithically via prefill_whole (DESIGN.md §9)")
        chunk = np.asarray(tokens, np.int32)[None, :]
        pos = np.asarray([start], np.int32)
        bt = self.block_tables[slot:slot + 1]
        logits = self._paged_call(chunk, pos, bt, phase="prefill")
        return int(np.argmax(logits[0]))

    def prefill_whole(self, slot: int, tokens, start: int = 0) -> int:
        """Monolithic prefill of one request into its allocated pages:
        one maximal chunk at c == 1, or — under context parallelism — one
        sequence-sharded CP pass whose assembled full KV is scattered into
        the slot's pages (``_seed_pages``).  Returns the first greedy
        token; ``begin_prefill`` (or ``begin_prefill_cached``, whose hit
        length becomes ``start``) must have run.  With ``start > 0`` only
        positions start.. are computed — ONE suffix chunk over the cached
        prefix's pages (DESIGN.md §13)."""
        self._require_paged()
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if not 0 <= start < len(tokens):
            raise ValueError(
                f"start {start} outside [0, {len(tokens)}) — a cache hit "
                "always leaves at least the final position to prefill")
        if self.c == 1:
            return self.prefill_chunk(slot, tokens[start:], start)
        if start:
            raise RuntimeError(
                "suffix prefill needs the chunked (offset) path; "
                "c > 1 backends prefill monolithically (DESIGN.md §9)")
        logits, small = self._prefill_one(tokens)
        self._seed_slot_pages(small, slot)
        return int(np.argmax(np.asarray(logits)[0]))

    def _seed_slot_pages(self, small, slot: int) -> None:
        """Write a batch-1 contiguous cache into the slot's pages."""
        raise NotImplementedError

    def finish_prefill(self, slot: int) -> None:
        """Mark a fully-prefilled slot decode-eligible."""
        self._require_paged()
        self._decodable.add(slot)

    def _paged_decode(self, tokens, pos) -> np.ndarray:
        """Paged decode step: extend decode-eligible slots' pages to cover
        the incoming position, then ONE jitted paged pass (S=1) over the
        full slot batch.  Ineligible slots' block-table rows are pointed at
        the scratch page so their garbage lanes stay harmless."""
        pos = np.asarray(pos)
        for slot in sorted(self._decodable):
            self._claim_guard(
                lambda s=slot: self.pool.extend(self._owner(s),
                                                int(pos[s]) + 1))
            self._set_table(slot)
        self._apply_cow()
        bt = self.block_tables.copy()
        for slot in range(self.num_slots):
            if slot not in self._decodable:
                bt[slot] = 0                # scratch page (kvpool.py)
        logits = self._paged_call(
            np.asarray(tokens, np.int32)[:, None],
            np.asarray(pos, np.int32), bt, phase="decode")
        return np.asarray(np.argmax(logits, -1), np.int32)

    def _paged_call(self, tokens, pos, bt, phase: str) -> np.ndarray:
        """(logits [B, v]) of one paged pass; updates the cache in place."""
        raise NotImplementedError

    def chunk_comm_ops(self, chunk_len: int, batch: int = 1) -> List[CommOp]:
        """Predicted collectives for ONE prefill chunk of ``chunk_len``
        tokens — the per-chunk rows of ``commodel.chunked_prefill_ops`` at
        the backend's activation width.  Counts are chunk-length- and
        batch-invariant; only message bytes scale."""
        return chunked_prefill_ops(
            self.cfg, chunk_len, chunk_len, self.t, self.p, batch=batch,
            b=jnp.dtype(self.cfg.dtype).itemsize, gather_mode="allgather")

    def decode_comm_ops(self, batch: int = 1) -> List[CommOp]:
        """Predicted collectives for ONE decode step over ``batch`` rows:
        the decode-phase rows of ``comm_ops_for`` at s_d=2 (one step past
        the prefill token), gather_mode="allgather" (the XLA engines), at
        the backend's actual activation width — so predicted bytes sit on
        the same scale as the measured TransferRecords.  Independent of c:
        context parallelism is prefill-only (DESIGN.md §9).  A
        quant-collectives backend gets the decomposed rows (f32 amax
        allreduce + 1-byte reducescatter/allgather per layer AR,
        DESIGN.md §12) — what its compiled decode module actually shows."""
        ops = comm_ops_for(self.cfg, 1, 2, self.t, self.p, c=self.c,
                           batch=batch,
                           b=jnp.dtype(self.cfg.dtype).itemsize,
                           gather_mode="allgather",
                           quant=self.quant, quant_chunk=self.quant_chunk)
        return [o for o in ops if o.phase == "decode"]

    def prefill_comm_ops(self, prompt_len: int,
                         batch: int = 1) -> List[CommOp]:
        """Predicted collectives for ONE monolithic prefill pass of a
        ``prompt_len``-token prompt at the backend's (t, c, p) layout —
        under CP this carries the per-layer ring rows of
        ``commodel.cp_comm_ops`` plus the TP/PP rows at the padded
        ceil(prompt_len/c) shard each rank processes."""
        ops = comm_ops_for(self.cfg, prompt_len, 1, self.t, self.p,
                           c=self.c, batch=batch,
                           b=jnp.dtype(self.cfg.dtype).itemsize,
                           gather_mode="allgather")
        return [o for o in ops if o.phase == "prefill"]

    def drain_transfers(self) -> dict:
        """Inter-stage bytes moved since the last drain (PP only)."""
        return {"count": 0, "bytes": 0}

    def make_queue(self):
        """Instruction queue the scheduler drains (DESIGN.md §11): the
        fused decode step wrapped as a degenerate 1-instruction queue."""
        return FusedQueue(self)

    def free_slots(self, slots: Sequence[int]) -> None:
        for s in slots:
            if not 0 <= s < self.num_slots:
                raise IndexError(f"slot {s} out of range")
        if self.paged:
            for s in slots:
                # no-op for never-admitted slots
                self.pool.free(self._owner(s))
                self.block_tables[s] = 0
                self._decodable.discard(s)
                self._worst.pop(s, None)

    # -- shared admission loop (template method) ---------------------------
    def prefill_into_slots(self, prompts, slots) -> np.ndarray:
        """Admit requests: one batch-1 prefill per prompt at its true
        length (row-wise identical to serving it solo; CP-padded and
        sequence-sharded when c > 1), scattered into the slot's batch row.
        Returns the first greedy token per request.

        In paged mode the prompt prefills straight into the slot's pages
        as one maximal chunk (one CP pass when c > 1) — the non-chunked
        protocol entry point over the chunked machinery (the scheduler's
        chunked path drives ``begin_prefill``/``prefill_chunk``/
        ``finish_prefill`` itself)."""
        first = np.zeros(len(slots), np.int32)
        for i, (prompt, slot) in enumerate(zip(prompts, slots)):
            prompt = np.asarray(prompt, np.int32).reshape(-1)
            if self.paged:
                self.begin_prefill(slot, len(prompt))
                first[i] = self.prefill_whole(slot, prompt)
                self.finish_prefill(slot)
            else:
                logits, small = self._prefill_one(prompt)
                self._scatter(small, slot)
                first[i] = self._first_token(logits)[0]
        return first

    def _pad_prompt(self, prompt):
        """(CP-padded prompt, true-last-position index): pads with token 0
        to a multiple of c so the sequence axis shards equally.  The pad
        positions' KV rows are garbage the causal mask hides until decode
        overwrites them position by position (DESIGN.md §9)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        padded = np.pad(prompt, (0, (-len(prompt)) % self.c))
        # sliding-window configs serve prompts beyond max_len (the ring
        # cache keeps the last W positions) — same waiver as the
        # scheduler's admission check
        if not self.paged and len(padded) > self.max_len \
                and not self.cfg.sliding_window:
            raise ValueError(
                f"CP-padded prompt ({len(padded)}) exceeds max_len "
                f"{self.max_len}")
        return padded, len(prompt) - 1

    def _prefill_one(self, prompt):
        """(logits [1, v], seeded batch-1 cache) for one raw 1-D prompt."""
        raise NotImplementedError

    def _scatter(self, small, slot: int) -> None:
        """Write a batch-1 cache into the slot row (default: single slot
        cache pytree on ``self.cache`` via the donating ``self._write``)."""
        self.cache = self._write(self.cache, small, jnp.int32(slot))

    def _first_token(self, logits) -> np.ndarray:
        return np.asarray(jnp.argmax(logits, -1), np.int32)

    def _as_prompt(self, prompt) -> jnp.ndarray:
        return jnp.asarray(np.asarray(prompt, np.int32))[None, :]


class ModelBackend(_BackendBase):
    """GSPMD ``Model`` path (the runtime/engine.py lineage) behind the
    DecodeBackend protocol.  Single jit per decode step, donated slot cache,
    per-sequence positions through ``Model.decode_step``."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 max_len: int = 256, paged: bool = False,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefix_cache: bool = False, pool: Optional[KVPool] = None,
                 owner_base: int = 0):
        super().__init__(cfg, num_slots, max_len, t=1, p=1, paged=paged,
                         page_size=page_size, num_pages=num_pages,
                         prefix_cache=prefix_cache, pool=pool,
                         owner_base=owner_base)
        self.model = get_model(cfg)
        self.params = params
        if self.paged:
            self.cache = self.model.init_paged_cache(self.pool.num_pages,
                                                     self.page_size)
            self._paged_fn = jax.jit(self.model.paged_step,
                                     donate_argnums=(1,))
        else:
            self.cache = self.model.init_cache(num_slots, max_len)
            self._prefill = jax.jit(
                functools.partial(self.model.prefill, max_len=max_len))
            self._step = jax.jit(self.model.decode_step, donate_argnums=(1,))
            self._write = jax.jit(_write_slot, donate_argnums=(0,))

    def _prefill_one(self, prompt):
        logits, small, _ = self._prefill(self.params, self._as_prompt(prompt))
        return logits, small

    def _paged_call(self, tokens, pos, bt, phase: str) -> np.ndarray:
        logits, self.cache = self._paged_fn(
            self.params, self.cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32), jnp.asarray(bt, jnp.int32))
        return np.asarray(logits)

    def _copy_page(self, src: int, dst: int) -> None:
        self.cache = _copy_page_rows(self.cache, jnp.int32(src),
                                     jnp.int32(dst))

    def decode_step(self, tokens, pos) -> np.ndarray:
        if self.paged:
            return self._paged_decode(tokens, pos)
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32))
        return self._first_token(logits)


class TPBackend(_BackendBase):
    """Explicit tensor-parallel engine (core/parallel_exec.py) behind the
    protocol: shard_map with hand-placed collectives — (2L+1) allreduce +
    1 logits all-gather per decode step, regardless of slot count.

    ``c > 1`` adds context parallelism on the same mesh (axes tp × cp;
    t=1 with c>1 is the pure-CP layout): prefill runs ``cp_prefill`` on
    the CP-padded prompt — per-layer ring KV exchange, one cp allreduce
    for the last hidden state — and the ring-assembled full cache lands in
    the slot row (contiguous) or the slot's pages (paged) exactly like a
    c=1 prefill's.  The decode step is the same jitted fn at any c, run
    replicated over the cp axis (DESIGN.md §9)."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 max_len: int = 256, t: int = 2, unroll: bool = False,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None, c: int = 1,
                 quant_collectives: Optional[str] = None,
                 quant_chunk: int = DEFAULT_QUANT_CHUNK,
                 prefix_cache: bool = False, pool: Optional[KVPool] = None,
                 owner_base: int = 0):
        super().__init__(cfg, num_slots, max_len, t=t, p=1, c=c,
                         paged=paged, page_size=page_size,
                         num_pages=num_pages,
                         quant_collectives=quant_collectives,
                         quant_chunk=quant_chunk,
                         prefix_cache=prefix_cache, pool=pool,
                         owner_base=owner_base)
        if cfg.family != "dense":
            raise ValueError("explicit TP engine covers the dense family")
        self.params = params
        self._unroll = unroll
        self.mesh = px.make_tp_cp_mesh(t, c)
        shard = lambda sp: NamedSharding(self.mesh, sp)
        kv_spec = shard(P(None, None, None, "tp" if t > 1 else None, None))
        if self.paged:
            self._paged_fn = px.tp_paged_step(cfg, self.mesh, unroll=unroll)
            self.cache = {
                key: jax.device_put(
                    jnp.zeros((cfg.num_layers, self.pool.num_pages,
                               self.page_size, cfg.num_kv_heads,
                               cfg.head_dim), jnp.dtype(cfg.dtype)), kv_spec)
                for key in ("k", "v")}
            if c > 1:
                self._cp_fns = {}       # padded prompt len -> cp_prefill fn
                self._seed = jax.jit(_seed_pages, donate_argnums=(0,))
        else:
            self.cache_w = get_model(cfg).cache_width(max_len)
            if c > 1:
                self._prefill = px.cp_prefill(cfg, self.mesh,
                                              cache_w=self.cache_w,
                                              unroll=unroll)
            else:
                self._prefill = px.tp_prefill(cfg, self.mesh,
                                              cache_w=self.cache_w,
                                              unroll=unroll)
            self._step = px.tp_decode_step(
                cfg, self.mesh, unroll=unroll, vector_pos=True,
                quant_collectives=self.quant, quant_chunk=self.quant_chunk)
            self.cache = {
                key: jax.device_put(
                    jnp.zeros((cfg.num_layers, num_slots, self.cache_w,
                               cfg.num_kv_heads, cfg.head_dim),
                              jnp.dtype(cfg.dtype)), kv_spec)
                for key in ("k", "v")}
            self._write = jax.jit(_write_slot, donate_argnums=(0,))

    def _cp_fn(self, cache_w: int):
        """CP prefill fn seeding a width-``cache_w`` staging cache (paged
        mode sizes it to the padded prompt so the page scatter writes
        exactly the allocated rows)."""
        if cache_w not in self._cp_fns:
            self._cp_fns[cache_w] = px.cp_prefill(
                self.cfg, self.mesh, cache_w=cache_w, unroll=self._unroll)
        return self._cp_fns[cache_w]

    def _prefill_one(self, prompt):
        if self.c > 1:
            padded, last = self._pad_prompt(prompt)
            fn = self._cp_fn(len(padded)) if self.paged else self._prefill
            return fn(self.params, self._as_prompt(padded), jnp.int32(last))
        return self._prefill(self.params, self._as_prompt(prompt))

    def _seed_slot_pages(self, small, slot: int) -> None:
        n = len(self.pool.block_table(self._owner(slot)))
        bt = jnp.asarray(self.block_tables[slot:slot + 1, :n])
        self.cache = self._seed(self.cache, small, bt)

    def _paged_call(self, tokens, pos, bt, phase: str) -> np.ndarray:
        logits, self.cache = self._paged_fn(
            self.params, self.cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32), jnp.asarray(bt, jnp.int32))
        return np.asarray(logits)

    def _copy_page(self, src: int, dst: int) -> None:
        self.cache = _copy_page_rows(self.cache, jnp.int32(src),
                                     jnp.int32(dst))

    def decode_step(self, tokens, pos) -> np.ndarray:
        if self.paged:
            return self._paged_decode(tokens, pos)
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32))
        return self._first_token(logits)

    def decode_step_hlo(self) -> str:
        """Compiled HLO of the slot decode step (collective-count checks)."""
        if self.paged:
            return self.paged_step_hlo(q_len=1, batch=self.num_slots)
        tok = jax.ShapeDtypeStruct((self.num_slots,), jnp.int32)
        pos = jax.ShapeDtypeStruct((self.num_slots,), jnp.int32)
        return self._step.lower(self.params, self.cache, tok,
                                pos).compile().as_text()

    def prefill_hlo(self, prompt_len: int) -> str:
        """Compiled HLO of one batch-1 prefill at a (CP-padded) prompt
        length — under c>1 the module shows the per-layer ring permutes
        and the cp allreduce next to the TP schedule, asserted against
        ``prefill_comm_ops`` / ``commodel.cp_comm_ops``."""
        if self.c > 1 and prompt_len % self.c:
            raise ValueError(f"prompt_len must be a multiple of c={self.c}")
        tok = jax.ShapeDtypeStruct((1, prompt_len), jnp.int32)
        if self.c > 1:
            fn = (self._cp_fn(prompt_len) if self.paged else self._prefill)
            last = jax.ShapeDtypeStruct((), jnp.int32)
            return fn.lower(self.params, tok, last).compile().as_text()
        return self._prefill.lower(self.params, tok).compile().as_text()

    def paged_step_hlo(self, q_len: int, batch: int = 1) -> str:
        """Compiled HLO of one paged pass at chunk length ``q_len`` — the
        per-chunk (and, at q_len=1, per-decode-step) collective-count
        check against ``commodel.chunked_prefill_ops``."""
        self._require_paged()
        tok = jax.ShapeDtypeStruct((batch, q_len), jnp.int32)
        pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
        bt = jax.ShapeDtypeStruct((batch, self.pages_per_slot), jnp.int32)
        return self._paged_fn.lower(self.params, self.cache, tok, pos,
                                    bt).compile().as_text()


class PPBackend(_BackendBase):
    """PipelineEngine (pure PP when t=1, hybrid TP×CP×PP otherwise) behind
    the protocol: per-stage slot caches, one decode step = one token through
    all p stages with (p-1)·2 logged boundary transfers.

    ``c > 1`` CP-shards each stage's prefill over the stage's cp mesh axis
    (boundary hops shrink to [S/c, h/t] per worker); the ring-assembled
    per-stage caches land in the stage slot rows or page pools, and decode
    runs the unchanged per-stage steps replicated over cp (DESIGN.md §9).

    ``inflight > 1`` (DESIGN.md §11) splits the slots into ``inflight``
    *microbatch groups* of ``num_slots // inflight`` rows each.  The slot
    contiguous caches become per-group per-stage caches (``gcaches[g][s]``)
    so groups can occupy different stages concurrently; paged pools stay
    shared per stage (rounds are isolated by their disjoint block tables).
    The group decode round is driven instruction-by-instruction via
    ``start_round`` / ``run_stage`` / ``send_boundary`` by the
    ``DynamicPPQueue`` that ``make_queue`` returns."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 max_len: int = 256, t: int = 1, p: int = 2,
                 unroll: bool = False, devices=None, paged: bool = False,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 c: int = 1, inflight: int = 1,
                 quant_collectives: Optional[str] = None,
                 quant_chunk: int = DEFAULT_QUANT_CHUNK,
                 prefix_cache: bool = False, pool: Optional[KVPool] = None,
                 owner_base: int = 0):
        super().__init__(cfg, num_slots, max_len, t=t, p=p, c=c,
                         paged=paged, page_size=page_size,
                         num_pages=num_pages,
                         quant_collectives=quant_collectives,
                         quant_chunk=quant_chunk,
                         prefix_cache=prefix_cache, pool=pool,
                         owner_base=owner_base)
        if cfg.family != "dense":
            raise ValueError("PipelineEngine covers the dense family")
        if inflight < 1 or num_slots % inflight:
            raise ValueError(
                f"inflight must divide num_slots: got inflight={inflight}, "
                f"num_slots={num_slots}")
        self.inflight = int(inflight)
        self.group_size = num_slots // self.inflight
        self.engine = px.PipelineEngine(cfg, t=t, p=p, c=c, unroll=unroll,
                                        devices=devices,
                                        quant_collectives=self.quant,
                                        quant_chunk=self.quant_chunk)
        self.staged = self.engine.prepare(params)
        kv_spec = lambda s: NamedSharding(
            self.engine.meshes[s],
            P(None, None, None, "tp" if t > 1 else None, None))
        self.caches = []       # paged: per-stage page pools
        self.gcaches = None    # contiguous: per-group per-stage slot caches
        if self.paged:
            for s in range(p):
                lo, hi = px.stage_layer_range(cfg, p, s)
                # per-stage page pools share ONE block-table space: logical
                # page j of a slot lives at physical page table[j] in every
                # stage's [L_s, P, ps, kv, D] pool
                leaves = {
                    key: jnp.zeros((hi - lo, self.pool.num_pages,
                                    self.page_size, cfg.num_kv_heads,
                                    cfg.head_dim), jnp.dtype(cfg.dtype))
                    for key in ("k", "v")}
                if t > 1 or c > 1:
                    leaves = {key: jax.device_put(a, kv_spec(s))
                              for key, a in leaves.items()}
                self.caches.append(leaves)
        else:
            self.cache_w = get_model(cfg).cache_width(max_len)

            def stage_cache(s):
                lo, hi = px.stage_layer_range(cfg, p, s)
                leaves = {
                    key: jnp.zeros((hi - lo, self.group_size, self.cache_w,
                                    cfg.num_kv_heads, cfg.head_dim),
                                   jnp.dtype(cfg.dtype))
                    for key in ("k", "v")}
                if t > 1 or c > 1:
                    leaves = {key: jax.device_put(a, kv_spec(s))
                              for key, a in leaves.items()}
                return leaves

            self.gcaches = [[stage_cache(s) for s in range(p)]
                            for _ in range(self.inflight)]
        self._writes = [jax.jit(_write_slot, donate_argnums=(0,))
                        for _ in range(p)]
        if self.paged and c > 1:
            self._seed = jax.jit(_seed_pages, donate_argnums=(0,))
        self._drained = 0              # transfer-log cursor

    def _prefill_one(self, prompt):
        if self.c > 1:
            padded, last = self._pad_prompt(prompt)
            w = len(padded) if self.paged else self.cache_w
            return self.engine.prefill_with_cache(
                self.staged, self._as_prompt(padded), cache_w=w, last=last)
        return self.engine.prefill_with_cache(
            self.staged, self._as_prompt(prompt), cache_w=self.cache_w)

    def _scatter(self, small, slot: int) -> None:
        g, row = divmod(slot, self.group_size)
        self.gcaches[g] = [
            self._writes[s](self.gcaches[g][s], small[s], jnp.int32(row))
            for s in range(self.p)]

    def _seed_slot_pages(self, small, slot: int) -> None:
        n = len(self.pool.block_table(self._owner(slot)))
        bt = jnp.asarray(self.block_tables[slot:slot + 1, :n])
        self.caches = [self._seed(self.caches[s], small[s], bt)
                       for s in range(self.p)]

    def _paged_call(self, tokens, pos, bt, phase: str) -> np.ndarray:
        logits, self.caches = self.engine.paged_pass(
            self.staged, self.caches, tokens, pos, bt, phase=phase)
        return np.asarray(logits)

    def _copy_page(self, src: int, dst: int) -> None:
        s, d = jnp.int32(src), jnp.int32(dst)
        self.caches = [_copy_page_rows(c, s, d) for c in self.caches]

    def export_page(self, page: int) -> dict:
        """Full-depth page rows, stages concatenated over the layer axis —
        the same [L, ps, kv, D] unit the single-pool backends export."""
        self._require_paged()
        return {key: np.concatenate(
                    [np.asarray(c[key][:, page]) for c in self.caches])
                for key in ("k", "v")}

    def import_page(self, page: int, data: dict) -> int:
        self._require_paged()
        total = 0
        for s in range(self.p):
            lo, hi = px.stage_layer_range(self.cfg, self.p, s)
            rows = {key: jnp.asarray(np.asarray(data[key][lo:hi]),
                                     jnp.dtype(self.cfg.dtype))
                    for key in ("k", "v")}
            self.caches[s] = _write_page(self.caches[s], rows,
                                         jnp.int32(page))
            total += sum(int(a.nbytes) for a in rows.values())
        return total

    def decode_step(self, tokens, pos) -> np.ndarray:
        if self.paged:
            return self._paged_decode(tokens, pos)
        tokens = np.asarray(tokens, np.int32)
        pos = np.asarray(np.asarray(pos), np.int32)
        out = np.zeros(self.num_slots, np.int32)
        G = self.group_size
        for g in range(self.inflight):
            lo = g * G
            logits, self.gcaches[g] = self.engine.decode_once(
                self.staged, self.gcaches[g],
                jnp.asarray(tokens[lo:lo + G]), jnp.asarray(pos[lo:lo + G]))
            out[lo:lo + G] = self._first_token(logits)
        return out

    # -- instruction-queue surface (runtime/schedule.py, DESIGN.md §11) ----
    def make_queue(self):
        """Dynamic per-stage instruction queue at depth ``inflight``."""
        return DynamicPPQueue(self)

    def start_round(self, g: int, tokens, pos):
        """(stage-0 feed, per-group positions, block tables | None) for one
        decode round of group ``g``.  Paged mode extends the group's
        decode-eligible slots' pages HERE — before any instruction issues —
        so pool exhaustion (MemoryError) surfaces with the round not in
        flight and the preemption ladder can free pages safely."""
        G = self.group_size
        lo = g * G
        toks = np.asarray(tokens, np.int32)[lo:lo + G]
        pos_np = np.asarray(np.asarray(pos), np.int32)[lo:lo + G]
        if self.paged:
            full_pos = np.asarray(pos)
            for slot in sorted(self._decodable):
                if lo <= slot < lo + G:
                    self._claim_guard(
                        lambda s=slot: self.pool.extend(
                            self._owner(s), int(full_pos[s]) + 1))
                    self._set_table(slot)
            self._apply_cow()
            bt = self.block_tables[lo:lo + G].copy()
            for i, slot in enumerate(range(lo, lo + G)):
                if slot not in self._decodable:
                    bt[i] = 0            # scratch page (kvpool.py)
            x = self.engine.feed_tokens(toks[:, None], paged=True)
            return x, jnp.asarray(pos_np), jnp.asarray(bt, jnp.int32)
        return self.engine.feed_tokens(toks), jnp.asarray(pos_np), None

    def run_stage(self, g: int, s: int, x, pos, bt=None):
        """One queue-issued StageForward: stage ``s``'s jitted fn against
        group ``g``'s cache (contiguous) or the stage's shared page pool
        (paged; rounds stay isolated through their disjoint block tables).
        The donated cache is rebound here, so Python issue order serializes
        the data dependencies between overlapping rounds."""
        if self.paged:
            fn = self.engine.paged_stage_fns()[s]
            out, self.caches[s] = fn(self.staged[s], self.caches[s], x,
                                     pos, bt)
        else:
            fn = self.engine.decode_stage_fns(vector_pos=True)[s]
            out, self.gcaches[g][s] = fn(self.staged[s], self.gcaches[g][s],
                                         x, pos)
        return out

    def send_boundary(self, out, s: int):
        """Queue-issued BoundarySend/Recv pair: ship stage ``s``'s boundary
        to stage ``s+1``, logging its decode TransferRecords."""
        return self.engine.send_boundary(out, s, phase="decode")

    def drain_transfers(self) -> dict:
        recs = self.engine.transfers[self._drained:]
        self._drained = len(self.engine.transfers)
        return {"count": sum(r.count for r in recs),
                "bytes": sum(r.bytes for r in recs)}

    def stage_paged_hlo(self, stage: int, q_len: int = 1,
                        batch: int = 1) -> str:
        """Compiled HLO of one stage's paged pass at chunk length ``q_len``
        — asserted against ``commodel.hybrid_stage_collectives`` (counts are
        chunk-length-invariant, DESIGN.md §8)."""
        self._require_paged()
        tok = jnp.zeros((batch, q_len), jnp.int32)
        pos = jnp.zeros((batch,), jnp.int32)
        bt = jnp.zeros((batch, self.pages_per_slot), jnp.int32)
        return self.engine.stage_paged_hlo(self.staged, self.caches, tok,
                                           pos, bt, stage)

    def stage_decode_hlo(self, stage: int) -> str:
        """Compiled HLO of one stage's slot decode step (vector pos) at the
        microbatch-group batch — collective counts are batch-invariant, so
        the check is depth-independent."""
        fns = self.engine._decode_fns(vector_pos=True)
        caches = self.gcaches[0]
        pos = jnp.zeros((self.group_size,), jnp.int32)
        tok = jnp.zeros((self.group_size,), jnp.int32)
        x = jax.device_put(tok, NamedSharding(self.engine.meshes[0], P(None)))
        for i in range(stage):
            fn, _ = fns[i]
            out, _ = fn(self.staged[i],
                        jax.tree.map(jnp.copy, caches[i]), x, pos)
            x = self.engine._move_boundary(out, i, "hlo", log=False)
        fn, _ = fns[stage]
        return fn.lower(self.staged[stage], caches[stage], x,
                        pos).compile().as_text()


def make_backend(kind: str, cfg: ModelConfig, params, num_slots: int,
                 max_len: int = 256, t: int = 1, p: int = 1,
                 unroll: bool = False, paged: bool = False,
                 page_size: int = 16,
                 num_pages: Optional[int] = None,
                 c: int = 1, inflight: int = 1,
                 quant_collectives: Optional[str] = None,
                 quant_chunk: int = DEFAULT_QUANT_CHUNK,
                 prefix_cache: bool = False,
                 pool: Optional[KVPool] = None,
                 owner_base: int = 0) -> DecodeBackend:
    """Backend factory keyed by engine kind: "gspmd" | "tp" | "pp".

    Degenerate layouts are rejected, not coerced — a silently bumped t/c/p
    would attribute measured SLOs to a layout the caller never asked for.
    ``paged=True`` swaps the contiguous slot cache for the KVPool-managed
    page pools and enables chunked prefill (DESIGN.md §8).  ``c > 1`` adds
    context-parallel prefill on the explicit engines (DESIGN.md §9): the
    pure-CP layout (t=1, c>1, p=1) goes through the "tp" kind — the
    single-stage explicit engine on a cp-only mesh.  ``inflight > 1``
    splits the slots into in-flight microbatch groups on the pp backend's
    dynamic instruction queue (DESIGN.md §11); the fused engines have no
    pipeline bubble to fill and reject it.  ``quant_collectives``
    ("int8" | "fp8", DESIGN.md §12) lowers the explicit engines' per-layer
    decode allreduces to the quantized two-step; GSPMD places its own
    collectives and the paged engines run full-width — both reject it.
    ``prefix_cache=True`` (DESIGN.md §13) attaches a cross-request
    ``PrefixIndex`` to the page pool: paged-only, c=1-only (the suffix
    prefill needs the chunk-offset path).  ``pool``/``owner_base``
    (DESIGN.md §14) make this backend share another backend's ``KVPool``
    under a disjoint slot-owner range — how the disaggregated prefill and
    decode pools address one page space while their device page pools stay
    separate (content crosses via ``export_page``/``import_page``).
    """
    kw = dict(paged=paged, page_size=page_size, num_pages=num_pages,
              prefix_cache=prefix_cache, pool=pool, owner_base=owner_base)
    if kind != "pp" and inflight != 1:
        raise ValueError(
            "in-flight microbatching fills the PP decode bubble; the "
            f"{kind!r} backend runs a fused step — inflight must be 1")
    qkw = dict(quant_collectives=quant_collectives, quant_chunk=quant_chunk)
    if kind == "gspmd":
        if c > 1:
            raise ValueError(
                "context parallelism needs the explicit engines — use the "
                "tp (single-stage) or pp backend with c > 1")
        if quant_collectives is not None:
            raise ValueError(
                "quantized collectives need the explicit engines' "
                "hand-placed psums — GSPMD places its own collectives; "
                "use the tp or pp backend")
        return ModelBackend(cfg, params, num_slots, max_len, **kw)
    if kind == "tp":
        if t < 2 and c < 2:
            raise ValueError(
                f"tp backend needs t >= 2 or c >= 2, got t={t} c={c}")
        return TPBackend(cfg, params, num_slots, max_len, t=t, c=c,
                         unroll=unroll, **kw, **qkw)
    if kind == "pp":
        if p < 2:
            raise ValueError(f"pp backend needs p >= 2, got p={p}")
        return PPBackend(cfg, params, num_slots, max_len, t=t, c=c, p=p,
                         unroll=unroll, inflight=inflight, **kw, **qkw)
    raise ValueError(f"unknown backend kind: {kind!r}")
