"""Instruction queues: dynamic pipeline schedules that fill the PP decode
bubble (DESIGN.md §11).

The serving scheduler no longer calls ``backend.decode_step`` directly.
Every backend exposes ``make_queue()`` returning an *instruction queue* —
a small state machine the scheduler drains and refills:

- ``DynamicPPQueue`` (PPBackend): keeps up to ``backend.inflight``
  microbatch *groups* in flight.  Each group's decode round is a linear
  chain ``StageForward(0) → BoundarySend/Recv(0→1) → … → StageForward(p-1)
  → SampleToken``; the queue issues at most one compute instruction per
  stage per *tick*, picking the deepest stage first and, within a stage,
  the oldest round.  With ``depth`` groups resident the per-stage busy
  fraction approaches ``depth/p`` of a lockstep wave's reciprocal — the
  bubble-occupancy term ``commodel.pp_schedule_stats`` predicts in closed
  form and the pp-occupancy bench series measures.
- ``FusedQueue`` (ModelBackend/TPBackend, and any duck-typed backend
  without ``make_queue``): the fused ``decode_step`` wrapped as a
  degenerate 1-instruction queue so the scheduler protocol stays unified.

Deadlock freedom: a round's only ready instruction is the head of its
chain, heads of distinct rounds never alias a resource (each group owns
its caches/pages; boundary buffers are per-round), and the tick loop
always runs every ready head whose stage is free — so every in-flight
microbatch makes progress every ``p`` ticks and ``pump`` terminates.

This module is backend-agnostic on purpose: it duck-types against the
``start_round`` / ``run_stage`` / ``send_boundary`` / ``decode_step``
surface and never imports ``runtime.backends`` (which imports us).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set

import numpy as np

__all__ = [
    "StageForward", "BoundarySend", "BoundaryRecv", "PrefillChunk",
    "SampleToken", "Sync", "RoundResult", "DynamicPPQueue", "FusedQueue",
    "make_queue",
]


# ---------------------------------------------------------------------------
# instruction set — the executed program is logged, one record per issue,
# so tests can pin instruction counts against commodel.pp_schedule_stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageForward:
    """Run stage ``stage``'s jitted fn for microbatch group ``mb``."""
    mb: int
    stage: int


@dataclasses.dataclass(frozen=True)
class BoundarySend:
    """Ship the boundary pair off stage ``stage`` for group ``mb``."""
    mb: int
    stage: int


@dataclasses.dataclass(frozen=True)
class BoundaryRecv:
    """Land the boundary pair on stage ``stage`` for group ``mb``."""
    mb: int
    stage: int


@dataclasses.dataclass(frozen=True)
class PrefillChunk:
    """A prefill chunk advanced for slot ``mb`` (logged by the scheduler;
    prefill itself stays on the fused per-backend path)."""
    mb: int


@dataclasses.dataclass(frozen=True)
class SampleToken:
    """Greedy-sample the last stage's logits for group ``mb``."""
    mb: int


@dataclasses.dataclass(frozen=True)
class Sync:
    """Barrier: all in-flight rounds were drained before a cache/page
    mutation (admission prefill, chunk, realloc) could alias them."""


@dataclasses.dataclass
class RoundResult:
    """One completed decode round: ``tokens[i]`` belongs to ``slots[i]``.

    ``ticks``/``stage_busy``/``stage_idle`` are deltas of the queue's
    schedule clock since the previous completion, so summing them over a
    serving run reproduces the queue totals exactly.  ``transfers`` counts
    only this round's own boundary hops (attributed at send time).
    """
    mb: int
    slots: List[int]
    tokens: np.ndarray
    transfers: Dict[str, int]
    wall_s: float
    ticks: int
    stage_busy: List[int]
    stage_idle: List[int]


@dataclasses.dataclass
class _Round:
    """An in-flight decode round: a linear chain whose head is the next
    ``StageForward``; ``x`` is the activation (token ids at stage 0)."""
    mb: int
    seq: int
    slots: List[int]
    x: object
    pos: object
    bt: object
    stage: int = 0
    tr_count: int = 0
    tr_bytes: int = 0


# ---------------------------------------------------------------------------
# dynamic PP queue
# ---------------------------------------------------------------------------


class DynamicPPQueue:
    """Priority-driven dynamic schedule over a PPBackend's stage fns.

    Tick loop (one tick = one sweep over stages, deepest first):

    1. For each stage ``s`` from ``p-1`` down to ``0``, issue the oldest
       round whose head targets ``s`` (at most one per stage per tick) —
       dispatches are async, so on a parallel host the per-tick stage
       work overlaps; the deterministic tick count is what the closed
       form ``commodel.pp_schedule_stats`` pins either way.
    2. Tick tail: move every just-produced boundary to its next stage
       (logged TransferRecords are attributed to the owning round) and
       force ``SampleToken`` on rounds that cleared the last stage.

    Deepest-first ordering is what makes the schedule drain-first: a
    round near completion never waits behind a newly started one, so
    with ``depth`` ≥ ``p`` every stage is busy every tick once the
    pipeline fills.
    """

    def __init__(self, backend):
        self.backend = backend
        self.p = int(backend.p)
        self.depth = int(backend.inflight)
        self.group_size = int(backend.group_size)
        self._rounds: List[_Round] = []
        self._seq = 0
        self.ticks = 0
        self.busy = [0] * self.p
        self.idle = [0] * self.p
        self.log: List[object] = []
        self._mark = (0, [0] * self.p, [0] * self.p)

    # -- state ------------------------------------------------------------

    @property
    def in_flight(self) -> int:
        return len(self._rounds)

    def busy_groups(self) -> Set[int]:
        """Groups with issued work in flight — their caches/pages must not
        be freed or reallocated (preemption picks victims elsewhere)."""
        return {r.mb for r in self._rounds}

    def pending_groups(self) -> Set[int]:
        """Groups that must not get another ``begin_round``."""
        return {r.mb for r in self._rounds}

    # -- refill -----------------------------------------------------------

    def begin_round(self, g: int, tokens: np.ndarray, pos: np.ndarray):
        """Start one decode round for group ``g`` from the scheduler's
        per-slot token/pos state.  Page extends happen here, before any
        instruction is issued, so pool exhaustion (MemoryError) surfaces
        with the failed group *not* in flight."""
        if any(r.mb == g for r in self._rounds):
            raise RuntimeError(f"group {g} already has a round in flight")
        x, pos_g, bt = self.backend.start_round(g, tokens, pos)
        self._seq += 1
        lo = g * self.group_size
        self._rounds.append(_Round(
            mb=g, seq=self._seq, slots=list(range(lo, lo + self.group_size)),
            x=x, pos=pos_g, bt=bt))

    # -- drain ------------------------------------------------------------

    def pump(self) -> List[RoundResult]:
        """Tick until at least one round completes; return the completions
        (empty only when nothing is in flight)."""
        if not self._rounds:
            return []
        t0 = time.perf_counter()
        done: List[RoundResult] = []
        while not done:
            done = self._tick()
        wall = (time.perf_counter() - t0) / len(done)
        for res in done:
            res.wall_s = wall
        return done

    def sync(self) -> List[RoundResult]:
        """Drain every in-flight round (the ``Sync`` instruction): called
        before any operation that writes caches/pages a round may read."""
        out: List[RoundResult] = []
        drained = bool(self._rounds)
        while self._rounds:
            out.extend(self.pump())
        if drained:
            self.log.append(Sync())
        return out

    def abort_all(self) -> None:
        """Drop all in-flight rounds without completing them (permanent
        fault: the active set is being error-finished anyway)."""
        self._rounds.clear()

    def note_prefill(self, slot: int) -> None:
        self.log.append(PrefillChunk(mb=slot))

    # -- internals --------------------------------------------------------

    def _tick(self) -> List[RoundResult]:
        self.ticks += 1
        ran = []
        for s in range(self.p - 1, -1, -1):
            cand = None
            for r in self._rounds:
                if r.stage == s and (cand is None or r.seq < cand.seq):
                    cand = r
            if cand is None:
                self.idle[s] += 1
                continue
            out = self.backend.run_stage(cand.mb, s, cand.x, cand.pos,
                                         cand.bt)
            self.busy[s] += 1
            self.log.append(StageForward(mb=cand.mb, stage=s))
            ran.append((cand, out))
        # tick tail: boundary moves + sample forcing happen after every
        # stage dispatch of the tick is in the air
        eng = self.backend.engine
        finished = []
        for r, out in ran:
            if r.stage < self.p - 1:
                n0 = len(eng.transfers)
                r.x = self.backend.send_boundary(out, r.stage)
                for rec in eng.transfers[n0:]:
                    r.tr_count += rec.count
                    r.tr_bytes += rec.bytes
                self.log.append(BoundarySend(mb=r.mb, stage=r.stage))
                self.log.append(BoundaryRecv(mb=r.mb, stage=r.stage + 1))
                r.stage += 1
            else:
                finished.append((r, out))
        results = []
        for r, logits in finished:
            self._rounds.remove(r)
            toks = self.backend._first_token(logits)
            self.log.append(SampleToken(mb=r.mb))
            results.append(self._result(r, toks))
        return results

    def _result(self, r: _Round, toks: np.ndarray) -> RoundResult:
        d_ticks = self.ticks - self._mark[0]
        d_busy = [b - m for b, m in zip(self.busy, self._mark[1])]
        d_idle = [i - m for i, m in zip(self.idle, self._mark[2])]
        self._mark = (self.ticks, list(self.busy), list(self.idle))
        return RoundResult(
            mb=r.mb, slots=r.slots, tokens=np.asarray(toks, np.int32),
            transfers={"count": r.tr_count, "bytes": r.tr_bytes},
            wall_s=0.0, ticks=d_ticks, stage_busy=d_busy, stage_idle=d_idle)


# ---------------------------------------------------------------------------
# degenerate fused queue
# ---------------------------------------------------------------------------


class FusedQueue:
    """The fused ``decode_step`` as a 1-instruction queue (group 0 spans
    every slot).  ``begin_round`` stores *references* to the scheduler's
    token/pos arrays: after a MemoryError-triggered preemption mutates
    them in place, the retried ``pump`` sees the updated state — bitwise
    the pre-refactor recovery ladder."""

    def __init__(self, backend):
        self.backend = backend
        self.p = 1
        self.depth = 1
        self.group_size = int(backend.num_slots)
        self._round = None
        self.ticks = 0
        self.busy = [0]
        self.idle = [0]
        self.log: List[object] = []

    @property
    def in_flight(self) -> int:
        return 0 if self._round is None else 1

    def busy_groups(self) -> Set[int]:
        # nothing is ever issued before pump returns, so a pending round
        # pins no pages: preemption may pick any victim (old behavior)
        return set()

    def pending_groups(self) -> Set[int]:
        return set() if self._round is None else {0}

    def begin_round(self, g: int, tokens: np.ndarray, pos: np.ndarray):
        if self._round is not None:
            raise RuntimeError("fused queue already has a round pending")
        self._round = (g, tokens, pos)

    def pump(self) -> List[RoundResult]:
        if self._round is None:
            return []
        g, tokens, pos = self._round
        t0 = time.perf_counter()
        # may raise (faults, pool exhaustion): the round is retained so the
        # recovery ladder retries it against the mutated token/pos state
        nxt = self.backend.decode_step(tokens, pos)
        wall = time.perf_counter() - t0
        self._round = None
        self.ticks += 1
        self.busy[0] += 1
        self.log.append(StageForward(mb=g, stage=0))
        self.log.append(SampleToken(mb=g))
        tr = self.backend.drain_transfers()
        return [RoundResult(
            mb=g, slots=list(range(self.group_size)),
            tokens=np.asarray(nxt, np.int32), transfers=dict(tr),
            wall_s=wall, ticks=1, stage_busy=[1], stage_idle=[0])]

    def sync(self) -> List[RoundResult]:
        out: List[RoundResult] = []
        if self._round is not None:
            out = self.pump()
            self.log.append(Sync())
        return out

    def abort_all(self) -> None:
        self._round = None

    def note_prefill(self, slot: int) -> None:
        self.log.append(PrefillChunk(mb=slot))


def make_queue(backend):
    """Build the instruction queue for ``backend``.

    Resolved via the backend *class*, not instance getattr: test harnesses
    wrap backends in ``__getattr__``-delegating proxies to count
    ``decode_step`` calls, and delegation would hand back a queue bound to
    the inner object, bypassing the proxy.  A class without ``make_queue``
    gets the degenerate fused queue around the outer object.
    """
    mk = getattr(type(backend), "make_queue", None)
    if mk is None:
        return FusedQueue(backend)
    return mk(backend)
