import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination.

For each combination this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. lowers the right step function (train_step / prefill / serve_step) on
     ShapeDtypeStruct stand-ins with full production shardings,
  3. compiles it (SPMD partitioning for 256/512 host devices),
  4. records memory_analysis, cost_analysis and the HLO collective schedule
     into results/dryrun/<arch>__<shape>__<mesh>.json — the data source for
     EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # every combo, subprocess each
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

ASSIGNED_ARCHS = [
    "granite-8b", "rwkv6-7b", "mixtral-8x22b", "internlm2-1.8b",
    "phi3-mini-3.8b", "hubert-xlarge", "paligemma-3b", "gemma-7b",
    "deepseek-moe-16b", "hymba-1.5b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _record_path(arch: str, shape: str, mesh_name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")


def run_one(arch: str, shape_name: str, multi_pod: bool,
            variant: str = "baseline") -> dict:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.config.base import INPUT_SHAPES, TPU_V5E
    from repro.configs import get_config
    from repro.core import hlo_comm, roofline
    from repro.launch import specs as sp
    from repro.launch.mesh import make_production_mesh
    from repro.models.transformer import get_model
    from repro.optim.adamw import AdamW
    from repro.runtime.engine import make_serve_step
    from repro.runtime.train import make_train_step

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    if variant != "baseline":
        cfg = apply_variant(cfg, variant)
        mesh_name += f"__{variant}"
    shape = INPUT_SHAPES[shape_name]
    t0 = time.time()
    reason = sp.skip_reason(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "status": "skip", "skip_reason": reason}
    if reason is not None:
        return rec

    if variant.startswith("mesh"):
        # §Perf mesh-rebalance variant, e.g. mesh64x4 or mesh64x4-rwkv_chunked
        # -> (data=64, model=4) on the same 256 chips (planner-guided)
        from repro.launch.mesh import make_mesh
        spec = variant[4:].split("-")[0]
        d, m = spec.split("x")
        mesh = make_mesh((int(d), int(m)), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    from repro.runtime import meshctx
    meshctx.set_mesh(mesh)
    model = get_model(cfg)
    params, pspecs = sp.param_sds(cfg, mesh)

    if shape.mode == "train":
        optimizer = AdamW()
        opt_shapes = jax.eval_shape(optimizer.init, params)
        opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
        opt = jax.tree.map(
            lambda s, spc: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, spc)),
            opt_shapes, opt_specs,
            is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
        batch, bspecs = sp.batch_specs(cfg, shape, mesh)
        step = make_train_step(model, optimizer)
        with mesh:
            lowered = jax.jit(step).lower(params, opt, batch)
    elif shape.mode == "prefill":
        batch, bspecs = sp.batch_specs(cfg, shape, mesh)

        def prefill_fn(params, **kw):
            if cfg.family == "encoder":
                logits, _ = model.forward(params, features=kw["features"])
                return logits
            logits, cache, _ = model.prefill(params, kw["tokens"],
                                             max_len=shape.seq_len,
                                             prefix_emb=kw.get("prefix_emb"))
            return logits, cache

        with mesh:
            lowered = jax.jit(prefill_fn).lower(params, **batch)
    else:  # decode
        tok, pos, cache = sp.decode_specs(cfg, shape, mesh)
        step = make_serve_step(model)
        with mesh:
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                params, cache, tok, pos)

    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = hlo_comm.parse_hlo_collectives(hlo)
    rep = roofline.analyze(cfg, shape, mesh_name, n_chips, cost, hlo,
                           hw=TPU_V5E)
    mem_rec = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
        val = getattr(mem, field, None)
        if val is not None:
            mem_rec[field] = int(val)

    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "seconds_to_compile": round(time.time() - t0, 1),
        "memory_analysis": mem_rec,
        "bytes_per_device": mem_rec.get("argument_size_in_bytes", 0)
        + mem_rec.get("temp_size_in_bytes", 0),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "collectives": hlo_comm.summarize(colls),
        "roofline": {
            "flops_per_chip": rep.flops_per_chip,
            "hbm_bytes_per_chip": rep.hbm_bytes_per_chip,
            "coll_bytes_per_chip": rep.coll_bytes_per_chip,
            "compute_s": rep.compute_s,
            "memory_s": rep.memory_s,
            "collective_s": rep.collective_s,
            "dominant": rep.dominant,
            "model_flops_total": rep.model_flops_total,
            "useful_ratio": rep.useful_ratio,
        },
    })
    return rec


def apply_variant(cfg, variant: str):
    """Named beyond-baseline configuration variants for §Perf hillclimbs."""
    import dataclasses as dc
    if variant.startswith("mesh"):
        rest = variant.split("-", 1)
        return apply_variant(cfg, rest[1]) if len(rest) > 1 else cfg
    if variant == "remat":
        return dc.replace(cfg, remat="dots")
    if variant == "remat_full":
        return dc.replace(cfg, remat="full")
    if variant == "chunked_attn":
        return dc.replace(cfg, attention_impl="chunked")
    if variant.startswith("chunked_attn_c"):
        return dc.replace(cfg, attention_impl="chunked",
                          attention_chunk=int(variant.rsplit("c", 1)[1]))
    if variant == "chunked_attn_remat":
        return dc.replace(cfg, attention_impl="chunked", remat="dots")
    if variant == "moe_local":
        return dc.replace(cfg, moe_dispatch="local")
    if variant == "moe_local_fsdp":
        return dc.replace(cfg, moe_dispatch="local", moe_fsdp=True)
    if variant == "moe_local_chunked":
        return dc.replace(cfg, moe_dispatch="local", attention_impl="chunked")
    if variant == "moe_local_fsdp_chunked":
        return dc.replace(cfg, moe_dispatch="local", moe_fsdp=True,
                          attention_impl="chunked")
    if variant == "rwkv_chunked":
        return dc.replace(cfg, ssm=dc.replace(cfg.ssm, scan_impl="chunked"))
    if variant.startswith("rwkv_chunked_c"):
        return dc.replace(cfg, ssm=dc.replace(
            cfg.ssm, scan_impl="chunked",
            scan_chunk=int(variant.rsplit("c", 1)[1])))
    if variant == "ssm_attn_chunked":
        return dc.replace(cfg, attention_impl="chunked",
                          ssm=dc.replace(cfg.ssm, scan_impl="chunked"))
    if variant == "rwkv_chunked_remat":
        return dc.replace(cfg, remat="dots",
                          ssm=dc.replace(cfg.ssm, scan_impl="chunked"))
    raise KeyError(variant)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch, shape, mesh) in subprocesses")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    if args.all:
        combos = [(a, s, mp) for a in ASSIGNED_ARCHS for s in SHAPES
                  for mp in (False, True)]
        failures = []
        for a, s, mp in combos:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            path = _record_path(a, s, mesh_name)
            if os.path.exists(path) and not args.force:
                print(f"[cached] {a} {s} {mesh_name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s]
            if mp:
                cmd.append("--multi-pod")
            print(f"[run] {a} {s} {mesh_name} ...", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures.append((a, s, mesh_name, r.stderr[-2000:]))
                print(f"[FAIL] {a} {s} {mesh_name}\n{r.stderr[-2000:]}")
        print(f"done; {len(failures)} failures")
        sys.exit(1 if failures else 0)

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    if args.variant != "baseline":
        mesh_name += f"__{args.variant}"
    path = _record_path(args.arch, args.shape, mesh_name)
    try:
        rec = run_one(args.arch, args.shape, args.multi_pod, args.variant)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": mesh_name,
               "status": "error", "error": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(json.dumps({k: rec[k] for k in ("arch", "shape", "status")}))
        raise
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] == "ok":
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "status", "n_chips",
                           "bytes_per_device", "seconds_to_compile")}))
        print("memory_analysis:", rec["memory_analysis"])
        print("cost_analysis flops:",
              rec["cost_analysis"].get("flops"))
        print("roofline:", json.dumps(rec["roofline"], indent=1))
    else:
        print(json.dumps(rec))


if __name__ == "__main__":
    main()
