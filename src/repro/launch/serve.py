"""Serving driver: batched greedy generation with KV cache.

CPU-scale example:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.models.transformer import get_model
    from repro.runtime.engine import InferenceEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.new_tokens + cfg.num_prefix_tokens + 8
    engine = InferenceEngine(cfg, params, max_len=max_len)

    rng = np.random.default_rng(args.seed)
    if cfg.family == "encoder":
        feats = jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)), jnp.dtype(cfg.dtype))
        t0 = time.time()
        logits = engine.encode(feats)
        print(f"encoded {feats.shape} -> {logits.shape} "
              f"in {time.time()-t0:.2f}s")
        return

    prompts = jnp.asarray(rng.integers(2, cfg.vocab_size,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["prefix_emb"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_prefix_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype)) * 0.02
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens, **kw)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("first sequence:", np.asarray(out[0]).tolist())


if __name__ == "__main__":
    main()
