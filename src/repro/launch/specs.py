"""ShapeDtypeStruct input stand-ins for every (architecture × shape) combo.

``input_specs`` returns exactly what the lowered step function consumes —
weak-type-correct, shardable, zero device allocation.  Modality frontends
are stubs per the assignment: audio/vision entries receive precomputed
frame/patch embeddings of the right shape.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig, ShapeConfig
from repro.models.transformer import get_model
from repro.runtime import sharding as sh


def _sds(shape, dtype, mesh: Optional[Mesh] = None, spec: Optional[P] = None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Principled (arch × shape) skips — documented in DESIGN.md §4."""
    if shape.mode == "decode" and not cfg.is_decoder:
        return "encoder-only architecture: no decode phase"
    if shape.name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")
                         or cfg.sliding_window is not None)
        if not sub_quadratic:
            return ("pure full-attention arch: 524k dense KV not claimed by "
                    "the model card (needs SWA/block-sparse variant)")
    return None


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                mesh: Optional[Mesh] = None) -> Tuple[Dict, Dict]:
    """(ShapeDtypeStruct kwargs, PartitionSpec kwargs) for train/prefill data."""
    B, S = shape.global_batch, shape.seq_len
    dspec2 = sh.data_spec(mesh, B, 1) if mesh else None
    dspec3 = sh.data_spec(mesh, B, 2) if mesh else None
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "encoder":
        sds = {"features": _sds((B, S, cfg.d_model), dt, mesh, dspec3),
               "targets": _sds((B, S), jnp.int32, mesh, dspec2)}
        return sds, {"features": dspec3, "targets": dspec2}
    if cfg.family == "vlm":
        pfx = cfg.num_prefix_tokens
        s_text = max(S - pfx, 1)
        sds = {"tokens": _sds((B, s_text), jnp.int32, mesh, dspec2),
               "prefix_emb": _sds((B, pfx, cfg.d_model), dt, mesh, dspec3)}
        return sds, {"tokens": dspec2, "prefix_emb": dspec3}
    sds = {"tokens": _sds((B, S), jnp.int32, mesh, dspec2)}
    return sds, {"tokens": dspec2}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh]):
    """(params-independent) decode inputs: token, pos, cache SDS pytrees."""
    model = get_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    if mesh is None:
        cache = jax.tree.map(lambda s: _sds(s.shape, s.dtype), cache_shapes)
        tok = _sds((B,), jnp.int32)
        pos = _sds((), jnp.int32)
        return tok, pos, cache
    spec_tree = sh.cache_specs(cfg, mesh, B)(cache_shapes)
    cache = {k: _sds(v.shape, v.dtype, mesh, spec_tree[k])
             for k, v in cache_shapes.items()}
    tok = _sds((B,), jnp.int32, mesh, sh.data_spec(mesh, B, 0))
    pos = _sds((), jnp.int32, mesh, P())
    return tok, pos, cache


def param_sds(cfg: ModelConfig, mesh: Optional[Mesh] = None):
    """Abstract params (+ their specs) without allocating anything."""
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    axis_size = mesh.shape.get("model") if mesh is not None else None
    specs = sh.param_specs(cfg, shapes, axis_size=axis_size)
    if mesh is None:
        return shapes, specs
    sds = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
    return sds, specs
