"""Production mesh builders.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first backend
initialization, and only launch/dryrun.py sets the 512-device host platform.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod" axis carries
    data parallelism across the DCN/ICI boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))
