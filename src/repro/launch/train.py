"""Training driver.

CPU-scale example (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 20 --batch 8 --seq 128

On a real TPU slice, drop --reduced and pass --mesh data,model dims, e.g.
  python -m repro.launch.train --arch granite-8b --mesh 16,16 --steps 1000
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="data,model dims e.g. 16,16 (default: single device)")
    ap.add_argument("--loss-impl", default="dense", choices=["dense", "fused"])
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.data.pipeline import SyntheticTokens
    from repro.models.transformer import get_model
    from repro.optim.adamw import AdamW, cosine_schedule
    from repro.runtime.train import make_train_step
    from repro.checkpoint import save_checkpoint

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M family={cfg.family}")

    optimizer = AdamW(lr=cosine_schedule(args.lr, 10, args.steps))
    opt_state = optimizer.init(params)
    step_fn = jax.jit(make_train_step(model, optimizer, args.loss_impl),
                      donate_argnums=(0, 1))

    data = SyntheticTokens(cfg.vocab_size, args.seq + 1, args.batch,
                           seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for step, tokens in enumerate(data):
        if step >= args.steps:
            break
        batch = {"tokens": jnp.asarray(tokens)}
        if cfg.family == "vlm":
            batch["prefix_emb"] = jnp.asarray(
                rng.standard_normal(
                    (args.batch, cfg.num_prefix_tokens, cfg.d_model)),
                jnp.dtype(cfg.dtype)) * 0.02
        if cfg.family == "encoder":
            batch = {
                "features": jnp.asarray(
                    rng.standard_normal((args.batch, args.seq, cfg.d_model)),
                    jnp.dtype(cfg.dtype)),
                "targets": jnp.asarray(tokens[:, :args.seq] % cfg.vocab_size),
            }
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(metrics['loss']):8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):8.3f}  "
                  f"lr {float(metrics['lr']):.2e}")
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, {"params": params}, args.steps)
        print(f"saved checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()
