"""Mixture-of-Experts block: top-k routing with capacity-based sorted dispatch.

Dispatch is the sort-scatter formulation (GShard/MaxText style): token→expert
assignments are sorted by expert id, laid out into a dense [E, capacity, h]
buffer (tokens over capacity are dropped), run through a stacked-expert GLU,
and combined back with the renormalized router probabilities.  The expert
dimension E is the sharding axis for expert parallelism — under GSPMD the
scatter/gather pair around the expert einsum lowers to the all-to-all pattern
the paper's §VII names as future work (see core/commodel.py MoE extension).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import layers
from repro.models.layers import dense_init, mlp_apply, rms_norm

def init_moe_blocks(rng, cfg: ModelConfig, L: int, dtype):
    moe = cfg.moe
    ka, kr, k1, k2, k3, ks = jax.random.split(rng, 6)
    h, f, E = cfg.d_model, moe.expert_d_ff, moe.num_experts
    p = layers.init_attention(ka, cfg, L, dtype=dtype)
    p["router"] = dense_init(kr, (L, h, E), jnp.float32)
    p["we1"] = dense_init(k1, (L, E, h, f), dtype)
    p["we2"] = dense_init(k2, (L, E, f, h), dtype)
    p["we3"] = dense_init(k3, (L, E, h, f), dtype)
    if moe.num_shared_experts:
        sf = moe.shared_d_ff * moe.num_shared_experts
        p.update({f"s{k}": v for k, v in layers.init_mlp(
            ks, h, sf, cfg.activation, L, dtype).items()})
    p["ln1"] = jnp.zeros((L, cfg.d_model), dtype)
    p["ln2"] = jnp.zeros((L, cfg.d_model), dtype)
    return p


def moe_capacity(tokens: int, cfg: ModelConfig) -> int:
    moe = cfg.moe
    cap = int(math.ceil(tokens * moe.top_k / moe.num_experts
                        * moe.capacity_factor))
    return min(max(cap, moe.top_k), tokens * moe.top_k)


def moe_ffn(cfg: ModelConfig, p, x):
    """x: [B, S, h] -> (y [B, S, h], aux_loss scalar).  GSPMD path: the
    dispatch runs over the GLOBAL token set and the partitioner places the
    collectives (baseline for §Perf's local-dispatch comparison)."""
    moe = cfg.moe
    B, S, h = x.shape
    T = B * S
    cap = moe_capacity(T, cfg)
    xf = x.reshape(T, h)
    y, aux = _moe_compute(cfg, p, xf, cap)
    if moe.num_shared_experts:
        y = y + mlp_apply({"w1": p["sw1"], "w2": p["sw2"],
                           "w3": p.get("sw3")}, xf, cfg.activation)
    return y.reshape(B, S, h), aux


def _moe_compute(cfg: ModelConfig, p, xf, cap: int):
    """Core routed-expert computation on a flat token block [T, h].

    Shared by the GSPMD path (global tokens) and the shard_map local-dispatch
    path (per-data-shard tokens, f-sharded experts)."""
    moe = cfg.moe
    E, K = moe.num_experts, moe.top_k
    T, h = xf.shape

    router_logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(router_logits, axis=-1)                    # [T, E]
    top_p, top_i = jax.lax.top_k(probs, K)                            # [T, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    mean_prob = probs.mean(axis=0)
    frac_tok = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(mean_prob * frac_tok) * moe.router_aux_coef

    flat_e = top_i.reshape(-1)                                        # [T*K]
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_s, t_s, p_s = flat_e[order], flat_t[order], flat_p[order]
    counts = jnp.bincount(flat_e, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * K) - offsets[e_s]
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_s * cap + pos_in_e, E * cap)             # overflow

    xe = jnp.zeros((E * cap + 1, h), xf.dtype).at[slot].set(xf[t_s])
    xe = xe[:E * cap].reshape(E, cap, h)
    up = jnp.einsum("ech,ehf->ecf", xe, p["we1"])
    gate = jax.nn.silu(up) if cfg.activation == "swiglu" else jax.nn.gelu(up)
    act = gate * jnp.einsum("ech,ehf->ecf", xe, p["we3"])
    ye = jnp.einsum("ecf,efh->ech", act, p["we2"]).reshape(E * cap, h)
    ye = jnp.concatenate([ye, jnp.zeros((1, h), ye.dtype)], axis=0)

    y = jnp.zeros((T, h), xf.dtype).at[t_s].add(
        ye[slot] * (p_s * keep).astype(ye.dtype)[:, None])
    return y, aux


def moe_ffn_local(cfg: ModelConfig, p, x, mesh):
    """§Perf local-dispatch MoE (shard_map): tokens never leave their data
    shard — routing/sort/scatter are shard-local, experts are tensor-parallel
    on the model axis (f-dim), and the ONLY cross-chip communication is one
    psum per MoE layer (the row-parallel expert down-projection).

    This replaces the GSPMD-partitioned global sort-scatter, whose data-
    dependent gather/scatter forces full-activation all-gathers across the
    mesh (the dominant collective term in the mixtral/deepseek baselines).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, h = x.shape
    moe = cfg.moe
    baxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]
    bdim = baxes if (B % dp == 0 and B >= dp) else None
    t_loc = (B // dp if bdim else B) * S
    cap = moe_capacity(t_loc, cfg)

    x_spec = P(bdim, None, None)
    fsdp = cfg.moe_fsdp and "data" in mesh.shape and h % mesh.shape["data"] == 0
    d_ax = "data" if fsdp else None
    pspecs = {"router": P(None, None),
              "we1": P(None, d_ax, "model"), "we3": P(None, d_ax, "model"),
              "we2": P(None, "model", d_ax)}
    if moe.num_shared_experts:
        pspecs.update({"sw1": P(None, "model"), "sw3": P(None, "model"),
                       "sw2": P("model", None)})
    p_local = {k: p[k] for k in pspecs}

    def fn(p_l, x_l):
        Bl, Sl, _ = x_l.shape
        xf = x_l.reshape(Bl * Sl, h)
        if fsdp:   # just-in-time weight gather (ZeRO-3 for serving)
            p_l = dict(p_l,
                       we1=jax.lax.all_gather(p_l["we1"], "data", axis=1,
                                              tiled=True),
                       we3=jax.lax.all_gather(p_l["we3"], "data", axis=1,
                                              tiled=True),
                       we2=jax.lax.all_gather(p_l["we2"], "data", axis=2,
                                              tiled=True))
        y, aux = _moe_compute(cfg, p_l, xf, cap)
        if moe.num_shared_experts:
            y = y + mlp_apply({"w1": p_l["sw1"], "w2": p_l["sw2"],
                               "w3": p_l.get("sw3")}, xf, cfg.activation)
        y = jax.lax.psum(y, "model")          # row-parallel expert down-proj
        if bdim:
            aux = jax.lax.pmean(aux, bdim)
        return y.reshape(Bl, Sl, h), aux

    y, aux = shard_map(fn, mesh=mesh, in_specs=(pspecs, x_spec),
                       out_specs=(x_spec, P()), check_rep=False)(p_local, x)
    return y, aux


def moe_block_apply(cfg: ModelConfig, p, x, positions, mask,
                    cache=None, pos=None, build_cache_w=None):
    from repro.models.blocks import attention_apply
    from repro.runtime import meshctx
    attn_out, cache_out = attention_apply(
        cfg, p, rms_norm(x, p["ln1"], cfg.norm_eps), positions, mask,
        cache=cache, pos=pos, build_cache_w=build_cache_w)
    x = x + attn_out @ p["wo"]
    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    mesh = meshctx.get_mesh()
    if (cfg.moe_dispatch == "local" and mesh is not None
            and "model" in mesh.shape):
        y, aux = moe_ffn_local(cfg, p, xn, mesh)
    else:
        y, aux = moe_ffn(cfg, p, xn)
    return x + y, cache_out, aux
