"""Hymba-style hybrid-head block [arXiv:2411.13676]: within every layer the
input is processed in parallel by (i) sliding-window GQA attention heads and
(ii) mamba-style selective-SSM heads; the two normalized outputs are averaged.

Head split: n_attn = floor(num_heads · attn_head_fraction) rounded down to a
multiple of num_kv_heads (GQA divisibility); the remaining heads form the SSM
path with d_inner = n_ssm · head_dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import layers
from repro.models.blocks import attention_apply
from repro.models.layers import dense_init, rms_norm


def head_split(cfg: ModelConfig):
    kv = cfg.num_kv_heads
    n_attn = max(kv, int(cfg.num_heads * cfg.attn_head_fraction) // kv * kv)
    n_ssm = max(1, cfg.num_heads - n_attn)
    return n_attn, n_ssm


def init_hybrid_blocks(rng, cfg: ModelConfig, L: int, dtype):
    n_attn, n_ssm = head_split(cfg)
    h, d = cfg.d_model, cfg.head_dim
    di, N = n_ssm * d, cfg.ssm.state_size
    ks = jax.random.split(rng, 8)
    p = layers.init_attention(ks[0], cfg, L, n_heads=n_attn, dtype=dtype)
    p.update(layers.init_mlp(ks[1], h, cfg.d_ff, cfg.activation, L, dtype))
    p.update({
        "ln1": jnp.zeros((L, h), dtype), "ln2": jnp.zeros((L, h), dtype),
        "ln_attn": jnp.zeros((L, h), dtype), "ln_ssm": jnp.zeros((L, h), dtype),
        "in_proj": dense_init(ks[2], (L, h, 2 * di), dtype),
        "w_dt": dense_init(ks[3], (L, di, di), dtype, scale=0.01),
        "b_dt": jnp.full((L, di), -4.0, jnp.float32),   # softplus(-4) ~ 0.018
        "w_B": dense_init(ks[4], (L, di, N), dtype),
        "w_C": dense_init(ks[5], (L, di, N), dtype),
        "A_log": jnp.zeros((L, di, N), jnp.float32),    # A = -exp(A_log) = -1
        "D": jnp.ones((L, di), jnp.float32),
        "ssm_out": dense_init(ks[6], (L, di, h), dtype),
    })
    return p


def selective_scan(xm, dt, Bm, Cm, A, D, state):
    """Selective SSM scan.

    xm, dt: [B,S,di]; Bm, Cm: [B,S,N]; A: [di,N]; D: [di];
    state: [B,di,N] f32.  Returns (y [B,S,di], new_state).
    """
    xf, dtf = xm.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    def step(s, inp):
        x_t, dt_t, b_t, c_t = inp                       # [B,di],[B,di],[B,N],[B,N]
        decay = jnp.exp(dt_t[..., None] * A)            # [B,di,N]
        s = decay * s + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", s, c_t) + D * x_t
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xf, dtf, Bf, Cf))
    final, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(xm.dtype), final


def selective_scan_chunked(xm, dt, Bm, Cm, A, D, state, chunk: int = 16):
    """§Perf chunk-parallel selective scan (exact, pairwise log-domain).

    With T_t = Σ_{τ≤t} dt_τ (per channel d) the recurrence solution is
      s_t[d,n]  = e^{A[d,n]·T_t} s_0 + Σ_{τ≤t} e^{A[d,n](T_t-T_τ)} dt_τ x_τ B_τ[n]
      y_t[d]    = Σ_n C_t[n] s_t[d,n] + D x_t .
    A < 0 and T is increasing, so every exponent is ≤ 0 — stable in fp32.
    The scan carries state once per chunk (S/C state round-trips instead of
    S), the same cure applied to WKV6 in kernels/rwkv6_scan/chunked.py.
    Validated against the per-token scan in tests/test_perf_variants.py.
    """
    B, S, di = xm.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    def to_chunks(t):
        return (t.reshape(B, n, chunk, *t.shape[2:])
                 .transpose(1, 0, 2, *range(3, t.ndim + 1))
                 .astype(jnp.float32))

    xc, dtc, Bc, Cc = map(to_chunks, (xm, dt, Bm, Cm))
    Af = A.astype(jnp.float32)                                # [di,N]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))         # τ ≤ t

    def body(s, inp):
        x_c, dt_c, b_c, c_c = inp              # [B,C,di],[B,C,di],[B,C,N]
        T = jnp.cumsum(dt_c, axis=1)           # [B,C,di] inclusive
        # pairwise ΔT[t,τ,d] = T_t - T_τ  (≥ 0 for τ ≤ t)
        dT = T[:, :, None, :] - T[:, None, :, :]              # [B,C,C,di]
        dT = jnp.where(causal[None, :, :, None], dT, jnp.inf)
        E = jnp.exp(Af[None, None, None] * dT[..., None])     # [B,C,C,di,N]
        u = (dt_c * x_c)                                      # [B,C,di]
        y = jnp.einsum("btn,bsn,btsdn,bsd->btd", c_c, b_c, E, u)
        # inter-chunk: decayed initial state
        decay0 = jnp.exp(Af[None, None] * T[..., None])       # [B,C,di,N]
        y += jnp.einsum("btn,btdn,bdn->btd", c_c, decay0, s)
        y += D * x_c
        # state update
        T_end = T[:, -1:, :]                                  # [B,1,di]
        k_hat = jnp.exp(Af[None, None] * (T_end - T)[..., None])  # [B,C,di,N]
        s = jnp.exp(Af[None] * T_end[:, 0, :, None]) * s \
            + jnp.einsum("bsdn,bsd,bsn->bdn", k_hat, u, b_c)
        return s, y

    final, ys = jax.lax.scan(body, state.astype(jnp.float32),
                             (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    return y.astype(xm.dtype), final


def mamba_branch(cfg: ModelConfig, p, xn, state=None):
    """xn [B,S,h] -> (out [B,S,h], new_state [B,di,N])."""
    B, S, h = xn.shape
    n_attn, n_ssm = head_split(cfg)
    di, N = n_ssm * cfg.head_dim, cfg.ssm.state_size
    if state is None:
        state = jnp.zeros((B, di, N), jnp.float32)
    xz = xn @ p["in_proj"]
    xm, z = xz[..., :di], xz[..., di:]
    dt = jax.nn.softplus(xm @ p["w_dt"] + p["b_dt"])
    Bm, Cm = xm @ p["w_B"], xm @ p["w_C"]
    A = -jnp.exp(p["A_log"])
    if (cfg.ssm.scan_impl == "chunked" and S > 1
            and S % cfg.ssm.scan_chunk == 0):
        y, new_state = selective_scan_chunked(xm, dt, Bm, Cm, A, p["D"],
                                              state, chunk=cfg.ssm.scan_chunk)
    else:
        y, new_state = selective_scan(xm, dt, Bm, Cm, A, p["D"], state)
    return (y * jax.nn.silu(z)) @ p["ssm_out"], new_state


def init_hybrid_cache(cfg: ModelConfig, L: int, batch: int, width: int, dtype):
    n_attn, n_ssm = head_split(cfg)
    di, N = n_ssm * cfg.head_dim, cfg.ssm.state_size
    return {
        "k": jnp.zeros((L, batch, width, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, width, cfg.num_kv_heads, cfg.head_dim), dtype),
        "ssm_state": jnp.zeros((L, batch, di, N), jnp.float32),
    }


def hybrid_block_apply(cfg: ModelConfig, p, x, positions, mask,
                       cache=None, pos=None, build_cache_w=None):
    n_attn, _ = head_split(cfg)
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
    attn_out, attn_cache_out = attention_apply(
        cfg, p, xn, positions, mask, cache=attn_cache, pos=pos,
        build_cache_w=build_cache_w, n_heads=n_attn)
    ssm_state = None if cache is None else cache["ssm_state"]
    ssm_out, new_state = mamba_branch(cfg, p, xn, ssm_state)
    y = 0.5 * (rms_norm(attn_out @ p["wo"], p["ln_attn"], cfg.norm_eps)
               + rms_norm(ssm_out, p["ln_ssm"], cfg.norm_eps))
    x = x + y
    x = x + layers.mlp_apply(p, rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)

    cache_out = None
    if attn_cache_out is not None:
        cache_out = {"k": attn_cache_out["k"], "v": attn_cache_out["v"],
                     "ssm_state": new_state}
    return x, cache_out, jnp.zeros((), jnp.float32)
