"""RWKV-6 (Finch) block: token-shifted time-mix with data-dependent decay +
squared-ReLU channel-mix [arXiv:2404.05892].

The WKV recurrence itself is ``repro.kernels.rwkv6_scan`` (Pallas on TPU,
pure-jnp scan oracle elsewhere).  Attention-free: the per-layer cache is the
recurrent state + the two token-shift registers — O(1) in sequence length,
which is what qualifies rwkv6 for the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.kernels.rwkv6_scan.chunked import wkv6_chunked
from repro.kernels.rwkv6_scan.ops import wkv6
from repro.models.layers import dense_init, rms_norm

DECAY_LORA = 64


def init_ssm_blocks(rng, cfg: ModelConfig, L: int, dtype):
    h, ff = cfg.d_model, cfg.d_ff
    H, hs = cfg.num_heads, cfg.ssm.head_size
    ks = jax.random.split(rng, 12)
    p = {
        "ln1": jnp.zeros((L, h), dtype), "ln2": jnp.zeros((L, h), dtype),
        # time-mix lerp coefficients (one per projection)
        "mx_r": jnp.full((L, h), 0.5, dtype), "mx_k": jnp.full((L, h), 0.5, dtype),
        "mx_v": jnp.full((L, h), 0.5, dtype), "mx_w": jnp.full((L, h), 0.5, dtype),
        "mx_g": jnp.full((L, h), 0.5, dtype),
        "wr": dense_init(ks[0], (L, h, h), dtype),
        "wk": dense_init(ks[1], (L, h, h), dtype),
        "wv": dense_init(ks[2], (L, h, h), dtype),
        "wg": dense_init(ks[3], (L, h, h), dtype),
        "wo": dense_init(ks[4], (L, h, h), dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x @ wa) @ wb))
        "w0": jnp.full((L, h), -2.0, jnp.float32),
        "wa": dense_init(ks[5], (L, h, DECAY_LORA), dtype),
        "wb": dense_init(ks[6], (L, DECAY_LORA, h), dtype, scale=0.01),
        "u": dense_init(ks[7], (L, H, hs), jnp.float32, scale=0.5),
        "ln_x": jnp.zeros((L, h), dtype),      # per-head output norm
        # channel-mix
        "cmx_k": jnp.full((L, h), 0.5, dtype), "cmx_r": jnp.full((L, h), 0.5, dtype),
        "cwk": dense_init(ks[8], (L, h, ff), dtype),
        "cwv": dense_init(ks[9], (L, ff, h), dtype),
        "cwr": dense_init(ks[10], (L, h, h), dtype),
    }
    return p


def _shift(x, prev):
    """Token shift: xs[t] = x[t-1], xs[0] = prev.  x [B,S,h], prev [B,h]."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _head_norm(y, weight, H, hs, eps):
    B, S = y.shape[:2]
    yh = y.reshape(B, S, H, hs).astype(jnp.float32)
    var = jnp.mean(yh * yh, axis=-1, keepdims=True)
    yh = yh * jax.lax.rsqrt(var + eps)
    return (yh.reshape(B, S, H * hs) * (1.0 + weight.astype(jnp.float32))).astype(y.dtype)


def rwkv_time_mix(cfg: ModelConfig, p, x, prev_x, state):
    """x [B,S,h]; prev_x [B,h]; state [B,H,hs,hs] -> (y, last_x, new_state)."""
    B, S, h = x.shape
    H, hs = cfg.num_heads, cfg.ssm.head_size
    xs = _shift(x, prev_x)

    def mix(m):
        return x + (xs - x) * m

    r = (mix(p["mx_r"]) @ p["wr"]).reshape(B, S, H, hs)
    k = (mix(p["mx_k"]) @ p["wk"]).reshape(B, S, H, hs)
    v = (mix(p["mx_v"]) @ p["wv"]).reshape(B, S, H, hs)
    g = jax.nn.silu(mix(p["mx_g"]) @ p["wg"])
    dw = p["w0"] + jnp.tanh(mix(p["mx_w"]) @ p["wa"]) @ p["wb"]
    w = jnp.exp(-jnp.exp(dw.astype(jnp.float32))).reshape(B, S, H, hs)

    if (cfg.ssm.scan_impl == "chunked" and S > 1
            and S % cfg.ssm.scan_chunk == 0):
        y, new_state = wkv6_chunked(r, k, v, w.astype(r.dtype), p["u"],
                                    state, chunk=cfg.ssm.scan_chunk)
    else:
        y, new_state = wkv6(r, k, v, w.astype(r.dtype), p["u"], state)
    y = _head_norm(y.reshape(B, S, h), p["ln_x"], H, hs, cfg.norm_eps)
    return (y * g) @ p["wo"], x[:, -1, :], new_state


def rwkv_channel_mix(cfg: ModelConfig, p, x, prev_x):
    xs = _shift(x, prev_x)
    xk = x + (xs - x) * p["cmx_k"]
    xr = x + (xs - x) * p["cmx_r"]
    k = jnp.square(jax.nn.relu(xk @ p["cwk"]))
    return jax.nn.sigmoid(xr @ p["cwr"]) * (k @ p["cwv"]), x[:, -1, :]


def init_ssm_cache(cfg: ModelConfig, L: int, batch: int, dtype):
    H, hs, h = cfg.num_heads, cfg.ssm.head_size, cfg.d_model
    return {
        "state": jnp.zeros((L, batch, H, hs, hs), jnp.float32),
        "tm_prev": jnp.zeros((L, batch, h), dtype),
        "cm_prev": jnp.zeros((L, batch, h), dtype),
    }


def ssm_block_apply(cfg: ModelConfig, p, x, positions, mask,
                    cache=None, pos=None, build_cache_w=None):
    B = x.shape[0]
    H, hs, h = cfg.num_heads, cfg.ssm.head_size, cfg.d_model
    if cache is None:
        state = jnp.zeros((B, H, hs, hs), jnp.float32)
        tm_prev = jnp.zeros((B, h), x.dtype)
        cm_prev = jnp.zeros((B, h), x.dtype)
    else:
        state, tm_prev, cm_prev = cache["state"], cache["tm_prev"], cache["cm_prev"]

    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, tm_last, new_state = rwkv_time_mix(cfg, p, xn, tm_prev, state)
    x = x + y
    xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y2, cm_last = rwkv_channel_mix(cfg, p, xn2, cm_prev)
    x = x + y2

    cache_out = None
    if cache is not None or build_cache_w is not None:
        cache_out = {"state": new_state, "tm_prev": tm_last, "cm_prev": cm_last}
    return x, cache_out, jnp.zeros((), jnp.float32)
