"""Shared neural-net layers: norms, RoPE, masks, attention, GLU MLPs.

Everything is pure-functional: ``init_*`` builds param pytrees, ``*_apply``
consumes them.  Attention dispatches to the Pallas flash kernels on TPU and to
the pure-jnp reference elsewhere (see ``repro.kernels``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    half = d // 2
    freq = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    # positions [..., S] -> angles [..., S, 1, half] broadcasting over heads
    angles = positions[..., :, None, None].astype(jnp.float32) * freq
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


import dataclasses


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Lazy attention-mask description — the chunked (flash-style) attention
    path builds per-KV-block masks on the fly instead of materializing the
    [S, S] boolean (1 GiB at 32k), which is itself part of the §Perf win."""

    mode: str = "causal"         # causal | bidirectional | prefix
    window: Optional[int] = None
    prefix_len: int = 0
    q_offset: int = 0

    def materialize(self, q_len: int, kv_len: int):
        return make_mask(q_len, kv_len, mode=self.mode,
                         q_offset=self.q_offset, window=self.window,
                         prefix_len=self.prefix_len)

    def block(self, q_pos, kv_pos):
        """Mask for explicit position vectors: [len(q_pos), len(kv_pos)]."""
        qp = q_pos[:, None]
        kp = kv_pos[None, :]
        if self.mode == "bidirectional":
            m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
        elif self.mode == "prefix":
            m = (kp <= qp) | (kp < self.prefix_len)
        else:
            m = kp <= qp
        if self.window is not None:
            m &= kp > qp - self.window
        return m


def make_mask(q_len: int, kv_len: int, *, mode: str = "causal",
              q_offset=0, window=None, prefix_len: int = 0):
    """Boolean [q_len, kv_len] mask (True = attend).

    mode: "causal" | "bidirectional" | "prefix" (bidirectional prefix + causal
    suffix, PaliGemma-style).  ``window`` adds a sliding-window constraint.
    """
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    kv_pos = jnp.arange(kv_len)[None, :]
    if mode == "bidirectional":
        mask = jnp.ones((q_len, kv_len), bool)
    elif mode == "prefix":
        mask = (kv_pos <= q_pos) | (kv_pos < prefix_len)
    else:
        mask = kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    return mask


def decode_cache_mask(cache_len: int, pos, window=None):
    """Valid-slot mask for a (possibly ring-buffer) KV cache.

    With a ring buffer of width W == window, every slot is valid once pos > W;
    before that only the first ``pos`` slots are.  ``pos`` may be a scalar
    (shared decode position, mask [cache_len]) or a [B] vector of per-sequence
    positions (continuous batching, mask [B, cache_len]).
    """
    idx = jnp.arange(cache_len)
    p = jnp.asarray(pos)[..., None]
    mask = idx < p
    if window is not None:
        mask = mask | (p > cache_len)
    return mask


def decode_positions(pos, batch: int):
    """RoPE position tensor [B, 1] for one decode step from a scalar or [B]
    position; the scalar form broadcasts one shared position over the batch."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.full((batch, 1), pos, jnp.int32)
    return pos[:, None]


def decode_attn_mask(cache_len: int, pos, window=None):
    """`gqa_attention`-broadcastable cache mask for one decode step: [1, W]
    for a scalar position, [B, 1, 1, 1, W] (per-sequence) for pos [B]."""
    m = decode_cache_mask(cache_len, pos + 1, window)
    if jnp.ndim(pos) == 0:
        return m[None, :]
    return m[:, None, None, None, :]


def paged_cache_update(cache_k, cache_v, k, v, pos, block_table):
    """Write a chunk's K/V rows into their block-table pages.

    cache_k/v: [P, ps, Hkv, D] page pools (one layer); k/v: [B, S, Hkv, D];
    pos: [B] start positions; block_table: [B, n] int32 — logical page j of
    sequence b lives at physical page ``block_table[b, j]``.  Logical
    position q maps to physical row ``block_table[b, q // ps] * ps + q % ps``
    of the flattened pool.  Every live page is owned by exactly one sequence
    (runtime/kvpool.py), so the scatter destinations are distinct — except
    for the reserved scratch page 0, which inactive slots alias on purpose
    (their garbage writes must land somewhere harmless).
    """
    P, ps, Hkv, D = cache_k.shape
    B, S = k.shape[:2]
    lp = pos[:, None] + jnp.arange(S)[None, :]             # [B, S] logical
    phys = jnp.take_along_axis(block_table, lp // ps, axis=1)
    rows = (phys * ps + lp % ps).reshape(-1)               # [B*S] physical
    ck = cache_k.reshape(P * ps, Hkv, D).at[rows].set(
        k.reshape(B * S, Hkv, D)).reshape(P, ps, Hkv, D)
    cv = cache_v.reshape(P * ps, Hkv, D).at[rows].set(
        v.reshape(B * S, Hkv, D)).reshape(P, ps, Hkv, D)
    return ck, cv


def paged_gather(pages, block_table):
    """Materialize the logical KV view named by a block table:
    pages [P, ps, Hkv, D] + table [B, n] -> [B, n*ps, Hkv, D].  Row j*ps+r
    of the result is logical position j*ps+r of sequence b; entries past the
    sequence's length alias whatever page the table names there (scratch
    page 0 for unallocated blocks) and must be masked by the caller."""
    B, n = block_table.shape
    P, ps, Hkv, D = pages.shape
    out = pages[block_table]                               # [B, n, ps, Hkv, D]
    return out.reshape(B, n * ps, Hkv, D)


def paged_attn_mask(kv_len: int, pos, q_len: int):
    """[B, 1, 1, S, T] causal mask for a paged chunk step: query s of
    sequence b sits at absolute position pos[b]+s and may attend to logical
    KV positions <= it (which covers both the previously-cached prefix and
    the chunk's own causal triangle — the pages were just updated in place)."""
    q_pos = jnp.asarray(pos)[:, None] + jnp.arange(q_len)[None, :]  # [B, S]
    kv_pos = jnp.arange(kv_len)
    m = kv_pos[None, None, :] <= q_pos[:, :, None]                  # [B, S, T]
    return m[:, None, None, :, :]


def ring_kv_assemble(blk, axis: str, c: int):
    """Ring all-gather of per-shard K or V blocks over the ``axis`` mesh
    axis, assembled in ABSOLUTE sequence order (DESIGN.md §9).

    ``blk`` is this context-parallel worker's [B, S/c, Hkv, D] block of a
    sequence sharded over c workers; after c-1 ``ppermute`` rounds — each
    worker forwards the block it received last round to its ring successor
    — every worker holds the full [B, S, Hkv, D] tensor, with the block
    that originated on worker r at rows [r·S/c, (r+1)·S/c).  Because the
    assembly is in absolute order, the assembled K/V is *bitwise* the
    monolithic pass's and attention softmax-reduces over it in the same
    order — CP prefill differs from the single-group path only by matmul
    tiling noise (~1e-6, never a greedy-argmax flip), where the
    overlap-friendly online-softmax formulation of ring attention would
    reorder the reduction itself.

    Communication: c-1 collective-permutes per call; a layer calls this
    twice (K and V), giving the 2·L·(c-1) ring rows of
    ``commodel.cp_comm_ops``.  Must run inside shard_map with ``axis`` in
    the mesh.
    """
    idx = jax.lax.axis_index(axis)
    s_loc = blk.shape[1]
    full = jnp.zeros(blk.shape[:1] + (c * s_loc,) + blk.shape[2:], blk.dtype)
    perm = [(i, (i + 1) % c) for i in range(c)]
    cur = blk
    for step in range(c):
        src = (idx - step) % c
        full = jax.lax.dynamic_update_slice_in_dim(full, cur, src * s_loc,
                                                   axis=1)
        if step < c - 1:
            cur = jax.lax.ppermute(cur, axis, perm)
    return full


def ring_cache_update(cache_k, cache_v, k, v, pos):
    """Write this step's K/V row into slot ``pos % W`` of a ring cache.

    cache_k/v: [B, W, Hkv, D]; k/v: [B, 1, Hkv, D].  A scalar ``pos`` keeps
    the seed ``dynamic_update_slice`` (all sequences share one slot — XLA
    aliases the donated buffer); a [B] vector scatters one row per sequence
    at its own slot, the continuous-batching layout.
    """
    w = cache_k.shape[1]
    if jnp.ndim(pos) == 0:
        slot = pos % w
        return (jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0)),
                jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0)))
    bidx = jnp.arange(cache_k.shape[0])
    slot = pos % w
    return (cache_k.at[bidx, slot].set(k[:, 0]),
            cache_v.at[bidx, slot].set(v[:, 0]))


# ---------------------------------------------------------------------------
# attention (reference path; kernels/ holds the Pallas TPU versions)
# ---------------------------------------------------------------------------


def gqa_attention(q, k, v, mask, *, softcap=None):
    """Grouped-query attention.

    q: [B, S, Hq, D]; k, v: [B, T, Hkv, D]; mask broadcastable to
    [B, Hkv, G, S, T] (usually [S, T]).  Returns [B, S, Hq, D].
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits *= 1.0 / np.sqrt(D)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, Hq, D)


def chunked_gqa_attention(q, k, v, spec: "MaskSpec", *, kv_chunk: int = 1024,
                          softcap=None):
    """Flash-style attention: online softmax over KV chunks (lax.scan), no
    [S, T] score materialization and no [S, T] mask.  Peak activation is
    [B, Hkv, G, S, kv_chunk] — the jnp counterpart of the Pallas flash
    kernel, used by the production forward path on shapes where reference
    attention's S² HBM traffic dominates the roofline (§Perf)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kv_chunk = min(kv_chunk, T)
    assert T % kv_chunk == 0, (T, kv_chunk)
    n = T // kv_chunk
    qg = q.reshape(B, S, Hkv, G, D)
    q_pos = spec.q_offset + jnp.arange(S)
    scale = 1.0 / np.sqrt(D)

    kc = k.reshape(B, n, kv_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, kv_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m_run, l_run, acc = carry
        ci, k_c, v_c = inp                              # [B,C,Hkv,D]
        kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_c).astype(jnp.float32)
        logits *= scale
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        mask = spec.block(q_pos, kv_pos)                # [S, C]
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
        m_new = jnp.maximum(m_run, logits.max(-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l_run = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(q.dtype), v_c).astype(jnp.float32)
        return (m_new, l_run, acc), None

    init = (jnp.full((B, Hkv, G, S), jnp.finfo(jnp.float32).min, jnp.float32),
            jnp.zeros((B, Hkv, G, S), jnp.float32),
            jnp.zeros((B, Hkv, G, S, D), jnp.float32))
    (m_run, l_run, acc), _ = jax.lax.scan(body, init,
                                          (jnp.arange(n), kc, vc))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D).astype(q.dtype)


def init_attention(rng, cfg: ModelConfig, num_layers: int, n_heads=None,
                   dtype=None):
    n_heads = n_heads or cfg.num_heads
    dtype = dtype or jnp.dtype(cfg.dtype)
    h, d = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(rng, 4)
    L = num_layers
    return {
        "wq": dense_init(kq, (L, h, n_heads * d), dtype),
        "wk": dense_init(kk, (L, h, cfg.num_kv_heads * d), dtype),
        "wv": dense_init(kv, (L, h, cfg.num_kv_heads * d), dtype),
        "wo": dense_init(ko, (L, n_heads * d, h), dtype),
    }


# ---------------------------------------------------------------------------
# GLU / MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, d_model: int, d_ff: int, activation: str, num_layers: int,
             dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    L = num_layers
    p = {
        "w1": dense_init(k1, (L, d_model, d_ff), dtype),
        "w2": dense_init(k2, (L, d_ff, d_model), dtype),
    }
    if activation in ("swiglu", "geglu"):
        p["w3"] = dense_init(k3, (L, d_model, d_ff), dtype)
    return p


def mlp_apply(p, x, activation: str):
    up = x @ p["w1"]
    if activation == "swiglu":
        act = jax.nn.silu(up) * (x @ p["w3"])
    elif activation == "geglu":
        act = jax.nn.gelu(up, approximate=True) * (x @ p["w3"])
    else:
        act = jax.nn.gelu(up, approximate=True)
    return act @ p["w2"]
