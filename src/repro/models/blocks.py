"""Transformer blocks: dense (GQA) block shared by dense/encoder/vlm families.

Block API (used by the scan trunk in ``transformer.py``):

    init_blocks(rng, cfg, L, dtype)              -> stacked param pytree [L, ...]
    block_apply(cfg, p_l, x, positions, mask, cache=None, pos=None,
                build_cache_w=None, block_table=None) -> (y, cache_out, aux)

``cache`` is the per-layer cache slice in decode mode; ``build_cache_w`` asks a
full-sequence pass to emit a (ring-buffer) cache of width W for the engine;
``block_table`` switches the dense block to the paged-cache path
(DESIGN.md §8), where ``cache`` is a [P, ps, Hkv, D] page pool.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import layers
from repro.models.layers import (apply_rope, gqa_attention,
                                 mlp_apply, rms_norm)


def build_ring_cache(k, v, w: int):
    """Seed a ring-buffer cache of width W from full-sequence K/V [B,S,Hkv,D].

    Absolute position p lives in slot p % W; for S <= W this is the identity
    layout (right-padded), for S > W we scatter the last W positions.
    """
    B, S, Hkv, D = k.shape
    if S <= w:
        pad = [(0, 0), (0, w - S), (0, 0), (0, 0)]
        return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    slots = jnp.arange(S - w, S) % w
    ck = jnp.zeros((B, w, Hkv, D), k.dtype).at[:, slots].set(k[:, S - w:])
    cv = jnp.zeros((B, w, Hkv, D), v.dtype).at[:, slots].set(v[:, S - w:])
    return {"k": ck, "v": cv}


def attention_apply(cfg: ModelConfig, p, xn, positions, mask,
                    cache=None, pos=None, build_cache_w=None, n_heads=None,
                    block_table=None, cp_axis=None, cp_size: int = 1):
    """Self-attention over a normalized input xn [B,S,h].

    Returns (attn_out [B,S,n_heads*D], cache_out).

    ``cp_axis`` switches a full-sequence pass to the context-parallel ring
    branch (DESIGN.md §9; must run inside shard_map with that mesh axis):
    xn is this worker's [B, S/c, h] sequence shard and ``positions`` its
    absolute positions; the local K/V blocks rotate around the cp ring
    (``layers.ring_kv_assemble``, 2·(c-1) collective-permutes) so queries
    attend over the full assembled sequence, and ``mask`` must already be
    the shard-offset causal mask ([S/c, S]).  A ``build_cache_w`` cache is
    seeded from the assembled K/V, i.e. it comes out whole on every cp
    worker — the gather-into-slots handoff needs no further collective.
    """
    n_heads = n_heads or cfg.num_heads
    B, S, _ = xn.shape
    D, Hkv = cfg.head_dim, cfg.num_kv_heads
    q = (xn @ p["wq"]).reshape(B, S, n_heads, D)
    k = (xn @ p["wk"]).reshape(B, S, Hkv, D)
    v = (xn @ p["wv"]).reshape(B, S, Hkv, D)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cp_axis is not None:
        if cache is not None:
            raise ValueError("context parallelism is prefill-only: decode "
                             "runs replicated over the cp axis")
        k = layers.ring_kv_assemble(k, cp_axis, cp_size)
        v = layers.ring_kv_assemble(v, cp_axis, cp_size)

    if cache is not None and block_table is not None:
        # paged path (DESIGN.md §8): the chunk's K/V rows are scattered into
        # the [P, ps, Hkv, D] page pool at the pages the block table names,
        # then the logical view is gathered back for attention.  Serves both
        # chunked prefill (S > 1) and paged decode (S == 1); ``pos`` is the
        # [B] vector of start positions.
        ck, cv = layers.paged_cache_update(cache["k"], cache["v"], k, v,
                                           pos, block_table)
        kg = layers.paged_gather(ck, block_table)
        vg = layers.paged_gather(cv, block_table)
        pmask = layers.paged_attn_mask(kg.shape[1], pos, S)
        out = gqa_attention(q, kg, vg, pmask)
        cache_out = {"k": ck, "v": cv}
    elif cache is not None:
        # single-token decode against a ring-buffer cache; ``pos`` is a
        # scalar (fixed-batch serve path) or [B] per-sequence positions
        # (continuous batching: each sequence hits its own slot and mask)
        w = cache["k"].shape[1]
        ck, cv = layers.ring_cache_update(cache["k"], cache["v"], k, v, pos)
        dmask = layers.decode_attn_mask(w, pos, cfg.sliding_window)
        out = gqa_attention(q, ck, cv, dmask)
        cache_out = {"k": ck, "v": cv}
    else:
        if isinstance(mask, layers.MaskSpec):
            # flash-style chunked attention (cfg.attention_impl == "chunked")
            out = layers.chunked_gqa_attention(q, k, v, mask,
                                               kv_chunk=cfg.attention_chunk)
        else:
            out = gqa_attention(q, k, v, mask)
        cache_out = None
        if build_cache_w is not None:
            cache_out = build_ring_cache(k, v, build_cache_w)
    return out.reshape(B, S, n_heads * D), cache_out


def init_dense_blocks(rng, cfg: ModelConfig, L: int, dtype):
    ka, km, kn = jax.random.split(rng, 3)
    p = layers.init_attention(ka, cfg, L, dtype=dtype)
    p.update(layers.init_mlp(km, cfg.d_model, cfg.d_ff, cfg.activation, L, dtype))
    p["ln1"] = jnp.zeros((L, cfg.d_model), dtype)
    p["ln2"] = jnp.zeros((L, cfg.d_model), dtype)
    return p


def dense_block_apply(cfg: ModelConfig, p, x, positions, mask,
                      cache=None, pos=None, build_cache_w=None,
                      block_table=None, cp_axis=None, cp_size: int = 1):
    attn_out, cache_out = attention_apply(
        cfg, p, rms_norm(x, p["ln1"], cfg.norm_eps), positions, mask,
        cache=cache, pos=pos, build_cache_w=build_cache_w,
        block_table=block_table, cp_axis=cp_axis, cp_size=cp_size)
    x = x + attn_out @ p["wo"]
    x = x + mlp_apply(p, rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
    return x, cache_out, jnp.zeros((), jnp.float32)
